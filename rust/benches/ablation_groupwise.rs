//! **A3 — blockwise per-group scaling (§5 future work)**: sweep group size
//! between the paper's Vector (g=1 rows) and BitDelta's Scalar (g=∞),
//! reporting held-out layer MSE and artifact bytes — the
//! quality/metadata trade-off curve.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::delta::types::Axis;
use pawd::util::benchkit::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let (base, ft) = bench_common::synth_pair("tiny", 53);
    let docs = bench_common::calib_docs(16, 48);
    let dir = bench_common::tmp_dir("groupwise");
    let mut t = Table::new(&["scales", "mean val MSE", "artifact bytes"]);
    let sweep: Vec<(String, Vec<Axis>)> = vec![
        ("vector row/col (paper)".into(), vec![Axis::Row, Axis::Col]),
        ("row (g=1)".into(), vec![Axis::Row]),
        ("group g=4".into(), vec![Axis::Group(4)]),
        ("group g=8".into(), vec![Axis::Group(8)]),
        ("group g=32".into(), vec![Axis::Group(32)]),
        ("scalar (BitDelta)".into(), vec![Axis::Scalar]),
    ];
    for (label, axes) in sweep {
        let opts = CompressOptions { fit: FitMode::ClosedForm, axes, ..Default::default() };
        let (model, reports, _) = compress_model("g", &base, &ft, &docs, &opts);
        let mse = reports
            .iter()
            .map(|r| r.candidates.iter().map(|c| c.2).fold(f64::INFINITY, f64::min))
            .sum::<f64>()
            / reports.len() as f64;
        let bytes = save_delta(dir.join(format!("{}.pawd", label.replace([' ', '/', '(', ')', '='], "_"))), &model)?;
        t.row(&[label, format!("{mse:.3e}"), fmt_bytes(bytes)]);
    }
    t.print("Ablation A3: blockwise per-group scales (quality vs metadata)");
    Ok(())
}
