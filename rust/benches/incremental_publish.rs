//! **Incremental publish**: bytes shipped and publish→first-token latency
//! for a patch publish (~5% of modules changed) vs a full-artifact publish
//! of the same model.
//!
//! Structural claims are asserted, not just timed:
//!
//! * the patch artifact ships **<15%** of the full-artifact bytes;
//! * warming the new version with the parent resident reads only the patch
//!   (loader byte counter <15% of full, every unchanged module inherited
//!   as the parent's `Arc` — zero re-reads).
//!
//! Emits machine-readable metrics into `$PAWD_BENCH_JSON` (see
//! `BenchReport`); CI's bench-smoke lane runs this in fast mode.

#[path = "bench_common/mod.rs"]
mod bench_common;

use bench_common::{perturb, seeded_full};
use pawd::coordinator::{VariantCache, VariantStore};
use pawd::exec::{counters, ExecMode};
use pawd::model::config::ModelConfig;
use pawd::model::{FlatParams, Transformer};
use pawd::util::benchkit::{fmt_bytes, fmt_dur, BenchReport, Table};
use pawd::util::stats::Summary;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("PAWD_BENCH_FAST").is_ok();
    let cfg = ModelConfig::preset("llama-mini")?;
    let base = Arc::new(FlatParams::init(&cfg, 17));
    let tf = Transformer::new(&cfg);
    let n_modules = base.layout.patchable_modules().len();
    // ~5% of modules changed per publish (at least 1).
    let n_changed = (n_modules as f64 * 0.05).ceil() as usize;
    let dir = bench_common::tmp_dir("incremental_publish");
    let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
    let registry = store.registry().clone();
    let cache = VariantCache::new(store.clone(), u64::MAX);
    let probe: Vec<u8> = (0..24u8).map(|t| t.wrapping_mul(13) % 200 + 20).collect();

    // --- bytes shipped: full vs ~5%-changed patch -------------------------
    let v1 = seeded_full(&base, 1);
    let full = registry.publish_incremental("ft", v1.clone(), None)?;
    assert!(!full.patch, "first publish has no parent and must be full");
    cache.get("ft")?; // v1 resident (the serving steady state)
    let child = perturb(&v1, &base, n_changed, 2);
    let patched = registry.publish_incremental("ft", child.clone(), None)?;
    assert!(patched.patch, "a {n_changed}/{n_modules}-module change must ship as a patch");
    let fraction = patched.bytes as f64 / full.bytes as f64;
    println!(
        "bytes shipped: full {} vs patch {} ({n_changed}/{n_modules} modules changed, {:.1}%)",
        fmt_bytes(full.bytes),
        fmt_bytes(patched.bytes),
        fraction * 100.0
    );
    assert!(
        fraction < 0.15,
        "patch must ship <15% of the full artifact, got {:.1}%",
        fraction * 100.0
    );

    // --- warm-up cost: the cache composes onto the resident parent --------
    counters::reset();
    let (w2, cold) = cache.get("ft")?;
    assert!(cold.is_some(), "the new version must cold-load");
    assert_eq!(w2.version(), patched.version);
    let warm_bytes = counters::loader_bytes();
    let warm_reads = counters::module_reads();
    let inherited = counters::modules_inherited();
    println!(
        "warm-up: read {} in {warm_reads} module record(s), inherited {inherited} \
         module(s) from the resident parent",
        fmt_bytes(warm_bytes)
    );
    assert!(
        (warm_bytes as f64) < 0.15 * full.bytes as f64,
        "warming must not re-read unchanged modules ({warm_bytes}B vs full {}B)",
        full.bytes
    );
    assert_eq!(warm_reads as usize, n_changed, "only the changed modules are read");
    assert_eq!(
        inherited as usize,
        n_modules - n_changed,
        "every unchanged module must be inherited, not re-read"
    );

    // --- publish→first-token latency: patch vs full -----------------------
    // Each round publishes a fresh ~5%-changed child, warms it and scores
    // one probe. The chain is consolidated between rounds (outside the
    // timed region) so patch depth stays constant.
    let rounds = if fast { 3 } else { 8 };
    let mut patch_times = Vec::with_capacity(rounds);
    let mut effective = child;
    for round in 0..rounds {
        registry.consolidate("ft", None)?;
        effective = perturb(&effective, &base, n_changed, 100 + round as u64);
        let t0 = Instant::now();
        let out = registry.publish_incremental("ft", effective.clone(), None)?;
        let (w, _) = cache.get("ft")?;
        assert_eq!(w.version(), out.version);
        let logits = tf.forward_one(&w, &probe);
        std::hint::black_box(&logits);
        patch_times.push(t0.elapsed().as_secs_f64());
        assert!(out.patch);
    }
    let mut full_times = Vec::with_capacity(rounds);
    for round in 0..rounds {
        effective = perturb(&effective, &base, n_changed, 500 + round as u64);
        // A fresh cache models a worker that does not have the parent
        // resident — the full-artifact cold path.
        let cold_cache = VariantCache::new(store.clone(), u64::MAX);
        let t0 = Instant::now();
        let version = registry.publish("ft", effective.clone())?;
        let (w, _) = cold_cache.get(&format!("ft@{version}"))?;
        let logits = tf.forward_one(&w, &probe);
        std::hint::black_box(&logits);
        full_times.push(t0.elapsed().as_secs_f64());
    }
    let sp = Summary::of(&patch_times);
    let sf = Summary::of(&full_times);
    let mut t = Table::new(&["publish path", "publish→token p50", "mean", "bytes shipped"]);
    t.row(&[
        format!("patch ({n_changed}/{n_modules} modules)"),
        fmt_dur(sp.p50),
        fmt_dur(sp.mean),
        fmt_bytes(patched.bytes),
    ]);
    t.row(&[
        "full artifact".to_string(),
        fmt_dur(sf.p50),
        fmt_dur(sf.mean),
        fmt_bytes(full.bytes),
    ]);
    t.print(&format!(
        "Incremental publish: bytes shipped + publish→first-token (llama-mini, {rounds} rounds)"
    ));

    let mut report = BenchReport::new();
    report.add(
        "incremental_publish/bytes_shipped",
        &[
            ("full_bytes", full.bytes as f64),
            ("patch_bytes", patched.bytes as f64),
            ("patch_fraction", fraction),
        ],
    );
    report.add(
        "incremental_publish/warm",
        &[
            ("bytes_read", warm_bytes as f64),
            ("modules_read", warm_reads as f64),
            ("modules_inherited", inherited as f64),
        ],
    );
    report.add(
        "incremental_publish/publish_to_token",
        &[
            ("patch_p50_ms", sp.p50 * 1e3),
            ("full_p50_ms", sf.p50 * 1e3),
            ("speedup", sf.p50 / sp.p50.max(1e-12)),
        ],
    );
    report.flush_env()?;
    Ok(())
}
