//! Shared helpers for the bench targets: synthetic model pairs, compressed
//! variants on disk, and calibration docs — everything deterministic so
//! bench output is reproducible run-to-run.

// Each bench binary includes this module via `#[path]` and uses only the
// helpers it needs; the rest must not trip `-D warnings` as dead code.
#![allow(dead_code)]

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, DeltaModel, DeltaModule};
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::FlatParams;
use pawd::util::rng::Rng;
use std::path::PathBuf;

pub fn calib_docs(n: usize, len: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|i| (0..len).map(|t| ((t * 7 + i * 29) % 220 + 10) as u8).collect())
        .collect()
}

pub fn probe_docs(n: usize, len: usize) -> Vec<Vec<u8>> {
    (100..100 + n)
        .map(|i| (0..len).map(|t| ((t * 7 + i * 29) % 220 + 10) as u8).collect())
        .collect()
}

/// Base + synthetic fine-tune for a preset (no training needed; used by
/// the size/load/axis/kernel benches where the *bytes and structure*
/// matter, not downstream accuracy).
pub fn synth_pair(preset: &str, seed: u64) -> (FlatParams, FlatParams) {
    let cfg = ModelConfig::preset(preset).unwrap();
    let base = FlatParams::init(&cfg, seed);
    let ft = synth_finetune(
        &base,
        &SynthDeltaSpec { magnitude: 0.02, anisotropy: 1.0, axis_bias: 0.6, seed: seed ^ 0xF7 },
    );
    (base, ft)
}

/// Compress a pair with the vector method (closed-form for speed).
pub fn compress_vector(base: &FlatParams, ft: &FlatParams, docs: &[Vec<u8>]) -> DeltaModel {
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    compress_model("bench", base, ft, docs, &opts).0
}

/// A full delta covering every patchable module of `base` (variant "ft"),
/// content seeded — shared by the incremental-publish and replication
/// benches so both measure identical artifacts.
pub fn seeded_full(base: &FlatParams, seed: u64) -> DeltaModel {
    let cfg = base.cfg();
    let modules: Vec<DeltaModule> = base
        .layout
        .patchable_modules()
        .iter()
        .enumerate()
        .map(|(i, &id)| {
            let (rows, cols) = id.kind.shape(cfg);
            let mut r = Rng::new(seed.wrapping_mul(977).wrapping_add(i as u64));
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis: Axis::Row,
                scales: (0..rows).map(|_| r.uniform_in(0.005, 0.05)).collect(),
                codec: Codec::PerAxis,
            }
        })
        .collect();
    DeltaModel::new("ft", cfg.name.clone(), modules)
}

/// Replace `n_changed` modules of `model` (spread across small and large
/// projections) with freshly seeded content.
pub fn perturb(model: &DeltaModel, base: &FlatParams, n_changed: usize, seed: u64) -> DeltaModel {
    let mut out = model.clone();
    let n = out.modules.len();
    let fresh = seeded_full(base, seed);
    for j in 0..n_changed {
        let k = (j * n) / n_changed + (seed as usize % (n / n_changed.max(1)).max(1));
        out.modules[k % n] = fresh.modules[k % n].clone();
    }
    out
}

pub fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("pawd_bench").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
