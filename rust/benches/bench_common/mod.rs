//! Shared helpers for the bench targets: synthetic model pairs, compressed
//! variants on disk, and calibration docs — everything deterministic so
//! bench output is reproducible run-to-run.

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::types::DeltaModel;
use pawd::model::config::ModelConfig;
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::FlatParams;
use std::path::PathBuf;

pub fn calib_docs(n: usize, len: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|i| (0..len).map(|t| ((t * 7 + i * 29) % 220 + 10) as u8).collect())
        .collect()
}

pub fn probe_docs(n: usize, len: usize) -> Vec<Vec<u8>> {
    (100..100 + n)
        .map(|i| (0..len).map(|t| ((t * 7 + i * 29) % 220 + 10) as u8).collect())
        .collect()
}

/// Base + synthetic fine-tune for a preset (no training needed; used by
/// the size/load/axis/kernel benches where the *bytes and structure*
/// matter, not downstream accuracy).
pub fn synth_pair(preset: &str, seed: u64) -> (FlatParams, FlatParams) {
    let cfg = ModelConfig::preset(preset).unwrap();
    let base = FlatParams::init(&cfg, seed);
    let ft = synth_finetune(
        &base,
        &SynthDeltaSpec { magnitude: 0.02, anisotropy: 1.0, axis_bias: 0.6, seed: seed ^ 0xF7 },
    );
    (base, ft)
}

/// Compress a pair with the vector method (closed-form for speed).
pub fn compress_vector(base: &FlatParams, ft: &FlatParams, docs: &[Vec<u8>]) -> DeltaModel {
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
    compress_model("bench", base, ft, docs, &opts).0
}

pub fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("pawd_bench").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
