//! **A2 — calibration budget**: sweep the number of calibration documents
//! and training epochs; report held-out layer MSE and wall time. Backs the
//! paper's choice of 50 samples / 5 epochs and its §4 note that larger
//! calibration improves robustness at preparation cost.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::util::benchkit::{fmt_dur, Table};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (base, ft) = bench_common::synth_pair("tiny", 47);
    let mut t = Table::new(&["calib docs", "fit", "epochs", "mean val MSE", "wall"]);
    for &n_docs in &[5usize, 10, 25, 50] {
        let docs = bench_common::calib_docs(n_docs, 48);
        for (fit, epochs) in [(FitMode::AdamW, 1), (FitMode::AdamW, 5), (FitMode::ClosedForm, 0)] {
            let mut opts = CompressOptions { fit, ..Default::default() };
            opts.calib.epochs = epochs.max(1);
            let t0 = Instant::now();
            let (_, reports, _) = compress_model("x", &base, &ft, &docs, &opts);
            let wall = t0.elapsed();
            let mse = reports
                .iter()
                .map(|r| r.candidates.iter().map(|c| c.2).fold(f64::INFINITY, f64::min))
                .sum::<f64>()
                / reports.len() as f64;
            let fit_label = match fit {
                FitMode::AdamW => "adamw",
                FitMode::ClosedForm => "closed-form",
                FitMode::InitOnly => "init",
            };
            t.row(&[
                n_docs.to_string(),
                fit_label.into(),
                if fit == FitMode::ClosedForm { "-".into() } else { epochs.to_string() },
                format!("{mse:.3e}"),
                fmt_dur(wall.as_secs_f64()),
            ]);
        }
    }
    t.print("Ablation A2: calibration budget sweep (paper protocol: 50 docs, 5 epochs, AdamW)");
    Ok(())
}
