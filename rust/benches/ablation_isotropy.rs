//! **A1 — isotropy limitation (§4)**: sweep the anisotropy of the
//! synthetic delta and measure the vector-vs-scalar validation-MSE gap.
//! Paper's claim: gains rely on anisotropy; when ΔW is isotropic a single
//! scalar matches per-axis vectors.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::FlatParams;
use pawd::util::benchkit::Table;

fn main() -> anyhow::Result<()> {
    let cfg = pawd::model::ModelConfig::preset("tiny")?;
    let base = FlatParams::init(&cfg, 41);
    let docs = bench_common::calib_docs(16, 48);
    let mut t = Table::new(&["anisotropy", "vector val MSE", "scalar val MSE", "scalar/vector"]);
    for &aniso in &[0.0f32, 0.25, 0.5, 1.0, 1.5, 2.0] {
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { magnitude: 0.03, anisotropy: aniso, axis_bias: 0.7, seed: 5 },
        );
        let run = |axes: Vec<pawd::delta::Axis>| {
            let opts = CompressOptions { fit: FitMode::ClosedForm, axes, ..Default::default() };
            let (_, reports, _) = compress_model("x", &base, &ft, &docs, &opts);
            // Mean best val MSE across modules.
            reports
                .iter()
                .map(|r| r.candidates.iter().map(|c| c.2).fold(f64::INFINITY, f64::min))
                .sum::<f64>()
                / reports.len() as f64
        };
        let v = run(vec![pawd::delta::Axis::Row, pawd::delta::Axis::Col]);
        let s = run(vec![pawd::delta::Axis::Scalar]);
        t.row(&[
            format!("{aniso:.2}"),
            format!("{v:.3e}"),
            format!("{s:.3e}"),
            format!("{:.2}x", s / v),
        ]);
    }
    t.print("Ablation A1: per-axis advantage vs delta anisotropy (expect ratio -> 1 as anisotropy -> 0)");
    Ok(())
}
