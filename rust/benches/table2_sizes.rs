//! **T2 — Table 2**: checkpoint sizes — PAWD delta artifacts vs the full
//! FP16 checkpoint, for all three mini model pairs (structure-only: the
//! bytes depend on shapes, not on training).

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::format::save_delta;
use pawd::model::checkpoint::save_fp16;
use pawd::util::benchkit::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&["Model", "Artifact", "Size", "vs. FP16 weights"]);
    for preset in ["llama-mini", "qwen-mini", "phi-mini"] {
        let (base, ft) = bench_common::synth_pair(preset, 7);
        let docs = bench_common::calib_docs(6, 48);
        let dir = bench_common::tmp_dir(&format!("t2_{preset}"));
        let fp16 = save_fp16(dir.join("teacher.fp16"), &ft)?;

        // Vector (row/col) artifact.
        let vec_model = bench_common::compress_vector(&base, &ft, &docs);
        let vec_bytes = save_delta(dir.join("vector.pawd"), &vec_model)?;
        // BitDelta (scalar) artifact.
        let opts = pawd::delta::compress::CompressOptions {
            fit: pawd::delta::compress::FitMode::ClosedForm,
            ..pawd::baselines::bitdelta_options()
        };
        let (sca_model, _, _) =
            pawd::delta::compress::compress_model("s", &base, &ft, &docs, &opts);
        let sca_bytes = save_delta(dir.join("scalar.pawd"), &sca_model)?;

        t.row(&[preset.into(), "FP16 checkpoint".into(), fmt_bytes(fp16), "1.00x".into()]);
        t.row(&[
            "".into(),
            "BitDelta (scalar)".into(),
            fmt_bytes(sca_bytes),
            format!("≈ {:.2}x smaller", fp16 as f64 / sca_bytes as f64),
        ]);
        t.row(&[
            "".into(),
            "Vector (row/col)".into(),
            fmt_bytes(vec_bytes),
            format!("≈ {:.2}x smaller", fp16 as f64 / vec_bytes as f64),
        ]);
    }
    t.print("Table 2 (reproduction): checkpoint sizes");
    println!(
        "note: deltas cover the 7·L projection matrices (attention+MLP), as in the paper;\n\
         embeddings/norms ride with the shared base. The paper's 5-8x ratios arise at\n\
         8-14B scale where projections dominate the parameter count; at mini scale the\n\
         embedding tables weigh relatively more, so ratios here are structural lower bounds."
    );
    Ok(())
}
