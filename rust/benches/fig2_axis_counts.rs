//! **F2 — Figure 2**: counts of row vs column axis selections per module
//! sub-type (q/k/v/o/gate/up/down) plus the layer-wise trend, across the
//! three mini pairs. The synthetic fine-tunes carry the kind-dependent
//! anisotropy structure the paper observes (q/v/o/down row-leaning,
//! gate/up col-leaning, k mixed) — the selection machinery must discover
//! it from activations alone.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::types::Axis;
use pawd::model::ProjKind;
use pawd::util::benchkit::Table;
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let mut per_kind: BTreeMap<&'static str, (usize, usize)> = BTreeMap::new();
    let mut per_layer: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
    for preset in ["llama-mini", "qwen-mini", "phi-mini"] {
        let (base, ft) = bench_common::synth_pair(preset, 23);
        let docs = bench_common::calib_docs(8, 48);
        let model = bench_common::compress_vector(&base, &ft, &docs);
        for m in &model.modules {
            let slot = per_kind.entry(m.id.kind.name()).or_insert((0, 0));
            let lslot = per_layer.entry(m.id.layer).or_insert((0, 0));
            match m.axis {
                Axis::Row => {
                    slot.0 += 1;
                    lslot.0 += 1;
                }
                Axis::Col => {
                    slot.1 += 1;
                    lslot.1 += 1;
                }
                _ => {}
            }
        }
    }
    let mut t = Table::new(&["sub_type", "row", "col", "bar (row=#, col=.)"]);
    for kind in ProjKind::ALL {
        let (r, c) = per_kind.get(kind.name()).copied().unwrap_or((0, 0));
        t.row(&[kind.name().into(), r.to_string(), c.to_string(), format!("{}{}", "#".repeat(r), ".".repeat(c))]);
    }
    t.print("Figure 2 (reproduction): row vs col delta-quantization per sub_type (3 pairs pooled)");

    let mut t2 = Table::new(&["layer", "row", "col"]);
    for (layer, (r, c)) in &per_layer {
        t2.row(&[layer.to_string(), r.to_string(), c.to_string()]);
    }
    t2.print("Layer-wise axis trend");
    Ok(())
}
