//! **S1 — serving**: coordinator throughput/latency as the number of
//! variants, the cache budget, and the exec mode vary (the paper's
//! multi-tenant motivation: many fine-tunes of one base, hot-swapped on
//! demand).
//!
//! The `exec` column is the dense-vs-fused A/B: `dense` materializes
//! `Ŵ = W_b + v ⊙ B` per resident variant, `fused` keeps deltas packed and
//! executes them in place — same budget, ~compression-ratio more resident
//! variants, and hot swaps with no materialize pass.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::coordinator::{Engine, Payload, Server, ServerConfig, VariantStore};
use pawd::delta::format::save_delta;
use pawd::exec::ExecMode;
use pawd::util::benchkit::{fmt_bytes, BenchReport, Table};
use pawd::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (base, _) = bench_common::synth_pair("tiny", 31);
    let base = Arc::new(base);
    let docs = bench_common::calib_docs(4, 40);
    let n_requests: usize = if std::env::var("PAWD_BENCH_FAST").is_ok() { 120 } else { 320 };

    let mut report = BenchReport::new();
    let mut t = Table::new(&[
        "variants", "cache", "exec", "req/s", "p50 total", "p99 total", "resident", "res bytes",
        "cold starts", "evictions",
    ]);
    for &n_variants in &[2usize, 6, 12] {
        // Build fleet.
        let dir = bench_common::tmp_dir(&format!("serve_{n_variants}"));
        for k in 0..n_variants {
            let ft = pawd::model::synth::synth_finetune(
                &base,
                &pawd::model::synth::SynthDeltaSpec { seed: 70 + k as u64, ..Default::default() },
            );
            let (delta, _, _) = pawd::delta::compress::compress_model(
                &format!("v{k}"),
                &base,
                &ft,
                &docs,
                &pawd::delta::compress::CompressOptions {
                    fit: pawd::delta::compress::FitMode::ClosedForm,
                    ..Default::default()
                },
            );
            save_delta(dir.join(format!("v{k}.pawd")), &delta)?;
        }
        let one = (base.data.len() * 4) as u64;
        for (cache_label, budget) in [
            ("all", one * n_variants as u64 + 1024),
            ("half", one * (n_variants as u64 / 2).max(1) + 1024),
            // The headline row: a budget that fits ONE dense variant. Dense
            // mode thrashes; fused mode holds the entire fleet resident.
            ("one", one + 1024),
        ] {
            for exec in [ExecMode::Dense, ExecMode::Fused] {
                let store = VariantStore::new(base.clone(), &dir);
                let server = Server::start(
                    store,
                    Engine::Native,
                    ServerConfig {
                        max_batch: 8,
                        n_workers: 2,
                        cache_budget_bytes: budget,
                        exec,
                        ..Default::default()
                    },
                );
                let t0 = Instant::now();
                std::thread::scope(|s| {
                    for tid in 0..4u64 {
                        let client = server.client();
                        s.spawn(move || {
                            let mut rng = Rng::new(tid);
                            for i in 0..n_requests / 4 {
                                let v =
                                    if rng.chance(0.5) { 0 } else { rng.below(n_variants) };
                                let rx = client.submit(
                                    &format!("v{v}"),
                                    Payload::score(
                                        &format!("Q: item {i}? A: "),
                                        &["yes".into(), "no".into()],
                                    ),
                                );
                                let _ = rx.recv();
                            }
                        });
                    }
                });
                let wall = t0.elapsed().as_secs_f64();
                let snap = server.metrics.snapshot();
                let cache = server.cache.stats();
                let res = server.cache.residency();
                report.add(
                    &format!("router_serving/v{n_variants}_{cache_label}_{}", exec.label()),
                    &[
                        ("req_per_s", snap.served as f64 / wall),
                        ("p50_us", snap.total_p50_us as f64),
                        ("p99_us", snap.total_p99_us as f64),
                        ("mean_batch", snap.mean_batch_size),
                    ],
                );
                t.row(&[
                    n_variants.to_string(),
                    cache_label.into(),
                    exec.label().into(),
                    format!("{:.0}", snap.served as f64 / wall),
                    format!("{}µs", snap.total_p50_us),
                    format!("{}µs", snap.total_p99_us),
                    res.variants.to_string(),
                    fmt_bytes(res.resident_bytes),
                    snap.cold_starts.to_string(),
                    cache.evictions.to_string(),
                ]);
                server.shutdown();
            }
        }
    }
    t.print(
        "Serving: throughput/latency vs fleet size, cache budget and exec mode (native engine, tiny)",
    );
    println!(
        "\n(`one` budget = a single dense variant: fused mode keeps every fleet size fully \
         resident because packed variants cost ~1/30 of dense bytes; mixed-variant windows \
         run as one shared-base BatchPlan — one base GEMM per module per window)"
    );
    report.flush_env()?;
    Ok(())
}
