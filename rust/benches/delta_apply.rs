//! **K1a — delta-apply hot path**: native apply throughput per weight
//! shape (row/col/scalar), compared against `memcpy` (the memory-bandwidth
//! roofline: apply reads base + packed mask and writes Ŵ, so ~2 passes)
//! and against the Pallas/XLA kernel artifact (validation path).

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, DeltaModule};
use pawd::model::{ModuleId, ProjKind};
use pawd::util::benchkit::{fmt_rate, Bench};
use pawd::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::from_env();
    let shapes = [(256usize, 256usize), (688, 256), (256, 688), (768, 3072), (3072, 768)];
    for (d_out, d_in) in shapes {
        let n = d_out * d_in;
        let bytes = (n * 4 * 2) as f64; // read base + write out
        let mut rng = Rng::new(1);
        let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let delta: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let mut out = vec![0f32; n];

        // memcpy roofline reference.
        b.run_items(&format!("memcpy_{d_out}x{d_in}"), bytes, || {
            out.copy_from_slice(&base);
            std::hint::black_box(&out);
        });
        for axis in [Axis::Row, Axis::Col, Axis::Scalar] {
            let m = DeltaModule {
                id: ModuleId { layer: 0, kind: ProjKind::Q },
                mask: mask.clone(),
                axis,
                scales: vec![0.05; axis.n_scales(d_out, d_in)],
                codec: Codec::PerAxis,
            };
            b.run_items(&format!("apply_{}_{d_out}x{d_in}", axis.label()), bytes, || {
                pawd::delta::apply::apply_module_into(&base, &mut out, &m);
                std::hint::black_box(&out);
            });
        }
    }
    // Effective bandwidth summary.
    println!("\nroofline note: apply touches 2 passes of the dense matrix + 1/32 packed mask;");
    println!("target is the memcpy rate above (same traffic). Gap = compute overhead.");

    // XLA/Pallas kernel path (single shape, includes PJRT transfer cost).
    if bench_common::have_artifacts() {
        let h = pawd::runtime::start(&bench_common::artifacts_dir())?;
        let (d_out, d_in) = (688usize, 256usize);
        let n = d_out * d_in;
        let mut rng = Rng::new(2);
        let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let delta: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let scales = vec![0.05f32; d_out];
        // warm the compile cache
        let _ = pawd::runtime::api::delta_apply_xla(&h, "row", &base, d_out, d_in, &mask.words, &scales)?;
        b.run_items("apply_xla_pallas_row_688x256 (incl. transfers)", (n * 8) as f64, || {
            let out = pawd::runtime::api::delta_apply_xla(
                &h, "row", &base, d_out, d_in, &mask.words, &scales,
            )
            .unwrap();
            std::hint::black_box(&out);
        });
        h.shutdown();
    } else {
        println!("(skipping XLA kernel path — run `make artifacts`)");
    }
    let _ = fmt_rate(0.0);
    Ok(())
}
