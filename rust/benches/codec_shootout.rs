//! **C1 — codec shoot-out**: per-module reconstruction error vs payload
//! bytes vs fused throughput for every delta codec (per-axis, scalar,
//! low-rank-residual), plus the calibration-driven auto selection. Asserts
//! the two structural guarantees the codec API promises: per-axis never
//! loses to scalar on calibration error, and auto never selects a codec
//! with worse calibration error than per-axis.
//!
//! Emits one gated `*_fused_rows_per_s` throughput metric per codec (and
//! report-only error/bytes metrics) into `BenchReport`; CI's bench-smoke
//! lane runs this in fast mode. The lowrank rank sweep adds
//! `lowrank_r{2,8}_*` series next to the configured-default `lowrank_*`
//! keys so the rank/bytes/error trade is tracked over time.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::compress::{CompressOptions, FitMode};
use pawd::delta::CodecKind;
use pawd::eval::{codec_shootout, render_shootout, ModuleShootout, ShootoutRow};
use pawd::util::benchkit::BenchReport;

/// The sweep emits several lowrank rows per module; address one exactly.
fn pick(m: &ModuleShootout, kind: CodecKind, rank: Option<usize>) -> &ShootoutRow {
    m.rows.iter().find(|r| r.kind == kind && r.rank == rank).unwrap()
}

fn main() -> anyhow::Result<()> {
    let (base, ft) = bench_common::synth_pair("tiny", 11);
    let docs = bench_common::calib_docs(6, 48);
    let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };

    let modules = codec_shootout(&base, &ft, &docs, &opts);
    println!("{}", render_shootout(&modules));

    // Structural guarantees — a red run here is a codec regression, not noise.
    for m in &modules {
        let pa = pick(m, CodecKind::PerAxis, None);
        let sc = pick(m, CodecKind::Scalar, None);
        let sel = m.selected_row();
        assert!(
            pa.val_mse <= sc.val_mse,
            "{:?}: per-axis val MSE {} worse than scalar {}",
            m.id,
            pa.val_mse,
            sc.val_mse
        );
        assert!(
            sel.val_mse <= pa.val_mse,
            "{:?}: auto selected {} with val MSE {} worse than per-axis {}",
            m.id,
            sel.kind.label(),
            sel.val_mse,
            pa.val_mse
        );
    }

    // Aggregate per codec across modules: mean fused throughput (gated),
    // total payload bytes and mean calibration error (report-only). The
    // legacy `lowrank_*` keys keep reporting the configured default rank;
    // the sweep adds `lowrank_r{2,8}_*` series alongside.
    let mut report = BenchReport::new();
    let n = modules.len() as f64;
    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut emit = |key: String, rows: Vec<&ShootoutRow>| {
        let mean_rps = rows.iter().map(|r| r.fused_rows_per_s).sum::<f64>() / n;
        let bytes: u64 = rows.iter().map(|r| r.payload_bytes).sum();
        let mean_mse = rows.iter().map(|r| r.val_mse).sum::<f64>() / n;
        metrics.push((format!("{key}_fused_rows_per_s"), mean_rps));
        metrics.push((format!("{key}_payload_bytes"), bytes as f64));
        metrics.push((format!("{key}_mean_val_mse"), mean_mse));
    };
    for kind in CodecKind::ALL {
        let rank = (kind == CodecKind::LowRank).then_some(opts.lowrank_rank);
        let key = kind.label().replace('-', "_");
        emit(key, modules.iter().map(|m| pick(m, kind, rank)).collect());
    }
    for r in [2usize, 8] {
        let rows = modules.iter().map(|m| pick(m, CodecKind::LowRank, Some(r))).collect();
        emit(format!("lowrank_r{r}"), rows);
    }
    let auto_per_axis =
        modules.iter().filter(|m| m.selected == CodecKind::PerAxis).count() as f64;
    metrics.push(("auto_selected_per_axis".into(), auto_per_axis));
    let borrowed: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    report.add("codec_shootout/tiny", &borrowed);
    report.flush_env()?;
    Ok(())
}
