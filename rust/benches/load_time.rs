//! **LT — §3.2 load-time**: cold-start latency of the delta hot-swap path
//! (read PAWD + one fused apply per module onto the resident base) vs
//! loading the full FP16 checkpoint. 10 runs each, as in the paper.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::coordinator::VariantStore;
use pawd::delta::format::save_delta;
use pawd::model::checkpoint::save_fp16;
use pawd::util::benchkit::{fmt_bytes, fmt_dur, Table};
use pawd::util::stats::Summary;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let runs = 10;
    let mut t = Table::new(&["Model", "Path", "Bytes read", "Load (mean of 10)", "p50", "Speedup"]);
    for preset in ["llama-mini", "qwen-mini", "phi-mini"] {
        let (base, ft) = bench_common::synth_pair(preset, 11);
        let docs = bench_common::calib_docs(6, 48);
        let dir = bench_common::tmp_dir(&format!("lt_{preset}"));
        let delta = bench_common::compress_vector(&base, &ft, &docs);
        save_delta(dir.join("variant.pawd"), &delta)?;
        save_fp16(dir.join("variant_full.fp16"), &ft)?;
        // Rename so the store sees two distinct variants.
        std::fs::rename(dir.join("variant_full.fp16"), dir.join("full.fp16"))?;
        let store = VariantStore::new(Arc::new(base), &dir);

        let mut time_path = |name: &str| -> anyhow::Result<(Vec<f64>, u64)> {
            let mut times = Vec::with_capacity(runs);
            let mut bytes = 0;
            for _ in 0..runs {
                let v = store.load(name)?;
                times.push(v.load_time.as_secs_f64());
                bytes = v.bytes_read;
            }
            Ok((times, bytes))
        };
        let (d_times, d_bytes) = time_path("variant")?;
        let (f_times, f_bytes) = time_path("full")?;
        let ds = Summary::of(&d_times);
        let fs = Summary::of(&f_times);
        t.row(&[
            preset.into(),
            "delta hot-swap".into(),
            fmt_bytes(d_bytes),
            fmt_dur(ds.mean),
            fmt_dur(ds.p50),
            format!("{:.2}x faster", fs.mean / ds.mean),
        ]);
        t.row(&[
            "".into(),
            "full FP16 load".into(),
            fmt_bytes(f_bytes),
            fmt_dur(fs.mean),
            fmt_dur(fs.p50),
            "1.00x".into(),
        ]);
    }
    t.print("Load time (reproduction of §3.2: paper reports 0.80s delta vs 2.08s full at 8B)");
    Ok(())
}
