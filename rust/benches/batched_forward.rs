//! **S3 — batched multi-variant forward**: the shared-base `BatchPlan` path
//! against the per-request fused path, single-variant and mixed batches.
//!
//! The structural claim is asserted, not just timed: the op counter must
//! show the batched path issuing **one base GEMM per module per batch**
//! while the per-request path issues one per module per *sequence* — that
//! is the whole win (base weights/activations stream once per window, each
//! variant pays only its packed mask reduction on its own rows).
//!
//! Emits machine-readable metrics into `$PAWD_BENCH_JSON` (see
//! `BenchReport`); CI's bench-smoke lane runs this in fast mode and gates
//! throughput against `BENCH_baseline.json`.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::coordinator::{Engine, Payload, Server, ServerConfig, VariantStore};
use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::delta::format::save_delta;
use pawd::exec::{
    counters, pool, prefix, BatchPlan, ExecMode, PackedVariant, PrefixCache, Uniform,
    VariantWeights,
};
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::Transformer;
use pawd::util::benchkit::{Bench, BenchReport, Table};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let (base, _) = bench_common::synth_pair("tiny", 17);
    let base = Arc::new(base);
    let cfg = base.cfg().clone();
    let tf = Transformer::new(&cfg);
    let docs = bench_common::calib_docs(4, 40);

    // A small fleet of packed variants sharing the one base; each artifact
    // also lands on disk so the churn scenario can serve it through the
    // full engine stack.
    let churn_dir = bench_common::tmp_dir("engine_churn");
    let n_variants = 4usize;
    let variants: Vec<VariantWeights> = (0..n_variants)
        .map(|k| {
            let ft = synth_finetune(
                &base,
                &SynthDeltaSpec { seed: 900 + k as u64, ..Default::default() },
            );
            let (delta, _, _) = compress_model(
                &format!("v{k}"),
                &base,
                &ft,
                &docs,
                &CompressOptions { fit: FitMode::ClosedForm, ..Default::default() },
            );
            save_delta(churn_dir.join(format!("v{k}.pawd")), &delta).unwrap();
            VariantWeights::Packed(PackedVariant::new(base.clone(), Arc::new(delta)).unwrap())
        })
        .collect();

    let batch = 8usize;
    let seq_len = 24usize;
    let mk_tokens = |i: usize| -> Vec<u8> {
        (0..seq_len).map(|t| ((t * 13 + i * 41) % 200 + 20) as u8).collect()
    };
    // Mixed batch: requests round-robin across the variant fleet.
    let mixed_weights: Vec<VariantWeights> =
        (0..batch).map(|i| variants[i % n_variants].clone()).collect();
    let plans = BatchPlan::group(&mixed_weights);
    assert_eq!(plans.len(), 1, "packed variants of one base must share one plan");
    let (plan, members) = &plans[0];
    let seqs: Vec<(usize, Vec<u8>)> = (0..batch).map(|i| (i, mk_tokens(i))).collect();
    let single_seqs: Vec<(usize, Vec<u8>)> = (0..batch).map(|i| (0, mk_tokens(i))).collect();
    let tokens_per_batch = (batch * seq_len) as f64;

    // --- correctness + op-count structure (assert before timing) ---------
    let batched = tf.forward_plan(plan, &seqs);
    for ((entry, tokens), got) in seqs.iter().zip(&batched) {
        let want = tf.forward_one(&mixed_weights[members[*entry]], tokens);
        assert_eq!(got.data, want.data, "batched forward must match the per-request path");
    }
    let gemms_per_forward = (cfg.n_layers * 7 + 1) as u64; // 7 projections + LM head
    counters::reset();
    let _ = tf.forward_plan(plan, &seqs);
    let batched_gemms = counters::base_gemms();
    assert_eq!(
        batched_gemms, gemms_per_forward,
        "shared-base path must issue ONE base GEMM per module per batch"
    );
    counters::reset();
    for (entry, tokens) in &seqs {
        let _ = tf.forward_one(&mixed_weights[members[*entry]], tokens);
    }
    let per_request_gemms = counters::base_gemms();
    assert_eq!(
        per_request_gemms,
        gemms_per_forward * batch as u64,
        "per-request path pays the base GEMM once per sequence"
    );
    println!(
        "op counter: batched {batched_gemms} base GEMMs/batch vs per-request \
         {per_request_gemms} (batch={batch}, {n_variants} variants)"
    );
    // Single-pass structure: the fused per-request kernel computes base dot
    // + mask signed-sum in ONE traversal per (activation row, output row);
    // the batched path's base-GEMM-then-delta is two traversals. This bench
    // owns its process, so strict counter comparison is safe here.
    counters::reset();
    for (entry, tokens) in &seqs {
        let _ = tf.forward_one(&mixed_weights[members[*entry]], tokens);
    }
    let fused_act_reads = counters::activation_row_reads();
    counters::reset();
    let _ = tf.forward_plan(plan, &seqs);
    let two_pass_act_reads = counters::activation_row_reads();
    assert!(
        fused_act_reads < two_pass_act_reads,
        "single-pass fused kernel must read fewer activation rows \
         ({fused_act_reads}) than base-then-delta ({two_pass_act_reads})"
    );
    println!(
        "op counter: fused single-pass {fused_act_reads} activation-row reads \
         vs two-pass {two_pass_act_reads}\n"
    );

    // --- throughput --------------------------------------------------------
    let mut b = Bench::from_env();
    let r_per_req_mixed = b
        .run_items(&format!("per-request fused, mixed x{batch}"), tokens_per_batch, || {
            for (entry, tokens) in &seqs {
                std::hint::black_box(tf.forward_one(&mixed_weights[members[*entry]], tokens));
            }
        })
        .clone();
    let r_plan_mixed = b
        .run_items(&format!("BatchPlan shared base, mixed x{batch}"), tokens_per_batch, || {
            std::hint::black_box(tf.forward_plan(plan, &seqs));
        })
        .clone();
    let r_per_req_single = b
        .run_items(&format!("per-request fused, single x{batch}"), tokens_per_batch, || {
            for (_, tokens) in &single_seqs {
                std::hint::black_box(tf.forward_one(&mixed_weights[0], tokens));
            }
        })
        .clone();
    let r_uniform_single = b
        .run_items(&format!("Uniform batched, single x{batch}"), tokens_per_batch, || {
            std::hint::black_box(tf.forward_plan(&Uniform(&mixed_weights[0]), &single_seqs));
        })
        .clone();
    // Intra-host compute pool: the same mixed window at a forced serial
    // width vs 4 pool threads (results are bitwise-identical; only the
    // wall clock moves).
    let r_pool1 = b
        .run_items(&format!("BatchPlan mixed x{batch}, 1 thread"), tokens_per_batch, || {
            pool::with_thread_limit(1, || {
                std::hint::black_box(tf.forward_plan(plan, &seqs));
            });
        })
        .clone();
    let r_pool4 = b
        .run_items(&format!("BatchPlan mixed x{batch}, 4 threads"), tokens_per_batch, || {
            pool::with_thread_limit(4, || {
                std::hint::black_box(tf.forward_plan(plan, &seqs));
            });
        })
        .clone();
    let r_single_pool4 = b
        .run_items(&format!("Uniform single x{batch}, 4 threads"), tokens_per_batch, || {
            pool::with_thread_limit(4, || {
                std::hint::black_box(tf.forward_plan(&Uniform(&mixed_weights[0]), &single_seqs));
            });
        })
        .clone();
    let pool4_speedup = r_pool1.mean_s() / r_pool4.mean_s();
    println!("pool speedup: {pool4_speedup:.2}x (mixed window, 4 threads over serial)");
    if std::env::var("PAWD_BENCH_STRICT").is_ok() {
        assert!(
            pool4_speedup >= 2.0,
            "strict mode: 4-thread mixed-window throughput must be >= 2x serial, \
             got {pool4_speedup:.2}x"
        );
    }

    // --- cross-window prefix cache -----------------------------------------
    // Same mixed window, but every request shares a 16-token prefix (two
    // requests per variant). A warm `PrefixCache` resumes each sequence
    // from cached per-layer K/V + prefix logits, so only the 8 suffix rows
    // are computed — and the output stays bitwise-identical to cold.
    let shared_prefix: Vec<u8> = (0..16).map(|t| ((t * 13) % 200 + 20) as u8).collect();
    let pseqs: Vec<(usize, Vec<u8>)> = (0..batch)
        .map(|i| {
            let mut toks = shared_prefix.clone();
            toks.extend((0..seq_len - 16).map(|t| ((t * 7 + i * 31) % 200 + 20) as u8));
            (i, toks)
        })
        .collect();
    let pcache = PrefixCache::with_budget(64 << 20);
    let cold_logits = tf.forward_plan(plan, &pseqs);
    let warm_logits = prefix::run_plan(&tf, plan, &pseqs, &pcache); // capture pass
    assert!(!pcache.is_empty(), "warm pass must capture shared prefixes");
    let hit_logits = prefix::run_plan(&tf, plan, &pseqs, &pcache); // all-hit pass
    for (c, w) in cold_logits.iter().zip(&warm_logits) {
        assert_eq!(c.data, w.data, "prefix capture pass must be bitwise-equal to cold");
    }
    for (c, h) in cold_logits.iter().zip(&hit_logits) {
        assert_eq!(c.data, h.data, "prefix-cached forward must be bitwise-equal to cold");
    }
    let hits_before = pcache.stats().hits;
    let r_prefix_cold = b
        .run_items(&format!("shared-prefix mixed x{batch}, cold"), tokens_per_batch, || {
            std::hint::black_box(tf.forward_plan(plan, &pseqs));
        })
        .clone();
    let r_prefix_hit = b
        .run_items(&format!("shared-prefix mixed x{batch}, cache hit"), tokens_per_batch, || {
            std::hint::black_box(prefix::run_plan(&tf, plan, &pseqs, &pcache));
        })
        .clone();
    assert!(pcache.stats().hits > hits_before, "timed passes must hit the cache");
    let prefix_speedup = r_prefix_cold.mean_s() / r_prefix_hit.mean_s();
    println!(
        "prefix cache speedup: {prefix_speedup:.2}x (16 of {seq_len} rows per sequence cached)"
    );
    if std::env::var("PAWD_BENCH_STRICT").is_ok() {
        assert!(
            prefix_speedup >= 1.5,
            "strict mode: warm prefix-cache throughput must be >= 1.5x cold, \
             got {prefix_speedup:.2}x"
        );
    }

    // --- serving under publish churn ---------------------------------------
    // The continuous engine overlaps publish warms with serving: measure
    // end-to-end request throughput on stable variants while a background
    // admin client storms `publish_incremental` on another.
    let store = VariantStore::new(base.clone(), &churn_dir).with_mode(ExecMode::Fused);
    let server = Server::start(
        store,
        Engine::Native,
        ServerConfig { n_workers: 2, ..Default::default() },
    );
    let client = server.client();
    let choices = vec!["alpha".to_string(), "beta".to_string(), "gamma".to_string()];
    for k in 0..n_variants {
        let warm = client.score(&format!("v{k}"), "Q: warm? A: ", &choices);
        assert!(warm.result.is_ok(), "churn warmup failed: {:?}", warm.result);
    }
    let stop = AtomicBool::new(false);
    let n_publishes = AtomicU64::new(0);
    let mut r_churn = None;
    std::thread::scope(|s| {
        let publisher = server.client();
        let (stop_ref, pubs) = (&stop, &n_publishes);
        let staging = bench_common::tmp_dir("engine_churn_staging");
        let src = churn_dir.join("v0.pawd");
        s.spawn(move || {
            let mut model = pawd::delta::format::load_delta(&src).unwrap();
            let mut i = 0u64;
            while !stop_ref.load(Ordering::Relaxed) {
                {
                    let m = Arc::make_mut(&mut model.modules[0]);
                    for sc in &mut m.scales {
                        *sc *= 1.0001;
                    }
                }
                let staged = staging.join(format!("c{i}.pawd"));
                save_delta(&staged, &model).unwrap();
                if publisher.publish_incremental("v0", &staged, None).is_ok() {
                    pubs.fetch_add(1, Ordering::Relaxed);
                }
                let _ = std::fs::remove_file(&staged);
                i += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let r = b
            .run_items(&format!("serve mixed x{batch} under publish churn"), batch as f64, || {
                let rxs: Vec<_> = (0..batch)
                    .map(|i| {
                        client.submit(
                            &format!("v{}", 1 + i % (n_variants - 1)),
                            Payload::score(&format!("Q: churn {i}? A: "), &choices),
                        )
                    })
                    .collect();
                for rx in rxs {
                    let resp = rx.recv().unwrap();
                    assert!(resp.result.is_ok(), "request failed under churn: {:?}", resp.result);
                }
            })
            .clone();
        stop.store(true, Ordering::Relaxed);
        r_churn = Some(r);
    });
    let r_churn = r_churn.unwrap();
    let churn_publishes = n_publishes.load(Ordering::Relaxed);
    println!(
        "publish churn: {churn_publishes} incremental publishes overlapped with serving"
    );
    server.shutdown();

    let tok_per_s = |r: &pawd::util::benchkit::BenchResult| tokens_per_batch / r.mean_s();
    let mut t = Table::new(&["scenario", "tok/s", "batch ms", "base GEMMs/batch"]);
    for (name, r, gemms) in [
        ("per-request, mixed", &r_per_req_mixed, per_request_gemms),
        ("BatchPlan, mixed", &r_plan_mixed, batched_gemms),
        ("per-request, single-variant", &r_per_req_single, per_request_gemms),
        ("Uniform batched, single-variant", &r_uniform_single, gemms_per_forward),
        ("BatchPlan mixed, pool x1", &r_pool1, batched_gemms),
        ("BatchPlan mixed, pool x4", &r_pool4, batched_gemms),
        ("Uniform single, pool x4", &r_single_pool4, gemms_per_forward),
        ("shared-prefix mixed, cold", &r_prefix_cold, batched_gemms),
        ("shared-prefix mixed, cache hit", &r_prefix_hit, batched_gemms),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.0}", tok_per_s(r)),
            format!("{:.2}", r.mean_s() * 1e3),
            gemms.to_string(),
        ]);
    }
    t.print("Batched multi-variant forward: shared base GEMM vs per-request (tiny)");
    println!(
        "mixed-batch speedup: {:.2}x (shared-base BatchPlan over per-request fused)",
        r_per_req_mixed.mean_s() / r_plan_mixed.mean_s()
    );

    let mut report = BenchReport::new();
    report.add(
        "batched_forward/mixed8_per_request",
        &[("tok_per_s", tok_per_s(&r_per_req_mixed))],
    );
    report.add(
        "batched_forward/mixed8_batch_plan",
        &[("tok_per_s", tok_per_s(&r_plan_mixed))],
    );
    report.add(
        "batched_forward/single8_per_request",
        &[("tok_per_s", tok_per_s(&r_per_req_single))],
    );
    report.add(
        "batched_forward/single8_uniform",
        &[("tok_per_s", tok_per_s(&r_uniform_single))],
    );
    report.add("batched_forward/mixed8_pool1", &[("tok_per_s", tok_per_s(&r_pool1))]);
    report.add("batched_forward/mixed8_pool4", &[("tok_per_s", tok_per_s(&r_pool4))]);
    report.add(
        "batched_forward/single8_pool4",
        &[("tok_per_s", tok_per_s(&r_single_pool4))],
    );
    report.add(
        "batched_forward/prefix",
        &[
            ("prefix_cold_tokens_per_s", tok_per_s(&r_prefix_cold)),
            ("prefix_hit_tokens_per_s", tok_per_s(&r_prefix_hit)),
            ("prefix_speedup", prefix_speedup),
        ],
    );
    report.add(
        "batched_forward/churn",
        &[
            ("req_per_s", batch as f64 / r_churn.mean_s()),
            ("publishes_overlapped", churn_publishes as f64),
        ],
    );
    report.add(
        "batched_forward/structure",
        &[
            ("batched_base_gemms", batched_gemms as f64),
            ("per_request_base_gemms", per_request_gemms as f64),
            ("mixed_speedup", r_per_req_mixed.mean_s() / r_plan_mixed.mean_s()),
            ("fused_act_row_reads", fused_act_reads as f64),
            ("two_pass_act_row_reads", two_pass_act_reads as f64),
            ("pool4_speedup", pool4_speedup),
        ],
    );
    report.flush_env()?;
    Ok(())
}
