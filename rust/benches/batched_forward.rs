//! **S3 — batched multi-variant forward**: the shared-base `BatchPlan` path
//! against the per-request fused path, single-variant and mixed batches.
//!
//! The structural claim is asserted, not just timed: the op counter must
//! show the batched path issuing **one base GEMM per module per batch**
//! while the per-request path issues one per module per *sequence* — that
//! is the whole win (base weights/activations stream once per window, each
//! variant pays only its packed mask reduction on its own rows).
//!
//! Emits machine-readable metrics into `$PAWD_BENCH_JSON` (see
//! `BenchReport`); CI's bench-smoke lane runs this in fast mode and gates
//! throughput against `BENCH_baseline.json`.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::compress::{compress_model, CompressOptions, FitMode};
use pawd::exec::{counters, BatchPlan, PackedVariant, Uniform, VariantWeights};
use pawd::model::synth::{synth_finetune, SynthDeltaSpec};
use pawd::model::Transformer;
use pawd::util::benchkit::{Bench, BenchReport, Table};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let (base, _) = bench_common::synth_pair("tiny", 17);
    let base = Arc::new(base);
    let cfg = base.cfg().clone();
    let tf = Transformer::new(&cfg);
    let docs = bench_common::calib_docs(4, 40);

    // A small fleet of packed variants sharing the one base.
    let n_variants = 4usize;
    let variants: Vec<VariantWeights> = (0..n_variants)
        .map(|k| {
            let ft = synth_finetune(
                &base,
                &SynthDeltaSpec { seed: 900 + k as u64, ..Default::default() },
            );
            let (delta, _, _) = compress_model(
                &format!("v{k}"),
                &base,
                &ft,
                &docs,
                &CompressOptions { fit: FitMode::ClosedForm, ..Default::default() },
            );
            VariantWeights::Packed(PackedVariant::new(base.clone(), Arc::new(delta)).unwrap())
        })
        .collect();

    let batch = 8usize;
    let seq_len = 24usize;
    let mk_tokens = |i: usize| -> Vec<u8> {
        (0..seq_len).map(|t| ((t * 13 + i * 41) % 200 + 20) as u8).collect()
    };
    // Mixed batch: requests round-robin across the variant fleet.
    let mixed_weights: Vec<VariantWeights> =
        (0..batch).map(|i| variants[i % n_variants].clone()).collect();
    let plans = BatchPlan::group(&mixed_weights);
    assert_eq!(plans.len(), 1, "packed variants of one base must share one plan");
    let (plan, members) = &plans[0];
    let seqs: Vec<(usize, Vec<u8>)> = (0..batch).map(|i| (i, mk_tokens(i))).collect();
    let single_seqs: Vec<(usize, Vec<u8>)> = (0..batch).map(|i| (0, mk_tokens(i))).collect();
    let tokens_per_batch = (batch * seq_len) as f64;

    // --- correctness + op-count structure (assert before timing) ---------
    let batched = tf.forward_plan(plan, &seqs);
    for ((entry, tokens), got) in seqs.iter().zip(&batched) {
        let want = tf.forward_one(&mixed_weights[members[*entry]], tokens);
        assert_eq!(got.data, want.data, "batched forward must match the per-request path");
    }
    let gemms_per_forward = (cfg.n_layers * 7 + 1) as u64; // 7 projections + LM head
    counters::reset();
    let _ = tf.forward_plan(plan, &seqs);
    let batched_gemms = counters::base_gemms();
    assert_eq!(
        batched_gemms, gemms_per_forward,
        "shared-base path must issue ONE base GEMM per module per batch"
    );
    counters::reset();
    for (entry, tokens) in &seqs {
        let _ = tf.forward_one(&mixed_weights[members[*entry]], tokens);
    }
    let per_request_gemms = counters::base_gemms();
    assert_eq!(
        per_request_gemms,
        gemms_per_forward * batch as u64,
        "per-request path pays the base GEMM once per sequence"
    );
    println!(
        "op counter: batched {batched_gemms} base GEMMs/batch vs per-request \
         {per_request_gemms} (batch={batch}, {n_variants} variants)\n"
    );

    // --- throughput --------------------------------------------------------
    let mut b = Bench::from_env();
    let r_per_req_mixed = b
        .run_items(&format!("per-request fused, mixed x{batch}"), tokens_per_batch, || {
            for (entry, tokens) in &seqs {
                std::hint::black_box(tf.forward_one(&mixed_weights[members[*entry]], tokens));
            }
        })
        .clone();
    let r_plan_mixed = b
        .run_items(&format!("BatchPlan shared base, mixed x{batch}"), tokens_per_batch, || {
            std::hint::black_box(tf.forward_plan(plan, &seqs));
        })
        .clone();
    let r_per_req_single = b
        .run_items(&format!("per-request fused, single x{batch}"), tokens_per_batch, || {
            for (_, tokens) in &single_seqs {
                std::hint::black_box(tf.forward_one(&mixed_weights[0], tokens));
            }
        })
        .clone();
    let r_uniform_single = b
        .run_items(&format!("Uniform batched, single x{batch}"), tokens_per_batch, || {
            std::hint::black_box(tf.forward_plan(&Uniform(&mixed_weights[0]), &single_seqs));
        })
        .clone();

    let tok_per_s = |r: &pawd::util::benchkit::BenchResult| tokens_per_batch / r.mean_s();
    let mut t = Table::new(&["scenario", "tok/s", "batch ms", "base GEMMs/batch"]);
    for (name, r, gemms) in [
        ("per-request, mixed", &r_per_req_mixed, per_request_gemms),
        ("BatchPlan, mixed", &r_plan_mixed, batched_gemms),
        ("per-request, single-variant", &r_per_req_single, per_request_gemms),
        ("Uniform batched, single-variant", &r_uniform_single, gemms_per_forward),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.0}", tok_per_s(r)),
            format!("{:.2}", r.mean_s() * 1e3),
            gemms.to_string(),
        ]);
    }
    t.print("Batched multi-variant forward: shared base GEMM vs per-request (tiny)");
    println!(
        "mixed-batch speedup: {:.2}x (shared-base BatchPlan over per-request fused)",
        r_per_req_mixed.mean_s() / r_plan_mixed.mean_s()
    );

    let mut report = BenchReport::new();
    report.add(
        "batched_forward/mixed8_per_request",
        &[("tok_per_s", tok_per_s(&r_per_req_mixed))],
    );
    report.add(
        "batched_forward/mixed8_batch_plan",
        &[("tok_per_s", tok_per_s(&r_plan_mixed))],
    );
    report.add(
        "batched_forward/single8_per_request",
        &[("tok_per_s", tok_per_s(&r_per_req_single))],
    );
    report.add(
        "batched_forward/single8_uniform",
        &[("tok_per_s", tok_per_s(&r_uniform_single))],
    );
    report.add(
        "batched_forward/structure",
        &[
            ("batched_base_gemms", batched_gemms as f64),
            ("per_request_base_gemms", per_request_gemms as f64),
            ("mixed_speedup", r_per_req_mixed.mean_s() / r_plan_mixed.mean_s()),
        ],
    );
    report.flush_env()?;
    Ok(())
}
