//! **Replication sync**: wire bytes and sync latency for a follower
//! mirroring a leader's registry over the filesystem transport.
//!
//! Structural claims are asserted through the `exec::counters` wire gauges,
//! not just timed:
//!
//! * a follower syncing a ~5%-changed publish (leader ships a patch, the
//!   follower already holds the chain parent) moves **<15%** of the
//!   consolidated artifact bytes over the wire;
//! * an up-to-date follower polling the leader moves only manifest bytes —
//!   zero artifact files;
//! * post-sync eval logits are bitwise-equal between leader and follower;
//! * the same structure holds over the HTTP transport (loopback
//!   `HttpFrontend` + `HttpTransport`), where an idle long-poll costs only
//!   header bytes (the 304 path).
//!
//! Emits machine-readable metrics into `$PAWD_BENCH_JSON` (see
//! `BenchReport`); CI's bench-smoke lane runs this in fast mode.

#[path = "bench_common/mod.rs"]
mod bench_common;

use bench_common::{perturb, seeded_full};
use pawd::coordinator::{FsTransport, Replicator, VariantRegistry};
use pawd::exec::counters;
use pawd::model::config::ModelConfig;
use pawd::model::{FlatParams, Transformer};
use pawd::net::{FrontConfig, HttpFrontend, HttpTransport};
use pawd::util::benchkit::{fmt_bytes, fmt_dur, BenchReport, Table};
use pawd::util::stats::Summary;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bitwise_logits(base: &Arc<FlatParams>, tf: &Transformer, dir: &Path, probe: &[u8]) -> Vec<u32> {
    use pawd::coordinator::VariantStore;
    use pawd::exec::ExecMode;
    let store = VariantStore::new(base.clone(), dir).with_mode(ExecMode::Fused);
    let w = store.load("ft").unwrap().weights;
    tf.forward_one(&w, probe).data.iter().map(|x| x.to_bits()).collect()
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("PAWD_BENCH_FAST").is_ok();
    let cfg = ModelConfig::preset("llama-mini")?;
    let base = Arc::new(FlatParams::init(&cfg, 19));
    let tf = Transformer::new(&cfg);
    let n_modules = base.layout.patchable_modules().len();
    // ~5% of modules changed per publish (at least 1).
    let n_changed = (n_modules as f64 * 0.05).ceil() as usize;
    let leader_dir = bench_common::tmp_dir("replication_sync_leader");
    let follower_dir = bench_common::tmp_dir("replication_sync_follower");
    let leader = VariantRegistry::open(&leader_dir)?;
    let follower = Arc::new(VariantRegistry::open(&follower_dir)?);
    let replicator = Replicator::new(follower.clone(), Box::new(FsTransport::new(&leader_dir)));
    let probe: Vec<u8> = (0..24u8).map(|t| t.wrapping_mul(13) % 200 + 20).collect();

    // --- cold sync: the whole consolidated artifact moves ------------------
    let v1 = seeded_full(&base, 1);
    let full = leader.publish_incremental("ft", v1.clone(), None)?;
    assert!(!full.patch);
    counters::reset();
    let t0 = Instant::now();
    let cold_report = replicator.sync_once(None)?;
    let cold_time = t0.elapsed().as_secs_f64();
    let cold_wire = counters::wire_bytes();
    assert_eq!(cold_report.files_fetched, 1);
    assert_eq!(cold_report.artifact_bytes, full.bytes, "cold sync ships the full artifact");
    assert_eq!(
        cold_wire,
        full.bytes + cold_report.manifest_bytes,
        "wire counter must equal artifact + manifest bytes"
    );

    // --- warm sync: a ~5%-changed publish moves only the patch -------------
    let child = perturb(&v1, &base, n_changed, 2);
    let patched = leader.publish_incremental("ft", child, None)?;
    assert!(patched.patch, "a {n_changed}/{n_modules}-module change must ship as a patch");
    counters::reset();
    let t0 = Instant::now();
    let warm_report = replicator.sync_once(None)?;
    let warm_time = t0.elapsed().as_secs_f64();
    let warm_wire = counters::wire_bytes();
    let warm_files = counters::wire_files();
    assert_eq!(warm_files, 1, "warm sync must fetch exactly the patch file");
    assert_eq!(warm_report.patch_files_fetched, 1);
    let fraction = warm_report.artifact_bytes as f64 / full.bytes as f64;
    println!(
        "wire bytes: cold {} vs warm {} ({n_changed}/{n_modules} modules changed, {:.1}% of \
         consolidated)",
        fmt_bytes(cold_report.artifact_bytes),
        fmt_bytes(warm_report.artifact_bytes),
        fraction * 100.0
    );
    assert!(
        fraction < 0.15,
        "a ~5%-changed publish must replicate in <15% of the consolidated bytes, got {:.1}%",
        fraction * 100.0
    );
    // Including the manifest overhead the total still stays under the gate.
    let total_fraction = warm_wire as f64 / full.bytes as f64;
    assert!(
        total_fraction < 0.15,
        "total wire traffic (artifact + manifest) must stay <15%, got {:.1}%",
        total_fraction * 100.0
    );

    // --- fidelity: leader and follower serve bitwise-identical logits ------
    let ll = bitwise_logits(&base, &tf, &leader_dir, &probe);
    let fl = bitwise_logits(&base, &tf, &follower_dir, &probe);
    assert_eq!(ll, fl, "post-sync eval logits must be bitwise-equal");

    // --- steady state: polling an unchanged leader moves manifest bytes only
    counters::reset();
    let idle_report = replicator.sync_once(None)?;
    assert!(idle_report.up_to_date);
    assert_eq!(counters::wire_files(), 0);
    let idle_wire = counters::wire_bytes();
    assert_eq!(idle_wire, idle_report.manifest_bytes);

    // --- sync latency over repeated ~5%-changed publishes ------------------
    let rounds = if fast { 3 } else { 8 };
    let mut effective = leader.effective_model("ft", patched.version)?;
    let mut sync_times = Vec::with_capacity(rounds);
    let mut sync_bytes = Vec::with_capacity(rounds);
    for round in 0..rounds {
        // Consolidate between rounds (outside the timed region) so patch
        // depth stays constant; the follower mirrors the consolidation swap
        // before the next patch round.
        leader.consolidate("ft", None)?;
        replicator.sync_once(None)?;
        effective = perturb(&effective, &base, n_changed, 100 + round as u64);
        let out = leader.publish_incremental("ft", effective.clone(), None)?;
        assert!(out.patch);
        let t0 = Instant::now();
        let r = replicator.sync_once(None)?;
        sync_times.push(t0.elapsed().as_secs_f64());
        sync_bytes.push(r.artifact_bytes as f64);
        assert_eq!(r.patch_files_fetched, 1);
    }
    // --- HTTP transport: same structure over the network plane -------------
    // A sync-only frontend serves the leader registry; the follower pulls
    // through HttpTransport on loopback. Wire gauges include HTTP header
    // overhead, so the <15% gate exercises the real on-the-wire cost.
    let http_leader_dir = bench_common::tmp_dir("replication_sync_http_leader");
    let http_follower_dir = bench_common::tmp_dir("replication_sync_http_follower");
    let http_leader = Arc::new(VariantRegistry::open(&http_leader_dir)?);
    let frontend =
        HttpFrontend::start("127.0.0.1:0", None, http_leader.clone(), FrontConfig::default())?;
    let http_follower = Arc::new(VariantRegistry::open(&http_follower_dir)?);
    let http_repl = Replicator::new(
        http_follower.clone(),
        Box::new(HttpTransport::new(&frontend.url())?),
    );

    let hv1 = seeded_full(&base, 31);
    let hfull = http_leader.publish_incremental("ft", hv1.clone(), None)?;
    counters::reset();
    let t0 = Instant::now();
    let http_cold = http_repl.sync_once(None)?;
    let http_cold_time = t0.elapsed().as_secs_f64();
    assert_eq!(http_cold.files_fetched, 1);
    assert_eq!(
        bitwise_logits(&base, &tf, &http_leader_dir, &probe),
        bitwise_logits(&base, &tf, &http_follower_dir, &probe),
        "HTTP-synced follower must serve bitwise-equal logits"
    );

    let hchild = perturb(&hv1, &base, n_changed, 32);
    let hpatched = http_leader.publish_incremental("ft", hchild, None)?;
    assert!(hpatched.patch);
    counters::reset();
    let t0 = Instant::now();
    let http_warm = http_repl.sync_once(None)?;
    let http_warm_time = t0.elapsed().as_secs_f64();
    assert_eq!(counters::wire_files(), 1);
    let http_fraction = counters::wire_bytes() as f64 / hfull.bytes as f64;
    assert!(
        http_fraction < 0.15,
        "a ~5%-changed publish over HTTP must replicate in <15% of the consolidated \
         bytes (headers included), got {:.1}%",
        http_fraction * 100.0
    );

    // Idle long-poll: the whole pass is one 304 — zero files, header bytes.
    counters::reset();
    let http_idle = http_repl.sync_wait(None, Duration::from_millis(200))?;
    assert!(http_idle.up_to_date);
    assert_eq!(counters::wire_files(), 0);
    let http_idle_wire = counters::wire_bytes();
    assert!(
        http_idle_wire > 0 && http_idle_wire < 1024,
        "an idle long-poll must move only header bytes, got {http_idle_wire}"
    );
    assert!(counters::http_long_polls() >= 1, "the idle pass must ride the long-poll path");

    let st = Summary::of(&sync_times);
    let sb = Summary::of(&sync_bytes);
    let mut t = Table::new(&["sync", "latency", "wire bytes", "files"]);
    t.row(&[
        "cold (consolidated)".into(),
        fmt_dur(cold_time),
        fmt_bytes(cold_report.artifact_bytes),
        "1".into(),
    ]);
    t.row(&[
        format!("warm (patch, {n_changed}/{n_modules} modules)"),
        fmt_dur(warm_time),
        fmt_bytes(warm_report.artifact_bytes),
        "1".into(),
    ]);
    t.row(&[
        format!("steady warm p50 over {rounds} rounds"),
        fmt_dur(st.p50),
        fmt_bytes(sb.p50 as u64),
        "1".into(),
    ]);
    t.row(&["idle poll".into(), "-".into(), fmt_bytes(idle_wire), "0".into()]);
    t.row(&[
        "http cold (consolidated)".into(),
        fmt_dur(http_cold_time),
        fmt_bytes(http_cold.artifact_bytes),
        "1".into(),
    ]);
    t.row(&[
        "http warm (patch)".into(),
        fmt_dur(http_warm_time),
        fmt_bytes(http_warm.artifact_bytes),
        "1".into(),
    ]);
    t.row(&["http idle long-poll (304)".into(), "-".into(), fmt_bytes(http_idle_wire), "0".into()]);
    t.print("Replication sync: patch-aware transfer (llama-mini, fs + http transports)");

    let mut report = BenchReport::new();
    report.add(
        "replication_sync/wire_bytes",
        &[
            ("cold_bytes", cold_report.artifact_bytes as f64),
            ("warm_patch_bytes", warm_report.artifact_bytes as f64),
            ("warm_fraction", fraction),
            ("idle_poll_bytes", idle_wire as f64),
        ],
    );
    report.add(
        "replication_sync/http",
        &[
            ("cold_ms", http_cold_time * 1e3),
            ("warm_ms", http_warm_time * 1e3),
            ("warm_fraction", http_fraction),
            ("idle_poll_bytes", http_idle_wire as f64),
        ],
    );
    report.add(
        "replication_sync/latency",
        &[
            ("cold_ms", cold_time * 1e3),
            ("warm_ms", warm_time * 1e3),
            ("steady_p50_ms", st.p50 * 1e3),
        ],
    );
    report.flush_env()?;
    Ok(())
}
