//! **K1b — fused delta-GEMM**: on-the-fly serving mode (§4 future work).
//! Compares materialize-then-GEMM (native) against the fused Pallas kernel
//! artifact, and reports the resident-bytes saving that motivates the
//! fused mode.

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::delta::pack::PackedMask;
use pawd::delta::types::{Axis, Codec, DeltaModule};
use pawd::exec::{DenseLinear, FusedDeltaLinear, LinearOp};
use pawd::model::{ModuleId, ProjKind};
use pawd::tensor::Tensor2;
use pawd::util::benchkit::{fmt_bytes, Bench};
use pawd::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::from_env();
    let (n, d_out, d_in) = (64usize, 688usize, 256usize);
    let flops = (2 * n * d_out * d_in) as f64;
    let mut rng = Rng::new(3);
    let base: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let delta: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let mask = PackedMask::pack(&delta, d_out, d_in);
    let scales: Vec<f32> = (0..d_out).map(|_| rng.uniform_in(0.01, 0.1)).collect();
    let x: Vec<f32> = (0..n * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let module = DeltaModule {
        id: ModuleId { layer: 0, kind: ProjKind::Up },
        mask: mask.clone(),
        axis: Axis::Row,
        scales: scales.clone(),
        codec: Codec::PerAxis,
    };
    let xt = Tensor2::from_vec(n, d_in, x.clone());

    // Mode A: apply once + plain GEMM per forward (amortized swap cost).
    let mut w = vec![0f32; base.len()];
    pawd::delta::apply::apply_module_into(&base, &mut w, &module);
    let wt = Tensor2::from_vec(d_out, d_in, w);
    b.run_items(&format!("gemm_native_{n}x{d_out}x{d_in} (materialized)"), flops, || {
        let y = xt.matmul_bt(&wt);
        std::hint::black_box(&y);
    });
    b.run_items("apply+gemm_native (swap every forward)", flops, || {
        let mut w = vec![0f32; base.len()];
        pawd::delta::apply::apply_module_into(&base, &mut w, &module);
        let wt = Tensor2::from_vec(d_out, d_in, w);
        let y = xt.matmul_bt(&wt);
        std::hint::black_box(&y);
    });

    // Mode B: the exec-layer backends over the same operands — the one-flag
    // dense-vs-fused A/B the serving coordinator runs. DenseLinear is the
    // slice-view GEMM (no weight copy); FusedDeltaLinear executes straight
    // from the packed bitplane, so there is no resident Ŵ at all.
    let dense_op = DenseLinear::new(&wt.data, d_out, d_in);
    b.run_items("exec_dense_linear (slice-view GEMM)", flops, || {
        let y = dense_op.forward(&xt);
        std::hint::black_box(&y);
    });
    let fused_op = FusedDeltaLinear::new(&base, &module);
    b.run_items("exec_fused_delta_linear (packed, no Ŵ)", flops, || {
        let y = fused_op.forward(&xt);
        std::hint::black_box(&y);
    });
    // Sanity: the two backends agree to accumulation noise.
    {
        let a = dense_op.forward(&xt);
        let f = fused_op.forward(&xt);
        let max_rel = a
            .data
            .iter()
            .zip(&f.data)
            .map(|(x, y)| ((x - y).abs() / (1.0 + x.abs())) as f64)
            .fold(0.0f64, f64::max);
        println!("dense-vs-fused max rel err: {max_rel:.2e}");
        assert!(max_rel < 1e-5, "fused backend diverged from dense");
    }

    // Mode C: fused Pallas kernel through PJRT.
    if bench_common::have_artifacts() {
        let h = pawd::runtime::start(&bench_common::artifacts_dir())?;
        let _ = pawd::runtime::api::fused_delta_matmul_xla(
            &h, "row", &x, n, &base, d_out, d_in, &mask.words, &scales,
        )?; // warm compile
        b.run_items("fused_delta_gemm_xla (incl. transfers)", flops, || {
            let y = pawd::runtime::api::fused_delta_matmul_xla(
                &h, "row", &x, n, &base, d_out, d_in, &mask.words, &scales,
            )
            .unwrap();
            std::hint::black_box(&y);
        });
        h.shutdown();
    } else {
        println!("(skipping fused XLA path — run `make artifacts`)");
    }

    let dense = (d_out * d_in * 4) as u64;
    let packed = mask.n_bytes() + (scales.len() * 2) as u64;
    println!(
        "\nresident bytes per variant for this module: dense {} vs packed {} ({:.1}x)",
        fmt_bytes(dense),
        fmt_bytes(packed),
        dense as f64 / packed as f64
    );
    println!("(interpret-mode Pallas on CPU measures structure, not TPU wallclock — see DESIGN.md)");
    Ok(())
}
