//! **T1 — Table 1**: zero-shot accuracy for {Baseline, BitDelta (scalar),
//! Vector (row/col)} across model pairs and the five task suites, via the
//! full train→finetune→compress→e2e→eval pipeline.
//!
//! Defaults run the `tiny` pair (minutes). Set `PAWD_PAIRS=llama-mini` (or
//! a comma list incl. qwen-mini, phi-mini) and/or `PAWD_FULL=1` for the
//! paper-protocol calibration budget (50 + 150 samples, 5 epochs).

#[path = "bench_common/mod.rs"]
mod bench_common;

use pawd::baselines;
use pawd::data::tasks::TaskFamily;
use pawd::delta::compress::CompressOptions;
use pawd::delta::compress::FitMode;
use pawd::eval::fidelity::fidelity;
use pawd::model::Transformer;
use pawd::pipeline::{run_pair, PairConfig};
use pawd::util::benchkit::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    if !bench_common::have_artifacts() {
        eprintln!("table1_accuracy: artifacts missing — run `make artifacts`");
        return Ok(());
    }
    let pairs = std::env::var("PAWD_PAIRS").unwrap_or_else(|_| "tiny".to_string());
    let full = std::env::var("PAWD_FULL").is_ok();
    let h = pawd::runtime::start(&bench_common::artifacts_dir())?;

    for pair in pairs.split(',').filter(|s| !s.is_empty()) {
        let mut pc = if full { PairConfig::full(pair) } else { PairConfig::quick(pair) };
        if pair == "tiny" && !full {
            pc.base_steps = 800;
            pc.finetune_steps = 400;
            pc.base_lr = 3e-3;
            pc.finetune_lr = 1e-3;
            pc.eval_items_per_family = 30;
        }
        let methods = vec![
            (
                "BitDelta (scalar)",
                CompressOptions { fit: FitMode::AdamW, ..baselines::bitdelta_options() },
                false,
            ),
            ("Vector (row/col)", baselines::vector_options(), true),
        ];
        let out = bench_common::tmp_dir(&format!("table1_{pair}"));
        let res = run_pair(&h, &pc, &methods, &out, |m| eprintln!("{m}"))?;

        let mut t = Table::new(&[
            "Method", "ARC-C*", "ARC-E*", "HellaSwag*", "PIQA*", "Winogrande*", "Avg", "KL(teach)", "Agree%",
        ]);
        let tf = Transformer::new(&res.config);
        let probes: Vec<Vec<u8>> = bench_common::probe_docs(4, res.config.max_seq.min(96));
        let mut add = |suite: &pawd::eval::harness::SuiteResult, params: Option<&pawd::model::FlatParams>| {
            let mut row = vec![suite.label.clone()];
            for fam in TaskFamily::ALL {
                row.push(format!("{:.2}", suite.pct(fam)));
            }
            row.push(format!("{:.2}", suite.average() * 100.0));
            match params {
                Some(p) => {
                    let f = fidelity(&tf, &res.teacher, p, &probes);
                    row.push(format!("{:.4}", f.kl));
                    row.push(format!("{:.1}", f.agreement * 100.0));
                }
                None => {
                    row.push("-".into());
                    row.push("-".into());
                }
            }
            t.row(&row);
        };
        add(&res.base_suite, Some(&res.base));
        add(&res.baseline_suite, None);
        for m in &res.methods {
            let student = m
                .delta
                .as_ref()
                .map(|d| pawd::delta::apply::materialize(&res.base, &d.modules));
            add(&m.suite, student.as_ref());
        }
        t.print(&format!(
            "Table 1 (reproduction): zero-shot accuracy (%) — {} pair, calib {}+{} docs",
            res.config.name, pc.calib_layer_docs, pc.calib_e2e_docs
        ));
        println!(
            "fp16 teacher checkpoint: {}; loss base {:.3}->{:.3}, ft {:.3}->{:.3}",
            fmt_bytes(res.fp16_bytes),
            res.base_losses.first().unwrap(),
            res.base_losses.last().unwrap(),
            res.finetune_losses.first().unwrap(),
            res.finetune_losses.last().unwrap()
        );
    }
    h.shutdown();
    Ok(())
}
