//! Request/response types for the multi-variant serving coordinator.
//!
//! The payload is split into two planes:
//!
//! * [`Payload::Data`] — inference work routed through the per-variant
//!   queues, the batcher and a worker engine.
//! * [`Payload::Admin`] — control-plane operations ([`AdminOp`]) answered by
//!   a worker **without touching an engine**: stats, and the variant
//!   lifecycle (publish / rollback / pin / retire / list) executed against
//!   the registry behind the cache.

use super::metrics::MetricsSnapshot;
use super::registry::VariantDesc;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Pseudo-variant name admin requests are queued under (admin ops carry
/// their target variant, if any, inside the op). The pre-admin-plane
/// `"__stats__"` alias was removed after its deprecation window; admin
/// routing is by payload type, not variant name.
pub const ADMIN_VARIANT: &str = "__admin__";

/// What a client asks of a variant.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Inference against the request's variant (engine path).
    Data(DataOp),
    /// Control-plane operation (no engine; answered from registry/metrics).
    Admin(AdminOp),
}

impl Payload {
    /// Convenience constructor for a score request.
    pub fn score(prompt: &str, choices: &[String]) -> Payload {
        Payload::Data(DataOp::Score { prompt: prompt.to_string(), choices: choices.to_vec() })
    }

    /// Convenience constructor for a perplexity request.
    pub fn perplexity(text: &str) -> Payload {
        Payload::Data(DataOp::Perplexity { text: text.to_string() })
    }
}

/// Inference operations (the engine path).
#[derive(Clone, Debug)]
pub enum DataOp {
    /// Rank `choices` as completions of `prompt` by log-likelihood
    /// (the zero-shot MC scoring primitive).
    Score { prompt: String, choices: Vec<String> },
    /// Per-token cross entropy of `text` (perplexity probes, health checks).
    Perplexity { text: String },
}

/// Control-plane operations (no engine involved).
#[derive(Clone, Debug)]
pub enum AdminOp {
    /// Server metrics + cache residency gauges.
    Stats,
    /// Publish the `.pawd` artifact at `artifact` as the next **full**
    /// version of `variant` and flip the alias (unless pinned). The new
    /// version is warmed into the cache before the response is sent.
    Publish { variant: String, artifact: PathBuf },
    /// Publish the effective model in `artifact` as the next version of
    /// `variant`, shipping a **patch artifact** with only the modules that
    /// changed vs `parent` (default: the active version); falls back to a
    /// full publish when no patch is expressible. Warming the new version
    /// composes onto the resident parent, so the cache cost is also
    /// proportional to what changed.
    PublishIncremental { variant: String, artifact: PathBuf, parent: Option<u32> },
    /// Rebase the patch chain of `variant@version` (default: the active
    /// version) into a single full artifact in place.
    Consolidate { variant: String, version: Option<u32> },
    /// Flip the alias back to `to` (or the active version's parent).
    Rollback { variant: String, to: Option<u32> },
    /// Freeze the alias on `version` until unpinned.
    Pin { variant: String, version: u32 },
    /// Release a pin (the alias stays put until the next publish).
    Unpin { variant: String },
    /// Mark `version` unservable (must not be the active version).
    Retire { variant: String, version: u32 },
    /// Delete retired versions' artifact files from disk (all variants, or
    /// just `variant`); the version records stay as tombstones so numbering
    /// remains monotone.
    Gc { variant: Option<String> },
    /// List all variants with their version histories.
    List,
    /// Replication probe: the local registry's monotonic `manifest_seq` and
    /// record counts (what a leader exposes, what a follower has applied).
    SyncStatus,
    /// Pull-replicate from a leader's registry directory (filesystem
    /// transport): diff the leader manifest against the local registry,
    /// fetch + verify missing artifacts (patches preferred when the chain
    /// parent is already held), commit, and warm the synced versions into
    /// the cache.
    PullFrom { dir: PathBuf },
}

#[derive(Clone, Debug)]
pub enum RespBody {
    Score { choice: usize, scores: Vec<f64> },
    Perplexity { nats_per_token: f64 },
    Admin(AdminResp),
}

/// Control-plane responses, mirroring [`AdminOp`].
#[derive(Clone, Debug)]
pub enum AdminResp {
    /// Boxed: the snapshot dwarfs every other variant.
    Stats { snapshot: Box<MetricsSnapshot> },
    /// `patch` reports whether a patch artifact shipped (always `false` for
    /// plain `Publish`); `bytes` is the artifact size written.
    Published { variant: String, version: u32, patch: bool, bytes: u64 },
    Consolidated { variant: String, version: u32, bytes: u64, rebased_links: usize },
    RolledBack { variant: String, version: u32 },
    Pinned { variant: String, version: u32 },
    Unpinned { variant: String },
    Retired { variant: String, version: u32 },
    Gced { files_removed: usize, bytes_freed: u64 },
    Variants { variants: Vec<VariantDesc> },
    /// Local replication state: manifest sequence number plus variant and
    /// version record counts.
    SyncStatus { manifest_seq: u64, variants: usize, versions: usize },
    /// One pull-replication pass completed against `peer`.
    Synced { peer: String, report: super::replicate::SyncReport },
}

/// Timing breakdown a response carries back (drives the latency
/// histograms and the cold-start experiments).
#[derive(Clone, Debug, Default)]
pub struct Timing {
    /// Time spent queued before batching.
    pub queue: Duration,
    /// Variant materialization time, if this request triggered a cold load.
    pub cold_start: Option<Duration>,
    /// Forward/scoring compute time for the batch this request rode in.
    pub compute: Duration,
    /// Total submit→response latency.
    pub total: Duration,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub variant: String,
    pub payload: Payload,
    pub resp: mpsc::Sender<Response>,
    pub submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub variant: String,
    /// Registry version that served a data request (`None` for admin
    /// responses and failures before version resolution).
    pub version: Option<u32>,
    pub result: Result<RespBody, String>,
    pub timing: Timing,
}

impl Request {
    pub fn new(
        id: u64,
        variant: &str,
        payload: Payload,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                variant: variant.to_string(),
                payload,
                resp: tx,
                submitted: Instant::now(),
            },
            rx,
        )
    }
}
