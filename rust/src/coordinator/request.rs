//! Request/response types for the multi-variant serving coordinator.

use super::metrics::MetricsSnapshot;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Pseudo-variant name that routes a request to the stats endpoint instead
/// of a model (see `Client::stats`).
pub const STATS_VARIANT: &str = "__stats__";

/// What a client asks of a variant.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Rank `choices` as completions of `prompt` by log-likelihood
    /// (the zero-shot MC scoring primitive).
    Score { prompt: String, choices: Vec<String> },
    /// Per-token cross entropy of `text` (perplexity probes, health checks).
    Perplexity { text: String },
    /// Server metrics + cache residency gauges (submit to
    /// [`STATS_VARIANT`]; answered by a worker without touching an engine).
    Stats,
}

#[derive(Clone, Debug)]
pub enum RespBody {
    Score { choice: usize, scores: Vec<f64> },
    Perplexity { nats_per_token: f64 },
    Stats { snapshot: MetricsSnapshot },
}

/// Timing breakdown a response carries back (drives the latency
/// histograms and the cold-start experiments).
#[derive(Clone, Debug, Default)]
pub struct Timing {
    /// Time spent queued before batching.
    pub queue: Duration,
    /// Variant materialization time, if this request triggered a cold load.
    pub cold_start: Option<Duration>,
    /// Forward/scoring compute time for the batch this request rode in.
    pub compute: Duration,
    /// Total submit→response latency.
    pub total: Duration,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub variant: String,
    pub payload: Payload,
    pub resp: mpsc::Sender<Response>,
    pub submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub variant: String,
    pub result: Result<RespBody, String>,
    pub timing: Timing,
}

impl Request {
    pub fn new(
        id: u64,
        variant: &str,
        payload: Payload,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                variant: variant.to_string(),
                payload,
                resp: tx,
                submitted: Instant::now(),
            },
            rx,
        )
    }
}
