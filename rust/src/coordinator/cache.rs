//! LRU cache of resident variants under a byte budget.
//!
//! Serving many fine-tuned variants of one base means most variants are
//! cold most of the time; the cache keeps the hot set resident and charges
//! cold loads to the hot-swap loader (whose latency the paper's §3.2
//! load-time experiment measures).
//!
//! Residency accounting follows the store's [`ExecMode`]: a dense entry
//! charges the full materialized parameter bytes, a packed entry charges
//! only its mask + scale bytes (the shared base is owned by the store and
//! charged to nobody). Under a fixed budget this multiplies the number of
//! resident variants by the compression ratio, and a hot swap is an `Arc`
//! clone — no materialize/revert pass ever runs on the request path.

use super::store::{LoadedVariant, VariantStore};
use crate::exec::VariantWeights;
use crate::model::FlatParams;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Cold-start (load/validate, or materialization in dense mode) times
    /// observed on misses.
    pub cold_start: Vec<Duration>,
}

/// Point-in-time residency gauges (the satellite metrics surfaced through
/// `Metrics::snapshot` and the server's stats responses).
#[derive(Clone, Copy, Debug, Default)]
pub struct Residency {
    /// Number of variants currently resident.
    pub variants: usize,
    /// Bytes actually charged against the budget (packed bytes for fused
    /// entries, dense bytes otherwise).
    pub resident_bytes: u64,
    /// What the same resident set would cost fully materialized.
    pub dense_equiv_bytes: u64,
}

struct Entry {
    weights: VariantWeights,
    bytes: u64,
    dense_equiv: u64,
    /// Monotone counter for LRU ordering.
    last_used: u64,
}

struct Inner {
    entries: HashMap<String, Entry>,
    /// Variants currently being loaded by some thread (single-flight guard:
    /// concurrent requests for the same cold variant wait instead of
    /// duplicating the load).
    loading: std::collections::HashSet<String>,
    clock: u64,
    used_bytes: u64,
    /// Running dense-equivalent total for the resident set, maintained
    /// incrementally alongside `used_bytes` so `residency()` is O(1) (it
    /// runs on the worker hot path).
    dense_equiv_bytes: u64,
    stats: CacheStats,
}

/// Thread-safe LRU variant cache with single-flight cold loads.
pub struct VariantCache {
    store: VariantStore,
    budget_bytes: u64,
    inner: Mutex<Inner>,
    loaded_cv: std::sync::Condvar,
}

impl VariantCache {
    pub fn new(store: VariantStore, budget_bytes: u64) -> VariantCache {
        VariantCache {
            store,
            budget_bytes,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                loading: std::collections::HashSet::new(),
                clock: 0,
                used_bytes: 0,
                dense_equiv_bytes: 0,
                stats: CacheStats::default(),
            }),
            loaded_cv: std::sync::Condvar::new(),
        }
    }

    pub fn base(&self) -> Arc<FlatParams> {
        self.store.base.clone()
    }

    /// Fetch a variant, loading on miss. Returns the weights and the
    /// cold-start duration if this call performed the load.
    pub fn get(&self, name: &str) -> Result<(VariantWeights, Option<Duration>)> {
        // Fast path under the lock; on a cold miss, claim the single-flight
        // slot (or wait for whoever holds it).
        {
            let mut inner = self.inner.lock().unwrap();
            loop {
                inner.clock += 1;
                let clock = inner.clock;
                let hit = if let Some(e) = inner.entries.get_mut(name) {
                    e.last_used = clock;
                    Some(e.weights.clone())
                } else {
                    None
                };
                if let Some(weights) = hit {
                    inner.stats.hits += 1;
                    return Ok((weights, None));
                }
                if inner.loading.insert(name.to_string()) {
                    inner.stats.misses += 1;
                    break; // we own the load
                }
                // Someone else is loading this variant: wait, then re-check.
                inner = self.loaded_cv.wait(inner).unwrap();
            }
        }
        // Load outside the lock (the expensive part). Ensure the loading
        // claim is released even on error.
        let loaded: Result<LoadedVariant> = self.store.load(name);
        let loaded: LoadedVariant = match loaded {
            Ok(l) => l,
            Err(e) => {
                let mut inner = self.inner.lock().unwrap();
                inner.loading.remove(name);
                drop(inner);
                self.loaded_cv.notify_all();
                return Err(e);
            }
        };
        let bytes = loaded.weights.resident_bytes();
        let dense_equiv = loaded.weights.dense_equiv_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        inner.stats.cold_start.push(loaded.load_time);
        // Evict LRU until the new entry fits.
        while inner.used_bytes + bytes > self.budget_bytes && !inner.entries.is_empty() {
            let lru = inner
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .unwrap();
            if let Some(e) = inner.entries.remove(&lru) {
                inner.used_bytes -= e.bytes;
                inner.dense_equiv_bytes -= e.dense_equiv;
                inner.stats.evictions += 1;
            }
        }
        inner.used_bytes += bytes;
        inner.dense_equiv_bytes += dense_equiv;
        inner.entries.insert(
            name.to_string(),
            Entry { weights: loaded.weights.clone(), bytes, dense_equiv, last_used: clock },
        );
        inner.loading.remove(name);
        drop(inner);
        self.loaded_cv.notify_all();
        Ok((loaded.weights, Some(loaded.load_time)))
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn resident(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<_> = inner.entries.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used_bytes
    }

    /// Current residency gauges (O(1): totals are maintained incrementally).
    pub fn residency(&self) -> Residency {
        let inner = self.inner.lock().unwrap();
        Residency {
            variants: inner.entries.len(),
            resident_bytes: inner.used_bytes,
            dense_equiv_bytes: inner.dense_equiv_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::compress::{compress_model, CompressOptions, FitMode};
    use crate::delta::format::save_delta;
    use crate::exec::{ExecMode, Weights};
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};
    use std::path::Path;

    fn setup(dir: &Path, n_variants: usize) -> VariantStore {
        let _ = std::fs::remove_dir_all(dir);
        std::fs::create_dir_all(dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 1);
        let docs: Vec<Vec<u8>> = (0..2).map(|i| vec![(i + 9) as u8; 20]).collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        for k in 0..n_variants {
            let ft = synth_finetune(
                &base,
                &SynthDeltaSpec { seed: 100 + k as u64, ..Default::default() },
            );
            let (delta, _, _) = compress_model(&format!("v{k}"), &base, &ft, &docs, &opts);
            save_delta(dir.join(format!("v{k}.pawd")), &delta).unwrap();
        }
        VariantStore::new(Arc::new(base), dir)
    }

    #[test]
    fn hit_after_miss() {
        let dir = std::env::temp_dir().join("pawd_test_cache1");
        let store = setup(&dir, 2);
        let cache = VariantCache::new(store, u64::MAX);
        let (_, cold) = cache.get("v0").unwrap();
        assert!(cold.is_some());
        let (_, cold2) = cache.get("v0").unwrap();
        assert!(cold2.is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2 - 1));
    }

    #[test]
    fn budget_evicts_lru() {
        let dir = std::env::temp_dir().join("pawd_test_cache2");
        let store = setup(&dir, 3); // dense mode: entries cost full params
        let one = (ModelConfig::preset("tiny").unwrap().n_params() * 4) as u64;
        let cache = VariantCache::new(store, one * 2 + 1024); // fits 2 variants
        cache.get("v0").unwrap();
        cache.get("v1").unwrap();
        cache.get("v0").unwrap(); // refresh v0 -> v1 becomes LRU
        cache.get("v2").unwrap(); // must evict v1
        let resident = cache.resident();
        assert!(resident.contains(&"v0".to_string()));
        assert!(resident.contains(&"v2".to_string()));
        assert!(!resident.contains(&"v1".to_string()));
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.used_bytes() <= one * 2 + 1024);
    }

    #[test]
    fn packed_mode_multiplies_residency_under_same_budget() {
        let dir = std::env::temp_dir().join("pawd_test_cache4");
        let store = setup(&dir, 4).with_mode(ExecMode::Fused);
        // A budget that fits exactly ONE dense variant holds the whole
        // packed fleet with room to spare.
        let one_dense = (ModelConfig::preset("tiny").unwrap().n_params() * 4) as u64;
        let cache = VariantCache::new(store, one_dense);
        for k in 0..4 {
            let (w, _) = cache.get(&format!("v{k}")).unwrap();
            assert!(w.is_packed());
        }
        assert_eq!(cache.resident().len(), 4);
        assert_eq!(cache.stats().evictions, 0);
        let r = cache.residency();
        assert_eq!(r.variants, 4);
        assert!(r.resident_bytes <= one_dense);
        // Dense-equivalent accounting shows the capacity multiplier.
        assert_eq!(r.dense_equiv_bytes, one_dense * 4);
        assert!(
            r.dense_equiv_bytes / r.resident_bytes.max(1) >= 8,
            "expected ≥8x residency multiplier, got {}x",
            r.dense_equiv_bytes / r.resident_bytes.max(1)
        );
    }

    #[test]
    fn concurrent_gets_are_consistent() {
        let dir = std::env::temp_dir().join("pawd_test_cache3");
        let store = setup(&dir, 2);
        let cache = std::sync::Arc::new(VariantCache::new(store, u64::MAX));
        std::thread::scope(|s| {
            for t in 0..8 {
                let c = cache.clone();
                s.spawn(move || {
                    for i in 0..20 {
                        let name = if (t + i) % 2 == 0 { "v0" } else { "v1" };
                        let (w, _) = c.get(name).unwrap();
                        assert!(!w.flat().data.is_empty());
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 8 * 20);
    }
}
