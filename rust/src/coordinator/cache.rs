//! LRU cache of resident variant **versions** under a byte budget.
//!
//! Serving many fine-tuned variants of one base means most variants are
//! cold most of the time; the cache keeps the hot set resident and charges
//! cold loads to the hot-swap loader (whose latency the paper's §3.2
//! load-time experiment measures).
//!
//! Entries are keyed by `(variant, version)`: a `get("name")` first resolves
//! the alias through the registry, so publishing version `N+1` simply makes
//! new requests miss into a fresh key — the publish *warms* `N+1` while `N`
//! ages out of the LRU under the byte budget, and in-flight requests keep
//! executing the `Arc` of `N` they already hold. Rollback is the same
//! mechanism in reverse (and usually a pure cache hit, since `N` is often
//! still resident).
//!
//! Residency accounting follows the store's [`ExecMode`](crate::exec::ExecMode): a dense entry
//! charges the full materialized parameter bytes, a packed entry charges
//! only its mask + scale bytes (the shared base is owned by the store and
//! charged to nobody). Under a fixed budget this multiplies the number of
//! resident versions by the compression ratio, and a hot swap is an `Arc`
//! clone — no materialize/revert pass ever runs on the request path.
//!
//! **Per-module sharing across versions.** Packed entries are charged per
//! `Arc<DeltaModule>`, refcounted across all resident entries: when
//! `variant@N+1` loads as a patch it inherits `@N`'s module Arcs for every
//! unchanged module (the cache passes the resident parent as a composition
//! hint to the store), so holding both versions costs the budget one copy
//! of the shared modules plus the changed ones — a publish warms the new
//! version at a marginal cost proportional to what actually changed.

use super::store::{LoadedVariant, VariantStore};
use crate::delta::types::DeltaModel;
use crate::exec::VariantWeights;
use crate::model::FlatParams;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Cold-start (load/validate, or materialization in dense mode) times
    /// observed on misses.
    pub cold_start: Vec<Duration>,
}

/// Residency of one cached `(variant, version)` entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VersionResidency {
    pub variant: String,
    pub version: u32,
    /// Standalone bytes of this entry (what it would cost resident alone).
    /// Modules shared with other resident versions are charged against the
    /// budget only once, so the budget total can be less than the sum of
    /// these.
    pub bytes: u64,
}

/// Point-in-time residency gauges (the satellite metrics surfaced through
/// `Metrics::snapshot` and the server's stats responses).
#[derive(Clone, Debug, Default)]
pub struct Residency {
    /// Number of variant versions currently resident.
    pub variants: usize,
    /// Bytes actually charged against the budget (packed bytes for fused
    /// entries, dense bytes otherwise).
    pub resident_bytes: u64,
    /// What the same resident set would cost fully materialized.
    pub dense_equiv_bytes: u64,
    /// Per-entry breakdown, sorted by (variant, version).
    pub per_version: Vec<VersionResidency>,
}

struct Entry {
    weights: VariantWeights,
    /// Standalone bytes (shared modules included) — reported per version.
    bytes: u64,
    dense_equiv: u64,
    /// Monotone counter for LRU ordering.
    last_used: u64,
}

type Key = (String, u32);

struct Inner {
    entries: HashMap<Key, Entry>,
    /// Versions currently being loaded by some thread (single-flight guard:
    /// concurrent requests for the same cold version wait instead of
    /// duplicating the load).
    loading: std::collections::HashSet<Key>,
    /// Budget charge per distinct `Arc<DeltaModule>` (keyed by pointer
    /// identity): `(bytes, refcount across resident entries)`. A module
    /// shared by several resident versions is charged once; its bytes are
    /// released only when the last holder is evicted.
    module_refs: HashMap<usize, (u64, usize)>,
    clock: u64,
    used_bytes: u64,
    /// Running dense-equivalent total for the resident set, maintained
    /// incrementally alongside `used_bytes` so the totals are O(1) (they
    /// run on the worker hot path).
    dense_equiv_bytes: u64,
    stats: CacheStats,
}

impl Inner {
    /// Bytes inserting `weights` would add to the budget right now (zero
    /// for modules some resident entry already holds).
    fn preview_charge(&self, weights: &VariantWeights) -> u64 {
        match weights {
            VariantWeights::Packed(pv) => pv
                .module_arcs()
                .iter()
                .filter(|m| !self.module_refs.contains_key(&(Arc::as_ptr(m) as usize)))
                .map(|m| m.resident_bytes())
                .sum(),
            dense => dense.resident_bytes(),
        }
    }

    /// Charge `weights` against the budget, refcounting packed modules.
    fn charge(&mut self, weights: &VariantWeights) {
        match weights {
            VariantWeights::Packed(pv) => {
                for m in pv.module_arcs() {
                    let slot = self
                        .module_refs
                        .entry(Arc::as_ptr(m) as usize)
                        .or_insert((m.resident_bytes(), 0));
                    if slot.1 == 0 {
                        self.used_bytes += slot.0;
                    }
                    slot.1 += 1;
                }
            }
            dense => self.used_bytes += dense.resident_bytes(),
        }
    }

    /// Release `weights`' charge; module bytes come back only when the last
    /// resident holder lets go.
    fn release(&mut self, weights: &VariantWeights) {
        match weights {
            VariantWeights::Packed(pv) => {
                for m in pv.module_arcs() {
                    let key = Arc::as_ptr(m) as usize;
                    if let Some(slot) = self.module_refs.get_mut(&key) {
                        slot.1 -= 1;
                        if slot.1 == 0 {
                            self.used_bytes -= slot.0;
                            self.module_refs.remove(&key);
                        }
                    }
                }
            }
            dense => self.used_bytes -= dense.resident_bytes(),
        }
    }

    /// Evict the least-recently-used entry, returning whether one existed.
    fn evict_lru(&mut self) -> bool {
        let Some(lru) = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone())
        else {
            return false;
        };
        if let Some(e) = self.entries.remove(&lru) {
            self.release(&e.weights);
            self.dense_equiv_bytes -= e.dense_equiv;
            self.stats.evictions += 1;
        }
        true
    }
}

/// Thread-safe LRU variant cache with single-flight cold loads.
pub struct VariantCache {
    store: VariantStore,
    budget_bytes: u64,
    inner: Mutex<Inner>,
    loaded_cv: std::sync::Condvar,
}

impl VariantCache {
    pub fn new(store: VariantStore, budget_bytes: u64) -> VariantCache {
        VariantCache {
            store,
            budget_bytes,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                loading: std::collections::HashSet::new(),
                module_refs: HashMap::new(),
                clock: 0,
                used_bytes: 0,
                dense_equiv_bytes: 0,
                stats: CacheStats::default(),
            }),
            loaded_cv: std::sync::Condvar::new(),
        }
    }

    pub fn base(&self) -> Arc<FlatParams> {
        self.store.base.clone()
    }

    /// The store (and through it the registry) this cache loads from.
    pub fn store(&self) -> &VariantStore {
        &self.store
    }

    /// Fetch a variant by alias (or explicit `name@N`), loading on miss.
    /// Returns the weights and the cold-start duration if this call
    /// performed the load. The alias is resolved to a concrete version
    /// *once*, up front: that exact version is keyed, loaded and returned
    /// even if a publish flips the alias mid-load.
    pub fn get(&self, name: &str) -> Result<(VariantWeights, Option<Duration>)> {
        let resolved = self.store.registry().resolve(name)?;
        let key: Key = (resolved.name.clone(), resolved.version);
        // Fast path under the lock; on a cold miss, claim the single-flight
        // slot (or wait for whoever holds it).
        {
            let mut inner = self.inner.lock().unwrap();
            loop {
                inner.clock += 1;
                let clock = inner.clock;
                let hit = if let Some(e) = inner.entries.get_mut(&key) {
                    e.last_used = clock;
                    Some(e.weights.clone())
                } else {
                    None
                };
                if let Some(weights) = hit {
                    inner.stats.hits += 1;
                    return Ok((weights, None));
                }
                if inner.loading.insert(key.clone()) {
                    inner.stats.misses += 1;
                    break; // we own the load
                }
                // Someone else is loading this version: wait, then re-check.
                inner = self.loaded_cv.wait(inner).unwrap();
            }
        }
        // For a patch version, pass the resident direct parent (if any) as a
        // composition hint: the store then reads only the patch file and
        // inherits every unchanged module's Arc — the warm-publish path.
        let parent_hint: Option<Arc<DeltaModel>> = if resolved.patch {
            resolved.parent.and_then(|pv| self.resident_delta(&resolved.name, pv))
        } else {
            None
        };
        // Load outside the lock (the expensive part). Ensure the loading
        // claim is released even on error.
        let loaded: Result<LoadedVariant> =
            self.store.load_resolved_hinted(&resolved, parent_hint);
        let loaded: LoadedVariant = match loaded {
            Ok(l) => l,
            Err(e) => {
                let mut inner = self.inner.lock().unwrap();
                inner.loading.remove(&key);
                drop(inner);
                self.loaded_cv.notify_all();
                return Err(e);
            }
        };
        let bytes = loaded.weights.resident_bytes();
        let dense_equiv = loaded.weights.dense_equiv_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        inner.stats.cold_start.push(loaded.load_time);
        // Evict LRU until the new entry's *marginal* charge fits — modules
        // shared with resident versions cost nothing extra, but evictions
        // can strip sharers away, so the preview is recomputed per round.
        loop {
            let marginal = inner.preview_charge(&loaded.weights);
            if inner.used_bytes + marginal <= self.budget_bytes || inner.entries.is_empty() {
                break;
            }
            if !inner.evict_lru() {
                break;
            }
        }
        inner.charge(&loaded.weights);
        inner.dense_equiv_bytes += dense_equiv;
        inner.entries.insert(
            key.clone(),
            Entry { weights: loaded.weights.clone(), bytes, dense_equiv, last_used: clock },
        );
        inner.loading.remove(&key);
        drop(inner);
        self.loaded_cv.notify_all();
        Ok((loaded.weights, Some(loaded.load_time)))
    }

    /// Multi-get for a batch window: resolve and pin every name, returning
    /// one entry per input (in order). Each `Ok` holds its own
    /// [`VariantWeights`] clone, so the whole working set stays executable
    /// for the batch even if the LRU evicts underneath; duplicate names
    /// coalesce via the single-flight guard in [`get`](Self::get).
    /// Per-name failures are per-entry — one unknown variant never fails
    /// the rest of the window.
    ///
    /// Multi-name windows fetch concurrently (scoped threads), so a window
    /// touching K cold variants pays ~one artifact load time, not the sum
    /// of K; single-name windows skip the spawn overhead.
    pub fn get_many(&self, names: &[String]) -> Vec<Result<(VariantWeights, Option<Duration>)>> {
        if names.len() <= 1 {
            return names.iter().map(|n| self.get(n)).collect();
        }
        let mut out: Vec<Option<Result<(VariantWeights, Option<Duration>)>>> =
            names.iter().map(|_| None).collect();
        std::thread::scope(|s| {
            for (slot, name) in out.iter_mut().zip(names) {
                s.spawn(move || *slot = Some(self.get(name)));
            }
        });
        out.into_iter().map(|o| o.expect("scoped fetch completed")).collect()
    }

    /// The resident *packed* delta of `(variant, version)`, if any — the
    /// chain-composition hint: `get` passes the resident direct parent to
    /// the store so warming a patch version reads only the patch file, and
    /// the replicator passes it to patch verification so a steady-state
    /// sync does not re-read the parent chain from disk.
    pub fn resident_delta(&self, variant: &str, version: u32) -> Option<Arc<DeltaModel>> {
        let inner = self.inner.lock().unwrap();
        inner.entries.get(&(variant.to_string(), version)).and_then(|e| match &e.weights {
            VariantWeights::Packed(p) => Some(p.delta().clone()),
            VariantWeights::Dense(..) => None,
        })
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Resident `(variant, version)` keys, sorted.
    pub fn resident(&self) -> Vec<(String, u32)> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<_> = inner.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Distinct resident variant names (any version), sorted.
    pub fn resident_names(&self) -> Vec<String> {
        let mut v = self.resident().into_iter().map(|(n, _)| n).collect::<Vec<_>>();
        v.dedup();
        v
    }

    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used_bytes
    }

    /// Residency totals only (`per_version` left empty) — O(1), safe on the
    /// worker hot path. The full breakdown comes from [`residency`](Self::residency),
    /// which the stats endpoint calls on demand.
    pub fn residency_totals(&self) -> Residency {
        let inner = self.inner.lock().unwrap();
        Residency {
            variants: inner.entries.len(),
            resident_bytes: inner.used_bytes,
            dense_equiv_bytes: inner.dense_equiv_bytes,
            per_version: Vec::new(),
        }
    }

    /// Current residency gauges. Totals are O(1) (maintained incrementally);
    /// the per-version breakdown is O(resident entries).
    pub fn residency(&self) -> Residency {
        let inner = self.inner.lock().unwrap();
        let mut per_version: Vec<VersionResidency> = inner
            .entries
            .iter()
            .map(|((name, version), e)| VersionResidency {
                variant: name.clone(),
                version: *version,
                bytes: e.bytes,
            })
            .collect();
        per_version.sort_by(|a, b| (&a.variant, a.version).cmp(&(&b.variant, b.version)));
        Residency {
            variants: inner.entries.len(),
            resident_bytes: inner.used_bytes,
            dense_equiv_bytes: inner.dense_equiv_bytes,
            per_version,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::compress::{compress_model, CompressOptions, FitMode};
    use crate::delta::format::save_delta;
    use crate::exec::{ExecMode, Weights};
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};
    use std::path::Path;

    fn setup(dir: &Path, n_variants: usize) -> VariantStore {
        let _ = std::fs::remove_dir_all(dir);
        std::fs::create_dir_all(dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 1);
        let docs: Vec<Vec<u8>> = (0..2).map(|i| vec![(i + 9) as u8; 20]).collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        for k in 0..n_variants {
            let ft = synth_finetune(
                &base,
                &SynthDeltaSpec { seed: 100 + k as u64, ..Default::default() },
            );
            let (delta, _, _) = compress_model(&format!("v{k}"), &base, &ft, &docs, &opts);
            save_delta(dir.join(format!("v{k}.pawd")), &delta).unwrap();
        }
        VariantStore::new(Arc::new(base), dir)
    }

    #[test]
    fn hit_after_miss() {
        let dir = std::env::temp_dir().join("pawd_test_cache1");
        let store = setup(&dir, 2);
        let cache = VariantCache::new(store, u64::MAX);
        let (_, cold) = cache.get("v0").unwrap();
        assert!(cold.is_some());
        let (_, cold2) = cache.get("v0").unwrap();
        assert!(cold2.is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2 - 1));
    }

    #[test]
    fn budget_evicts_lru() {
        let dir = std::env::temp_dir().join("pawd_test_cache2");
        let store = setup(&dir, 3); // dense mode: entries cost full params
        let one = (ModelConfig::preset("tiny").unwrap().n_params() * 4) as u64;
        let cache = VariantCache::new(store, one * 2 + 1024); // fits 2 variants
        cache.get("v0").unwrap();
        cache.get("v1").unwrap();
        cache.get("v0").unwrap(); // refresh v0 -> v1 becomes LRU
        cache.get("v2").unwrap(); // must evict v1
        let resident = cache.resident_names();
        assert!(resident.contains(&"v0".to_string()));
        assert!(resident.contains(&"v2".to_string()));
        assert!(!resident.contains(&"v1".to_string()));
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.used_bytes() <= one * 2 + 1024);
    }

    #[test]
    fn packed_mode_multiplies_residency_under_same_budget() {
        let dir = std::env::temp_dir().join("pawd_test_cache4");
        let store = setup(&dir, 4).with_mode(ExecMode::Fused);
        // A budget that fits exactly ONE dense variant holds the whole
        // packed fleet with room to spare.
        let one_dense = (ModelConfig::preset("tiny").unwrap().n_params() * 4) as u64;
        let cache = VariantCache::new(store, one_dense);
        for k in 0..4 {
            let (w, _) = cache.get(&format!("v{k}")).unwrap();
            assert!(w.is_packed());
        }
        assert_eq!(cache.resident().len(), 4);
        assert_eq!(cache.stats().evictions, 0);
        let r = cache.residency();
        assert_eq!(r.variants, 4);
        assert!(r.resident_bytes <= one_dense);
        // Dense-equivalent accounting shows the capacity multiplier.
        assert_eq!(r.dense_equiv_bytes, one_dense * 4);
        assert!(
            r.dense_equiv_bytes / r.resident_bytes.max(1) >= 8,
            "expected ≥8x residency multiplier, got {}x",
            r.dense_equiv_bytes / r.resident_bytes.max(1)
        );
        // Per-version breakdown: all version 1, bytes sum to the total.
        assert_eq!(r.per_version.len(), 4);
        assert!(r.per_version.iter().all(|e| e.version == 1));
        assert_eq!(r.per_version.iter().map(|e| e.bytes).sum::<u64>(), r.resident_bytes);
    }

    #[test]
    fn publish_keys_a_fresh_version_and_old_one_ages_out() {
        let dir = std::env::temp_dir().join("pawd_test_cache5");
        let store = setup(&dir, 1).with_mode(ExecMode::Fused);
        let registry = store.registry().clone();
        let cache = VariantCache::new(store, u64::MAX);
        let (w1, _) = cache.get("v0").unwrap();
        assert_eq!(w1.version(), 1);
        // Publish v2: the alias now misses into a new key; the old entry
        // stays addressable as v0@1 (and still serves the clone w1 holds).
        let m = crate::delta::format::load_delta(dir.join("v0.pawd")).unwrap();
        assert_eq!(registry.publish("v0", m).unwrap(), 2);
        let (w2, cold) = cache.get("v0").unwrap();
        assert!(cold.is_some(), "new version must cold-load");
        assert_eq!(w2.version(), 2);
        assert_eq!(w1.version(), 1, "in-flight clone keeps executing the old version");
        assert_eq!(cache.resident(), vec![("v0".into(), 1), ("v0".into(), 2)]);
        // Rollback: the alias points at v1 again — a pure cache hit.
        registry.rollback("v0", None).unwrap();
        let (w1b, cold) = cache.get("v0").unwrap();
        assert!(cold.is_none(), "rollback target was still resident");
        assert_eq!(w1b.version(), 1);
    }

    #[test]
    fn get_many_pins_the_working_set_and_reports_per_name_errors() {
        let dir = std::env::temp_dir().join("pawd_test_cache6");
        let store = setup(&dir, 2).with_mode(ExecMode::Fused);
        let cache = VariantCache::new(store, u64::MAX);
        let names: Vec<String> =
            vec!["v0".into(), "ghost".into(), "v1".into(), "v0".into()];
        let got = cache.get_many(&names);
        assert_eq!(got.len(), 4);
        assert!(got[0].is_ok() && got[2].is_ok());
        assert!(got[1].is_err(), "unknown variant fails alone, not the batch");
        // The duplicate resolves to the same resident entry (a hit).
        let (w0, cold0) = got[0].as_ref().unwrap();
        let (w3, cold3) = got[3].as_ref().unwrap();
        assert!(cold0.is_some() && cold3.is_none());
        assert_eq!(w0.version(), w3.version());
        // Both variants resident after one multi-get.
        assert_eq!(cache.resident_names(), vec!["v0".to_string(), "v1".to_string()]);
    }

    #[test]
    fn patch_versions_share_module_arcs_and_charge_the_budget_once() {
        let dir = std::env::temp_dir().join("pawd_test_cache7");
        let store = setup(&dir, 1).with_mode(ExecMode::Fused);
        let registry = store.registry().clone();
        let cache = VariantCache::new(store, u64::MAX);
        let (w1, _) = cache.get("v0").unwrap();
        // Publish v2 as a patch: one module's scales doubled (f16-exact).
        let mut v2 = registry.effective_model("v0", 1).unwrap();
        {
            let m = std::sync::Arc::make_mut(&mut v2.modules[0]);
            for s in &mut m.scales {
                *s *= 2.0;
            }
        }
        let out = registry.publish_incremental("v0", v2, None).unwrap();
        assert!(out.patch);
        let used_before = cache.used_bytes();
        let (w2, cold) = cache.get("v0").unwrap();
        assert!(cold.is_some());
        assert_eq!(w2.version(), out.version);
        // The new entry inherited the parent's module Arcs for everything
        // unchanged, so the *marginal* budget charge is just the changed
        // module — not another full packed variant.
        let (a, b) = match (&w1, &w2) {
            (VariantWeights::Packed(a), VariantWeights::Packed(b)) => (a, b),
            _ => panic!("expected packed entries"),
        };
        let shared = b
            .module_arcs()
            .iter()
            .filter(|m| a.module_arcs().iter().any(|p| std::sync::Arc::ptr_eq(p, m)))
            .count();
        assert_eq!(shared, b.module_arcs().len() - 1, "all but the changed module shared");
        let changed_bytes: u64 = b
            .module_arcs()
            .iter()
            .filter(|m| !a.module_arcs().iter().any(|p| std::sync::Arc::ptr_eq(p, m)))
            .map(|m| m.resident_bytes())
            .sum();
        assert_eq!(
            cache.used_bytes() - used_before,
            changed_bytes,
            "marginal charge must be the changed module only"
        );
        // Standalone per-version bytes now sum to more than the shared
        // budget charge — the sharing is visible in the residency gauges.
        let r = cache.residency();
        assert_eq!(r.variants, 2);
        assert!(r.per_version.iter().map(|e| e.bytes).sum::<u64>() > r.resident_bytes);
    }

    #[test]
    fn get_many_keeps_window_working_set_executable_beyond_the_budget() {
        // Satellite invariant: a batch window's pinned working set must
        // stay executable for the whole batch even when it exceeds the soft
        // byte budget — eviction may drop entries from the *cache*, but
        // every `Ok` the window holds keeps its own `VariantWeights` clone.
        let dir = std::env::temp_dir().join("pawd_test_cache8");
        let store = setup(&dir, 3).with_mode(ExecMode::Fused);
        let one_packed = store.load("v0").unwrap().weights.resident_bytes();
        // Budget fits one variant (plus slack), window needs three.
        let cache = VariantCache::new(store, one_packed + one_packed / 2);
        let names: Vec<String> = vec!["v0".into(), "v1".into(), "v2".into()];
        let got = cache.get_many(&names);
        assert_eq!(got.len(), 3);
        for (name, res) in names.iter().zip(&got) {
            let (w, _) = res.as_ref().unwrap_or_else(|e| panic!("{name} failed: {e}"));
            assert!(w.is_packed());
            assert_eq!(w.version(), 1);
            assert!(!w.flat().data.is_empty(), "{name} must stay executable");
        }
        let s = cache.stats();
        assert!(s.evictions >= 1, "the budget must actually have been under pressure");
        assert!(
            cache.resident().len() < 3,
            "the cache itself respects the budget after the window"
        );
        // The cache stays usable afterwards: a re-get of an evicted variant
        // cold-loads cleanly.
        for name in &names {
            assert!(cache.get(name).is_ok());
        }
    }

    #[test]
    fn concurrent_gets_are_consistent() {
        let dir = std::env::temp_dir().join("pawd_test_cache3");
        let store = setup(&dir, 2);
        let cache = std::sync::Arc::new(VariantCache::new(store, u64::MAX));
        std::thread::scope(|s| {
            for t in 0..8 {
                let c = cache.clone();
                s.spawn(move || {
                    for i in 0..20 {
                        let name = if (t + i) % 2 == 0 { "v0" } else { "v1" };
                        let (w, _) = c.get(name).unwrap();
                        assert!(!w.flat().data.is_empty());
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 8 * 20);
    }
}
