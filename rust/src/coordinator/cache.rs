//! LRU cache of resident variant **versions** under a byte budget.
//!
//! Serving many fine-tuned variants of one base means most variants are
//! cold most of the time; the cache keeps the hot set resident and charges
//! cold loads to the hot-swap loader (whose latency the paper's §3.2
//! load-time experiment measures).
//!
//! Entries are keyed by `(variant, version)`: a `get("name")` first resolves
//! the alias through the registry, so publishing version `N+1` simply makes
//! new requests miss into a fresh key — the publish *warms* `N+1` while `N`
//! ages out of the LRU under the byte budget, and in-flight requests keep
//! executing the `Arc` of `N` they already hold. Rollback is the same
//! mechanism in reverse (and usually a pure cache hit, since `N` is often
//! still resident).
//!
//! Residency accounting follows the store's [`ExecMode`](crate::exec::ExecMode): a dense entry
//! charges the full materialized parameter bytes, a packed entry charges
//! only its mask + scale bytes (the shared base is owned by the store and
//! charged to nobody). Under a fixed budget this multiplies the number of
//! resident versions by the compression ratio, and a hot swap is an `Arc`
//! clone — no materialize/revert pass ever runs on the request path.

use super::store::{LoadedVariant, VariantStore};
use crate::exec::VariantWeights;
use crate::model::FlatParams;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Cold-start (load/validate, or materialization in dense mode) times
    /// observed on misses.
    pub cold_start: Vec<Duration>,
}

/// Residency of one cached `(variant, version)` entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VersionResidency {
    pub variant: String,
    pub version: u32,
    /// Bytes charged against the budget for this entry.
    pub bytes: u64,
}

/// Point-in-time residency gauges (the satellite metrics surfaced through
/// `Metrics::snapshot` and the server's stats responses).
#[derive(Clone, Debug, Default)]
pub struct Residency {
    /// Number of variant versions currently resident.
    pub variants: usize,
    /// Bytes actually charged against the budget (packed bytes for fused
    /// entries, dense bytes otherwise).
    pub resident_bytes: u64,
    /// What the same resident set would cost fully materialized.
    pub dense_equiv_bytes: u64,
    /// Per-entry breakdown, sorted by (variant, version).
    pub per_version: Vec<VersionResidency>,
}

struct Entry {
    weights: VariantWeights,
    bytes: u64,
    dense_equiv: u64,
    /// Monotone counter for LRU ordering.
    last_used: u64,
}

type Key = (String, u32);

struct Inner {
    entries: HashMap<Key, Entry>,
    /// Versions currently being loaded by some thread (single-flight guard:
    /// concurrent requests for the same cold version wait instead of
    /// duplicating the load).
    loading: std::collections::HashSet<Key>,
    clock: u64,
    used_bytes: u64,
    /// Running dense-equivalent total for the resident set, maintained
    /// incrementally alongside `used_bytes` so the totals are O(1) (they
    /// run on the worker hot path).
    dense_equiv_bytes: u64,
    stats: CacheStats,
}

/// Thread-safe LRU variant cache with single-flight cold loads.
pub struct VariantCache {
    store: VariantStore,
    budget_bytes: u64,
    inner: Mutex<Inner>,
    loaded_cv: std::sync::Condvar,
}

impl VariantCache {
    pub fn new(store: VariantStore, budget_bytes: u64) -> VariantCache {
        VariantCache {
            store,
            budget_bytes,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                loading: std::collections::HashSet::new(),
                clock: 0,
                used_bytes: 0,
                dense_equiv_bytes: 0,
                stats: CacheStats::default(),
            }),
            loaded_cv: std::sync::Condvar::new(),
        }
    }

    pub fn base(&self) -> Arc<FlatParams> {
        self.store.base.clone()
    }

    /// The store (and through it the registry) this cache loads from.
    pub fn store(&self) -> &VariantStore {
        &self.store
    }

    /// Fetch a variant by alias (or explicit `name@N`), loading on miss.
    /// Returns the weights and the cold-start duration if this call
    /// performed the load. The alias is resolved to a concrete version
    /// *once*, up front: that exact version is keyed, loaded and returned
    /// even if a publish flips the alias mid-load.
    pub fn get(&self, name: &str) -> Result<(VariantWeights, Option<Duration>)> {
        let resolved = self.store.registry().resolve(name)?;
        let key: Key = (resolved.name.clone(), resolved.version);
        // Fast path under the lock; on a cold miss, claim the single-flight
        // slot (or wait for whoever holds it).
        {
            let mut inner = self.inner.lock().unwrap();
            loop {
                inner.clock += 1;
                let clock = inner.clock;
                let hit = if let Some(e) = inner.entries.get_mut(&key) {
                    e.last_used = clock;
                    Some(e.weights.clone())
                } else {
                    None
                };
                if let Some(weights) = hit {
                    inner.stats.hits += 1;
                    return Ok((weights, None));
                }
                if inner.loading.insert(key.clone()) {
                    inner.stats.misses += 1;
                    break; // we own the load
                }
                // Someone else is loading this version: wait, then re-check.
                inner = self.loaded_cv.wait(inner).unwrap();
            }
        }
        // Load outside the lock (the expensive part). Ensure the loading
        // claim is released even on error.
        let loaded: Result<LoadedVariant> = self.store.load_resolved(&resolved);
        let loaded: LoadedVariant = match loaded {
            Ok(l) => l,
            Err(e) => {
                let mut inner = self.inner.lock().unwrap();
                inner.loading.remove(&key);
                drop(inner);
                self.loaded_cv.notify_all();
                return Err(e);
            }
        };
        let bytes = loaded.weights.resident_bytes();
        let dense_equiv = loaded.weights.dense_equiv_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        inner.stats.cold_start.push(loaded.load_time);
        // Evict LRU until the new entry fits.
        while inner.used_bytes + bytes > self.budget_bytes && !inner.entries.is_empty() {
            let lru = inner
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .unwrap();
            if let Some(e) = inner.entries.remove(&lru) {
                inner.used_bytes -= e.bytes;
                inner.dense_equiv_bytes -= e.dense_equiv;
                inner.stats.evictions += 1;
            }
        }
        inner.used_bytes += bytes;
        inner.dense_equiv_bytes += dense_equiv;
        inner.entries.insert(
            key.clone(),
            Entry { weights: loaded.weights.clone(), bytes, dense_equiv, last_used: clock },
        );
        inner.loading.remove(&key);
        drop(inner);
        self.loaded_cv.notify_all();
        Ok((loaded.weights, Some(loaded.load_time)))
    }

    /// Multi-get for a batch window: resolve and pin every name, returning
    /// one entry per input (in order). Each `Ok` holds its own
    /// [`VariantWeights`] clone, so the whole working set stays executable
    /// for the batch even if the LRU evicts underneath; duplicate names
    /// coalesce via the single-flight guard in [`get`](Self::get).
    /// Per-name failures are per-entry — one unknown variant never fails
    /// the rest of the window.
    ///
    /// Multi-name windows fetch concurrently (scoped threads), so a window
    /// touching K cold variants pays ~one artifact load time, not the sum
    /// of K; single-name windows skip the spawn overhead.
    pub fn get_many(&self, names: &[String]) -> Vec<Result<(VariantWeights, Option<Duration>)>> {
        if names.len() <= 1 {
            return names.iter().map(|n| self.get(n)).collect();
        }
        let mut out: Vec<Option<Result<(VariantWeights, Option<Duration>)>>> =
            names.iter().map(|_| None).collect();
        std::thread::scope(|s| {
            for (slot, name) in out.iter_mut().zip(names) {
                s.spawn(move || *slot = Some(self.get(name)));
            }
        });
        out.into_iter().map(|o| o.expect("scoped fetch completed")).collect()
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Resident `(variant, version)` keys, sorted.
    pub fn resident(&self) -> Vec<(String, u32)> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<_> = inner.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Distinct resident variant names (any version), sorted.
    pub fn resident_names(&self) -> Vec<String> {
        let mut v = self.resident().into_iter().map(|(n, _)| n).collect::<Vec<_>>();
        v.dedup();
        v
    }

    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used_bytes
    }

    /// Residency totals only (`per_version` left empty) — O(1), safe on the
    /// worker hot path. The full breakdown comes from [`residency`](Self::residency),
    /// which the stats endpoint calls on demand.
    pub fn residency_totals(&self) -> Residency {
        let inner = self.inner.lock().unwrap();
        Residency {
            variants: inner.entries.len(),
            resident_bytes: inner.used_bytes,
            dense_equiv_bytes: inner.dense_equiv_bytes,
            per_version: Vec::new(),
        }
    }

    /// Current residency gauges. Totals are O(1) (maintained incrementally);
    /// the per-version breakdown is O(resident entries).
    pub fn residency(&self) -> Residency {
        let inner = self.inner.lock().unwrap();
        let mut per_version: Vec<VersionResidency> = inner
            .entries
            .iter()
            .map(|((name, version), e)| VersionResidency {
                variant: name.clone(),
                version: *version,
                bytes: e.bytes,
            })
            .collect();
        per_version.sort_by(|a, b| (&a.variant, a.version).cmp(&(&b.variant, b.version)));
        Residency {
            variants: inner.entries.len(),
            resident_bytes: inner.used_bytes,
            dense_equiv_bytes: inner.dense_equiv_bytes,
            per_version,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::compress::{compress_model, CompressOptions, FitMode};
    use crate::delta::format::save_delta;
    use crate::exec::{ExecMode, Weights};
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};
    use std::path::Path;

    fn setup(dir: &Path, n_variants: usize) -> VariantStore {
        let _ = std::fs::remove_dir_all(dir);
        std::fs::create_dir_all(dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 1);
        let docs: Vec<Vec<u8>> = (0..2).map(|i| vec![(i + 9) as u8; 20]).collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        for k in 0..n_variants {
            let ft = synth_finetune(
                &base,
                &SynthDeltaSpec { seed: 100 + k as u64, ..Default::default() },
            );
            let (delta, _, _) = compress_model(&format!("v{k}"), &base, &ft, &docs, &opts);
            save_delta(dir.join(format!("v{k}.pawd")), &delta).unwrap();
        }
        VariantStore::new(Arc::new(base), dir)
    }

    #[test]
    fn hit_after_miss() {
        let dir = std::env::temp_dir().join("pawd_test_cache1");
        let store = setup(&dir, 2);
        let cache = VariantCache::new(store, u64::MAX);
        let (_, cold) = cache.get("v0").unwrap();
        assert!(cold.is_some());
        let (_, cold2) = cache.get("v0").unwrap();
        assert!(cold2.is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2 - 1));
    }

    #[test]
    fn budget_evicts_lru() {
        let dir = std::env::temp_dir().join("pawd_test_cache2");
        let store = setup(&dir, 3); // dense mode: entries cost full params
        let one = (ModelConfig::preset("tiny").unwrap().n_params() * 4) as u64;
        let cache = VariantCache::new(store, one * 2 + 1024); // fits 2 variants
        cache.get("v0").unwrap();
        cache.get("v1").unwrap();
        cache.get("v0").unwrap(); // refresh v0 -> v1 becomes LRU
        cache.get("v2").unwrap(); // must evict v1
        let resident = cache.resident_names();
        assert!(resident.contains(&"v0".to_string()));
        assert!(resident.contains(&"v2".to_string()));
        assert!(!resident.contains(&"v1".to_string()));
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.used_bytes() <= one * 2 + 1024);
    }

    #[test]
    fn packed_mode_multiplies_residency_under_same_budget() {
        let dir = std::env::temp_dir().join("pawd_test_cache4");
        let store = setup(&dir, 4).with_mode(ExecMode::Fused);
        // A budget that fits exactly ONE dense variant holds the whole
        // packed fleet with room to spare.
        let one_dense = (ModelConfig::preset("tiny").unwrap().n_params() * 4) as u64;
        let cache = VariantCache::new(store, one_dense);
        for k in 0..4 {
            let (w, _) = cache.get(&format!("v{k}")).unwrap();
            assert!(w.is_packed());
        }
        assert_eq!(cache.resident().len(), 4);
        assert_eq!(cache.stats().evictions, 0);
        let r = cache.residency();
        assert_eq!(r.variants, 4);
        assert!(r.resident_bytes <= one_dense);
        // Dense-equivalent accounting shows the capacity multiplier.
        assert_eq!(r.dense_equiv_bytes, one_dense * 4);
        assert!(
            r.dense_equiv_bytes / r.resident_bytes.max(1) >= 8,
            "expected ≥8x residency multiplier, got {}x",
            r.dense_equiv_bytes / r.resident_bytes.max(1)
        );
        // Per-version breakdown: all version 1, bytes sum to the total.
        assert_eq!(r.per_version.len(), 4);
        assert!(r.per_version.iter().all(|e| e.version == 1));
        assert_eq!(r.per_version.iter().map(|e| e.bytes).sum::<u64>(), r.resident_bytes);
    }

    #[test]
    fn publish_keys_a_fresh_version_and_old_one_ages_out() {
        let dir = std::env::temp_dir().join("pawd_test_cache5");
        let store = setup(&dir, 1).with_mode(ExecMode::Fused);
        let registry = store.registry().clone();
        let cache = VariantCache::new(store, u64::MAX);
        let (w1, _) = cache.get("v0").unwrap();
        assert_eq!(w1.version(), 1);
        // Publish v2: the alias now misses into a new key; the old entry
        // stays addressable as v0@1 (and still serves the clone w1 holds).
        let m = crate::delta::format::load_delta(dir.join("v0.pawd")).unwrap();
        assert_eq!(registry.publish("v0", m).unwrap(), 2);
        let (w2, cold) = cache.get("v0").unwrap();
        assert!(cold.is_some(), "new version must cold-load");
        assert_eq!(w2.version(), 2);
        assert_eq!(w1.version(), 1, "in-flight clone keeps executing the old version");
        assert_eq!(cache.resident(), vec![("v0".into(), 1), ("v0".into(), 2)]);
        // Rollback: the alias points at v1 again — a pure cache hit.
        registry.rollback("v0", None).unwrap();
        let (w1b, cold) = cache.get("v0").unwrap();
        assert!(cold.is_none(), "rollback target was still resident");
        assert_eq!(w1b.version(), 1);
    }

    #[test]
    fn get_many_pins_the_working_set_and_reports_per_name_errors() {
        let dir = std::env::temp_dir().join("pawd_test_cache6");
        let store = setup(&dir, 2).with_mode(ExecMode::Fused);
        let cache = VariantCache::new(store, u64::MAX);
        let names: Vec<String> =
            vec!["v0".into(), "ghost".into(), "v1".into(), "v0".into()];
        let got = cache.get_many(&names);
        assert_eq!(got.len(), 4);
        assert!(got[0].is_ok() && got[2].is_ok());
        assert!(got[1].is_err(), "unknown variant fails alone, not the batch");
        // The duplicate resolves to the same resident entry (a hit).
        let (w0, cold0) = got[0].as_ref().unwrap();
        let (w3, cold3) = got[3].as_ref().unwrap();
        assert!(cold0.is_some() && cold3.is_none());
        assert_eq!(w0.version(), w3.version());
        // Both variants resident after one multi-get.
        assert_eq!(cache.resident_names(), vec!["v0".to_string(), "v1".to_string()]);
    }

    #[test]
    fn concurrent_gets_are_consistent() {
        let dir = std::env::temp_dir().join("pawd_test_cache3");
        let store = setup(&dir, 2);
        let cache = std::sync::Arc::new(VariantCache::new(store, u64::MAX));
        std::thread::scope(|s| {
            for t in 0..8 {
                let c = cache.clone();
                s.spawn(move || {
                    for i in 0..20 {
                        let name = if (t + i) % 2 == 0 { "v0" } else { "v1" };
                        let (w, _) = c.get(name).unwrap();
                        assert!(!w.flat().data.is_empty());
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 8 * 20);
    }
}
