//! The serving coordinator: ingress → continuous-batching engine → worker
//! engines over the LRU variant cache — plus the **admin lane**, which
//! answers control-plane operations (stats, publish, rollback, pin,
//! retire, gc, list) without touching an engine.
//!
//! Thread topology (no async runtime available offline; this is plain
//! threads + channels, which for a CPU-bound engine is also the faster
//! choice):
//!
//! ```text
//! clients --mpsc--> engine loop ----work queue----> worker 0..N-1
//!                    (steps on every arrival /       (cache multi-get,
//!                     abort / worker StepDone;        BatchPlan per shared
//!                     FAIR-SHARE round-robin          base: ONE base GEMM
//!                     admission onto idle slots,      per module per window;
//!                     no deadline waits; admin        admin ops -> registry;
//!                     ops take the fast lane)         StepDone -> engine)
//! ```
//!
//! **Continuous batching.** The [`engine`](super::engine) loop admits a
//! fair-share window onto every idle worker slot the moment one exists:
//! concurrent data requests — whatever variant they target — coalesce into
//! mixed windows while all workers are busy, and a lone request on an idle
//! host dispatches immediately — there is no dispatch deadline to wait
//! out. A worker pins every `(variant, version)` the window
//! needs with one cache multi-get, groups the window by shared base storage
//! into [`BatchPlan`]s, and runs each plan as ONE stacked forward: the base
//! GEMM executes once per module for the whole window and each variant pays
//! only its packed mask reduction on its own rows. Within a window the
//! compute layer fans out across the intra-host pool
//! ([`exec::pool`](crate::exec::pool), width `n_compute_threads`).
//!
//! **Fair share.** Admission picks requests **round-robin across the
//! variants waiting** (per-variant FIFO within each), so a variant that
//! floods the ingress cannot fill whole windows and starve a cold
//! variant's single request: any variant waiting is guaranteed a slot in
//! the next admitted window as long as `max_batch` ≥ the number of
//! distinct variants waiting.
//!
//! Publishing through the admin lane is the live-update path: the registry
//! flips the alias atomically, the publishing worker warms the new version
//! into the cache, and data requests already holding the old version's `Arc`
//! finish undisturbed while the old entry ages out of the LRU. Because
//! admin items ride their own worker slot, a `publish_incremental` storm
//! overlaps with serving instead of stalling it.

use super::cache::VariantCache;
use super::engine::{engine_loop, Ingress, VariantGroup, WorkItem};
use super::metrics::Metrics;
use super::request::{
    AdminOp, AdminResp, DataOp, Payload, Request, RespBody, Response, Timing, ADMIN_VARIANT,
};
use super::store::VariantStore;
use crate::data::corpus::encode;
use crate::exec::{pool, prefix, BatchPlan, ExecMode, PrefixCache, VariantWeights};
use crate::model::Transformer;
use crate::runtime::RuntimeHandle;
use crate::tensor::ops::log_softmax_into;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Which engine executes forwards.
#[derive(Clone)]
pub enum Engine {
    /// Native Rust transformer (always available).
    Native,
    /// AOT artifacts through the PJRT runtime thread; `config` names the
    /// manifest config whose buckets to use.
    Xla { handle: RuntimeHandle, config: String },
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub n_workers: usize,
    pub cache_budget_bytes: u64,
    /// Byte budget of the cross-window prefix/activation cache (LRU of
    /// per-layer prefix K/V + logits, shared by every worker). Env
    /// `PAWD_PREFIX_CACHE` overrides it; `0` (either way) disables the
    /// cache and every window runs the cold stacked forward.
    pub prefix_cache_bytes: u64,
    /// Dense-vs-fused A/B switch: how delta variants are resident and
    /// executed. The XLA engine forces `Dense` (it consumes flat buffers).
    pub exec: ExecMode,
    /// Intra-host compute width each worker uses for the pooled GEMM /
    /// mask-reduction / attention fan-out. `0` = auto: the
    /// `PAWD_COMPUTE_THREADS` env override when set, else the machine
    /// parallelism. Results are bitwise-identical at any width.
    pub n_compute_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            n_workers: 2,
            cache_budget_bytes: 1 << 30,
            prefix_cache_bytes: 64 << 20,
            exec: ExecMode::Fused,
            n_compute_threads: 0,
        }
    }
}

pub struct Server {
    ingress: mpsc::Sender<Ingress>,
    next_id: Arc<AtomicU64>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<VariantCache>,
    /// The cross-window prefix/activation cache shared by every worker
    /// (public so tests and tools can inspect residency and stats).
    pub prefix: Arc<PrefixCache>,
    engine_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Cheap cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Ingress>,
    next_id: Arc<AtomicU64>,
}

impl Client {
    /// Submit without blocking; returns the response receiver.
    pub fn submit(&self, variant: &str, payload: Payload) -> mpsc::Receiver<Response> {
        self.submit_tracked(variant, payload).1
    }

    /// Submit without blocking, returning the request id alongside the
    /// response receiver so the caller can [`abort`](Self::abort) it while
    /// it is still waiting for admission.
    pub fn submit_tracked(
        &self,
        variant: &str,
        payload: Payload,
    ) -> (u64, mpsc::Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (req, rx) = Request::new(id, variant, payload);
        // If the server is gone the receiver errors on recv — fine.
        let _ = self.tx.send(Ingress::Req(req));
        (id, rx)
    }

    /// Abort a request by id. Best-effort: only requests still pending
    /// admission are dropped (they answer with an error response);
    /// admitted requests complete normally, and unknown ids are a no-op.
    pub fn abort(&self, id: u64) {
        let _ = self.tx.send(Ingress::Abort(id));
    }

    /// Blocking convenience: score choices on a variant.
    pub fn score(&self, variant: &str, prompt: &str, choices: &[String]) -> Response {
        let rx = self.submit(variant, Payload::score(prompt, choices));
        rx.recv().unwrap_or(Response {
            id: 0,
            variant: variant.into(),
            version: None,
            result: Err("server terminated".into()),
            timing: Timing::default(),
        })
    }

    /// Blocking convenience: run one control-plane operation.
    pub fn admin(&self, op: AdminOp) -> Result<AdminResp, String> {
        let rx = self.submit(ADMIN_VARIANT, Payload::Admin(op));
        match rx.recv() {
            Ok(resp) => match resp.result {
                Ok(RespBody::Admin(a)) => Ok(a),
                Ok(other) => Err(format!("unexpected admin response {other:?}")),
                Err(e) => Err(e),
            },
            Err(_) => Err("server terminated".into()),
        }
    }

    /// Blocking convenience: fetch server metrics + residency gauges
    /// through the request path (useful for remote/ops probes; in-process
    /// callers can also read `Server::metrics` directly).
    pub fn stats(&self) -> Result<super::metrics::MetricsSnapshot, String> {
        match self.admin(AdminOp::Stats)? {
            AdminResp::Stats { snapshot } => Ok(*snapshot),
            other => Err(format!("unexpected stats response {other:?}")),
        }
    }

    /// Publish `artifact` as the next full version of `variant`; returns
    /// the assigned version once the alias has flipped and the new version
    /// has been warmed into the cache.
    pub fn publish(&self, variant: &str, artifact: &Path) -> Result<u32, String> {
        match self.admin(AdminOp::Publish {
            variant: variant.to_string(),
            artifact: artifact.to_path_buf(),
        })? {
            AdminResp::Published { version, .. } => Ok(version),
            other => Err(format!("unexpected publish response {other:?}")),
        }
    }

    /// Publish the effective model in `artifact` incrementally: ship a
    /// patch with only the modules changed vs `parent` (default: active
    /// version) when possible. Returns `(version, shipped_as_patch,
    /// bytes_written)`.
    pub fn publish_incremental(
        &self,
        variant: &str,
        artifact: &Path,
        parent: Option<u32>,
    ) -> Result<(u32, bool, u64), String> {
        match self.admin(AdminOp::PublishIncremental {
            variant: variant.to_string(),
            artifact: artifact.to_path_buf(),
            parent,
        })? {
            AdminResp::Published { version, patch, bytes, .. } => Ok((version, patch, bytes)),
            other => Err(format!("unexpected publish response {other:?}")),
        }
    }

    /// Rebase the patch chain of `variant@version` (default: active) into a
    /// full artifact in place; returns the consolidated version.
    pub fn consolidate(&self, variant: &str, version: Option<u32>) -> Result<u32, String> {
        match self.admin(AdminOp::Consolidate { variant: variant.to_string(), version })? {
            AdminResp::Consolidated { version, .. } => Ok(version),
            other => Err(format!("unexpected consolidate response {other:?}")),
        }
    }

    /// Roll `variant` back to `to` (or its active version's parent);
    /// returns the version now active.
    pub fn rollback(&self, variant: &str, to: Option<u32>) -> Result<u32, String> {
        match self.admin(AdminOp::Rollback { variant: variant.to_string(), to })? {
            AdminResp::RolledBack { version, .. } => Ok(version),
            other => Err(format!("unexpected rollback response {other:?}")),
        }
    }

    /// Garbage-collect retired versions' artifact files (all variants, or
    /// just `variant`); returns `(files_removed, bytes_freed)`.
    pub fn gc(&self, variant: Option<&str>) -> Result<(usize, u64), String> {
        match self.admin(AdminOp::Gc { variant: variant.map(|s| s.to_string()) })? {
            AdminResp::Gced { files_removed, bytes_freed } => Ok((files_removed, bytes_freed)),
            other => Err(format!("unexpected gc response {other:?}")),
        }
    }

    /// List all variants with their version histories.
    pub fn variants(&self) -> Result<Vec<super::registry::VariantDesc>, String> {
        match self.admin(AdminOp::List)? {
            AdminResp::Variants { variants } => Ok(variants),
            other => Err(format!("unexpected list response {other:?}")),
        }
    }

    /// Local replication state: `(manifest_seq, variants, version records)`.
    pub fn sync_status(&self) -> Result<(u64, usize, usize), String> {
        match self.admin(AdminOp::SyncStatus)? {
            AdminResp::SyncStatus { manifest_seq, variants, versions } => {
                Ok((manifest_seq, variants, versions))
            }
            other => Err(format!("unexpected sync-status response {other:?}")),
        }
    }

    /// Pull-replicate once from a leader registry directory; synced
    /// versions are warmed into the cache before this returns.
    pub fn pull_from(&self, dir: &Path) -> Result<super::replicate::SyncReport, String> {
        match self.admin(AdminOp::PullFrom { dir: dir.to_path_buf() })? {
            AdminResp::Synced { report, .. } => Ok(report),
            other => Err(format!("unexpected pull response {other:?}")),
        }
    }
}

impl Server {
    pub fn start(mut store: VariantStore, engine: Engine, cfg: ServerConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        // The XLA engine executes flat parameter buffers, so it cannot run
        // packed variants; force dense residency there.
        store.set_mode(match &engine {
            Engine::Native => cfg.exec,
            Engine::Xla { .. } => ExecMode::Dense,
        });
        let cache = Arc::new(VariantCache::new(store, cfg.cache_budget_bytes));
        let prefix = Arc::new(PrefixCache::new(cfg.prefix_cache_bytes));
        let (ingress_tx, ingress_rx) = mpsc::channel::<Ingress>();
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let sync_seqs: Arc<SyncSeqs> = Arc::new(Mutex::new(HashMap::new()));
        let mut workers = Vec::new();
        for wid in 0..cfg.n_workers.max(1) {
            let work_rx = work_rx.clone();
            let cache = cache.clone();
            let metrics = metrics.clone();
            let engine = engine.clone();
            let sync_seqs = sync_seqs.clone();
            let notify = ingress_tx.clone();
            let n_compute = cfg.n_compute_threads;
            let prefix = prefix.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("pawd-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(
                            work_rx, cache, prefix, metrics, engine, sync_seqs, notify, n_compute,
                        )
                    })
                    .expect("spawn worker"),
            );
        }
        let ecfg = cfg.clone();
        let emetrics = metrics.clone();
        let engine_thread = std::thread::Builder::new()
            .name("pawd-engine".into())
            .spawn(move || engine_loop(ingress_rx, work_tx, ecfg, emetrics))
            .expect("spawn engine");

        Server {
            ingress: ingress_tx,
            next_id: Arc::new(AtomicU64::new(1)),
            metrics,
            cache,
            prefix,
            engine_thread: Some(engine_thread),
            workers,
        }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.ingress.clone(), next_id: self.next_id.clone() }
    }

    /// Graceful shutdown: signal the engine loop (live Client clones keep
    /// the channel open, so dropping our sender is not enough), drain,
    /// join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        drop(self.ingress);
        if let Some(e) = self.engine_thread.take() {
            let _ = e.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    work: Arc<Mutex<mpsc::Receiver<WorkItem>>>,
    cache: Arc<VariantCache>,
    prefix_cache: Arc<PrefixCache>,
    metrics: Arc<Metrics>,
    engine: Engine,
    sync_seqs: Arc<SyncSeqs>,
    notify: mpsc::Sender<Ingress>,
    n_compute_threads: usize,
) {
    // Apply the configured intra-host compute width to everything this
    // worker executes (0 = pool default).
    pool::set_thread_limit(n_compute_threads);
    // One Transformer per worker (RoPE tables etc.) for the native engine.
    let tf = Transformer::new(cache.base().cfg());
    // The `(variant, version)` set this worker's previous window executed;
    // entering a context that was not in it counts as a hot swap (with
    // packed residency that is an Arc flip, no materialize/revert pass).
    let mut last_set: Vec<(String, u32)> = Vec::new();
    loop {
        let item = {
            let rx = work.lock().unwrap();
            match rx.recv() {
                Ok(b) => b,
                Err(_) => return,
            }
        };
        let batch_start = Instant::now();
        match item {
            WorkItem::Admin(req) => {
                let result = match &req.payload {
                    Payload::Admin(op) => {
                        run_admin(op, &cache, &metrics, &sync_seqs).map(RespBody::Admin)
                    }
                    // Data ops can only land here via the reserved
                    // pseudo-variant names; reject them instead of
                    // answering with a surprise body.
                    Payload::Data(_) => Err(format!(
                        "variant name '{}' is reserved for control-plane probes",
                        req.variant
                    )),
                };
                let timing = Timing {
                    queue: batch_start.duration_since(req.submitted),
                    total: req.submitted.elapsed(),
                    ..Default::default()
                };
                let _ = req.resp.send(Response {
                    id: req.id,
                    variant: req.variant.clone(),
                    version: None,
                    result,
                    timing,
                });
            }
            WorkItem::Window(groups) => {
                run_window(
                    groups,
                    batch_start,
                    &tf,
                    &cache,
                    &prefix_cache,
                    &metrics,
                    &engine,
                    &mut last_set,
                );
            }
        }
        // Free this worker's slot so the engine can step again (ignore
        // send failure: the engine is gone during shutdown drain).
        let _ = notify.send(Ingress::StepDone);
    }
}

/// Execute one flushed batch window: pin every needed `(variant, version)`
/// with a cache multi-get, group the window into shared-base [`BatchPlan`]s,
/// and run each plan as one stacked forward (native engine) or fall back to
/// per-group scoring (XLA engine, which consumes flat buffers).
#[allow(clippy::too_many_arguments)]
fn run_window(
    groups: Vec<VariantGroup>,
    batch_start: Instant,
    tf: &Transformer,
    cache: &VariantCache,
    prefix_cache: &PrefixCache,
    metrics: &Metrics,
    engine: &Engine,
    last_set: &mut Vec<(String, u32)>,
) {
    // Pin the whole working set for the window in one multi-get: each group
    // holds its weights' Arc until the responses are out, so an eviction
    // mid-window never invalidates in-flight work.
    let names: Vec<String> = groups.iter().map(|g| g.variant.clone()).collect();
    let fetched = cache.get_many(&names);
    let mut loaded: Vec<(VariantGroup, VariantWeights, u32, Option<Duration>)> = Vec::new();
    for (group, res) in groups.into_iter().zip(fetched) {
        match res {
            Ok((weights, cold)) => {
                if let Some(c) = cold {
                    metrics.record_cold_start(c);
                }
                let version = weights.version();
                loaded.push((group, weights, version, cold));
            }
            Err(e) => {
                let msg = format!("variant load failed: {e}");
                for req in group.requests {
                    let timing = Timing {
                        queue: batch_start.duration_since(req.submitted),
                        total: req.submitted.elapsed(),
                        ..Default::default()
                    };
                    metrics.record_request(
                        &req.variant,
                        timing.queue,
                        Duration::ZERO,
                        timing.total,
                        true,
                    );
                    let _ = req.resp.send(Response {
                        id: req.id,
                        variant: req.variant.clone(),
                        version: None,
                        result: Err(msg.clone()),
                        timing,
                    });
                }
            }
        }
    }
    if loaded.is_empty() {
        return;
    }
    // Swap accounting under batching: executing a whole mixed window is one
    // shared-base pass, so a "swap" is entering a (variant, version)
    // context that was not part of this worker's previous window — not
    // every group-to-group transition inside the window (that would
    // inflate the counter under steady mixed traffic where nothing is
    // actually switched).
    let mut set: Vec<(String, u32)> =
        loaded.iter().map(|(g, _, v, _)| (g.variant.clone(), *v)).collect();
    set.sort();
    set.dedup();
    if !last_set.is_empty() {
        for key in &set {
            if !last_set.contains(key) {
                metrics.record_swap();
            }
        }
    }
    *last_set = set;
    // Per-window gauge update sticks to the O(1) totals; the per-version
    // breakdown is only materialized when a stats probe asks for it.
    metrics.set_residency(cache.residency_totals());
    let compute_start = Instant::now();
    // Results aligned with `loaded`: per group, per request.
    let results: Vec<Vec<Result<RespBody, String>>> = match engine {
        Engine::Native => {
            let weights: Vec<VariantWeights> =
                loaded.iter().map(|(_, w, _, _)| w.clone()).collect();
            let mut out: Vec<Vec<Option<Result<RespBody, String>>>> = loaded
                .iter()
                .map(|(g, ..)| (0..g.requests.len()).map(|_| None).collect())
                .collect();
            // Group by shared base: all packed variants of one store share
            // one plan (ONE base GEMM per module for their whole slice of
            // the window); dense variants plan per materialized Arc.
            for (plan, members) in BatchPlan::group(&weights) {
                let mut refs: Vec<(usize, usize, usize)> = Vec::new(); // (entry, group, req)
                for (entry, &gi) in members.iter().enumerate() {
                    for ri in 0..loaded[gi].0.requests.len() {
                        refs.push((entry, gi, ri));
                    }
                }
                let payloads: Vec<(usize, &Payload)> = refs
                    .iter()
                    .map(|&(entry, gi, ri)| (entry, &loaded[gi].0.requests[ri].payload))
                    .collect();
                let plan_results = score_plan_native(tf, &plan, prefix_cache, &payloads);
                for ((_, gi, ri), r) in refs.into_iter().zip(plan_results) {
                    out[gi][ri] = Some(r);
                }
            }
            out.into_iter().map(|g| g.into_iter().map(|o| o.unwrap()).collect()).collect()
        }
        Engine::Xla { handle, config } => loaded
            .iter()
            .map(|(g, w, _, _)| {
                // The store runs Dense mode under this engine, so this is an
                // Arc clone, not a materialization.
                let params = w.materialized();
                g.requests
                    .iter()
                    .map(|r| score_one_xla(handle, config, &params, &r.payload))
                    .collect()
            })
            .collect(),
    };
    let compute = compute_start.elapsed();
    for ((group, _, version, cold), group_results) in loaded.into_iter().zip(results) {
        for (req, result) in group.requests.into_iter().zip(group_results) {
            let queue = batch_start.duration_since(req.submitted);
            let total = req.submitted.elapsed();
            metrics.record_request(&req.variant, queue, compute, total, result.is_err());
            let timing = Timing { queue, cold_start: cold, compute, total };
            let _ = req.resp.send(Response {
                id: req.id,
                variant: req.variant.clone(),
                version: Some(version),
                result,
                timing,
            });
        }
    }
}

/// Score a mixed-variant set of payloads through one [`BatchPlan`]: expand
/// every payload into its scored sequences, run ONE stacked forward for all
/// of them (one shared base GEMM per module), then reduce each request's
/// spans to scores. Numerically identical to the per-request path —
/// batching regroups work across requests, never the arithmetic.
fn score_plan_native(
    tf: &Transformer,
    plan: &BatchPlan,
    prefix_cache: &PrefixCache,
    payloads: &[(usize, &Payload)],
) -> Vec<Result<RespBody, String>> {
    enum Pending {
        Failed(String),
        /// (start, choice_len) per choice, sequences at `first_seq..`.
        Score { first_seq: usize, spans: Vec<(usize, usize)> },
        Ppl { seq: usize, n_tokens: usize },
    }
    let mut seqs: Vec<(usize, Vec<u8>)> = Vec::new();
    let mut pending = Vec::with_capacity(payloads.len());
    for &(entry, payload) in payloads {
        let op = match payload {
            Payload::Data(op) => op,
            Payload::Admin(_) => {
                pending.push(Pending::Failed("admin requests must not reach an engine".into()));
                continue;
            }
        };
        match op {
            DataOp::Score { prompt, choices } => {
                let first_seq = seqs.len();
                let mut spans = Vec::with_capacity(choices.len());
                for choice in choices {
                    let full = clamp(encode(&format!("{prompt}{choice}")), tf.cfg.max_seq);
                    // The choice is the tail of the sequence; score exactly
                    // its tokens (robust under prompt clamping).
                    let choice_len = encode(choice).len().min(full.len() - 1).max(1);
                    spans.push((full.len() - choice_len, choice_len));
                    seqs.push((entry, full));
                }
                pending.push(Pending::Score { first_seq, spans });
            }
            DataOp::Perplexity { text } => {
                let tokens = clamp(encode(text), tf.cfg.max_seq);
                if tokens.len() < 2 {
                    pending.push(Pending::Failed("text too short".into()));
                } else {
                    pending.push(Pending::Ppl { seq: seqs.len(), n_tokens: tokens.len() });
                    seqs.push((entry, tokens));
                }
            }
        }
    }
    // The prefix-cache seam: resume shared prefixes from cached per-layer
    // activations and capture new ones — bitwise-equal to the cold
    // `forward_plan` (and exactly that when the cache is disabled).
    let logits = prefix::run_plan(tf, plan, &seqs, prefix_cache);
    pending
        .into_iter()
        .map(|p| match p {
            Pending::Failed(e) => Err(e),
            Pending::Score { first_seq, spans } => {
                let mut scores = Vec::with_capacity(spans.len());
                for (i, (start, choice_len)) in spans.into_iter().enumerate() {
                    let (_, tokens) = &seqs[first_seq + i];
                    let s =
                        tf.span_logprob(&logits[first_seq + i], tokens, start..tokens.len());
                    scores.push(s / choice_len as f64);
                }
                let choice = argmax_f64(&scores);
                Ok(RespBody::Score { choice, scores })
            }
            Pending::Ppl { seq, n_tokens } => {
                let (_, tokens) = &seqs[seq];
                let s = tf.span_logprob(&logits[seq], tokens, 1..n_tokens);
                Ok(RespBody::Perplexity { nats_per_token: -s / (n_tokens - 1) as f64 })
            }
        })
        .collect()
}

/// Last applied leader `manifest_seq` per leader directory — shared across
/// workers so repeated `PullFrom` polls of an unchanged leader take the
/// cheap fast path.
type SyncSeqs = Mutex<HashMap<std::path::PathBuf, u64>>;

/// Execute one control-plane operation against the registry/cache/metrics —
/// no engine, no variant queue.
fn run_admin(
    op: &AdminOp,
    cache: &VariantCache,
    metrics: &Metrics,
    sync_seqs: &SyncSeqs,
) -> Result<AdminResp, String> {
    let registry = cache.store().registry();
    match op {
        AdminOp::Stats => {
            // One lock acquisition for gauge + snapshot, so a concurrent
            // worker's totals-only update can't blank the per-version
            // breakdown in the response.
            let snapshot = metrics.snapshot_with_residency(cache.residency());
            Ok(AdminResp::Stats { snapshot: Box::new(snapshot) })
        }
        AdminOp::Publish { variant, artifact } => {
            let delta = load_validated_artifact(artifact, cache)?;
            let outcome = registry.publish_full(variant, delta).map_err(|e| e.to_string())?;
            metrics.record_publish();
            warm_published(variant, outcome.version, cache, metrics)?;
            Ok(AdminResp::Published {
                variant: variant.clone(),
                version: outcome.version,
                patch: false,
                bytes: outcome.bytes,
            })
        }
        AdminOp::PublishIncremental { variant, artifact, parent } => {
            let delta = load_validated_artifact(artifact, cache)?;
            // Resident-parent hint: diffing against an already-composed
            // cache entry skips re-reading the consolidated parent chain
            // from disk — publish cost stays proportional to the change.
            let outcome = registry
                .publish_incremental_hinted(variant, delta, *parent, |v| {
                    cache.resident_delta(variant, v)
                })
                .map_err(|e| e.to_string())?;
            metrics.record_publish();
            // Warming a patch version composes onto the resident parent, so
            // the cold start charged here is proportional to the changed
            // modules, not the whole artifact.
            warm_published(variant, outcome.version, cache, metrics)?;
            Ok(AdminResp::Published {
                variant: variant.clone(),
                version: outcome.version,
                patch: outcome.patch,
                bytes: outcome.bytes,
            })
        }
        AdminOp::Consolidate { variant, version } => {
            let outcome =
                registry.consolidate(variant, *version).map_err(|e| e.to_string())?;
            Ok(AdminResp::Consolidated {
                variant: variant.clone(),
                version: outcome.version,
                bytes: outcome.bytes,
                rebased_links: outcome.rebased_links,
            })
        }
        AdminOp::Rollback { variant, to } => {
            let version = registry.rollback(variant, *to).map_err(|e| e.to_string())?;
            metrics.record_rollback();
            Ok(AdminResp::RolledBack { variant: variant.clone(), version })
        }
        AdminOp::Pin { variant, version } => {
            registry.pin(variant, *version).map_err(|e| e.to_string())?;
            Ok(AdminResp::Pinned { variant: variant.clone(), version: *version })
        }
        AdminOp::Unpin { variant } => {
            registry.unpin(variant).map_err(|e| e.to_string())?;
            Ok(AdminResp::Unpinned { variant: variant.clone() })
        }
        AdminOp::Retire { variant, version } => {
            registry.retire(variant, *version).map_err(|e| e.to_string())?;
            Ok(AdminResp::Retired { variant: variant.clone(), version: *version })
        }
        AdminOp::Gc { variant } => {
            let report = registry.gc(variant.as_deref()).map_err(|e| e.to_string())?;
            Ok(AdminResp::Gced {
                files_removed: report.files_removed,
                bytes_freed: report.bytes_freed,
            })
        }
        AdminOp::List => Ok(AdminResp::Variants { variants: registry.list() }),
        AdminOp::SyncStatus => {
            let descs = registry.list();
            Ok(AdminResp::SyncStatus {
                manifest_seq: registry.manifest_seq(),
                variants: descs.len(),
                versions: descs.iter().map(|d| d.versions.len()).sum(),
            })
        }
        AdminOp::PullFrom { dir } => {
            use super::replicate::{FsTransport, Replicator};
            let replicator =
                Replicator::new(registry.clone(), Box::new(FsTransport::new(dir)));
            // The replicator is per-call, so carry the last applied leader
            // sequence across calls: repeated polls of an unchanged leader
            // take the manifest_seq fast path instead of re-diffing the
            // whole registry every time.
            if let Some(seq) = sync_seqs.lock().unwrap().get(dir).copied() {
                replicator.resume_from(seq);
            }
            let report =
                replicator.sync_once(Some(cache)).map_err(|e| format!("{e:#}"))?;
            sync_seqs.lock().unwrap().insert(dir.clone(), report.leader_seq);
            metrics.set_residency(cache.residency());
            Ok(AdminResp::Synced { peer: replicator.peer(), report })
        }
    }
}

/// Load a `.pawd` artifact and validate config + per-module shapes against
/// the resident base BEFORE any alias flips — a wrong-base or mis-shaped
/// delta must not brick the variant.
fn load_validated_artifact(
    artifact: &Path,
    cache: &VariantCache,
) -> Result<crate::delta::DeltaModel, String> {
    let delta = Arc::new(
        crate::delta::format::load_delta(artifact)
            .map_err(|e| format!("unreadable artifact: {e}"))?,
    );
    crate::exec::PackedVariant::new(cache.base(), delta.clone())
        .map_err(|e| format!("artifact rejected: {e}"))?;
    Ok(Arc::try_unwrap(delta).unwrap_or_else(|arc| (*arc).clone()))
}

/// Warm a freshly published version so the first data request after the
/// flip hits a resident entry; its load time is charged as a cold start
/// here, on the control plane.
fn warm_published(
    variant: &str,
    version: u32,
    cache: &VariantCache,
    metrics: &Metrics,
) -> Result<(), String> {
    match cache.get(&format!("{variant}@{version}")) {
        Ok((_, Some(d))) => metrics.record_cold_start(d),
        Ok((_, None)) => {}
        Err(e) => return Err(format!("published v{version} but warming failed: {e}")),
    }
    metrics.set_residency(cache.residency());
    Ok(())
}

fn score_one_xla(
    handle: &RuntimeHandle,
    config: &str,
    params: &crate::model::FlatParams,
    payload: &Payload,
) -> Result<RespBody, String> {
    let op = match payload {
        Payload::Data(op) => op,
        Payload::Admin(_) => return Err("admin requests must not reach an engine".into()),
    };
    match op {
        DataOp::Score { prompt, choices } => {
            // One batched forward over all choice continuations.
            let max_seq = handle
                .manifest()
                .fwd_buckets(config)
                .last()
                .and_then(|p| p.seq)
                .unwrap_or(64);
            let seqs: Vec<Vec<u8>> = choices
                .iter()
                .map(|c| clamp(encode(&format!("{prompt}{c}")), max_seq))
                .collect();
            let logits = crate::runtime::forward_logits(handle, config, &params.data, &seqs)
                .map_err(|e| e.to_string())?;
            let mut scores = Vec::with_capacity(choices.len());
            for ((seq, l), choice) in seqs.iter().zip(&logits).zip(choices) {
                let choice_len = encode(choice).len().min(seq.len() - 1).max(1);
                let start = seq.len() - choice_len;
                let mut buf = vec![0f32; l.cols];
                let mut total = 0f64;
                for pos in start..seq.len() {
                    log_softmax_into(l.row(pos - 1), &mut buf);
                    total += buf[seq[pos] as usize] as f64;
                }
                scores.push(total / choice_len as f64);
            }
            let choice = argmax_f64(&scores);
            Ok(RespBody::Score { choice, scores })
        }
        DataOp::Perplexity { text } => {
            let max_seq = handle
                .manifest()
                .fwd_buckets(config)
                .last()
                .and_then(|p| p.seq)
                .unwrap_or(64);
            let tokens = clamp(encode(text), max_seq);
            if tokens.len() < 2 {
                return Err("text too short".into());
            }
            let logits = crate::runtime::forward_logits(handle, config, &params.data, &[tokens.clone()])
                .map_err(|e| e.to_string())?;
            let l = &logits[0];
            let mut buf = vec![0f32; l.cols];
            let mut total = 0f64;
            for pos in 1..tokens.len() {
                log_softmax_into(l.row(pos - 1), &mut buf);
                total += buf[tokens[pos] as usize] as f64;
            }
            Ok(RespBody::Perplexity { nats_per_token: -total / (tokens.len() - 1) as f64 })
        }
    }
}

fn clamp(tokens: Vec<u8>, max: usize) -> Vec<u8> {
    if tokens.len() <= max {
        tokens
    } else {
        tokens[tokens.len() - max..].to_vec()
    }
}

fn argmax_f64(xs: &[f64]) -> usize {
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (i, &x) in xs.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1
}

