//! The serving coordinator: ingress → per-variant queues → dynamic batcher
//! → worker engines over the LRU variant cache — plus the **admin lane**,
//! which answers control-plane operations (stats, publish, rollback, pin,
//! retire, list) without touching an engine.
//!
//! Thread topology (no async runtime available offline; this is plain
//! threads + channels, which for a CPU-bound engine is also the faster
//! choice):
//!
//! ```text
//! clients --mpsc--> dispatcher ----work queue----> worker 0..N-1
//!                    (per-variant queues,           (variant cache get,
//!                     size/deadline batching;        score batch, reply;
//!                     admin ops bypass batching)     admin ops -> registry)
//! ```
//!
//! Publishing through the admin lane is the live-update path: the registry
//! flips the alias atomically, the publishing worker warms the new version
//! into the cache, and data requests already holding the old version's `Arc`
//! finish undisturbed while the old entry ages out of the LRU.

use super::cache::VariantCache;
use super::metrics::Metrics;
use super::request::{
    AdminOp, AdminResp, DataOp, Payload, Request, RespBody, Response, Timing, ADMIN_VARIANT,
    STATS_VARIANT,
};
use super::store::VariantStore;
use crate::data::corpus::encode;
use crate::exec::{ExecMode, VariantWeights};
use crate::model::Transformer;
use crate::runtime::RuntimeHandle;
use crate::tensor::ops::log_softmax_into;
use crate::util::par;
use anyhow::Result;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Which engine executes forwards.
#[derive(Clone)]
pub enum Engine {
    /// Native Rust transformer (always available).
    Native,
    /// AOT artifacts through the PJRT runtime thread; `config` names the
    /// manifest config whose buckets to use.
    Xla { handle: RuntimeHandle, config: String },
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub n_workers: usize,
    pub cache_budget_bytes: u64,
    /// Dense-vs-fused A/B switch: how delta variants are resident and
    /// executed. The XLA engine forces `Dense` (it consumes flat buffers).
    pub exec: ExecMode,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(4),
            n_workers: 2,
            cache_budget_bytes: 1 << 30,
            exec: ExecMode::Fused,
        }
    }
}

struct Batch {
    variant: String,
    requests: Vec<Request>,
}

/// Ingress message: a request or an explicit shutdown signal (needed
/// because live `Client` clones keep the channel open).
enum Ingress {
    Req(Request),
    Shutdown,
}

pub struct Server {
    ingress: mpsc::Sender<Ingress>,
    next_id: Arc<AtomicU64>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<VariantCache>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Cheap cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Ingress>,
    next_id: Arc<AtomicU64>,
}

impl Client {
    /// Submit without blocking; returns the response receiver.
    pub fn submit(&self, variant: &str, payload: Payload) -> mpsc::Receiver<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (req, rx) = Request::new(id, variant, payload);
        // If the server is gone the receiver errors on recv — fine.
        let _ = self.tx.send(Ingress::Req(req));
        rx
    }

    /// Blocking convenience: score choices on a variant.
    pub fn score(&self, variant: &str, prompt: &str, choices: &[String]) -> Response {
        let rx = self.submit(variant, Payload::score(prompt, choices));
        rx.recv().unwrap_or(Response {
            id: 0,
            variant: variant.into(),
            version: None,
            result: Err("server terminated".into()),
            timing: Timing::default(),
        })
    }

    /// Blocking convenience: run one control-plane operation.
    pub fn admin(&self, op: AdminOp) -> Result<AdminResp, String> {
        let rx = self.submit(ADMIN_VARIANT, Payload::Admin(op));
        match rx.recv() {
            Ok(resp) => match resp.result {
                Ok(RespBody::Admin(a)) => Ok(a),
                Ok(other) => Err(format!("unexpected admin response {other:?}")),
                Err(e) => Err(e),
            },
            Err(_) => Err("server terminated".into()),
        }
    }

    /// Blocking convenience: fetch server metrics + residency gauges
    /// through the request path (useful for remote/ops probes; in-process
    /// callers can also read `Server::metrics` directly).
    pub fn stats(&self) -> Result<super::metrics::MetricsSnapshot, String> {
        match self.admin(AdminOp::Stats)? {
            AdminResp::Stats { snapshot } => Ok(*snapshot),
            other => Err(format!("unexpected stats response {other:?}")),
        }
    }

    /// Publish `artifact` as the next version of `variant`; returns the
    /// assigned version once the alias has flipped and the new version has
    /// been warmed into the cache.
    pub fn publish(&self, variant: &str, artifact: &Path) -> Result<u32, String> {
        match self.admin(AdminOp::Publish {
            variant: variant.to_string(),
            artifact: artifact.to_path_buf(),
        })? {
            AdminResp::Published { version, .. } => Ok(version),
            other => Err(format!("unexpected publish response {other:?}")),
        }
    }

    /// Roll `variant` back to `to` (or its active version's parent);
    /// returns the version now active.
    pub fn rollback(&self, variant: &str, to: Option<u32>) -> Result<u32, String> {
        match self.admin(AdminOp::Rollback { variant: variant.to_string(), to })? {
            AdminResp::RolledBack { version, .. } => Ok(version),
            other => Err(format!("unexpected rollback response {other:?}")),
        }
    }

    /// List all variants with their version histories.
    pub fn variants(&self) -> Result<Vec<super::registry::VariantDesc>, String> {
        match self.admin(AdminOp::List)? {
            AdminResp::Variants { variants } => Ok(variants),
            other => Err(format!("unexpected list response {other:?}")),
        }
    }
}

impl Server {
    pub fn start(mut store: VariantStore, engine: Engine, cfg: ServerConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        // The XLA engine executes flat parameter buffers, so it cannot run
        // packed variants; force dense residency there.
        store.set_mode(match &engine {
            Engine::Native => cfg.exec,
            Engine::Xla { .. } => ExecMode::Dense,
        });
        let cache = Arc::new(VariantCache::new(store, cfg.cache_budget_bytes));
        let (ingress_tx, ingress_rx) = mpsc::channel::<Ingress>();
        let (work_tx, work_rx) = mpsc::channel::<Batch>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut workers = Vec::new();
        for wid in 0..cfg.n_workers.max(1) {
            let work_rx = work_rx.clone();
            let cache = cache.clone();
            let metrics = metrics.clone();
            let engine = engine.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("pawd-worker-{wid}"))
                    .spawn(move || worker_loop(work_rx, cache, metrics, engine))
                    .expect("spawn worker"),
            );
        }
        let dcfg = cfg.clone();
        let dmetrics = metrics.clone();
        let dispatcher = std::thread::Builder::new()
            .name("pawd-dispatcher".into())
            .spawn(move || dispatcher_loop(ingress_rx, work_tx, dcfg, dmetrics))
            .expect("spawn dispatcher");

        Server {
            ingress: ingress_tx,
            next_id: Arc::new(AtomicU64::new(1)),
            metrics,
            cache,
            dispatcher: Some(dispatcher),
            workers,
        }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.ingress.clone(), next_id: self.next_id.clone() }
    }

    /// Graceful shutdown: signal the dispatcher (live Client clones keep
    /// the channel open, so dropping our sender is not enough), drain,
    /// join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        drop(self.ingress);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn dispatcher_loop(
    ingress: mpsc::Receiver<Ingress>,
    work: mpsc::Sender<Batch>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
) {
    // Per-variant FIFO queues with the arrival time of their oldest entry.
    let mut queues: HashMap<String, VecDeque<Request>> = HashMap::new();
    let mut open = true;
    while open || queues.values().any(|q| !q.is_empty()) {
        // Pull with a small timeout so deadline flushes happen on time.
        match ingress.recv_timeout(Duration::from_micros(500)) {
            Ok(Ingress::Req(req)) => {
                // Admin ops (and anything aimed at the deprecated stats
                // pseudo-variant) bypass batching: they never touch an
                // engine, so making them wait behind a batch deadline would
                // only delay alias flips.
                let admin = matches!(req.payload, Payload::Admin(_))
                    || req.variant == STATS_VARIANT
                    || req.variant == ADMIN_VARIANT;
                if admin {
                    if work
                        .send(Batch { variant: ADMIN_VARIANT.into(), requests: vec![req] })
                        .is_err()
                    {
                        return; // workers gone
                    }
                } else {
                    queues.entry(req.variant.clone()).or_default().push_back(req);
                }
            }
            Ok(Ingress::Shutdown) => open = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
        // Flush full or overdue queues.
        let now = Instant::now();
        for (variant, q) in queues.iter_mut() {
            let due = q
                .front()
                .map(|r| now.duration_since(r.submitted) >= cfg.max_wait)
                .unwrap_or(false);
            while q.len() >= cfg.max_batch || (due && !q.is_empty()) || (!open && !q.is_empty()) {
                let take = q.len().min(cfg.max_batch);
                let requests: Vec<Request> = q.drain(..take).collect();
                metrics.record_batch(requests.len());
                if work.send(Batch { variant: variant.clone(), requests }).is_err() {
                    return; // workers gone
                }
                if q.len() < cfg.max_batch && open {
                    break;
                }
            }
        }
    }
    // work sender drops here -> workers drain and exit.
}

fn worker_loop(
    work: Arc<Mutex<mpsc::Receiver<Batch>>>,
    cache: Arc<VariantCache>,
    metrics: Arc<Metrics>,
    engine: Engine,
) {
    // One Transformer per worker (RoPE tables etc.) for the native engine.
    let tf = Transformer::new(cache.base().cfg());
    // Which variant version this worker last executed — a change is a hot
    // swap (with packed residency: an Arc clone, no materialize/revert pass).
    let mut last_variant: Option<(String, u32)> = None;
    loop {
        let batch = {
            let rx = work.lock().unwrap();
            match rx.recv() {
                Ok(b) => b,
                Err(_) => return,
            }
        };
        let batch_start = Instant::now();
        if batch.variant == ADMIN_VARIANT {
            for req in batch.requests {
                let result = match &req.payload {
                    Payload::Admin(op) => run_admin(op, &cache, &metrics).map(RespBody::Admin),
                    // Data ops can only land here via the deprecated
                    // pseudo-variant names; reject them instead of answering
                    // with a surprise body.
                    Payload::Data(_) => Err(format!(
                        "variant name '{}' is reserved for control-plane probes",
                        req.variant
                    )),
                };
                let timing = Timing {
                    queue: batch_start.duration_since(req.submitted),
                    total: req.submitted.elapsed(),
                    ..Default::default()
                };
                let _ = req.resp.send(Response {
                    id: req.id,
                    variant: req.variant.clone(),
                    version: None,
                    result,
                    timing,
                });
            }
            continue;
        }
        let (weights, cold) = match cache.get(&batch.variant) {
            Ok(x) => x,
            Err(e) => {
                let msg = format!("variant load failed: {e}");
                for req in batch.requests {
                    let timing = Timing {
                        queue: batch_start.duration_since(req.submitted),
                        total: req.submitted.elapsed(),
                        ..Default::default()
                    };
                    metrics.record_request(&req.variant, timing.queue, Duration::ZERO, timing.total, true);
                    let _ = req.resp.send(Response {
                        id: req.id,
                        variant: req.variant.clone(),
                        version: None,
                        result: Err(msg.clone()),
                        timing,
                    });
                }
                continue;
            }
        };
        let version = weights.version();
        if let Some(c) = cold {
            metrics.record_cold_start(c);
        }
        let changed = match &last_variant {
            Some((n, v)) => n != &batch.variant || *v != version,
            None => true,
        };
        if changed {
            if last_variant.is_some() {
                metrics.record_swap();
            }
            last_variant = Some((batch.variant.clone(), version));
        }
        // Per-batch gauge update sticks to the O(1) totals; the per-version
        // breakdown is only materialized when a stats probe asks for it.
        metrics.set_residency(cache.residency_totals());
        let compute_start = Instant::now();
        let results = score_batch(&engine, &tf, &weights, &batch.requests);
        let compute = compute_start.elapsed();
        for (req, result) in batch.requests.into_iter().zip(results) {
            let queue = batch_start.duration_since(req.submitted);
            let total = req.submitted.elapsed();
            metrics.record_request(&req.variant, queue, compute, total, result.is_err());
            let timing = Timing { queue, cold_start: cold, compute, total };
            let _ = req.resp.send(Response {
                id: req.id,
                variant: req.variant.clone(),
                version: Some(version),
                result,
                timing,
            });
        }
    }
}

/// Execute one control-plane operation against the registry/cache/metrics —
/// no engine, no variant queue.
fn run_admin(
    op: &AdminOp,
    cache: &VariantCache,
    metrics: &Metrics,
) -> Result<AdminResp, String> {
    let registry = cache.store().registry();
    match op {
        AdminOp::Stats => {
            // One lock acquisition for gauge + snapshot, so a concurrent
            // worker's totals-only update can't blank the per-version
            // breakdown in the response.
            let snapshot = metrics.snapshot_with_residency(cache.residency());
            Ok(AdminResp::Stats { snapshot: Box::new(snapshot) })
        }
        AdminOp::Publish { variant, artifact } => {
            let delta = Arc::new(
                crate::delta::format::load_delta(artifact)
                    .map_err(|e| format!("unreadable artifact: {e}"))?,
            );
            // Validate config + per-module shapes against the resident base
            // BEFORE the alias flips — a wrong-base or mis-shaped delta must
            // not brick the variant.
            crate::exec::PackedVariant::new(cache.base(), delta.clone())
                .map_err(|e| format!("artifact rejected: {e}"))?;
            let delta = Arc::try_unwrap(delta).unwrap_or_else(|arc| (*arc).clone());
            let version = registry.publish(variant, delta).map_err(|e| e.to_string())?;
            metrics.record_publish();
            // Warm the new version so the first data request after the flip
            // hits a resident entry; its load time is charged as a cold
            // start here, on the control plane.
            match cache.get(&format!("{variant}@{version}")) {
                Ok((_, Some(d))) => metrics.record_cold_start(d),
                Ok((_, None)) => {}
                Err(e) => return Err(format!("published v{version} but warming failed: {e}")),
            }
            metrics.set_residency(cache.residency());
            Ok(AdminResp::Published { variant: variant.clone(), version })
        }
        AdminOp::Rollback { variant, to } => {
            let version = registry.rollback(variant, *to).map_err(|e| e.to_string())?;
            metrics.record_rollback();
            Ok(AdminResp::RolledBack { variant: variant.clone(), version })
        }
        AdminOp::Pin { variant, version } => {
            registry.pin(variant, *version).map_err(|e| e.to_string())?;
            Ok(AdminResp::Pinned { variant: variant.clone(), version: *version })
        }
        AdminOp::Unpin { variant } => {
            registry.unpin(variant).map_err(|e| e.to_string())?;
            Ok(AdminResp::Unpinned { variant: variant.clone() })
        }
        AdminOp::Retire { variant, version } => {
            registry.retire(variant, *version).map_err(|e| e.to_string())?;
            Ok(AdminResp::Retired { variant: variant.clone(), version: *version })
        }
        AdminOp::List => Ok(AdminResp::Variants { variants: registry.list() }),
    }
}

/// Score every request in a batch against the variant's weights (packed or
/// dense — the native engine is generic over the source).
fn score_batch(
    engine: &Engine,
    tf: &Transformer,
    weights: &VariantWeights,
    requests: &[Request],
) -> Vec<Result<RespBody, String>> {
    match engine {
        Engine::Native => {
            let out: Vec<Mutex<Option<Result<RespBody, String>>>> =
                (0..requests.len()).map(|_| Mutex::new(None)).collect();
            par::parallel_items(requests.len(), 8, |i| {
                let r = score_one_native(tf, weights, &requests[i].payload);
                *out[i].lock().unwrap() = Some(r);
            });
            out.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
        }
        Engine::Xla { handle, config } => {
            // The store runs Dense mode under this engine, so this is an Arc
            // clone, not a materialization.
            let params = weights.materialized();
            requests
                .iter()
                .map(|r| score_one_xla(handle, config, &params, &r.payload))
                .collect()
        }
    }
}

fn score_one_native(
    tf: &Transformer,
    weights: &VariantWeights,
    payload: &Payload,
) -> Result<RespBody, String> {
    let op = match payload {
        Payload::Data(op) => op,
        Payload::Admin(_) => return Err("admin requests must not reach an engine".into()),
    };
    match op {
        DataOp::Score { prompt, choices } => {
            let mut scores = Vec::with_capacity(choices.len());
            for choice in choices {
                let full = clamp(encode(&format!("{prompt}{choice}")), tf.cfg.max_seq);
                // The choice is the tail of the sequence; score exactly its
                // tokens (robust under prompt clamping).
                let choice_len = encode(choice).len().min(full.len() - 1).max(1);
                let start = full.len() - choice_len;
                let s = tf.score_span(weights, &full, start..full.len());
                scores.push(s / choice_len as f64);
            }
            let choice = argmax_f64(&scores);
            Ok(RespBody::Score { choice, scores })
        }
        DataOp::Perplexity { text } => {
            let tokens = clamp(encode(text), tf.cfg.max_seq);
            if tokens.len() < 2 {
                return Err("text too short".into());
            }
            Ok(RespBody::Perplexity { nats_per_token: tf.cross_entropy(weights, &tokens) })
        }
    }
}

fn score_one_xla(
    handle: &RuntimeHandle,
    config: &str,
    params: &crate::model::FlatParams,
    payload: &Payload,
) -> Result<RespBody, String> {
    let op = match payload {
        Payload::Data(op) => op,
        Payload::Admin(_) => return Err("admin requests must not reach an engine".into()),
    };
    match op {
        DataOp::Score { prompt, choices } => {
            // One batched forward over all choice continuations.
            let max_seq = handle
                .manifest()
                .fwd_buckets(config)
                .last()
                .and_then(|p| p.seq)
                .unwrap_or(64);
            let seqs: Vec<Vec<u8>> = choices
                .iter()
                .map(|c| clamp(encode(&format!("{prompt}{c}")), max_seq))
                .collect();
            let logits = crate::runtime::forward_logits(handle, config, &params.data, &seqs)
                .map_err(|e| e.to_string())?;
            let mut scores = Vec::with_capacity(choices.len());
            for ((seq, l), choice) in seqs.iter().zip(&logits).zip(choices) {
                let choice_len = encode(choice).len().min(seq.len() - 1).max(1);
                let start = seq.len() - choice_len;
                let mut buf = vec![0f32; l.cols];
                let mut total = 0f64;
                for pos in start..seq.len() {
                    log_softmax_into(l.row(pos - 1), &mut buf);
                    total += buf[seq[pos] as usize] as f64;
                }
                scores.push(total / choice_len as f64);
            }
            let choice = argmax_f64(&scores);
            Ok(RespBody::Score { choice, scores })
        }
        DataOp::Perplexity { text } => {
            let max_seq = handle
                .manifest()
                .fwd_buckets(config)
                .last()
                .and_then(|p| p.seq)
                .unwrap_or(64);
            let tokens = clamp(encode(text), max_seq);
            if tokens.len() < 2 {
                return Err("text too short".into());
            }
            let logits = crate::runtime::forward_logits(handle, config, &params.data, &[tokens.clone()])
                .map_err(|e| e.to_string())?;
            let l = &logits[0];
            let mut buf = vec![0f32; l.cols];
            let mut total = 0f64;
            for pos in 1..tokens.len() {
                log_softmax_into(l.row(pos - 1), &mut buf);
                total += buf[tokens[pos] as usize] as f64;
            }
            Ok(RespBody::Perplexity { nats_per_token: -total / (tokens.len() - 1) as f64 })
        }
    }
}

fn clamp(tokens: Vec<u8>, max: usize) -> Vec<u8> {
    if tokens.len() <= max {
        tokens
    } else {
        tokens[tokens.len() - max..].to_vec()
    }
}

fn argmax_f64(xs: &[f64]) -> usize {
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (i, &x) in xs.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1
}
