//! Transport-agnostic client surface: ONE trait over the in-process
//! [`Client`] and the HTTP mirror
//! (`net::api::HttpApiClient`), so harnesses, benches, and ops tooling can
//! be written once and run against either side of the wire. The error lane
//! is `Result<_, String>` — the in-process client's native lane — and the
//! HTTP impl folds its transport errors into the same shape, so a caller
//! cannot tell a local engine rejection from a remote one (which is the
//! point: the scores themselves are bitwise-equal across transports).

use super::metrics::MetricsSnapshot;
use super::request::{AdminOp, AdminResp, Payload, RespBody, Response};
use super::server::Client;
use std::sync::mpsc;

/// One data-plane answer, transport-agnostic: which version actually
/// served, and the body. The HTTP client's wire reply converts into this
/// losslessly (scores ride shortest-roundtrip `f64` JSON).
#[derive(Debug)]
pub struct ApiReply {
    pub variant: String,
    pub version: Option<u32>,
    pub body: RespBody,
}

/// The client surface both transports share. Implemented by
/// [`Client`] (in-process channel) and
/// `net::api::HttpApiClient` (loopback/remote HTTP).
pub trait ApiClient {
    /// Rank `choices` as completions of `prompt` on `variant`.
    fn score(&self, variant: &str, prompt: &str, choices: &[String]) -> Result<ApiReply, String>;

    /// Nats-per-token perplexity of `text` on `variant`.
    fn perplexity(&self, variant: &str, text: &str) -> Result<ApiReply, String>;

    /// One control-plane operation.
    fn admin(&self, op: AdminOp) -> Result<AdminResp, String>;

    /// Server metrics + residency gauges, via the admin lane.
    fn stats(&self) -> Result<MetricsSnapshot, String> {
        match self.admin(AdminOp::Stats)? {
            AdminResp::Stats { snapshot } => Ok(*snapshot),
            other => Err(format!("unexpected stats response {other:?}")),
        }
    }

    /// Liveness probe. In-process this is trivially `Ok` (a dead server
    /// surfaces as an error on the next real call); over HTTP it is
    /// `GET /v1/healthz`.
    fn health(&self) -> Result<(), String>;
}

/// Collapse a response receiver into the trait's reply shape.
fn recv_reply(rx: mpsc::Receiver<Response>) -> Result<ApiReply, String> {
    let resp = rx.recv().map_err(|_| "server terminated".to_string())?;
    Ok(ApiReply { variant: resp.variant, version: resp.version, body: resp.result? })
}

impl ApiClient for Client {
    fn score(&self, variant: &str, prompt: &str, choices: &[String]) -> Result<ApiReply, String> {
        recv_reply(self.submit(variant, Payload::score(prompt, choices)))
    }

    fn perplexity(&self, variant: &str, text: &str) -> Result<ApiReply, String> {
        recv_reply(self.submit(variant, Payload::perplexity(text)))
    }

    fn admin(&self, op: AdminOp) -> Result<AdminResp, String> {
        Client::admin(self, op)
    }

    fn health(&self) -> Result<(), String> {
        Ok(())
    }
}
