//! L3 serving coordinator — the systems side of the paper: serve many
//! fine-tuned variants of one shared base model, with compressed deltas
//! hot-swapped on cold start and **updated live** through a versioned
//! lifecycle registry.
//!
//! * [`api`] — the transport-agnostic [`ApiClient`](api::ApiClient) trait
//!   (score/perplexity/admin/stats/health) implemented by the in-process
//!   [`Client`](server::Client) here and by
//!   `net::api::HttpApiClient` over the wire.
//! * [`request`] — request/response types with per-stage timing, split into
//!   a data plane ([`DataOp`](request::DataOp)) and a control plane
//!   ([`AdminOp`](request::AdminOp)).
//! * [`registry`] — the variant lifecycle: versioned artifacts
//!   (`variant@N`), atomic publish/rollback alias flips, pin/retire, JSON
//!   manifest persistence, adoption of pre-registry directories.
//! * [`store`] — alias resolution + the single-read hot-swap loader (packed
//!   in fused mode, materialized in dense mode) and the FP16
//!   full-checkpoint baseline.
//! * [`cache`] — LRU cache of resident `(variant, version)` entries under a
//!   byte budget, charged in packed bytes when the store runs
//!   [`ExecMode::Fused`](crate::exec::ExecMode); a publish warms the new
//!   version while the old one ages out.
//! * [`engine`] — the continuous-batching step loop
//!   ([`EngineCore`](engine::EngineCore)): `add_request`/`step`/`abort`
//!   semantics, fair-share admission into the in-flight batch at every step
//!   boundary, immediate flush onto idle workers (no dispatch-deadline stall), and
//!   publish/pull warms overlapping data-plane serving.
//! * [`server`] — wiring around the engine loop: spawns the engine thread
//!   and worker engines, routes admin requests down the fast lane, and runs
//!   each admitted window as a shared-base
//!   [`BatchPlan`](crate::exec::BatchPlan) — one base GEMM per module for
//!   the whole mixed-variant window — while the PJRT runtime scores per
//!   group from flat buffers. Workers parallelize intra-host over the
//!   [`exec::pool`](crate::exec::pool) compute pool
//!   (`ServerConfig::n_compute_threads`).
//! * [`metrics`] — latency histograms, throughput, cold-start accounting,
//!   publish/rollback counters, per-version residency gauges.
//! * [`replicate`] — patch-aware multi-node replication: a follower pulls a
//!   leader's manifest through a [`SyncTransport`](replicate::SyncTransport),
//!   fetches only missing artifacts (patches when the chain parent is
//!   already held), crc-verifies them, and commits the mirrored records.
//!   Transports: filesystem here, HTTP long-poll in
//!   [`net`](crate::net) (the coordinator never depends on the network
//!   plane — `net` bridges *into* these seams).

pub mod api;
pub mod cache;
pub mod engine;
pub mod metrics;
pub mod registry;
pub mod replicate;
pub mod request;
pub mod server;
pub mod store;

pub use api::{ApiClient, ApiReply};
pub use cache::{Residency, VariantCache, VersionResidency};
pub use engine::EngineCore;
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{
    ArtifactKind, ConsolidateOutcome, GcReport, ManifestView, PublishOutcome, Resolved,
    VariantDesc, VariantRegistry, VersionRecord,
};
pub use replicate::{FsTransport, ManifestFetch, Replicator, SyncReport, SyncTransport};
pub use request::{AdminOp, AdminResp, DataOp, Payload, RespBody, Response, ADMIN_VARIANT};
pub use server::{Client, Engine, Server, ServerConfig};
pub use store::VariantStore;
