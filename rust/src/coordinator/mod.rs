//! L3 serving coordinator — the systems side of the paper: serve many
//! fine-tuned variants of one shared base model, with compressed deltas
//! hot-swapped on cold start.
//!
//! * [`request`] — request/response types with per-stage timing.
//! * [`store`] — on-disk variant registry + the single-read hot-swap loader
//!   (packed in fused mode, materialized in dense mode) and the FP16
//!   full-checkpoint baseline.
//! * [`cache`] — LRU cache of resident variants under a byte budget,
//!   charged in packed bytes when the store runs
//!   [`ExecMode::Fused`](crate::exec::ExecMode).
//! * [`server`] — dispatcher (per-variant queues, size/deadline batching)
//!   and worker engines (native transformer over dense *or* packed weights,
//!   or the PJRT runtime).
//! * [`metrics`] — latency histograms, throughput, cold-start accounting,
//!   residency gauges.

pub mod cache;
pub mod metrics;
pub mod request;
pub mod server;
pub mod store;

pub use cache::{Residency, VariantCache};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{Payload, RespBody, Response, STATS_VARIANT};
pub use server::{Client, Engine, Server, ServerConfig};
pub use store::VariantStore;
