//! L3 serving coordinator — the systems side of the paper: serve many
//! fine-tuned variants of one shared base model, with compressed deltas
//! hot-swapped on cold start.
//!
//! * [`request`] — request/response types with per-stage timing.
//! * [`store`] — on-disk variant registry + the single-read/single-apply
//!   hot-swap loader (delta path) and FP16 full-checkpoint baseline.
//! * [`cache`] — LRU cache of materialized variants under a byte budget.
//! * [`server`] — dispatcher (per-variant queues, size/deadline batching)
//!   and worker engines (native transformer or the PJRT runtime).
//! * [`metrics`] — latency histograms, throughput, cold-start accounting.

pub mod cache;
pub mod metrics;
pub mod request;
pub mod server;
pub mod store;

pub use cache::VariantCache;
pub use request::{Payload, RespBody, Response};
pub use server::{Client, Engine, Server, ServerConfig};
pub use store::VariantStore;
