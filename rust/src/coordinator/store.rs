//! Variant store: the on-disk registry of compressed deltas (and FP16 full
//! checkpoints for the baseline path) plus the hot-swap loader.
//!
//! This is the paper's loader: a variant is loaded by **one sequential
//! read** of its PAWD artifact. What happens next depends on the store's
//! [`ExecMode`]:
//!
//! * [`ExecMode::Fused`] (default for native serving) — the packed delta is
//!   validated against the resident base and kept packed; the returned
//!   [`VariantWeights::Packed`] executes in place through
//!   [`FusedDeltaLinear`](crate::exec::FusedDeltaLinear). No dense `Ŵ` is
//!   ever built, so "materialization" cost is just parse + validate.
//! * [`ExecMode::Dense`] — the classic path: clone the resident base and run
//!   one fused apply per module (required by the XLA engine, and the
//!   baseline side of the dense-vs-fused A/B).

use crate::delta::apply::apply_deltas_inplace;
use crate::delta::format::load_delta;
use crate::exec::{ExecMode, PackedVariant, VariantWeights};
use crate::model::checkpoint::load_fp16;
use crate::model::FlatParams;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How a variant is stored on disk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VariantSource {
    /// `<dir>/<name>.pawd` applied onto the shared base (the paper's path).
    Delta(PathBuf),
    /// `<dir>/<name>.fp16` full checkpoint (baseline path).
    Fp16(PathBuf),
}

#[derive(Clone)]
pub struct VariantStore {
    pub base: Arc<FlatParams>,
    dir: PathBuf,
    mode: ExecMode,
}

/// A loaded variant plus its load-time accounting.
pub struct LoadedVariant {
    pub weights: VariantWeights,
    pub source: VariantSource,
    pub load_time: Duration,
    /// Bytes read from disk for this load.
    pub bytes_read: u64,
}

impl LoadedVariant {
    /// Dense parameters, materializing a packed variant on demand (XLA
    /// engine and ground-truth comparisons; the serving hot path never
    /// calls this in fused mode).
    pub fn params(&self) -> Arc<FlatParams> {
        self.weights.materialized()
    }
}

impl VariantStore {
    /// A store that materializes deltas on load (the original behavior).
    pub fn new(base: Arc<FlatParams>, dir: &Path) -> VariantStore {
        VariantStore { base, dir: dir.to_path_buf(), mode: ExecMode::Dense }
    }

    /// Builder: choose how delta variants execute.
    pub fn with_mode(mut self, mode: ExecMode) -> VariantStore {
        self.mode = mode;
        self
    }

    pub fn set_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Locate a variant on disk: prefer the delta artifact, fall back to a
    /// full FP16 checkpoint.
    pub fn locate(&self, name: &str) -> Result<VariantSource> {
        let delta = self.dir.join(format!("{name}.pawd"));
        if delta.exists() {
            return Ok(VariantSource::Delta(delta));
        }
        let fp16 = self.dir.join(format!("{name}.fp16"));
        if fp16.exists() {
            return Ok(VariantSource::Fp16(fp16));
        }
        bail!("variant '{name}' not found in {}", self.dir.display());
    }

    /// Load a variant (the cold-start path under measurement).
    pub fn load(&self, name: &str) -> Result<LoadedVariant> {
        let source = self.locate(name)?;
        let t0 = Instant::now();
        let (weights, bytes_read) = match &source {
            VariantSource::Delta(path) => {
                let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                let delta = load_delta(path)
                    .with_context(|| format!("loading delta for '{name}'"))?;
                if delta.base_config != self.base.cfg().name {
                    bail!(
                        "delta '{name}' targets base '{}', store has '{}'",
                        delta.base_config,
                        self.base.cfg().name
                    );
                }
                let weights = match self.mode {
                    ExecMode::Fused => {
                        // Keep the delta packed: validate shapes, index
                        // modules, share the base. No dense reconstruction.
                        VariantWeights::Packed(PackedVariant::new(
                            self.base.clone(),
                            Arc::new(delta),
                        )?)
                    }
                    ExecMode::Dense => {
                        // Clone the resident base, then one fused apply per
                        // module.
                        let mut p = (*self.base).clone();
                        apply_deltas_inplace(&mut p, &delta.modules);
                        VariantWeights::Dense(Arc::new(p))
                    }
                };
                (weights, bytes)
            }
            VariantSource::Fp16(path) => {
                let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                let p = load_fp16(path).with_context(|| format!("loading fp16 '{name}'"))?;
                if p.cfg() != self.base.cfg() {
                    bail!("fp16 checkpoint '{name}' config mismatch");
                }
                (VariantWeights::Dense(Arc::new(p)), bytes)
            }
        };
        Ok(LoadedVariant { weights, source, load_time: t0.elapsed(), bytes_read })
    }

    /// List variant names available on disk (deduped across formats).
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = std::collections::BTreeSet::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let p = entry?.path();
            if let Some(ext) = p.extension().and_then(|e| e.to_str()) {
                if ext == "pawd" || ext == "fp16" {
                    if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                        names.insert(stem.to_string());
                    }
                }
            }
        }
        Ok(names.into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::compress::{compress_model, CompressOptions, FitMode};
    use crate::delta::format::save_delta;
    use crate::model::checkpoint::save_fp16;
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};

    fn setup(dir: &Path) -> (Arc<FlatParams>, FlatParams) {
        std::fs::create_dir_all(dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 1);
        let ft = synth_finetune(&base, &SynthDeltaSpec::default());
        let docs: Vec<Vec<u8>> = (0..3).map(|i| vec![(i + 5) as u8; 24]).collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let (delta, _, _) = compress_model("va", &base, &ft, &docs, &opts);
        save_delta(dir.join("va.pawd"), &delta).unwrap();
        save_fp16(dir.join("vb.fp16"), &ft).unwrap();
        (Arc::new(base), ft)
    }

    #[test]
    fn store_lists_and_loads_both_formats() {
        let dir = std::env::temp_dir().join("pawd_test_store");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, ft) = setup(&dir);
        let store = VariantStore::new(base.clone(), &dir);
        assert_eq!(store.list().unwrap(), vec!["va".to_string(), "vb".to_string()]);

        let va = store.load("va").unwrap();
        assert!(matches!(va.source, VariantSource::Delta(_)));
        assert!(va.bytes_read > 0);
        assert_ne!(va.params().data, base.data);

        let vb = store.load("vb").unwrap();
        assert!(matches!(vb.source, VariantSource::Fp16(_)));
        // fp16 roundtrip of ft
        for (a, b) in vb.params().data.iter().zip(&ft.data) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1e-3));
        }
        assert!(store.load("nonexistent").is_err());
    }

    #[test]
    fn delta_artifact_is_much_smaller_and_loads() {
        let dir = std::env::temp_dir().join("pawd_test_store2");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, _ft) = setup(&dir);
        let store = VariantStore::new(base, &dir);
        let delta_sz = std::fs::metadata(dir.join("va.pawd")).unwrap().len();
        let fp16_sz = std::fs::metadata(dir.join("vb.fp16")).unwrap().len();
        // Table-2 shape: the delta is several times smaller (here only the
        // patchable modules are stored at ~1/16 of their fp16 bytes).
        assert!(delta_sz * 3 < fp16_sz, "delta {delta_sz} vs fp16 {fp16_sz}");
        let v = store.load("va").unwrap();
        assert!(v.load_time.as_nanos() > 0);
    }

    #[test]
    fn fused_mode_loads_packed_and_matches_dense_mode() {
        let dir = std::env::temp_dir().join("pawd_test_store3");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, _ft) = setup(&dir);
        let dense_store = VariantStore::new(base.clone(), &dir);
        let fused_store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);

        let dense = dense_store.load("va").unwrap();
        let fused = fused_store.load("va").unwrap();
        assert!(!dense.weights.is_packed());
        assert!(fused.weights.is_packed());
        // Packed residency is a small fraction of the dense equivalent.
        assert!(fused.weights.resident_bytes() * 4 < dense.weights.resident_bytes());
        // Materializing the packed variant reproduces the dense load.
        assert_eq!(fused.params().data, dense.params().data);
        // FP16 checkpoints are always dense, whatever the mode.
        let vb = fused_store.load("vb").unwrap();
        assert!(!vb.weights.is_packed());
    }
}
