//! Variant store: the loader side of the versioned registry — resolve a
//! variant alias (or explicit `name@N`) through [`VariantRegistry`], read the
//! artifact with **one sequential read**, and produce executable weights.
//!
//! What happens after the read depends on the store's [`ExecMode`]:
//!
//! * [`ExecMode::Fused`] (default for native serving) — the packed delta is
//!   validated against the resident base and kept packed; the returned
//!   [`VariantWeights::Packed`] executes in place through
//!   [`FusedDeltaLinear`](crate::exec::FusedDeltaLinear). No dense `Ŵ` is
//!   ever built, so "materialization" cost is just parse + validate.
//! * [`ExecMode::Dense`] — the classic path: clone the resident base and run
//!   one fused apply per module (required by the XLA engine, and the
//!   baseline side of the dense-vs-fused A/B).

use super::registry::{ArtifactKind, Resolved, VariantRegistry};
use crate::delta::apply::apply_deltas_inplace;
use crate::delta::chain;
use crate::delta::format::load_delta;
use crate::delta::types::DeltaModel;
use crate::exec::{ExecMode, PackedVariant, VariantWeights};
use crate::model::checkpoint::load_fp16;
use crate::model::FlatParams;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How a variant is stored on disk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VariantSource {
    /// A PAWD delta artifact applied onto the shared base (the paper's path).
    Delta(PathBuf),
    /// A full FP16 checkpoint (baseline path).
    Fp16(PathBuf),
}

#[derive(Clone)]
pub struct VariantStore {
    pub base: Arc<FlatParams>,
    registry: Arc<VariantRegistry>,
    mode: ExecMode,
}

/// A loaded variant plus its load-time accounting.
pub struct LoadedVariant {
    pub weights: VariantWeights,
    pub source: VariantSource,
    /// Version the alias resolved to (== `weights.version()`).
    pub version: u32,
    pub load_time: Duration,
    /// Bytes read from disk for this load.
    pub bytes_read: u64,
}

impl LoadedVariant {
    /// Dense parameters, materializing a packed variant on demand (XLA
    /// engine and ground-truth comparisons; the serving hot path never
    /// calls this in fused mode).
    pub fn params(&self) -> Arc<FlatParams> {
        self.weights.materialized()
    }
}

impl VariantStore {
    /// Open the registry for `dir` and build a store that materializes
    /// deltas on load (dense mode — the original behavior).
    pub fn open(base: Arc<FlatParams>, dir: &Path) -> Result<VariantStore> {
        Ok(VariantStore {
            base,
            registry: Arc::new(VariantRegistry::open(dir)?),
            mode: ExecMode::Dense,
        })
    }

    /// [`open`](Self::open) that panics on a corrupt registry manifest —
    /// kept because store construction predates the registry and most
    /// callers (tests, benches, examples) have no error path to thread.
    pub fn new(base: Arc<FlatParams>, dir: &Path) -> VariantStore {
        Self::open(base, dir).expect("opening variant registry")
    }

    /// Builder: choose how delta variants execute.
    pub fn with_mode(mut self, mode: ExecMode) -> VariantStore {
        self.mode = mode;
        self
    }

    pub fn set_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn dir(&self) -> &Path {
        self.registry.dir()
    }

    /// The lifecycle registry behind this store (publish/rollback/… live
    /// there; the server's admin plane calls straight through).
    pub fn registry(&self) -> &Arc<VariantRegistry> {
        &self.registry
    }

    /// Load a variant (the cold-start path under measurement). `name` may be
    /// a bare alias (active version) or `name@N`.
    pub fn load(&self, name: &str) -> Result<LoadedVariant> {
        let resolved = self.registry.resolve(name)?;
        self.load_resolved(&resolved)
    }

    /// Load an already-resolved version (the cache uses this so the version
    /// it keyed on is exactly the one loaded, even if a publish lands in
    /// between).
    pub fn load_resolved(&self, resolved: &Resolved) -> Result<LoadedVariant> {
        self.load_resolved_hinted(resolved, None)
    }

    /// [`load_resolved`](Self::load_resolved) with an optional **resident
    /// parent hint**: when `resolved` is a patch version and `parent_hint`
    /// is its direct parent's effective model (the cache passes the
    /// already-resident entry), only the patch file is read and every
    /// unchanged module is inherited as the parent's own `Arc` — the warm
    /// half of "a publish costs what actually changed".
    pub fn load_resolved_hinted(
        &self,
        resolved: &Resolved,
        parent_hint: Option<Arc<DeltaModel>>,
    ) -> Result<LoadedVariant> {
        let name = &resolved.name;
        let t0 = Instant::now();
        let mut bytes_read = std::fs::metadata(&resolved.path).map(|m| m.len()).unwrap_or(0);
        let (weights, source) = match resolved.kind {
            ArtifactKind::Delta => {
                let delta = if resolved.patch {
                    let links = self.registry.chain_links(name, resolved.version)?;
                    let first = chain::load_effective(&links, parent_hint.as_deref());
                    let (model, stats) = match first {
                        Ok(ok) => ok,
                        Err(_) => {
                            // A concurrent `consolidate` may have swapped
                            // the version's backing file (and unlinked the
                            // patch) between our chain walk and the reads.
                            // Re-resolve the chain once — post-consolidation
                            // it is a single full link — before giving up.
                            let links = self.registry.chain_links(name, resolved.version)?;
                            chain::load_effective(&links, parent_hint.as_deref()).with_context(
                                || format!("composing chain for '{name}@{}'", resolved.version),
                            )?
                        }
                    };
                    bytes_read = stats.bytes_read;
                    model
                } else {
                    load_delta(&resolved.path).with_context(|| {
                        format!("loading delta for '{name}@{}'", resolved.version)
                    })?
                };
                if delta.base_config != self.base.cfg().name {
                    bail!(
                        "delta '{name}' targets base '{}', store has '{}'",
                        delta.base_config,
                        self.base.cfg().name
                    );
                }
                if delta.meta.version != resolved.version {
                    bail!(
                        "artifact {} carries version {} but the registry resolved '{name}@{}' \
                         (manifest and file out of sync)",
                        resolved.path.display(),
                        delta.meta.version,
                        resolved.version
                    );
                }
                let weights = match self.mode {
                    ExecMode::Fused => {
                        // Keep the delta packed: validate shapes, index
                        // modules, share the base. No dense reconstruction.
                        VariantWeights::Packed(PackedVariant::new(
                            self.base.clone(),
                            Arc::new(delta),
                        )?)
                    }
                    ExecMode::Dense => {
                        // Clone the resident base, then one fused apply per
                        // module.
                        let mut p = (*self.base).clone();
                        apply_deltas_inplace(&mut p, &delta.modules);
                        VariantWeights::Dense(Arc::new(p), resolved.version)
                    }
                };
                (weights, VariantSource::Delta(resolved.path.clone()))
            }
            ArtifactKind::Fp16 => {
                let p = load_fp16(&resolved.path)
                    .with_context(|| format!("loading fp16 '{name}'"))?;
                if p.cfg() != self.base.cfg() {
                    bail!("fp16 checkpoint '{name}' config mismatch");
                }
                (
                    VariantWeights::Dense(Arc::new(p), resolved.version),
                    VariantSource::Fp16(resolved.path.clone()),
                )
            }
        };
        Ok(LoadedVariant {
            weights,
            source,
            version: resolved.version,
            load_time: t0.elapsed(),
            bytes_read,
        })
    }

    /// List variant names known to the registry.
    pub fn list(&self) -> Result<Vec<String>> {
        Ok(self.registry.names())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::compress::{compress_model, CompressOptions, FitMode};
    use crate::delta::format::save_delta;
    use crate::model::checkpoint::save_fp16;
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};

    fn setup(dir: &Path) -> (Arc<FlatParams>, FlatParams) {
        std::fs::create_dir_all(dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 1);
        let ft = synth_finetune(&base, &SynthDeltaSpec::default());
        let docs: Vec<Vec<u8>> = (0..3).map(|i| vec![(i + 5) as u8; 24]).collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let (delta, _, _) = compress_model("va", &base, &ft, &docs, &opts);
        save_delta(dir.join("va.pawd"), &delta).unwrap();
        save_fp16(dir.join("vb.fp16"), &ft).unwrap();
        (Arc::new(base), ft)
    }

    #[test]
    fn store_lists_and_loads_both_formats() {
        let dir = std::env::temp_dir().join("pawd_test_store");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, ft) = setup(&dir);
        let store = VariantStore::new(base.clone(), &dir);
        assert_eq!(store.list().unwrap(), vec!["va".to_string(), "vb".to_string()]);

        let va = store.load("va").unwrap();
        assert!(matches!(va.source, VariantSource::Delta(_)));
        assert_eq!(va.version, 1, "adopted legacy artifact is version 1");
        assert_eq!(va.weights.version(), 1);
        assert!(va.bytes_read > 0);
        assert_ne!(va.params().data, base.data);

        let vb = store.load("vb").unwrap();
        assert!(matches!(vb.source, VariantSource::Fp16(_)));
        // fp16 roundtrip of ft
        for (a, b) in vb.params().data.iter().zip(&ft.data) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1e-3));
        }
        assert!(store.load("nonexistent").is_err());
    }

    #[test]
    fn delta_artifact_is_much_smaller_and_loads() {
        let dir = std::env::temp_dir().join("pawd_test_store2");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, _ft) = setup(&dir);
        let store = VariantStore::new(base, &dir);
        let delta_sz = std::fs::metadata(dir.join("va.pawd")).unwrap().len();
        let fp16_sz = std::fs::metadata(dir.join("vb.fp16")).unwrap().len();
        // Table-2 shape: the delta is several times smaller (here only the
        // patchable modules are stored at ~1/16 of their fp16 bytes).
        assert!(delta_sz * 3 < fp16_sz, "delta {delta_sz} vs fp16 {fp16_sz}");
        let v = store.load("va").unwrap();
        assert!(v.load_time.as_nanos() > 0);
    }

    #[test]
    fn fused_mode_loads_packed_and_matches_dense_mode() {
        let dir = std::env::temp_dir().join("pawd_test_store3");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, _ft) = setup(&dir);
        let dense_store = VariantStore::new(base.clone(), &dir);
        let fused_store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);

        let dense = dense_store.load("va").unwrap();
        let fused = fused_store.load("va").unwrap();
        assert!(!dense.weights.is_packed());
        assert!(fused.weights.is_packed());
        // Packed residency is a small fraction of the dense equivalent.
        assert!(fused.weights.resident_bytes() * 4 < dense.weights.resident_bytes());
        // Materializing the packed variant reproduces the dense load.
        assert_eq!(fused.params().data, dense.params().data);
        // FP16 checkpoints are always dense, whatever the mode.
        let vb = fused_store.load("vb").unwrap();
        assert!(!vb.weights.is_packed());
    }

    #[test]
    fn patch_versions_load_through_the_chain_in_both_modes() {
        let dir = std::env::temp_dir().join("pawd_test_store5");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, _ft) = setup(&dir);
        let fused = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
        let registry = fused.registry().clone();
        // Child effective model: v1 with one module's scales doubled
        // (doubling an f16-exact value stays f16-exact, so on-disk content
        // roundtrips bitwise).
        let mut v2 = registry.effective_model("va", 1).unwrap();
        {
            let m = Arc::make_mut(&mut v2.modules[0]);
            for s in &mut m.scales {
                *s *= 2.0;
            }
        }
        let out = registry.publish_incremental("va", v2.clone(), None).unwrap();
        assert!(out.patch, "single-module change must ship as a patch");

        let loaded = fused.load("va").unwrap();
        assert_eq!((loaded.version, loaded.weights.version()), (out.version, out.version));
        assert!(loaded.weights.is_packed());
        assert!(loaded.bytes_read > 0);
        let want = crate::delta::apply::materialize(&base, &v2.modules);
        assert_eq!(loaded.params().data, want.data, "fused chain load must compose the child");
        // Dense mode composes the same chain, then materializes. (A fresh
        // store reopens the manifest, exercising patch-record persistence.)
        drop(fused);
        let dense = VariantStore::new(base.clone(), &dir);
        let dl = dense.load("va").unwrap();
        assert!(!dl.weights.is_packed());
        assert_eq!(dl.params().data, want.data, "dense chain load must compose the child");
    }

    #[test]
    fn publish_flips_what_the_bare_alias_loads() {
        let dir = std::env::temp_dir().join("pawd_test_store4");
        let _ = std::fs::remove_dir_all(&dir);
        let (base, ft) = setup(&dir);
        let store = VariantStore::new(base.clone(), &dir).with_mode(ExecMode::Fused);
        assert_eq!(store.load("va").unwrap().version, 1);
        // Publish a second version with different content.
        let docs: Vec<Vec<u8>> = (0..3).map(|i| vec![(i + 50) as u8; 24]).collect();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let (delta2, _, _) = compress_model("va", &base, &ft, &docs, &opts);
        let v2 = store.registry().publish("va", delta2).unwrap();
        assert_eq!(v2, 2);
        let loaded = store.load("va").unwrap();
        assert_eq!((loaded.version, loaded.weights.version()), (2, 2));
        // Old version stays addressable; rollback restores it as the alias.
        assert_eq!(store.load("va@1").unwrap().version, 1);
        store.registry().rollback("va", None).unwrap();
        assert_eq!(store.load("va").unwrap().version, 1);
    }
}
