//! Serving metrics: latency histograms per stage, throughput counters,
//! cold-start accounting, and variant-cache residency gauges. Shared across
//! dispatcher/workers via a mutex (recording is a few hundred ns; the
//! engine dominates by orders of magnitude).

use super::cache::{Residency, VersionResidency};
use crate::util::stats::LatencyHistogram;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Default)]
struct Inner {
    queue: LatencyHistogram,
    compute: LatencyHistogram,
    total: LatencyHistogram,
    cold_start: LatencyHistogram,
    served: u64,
    errors: u64,
    batches: u64,
    batch_size_sum: u64,
    swaps: u64,
    publishes: u64,
    rollbacks: u64,
    residency: Residency,
    per_variant: BTreeMap<String, u64>,
    started: Option<Instant>,
}

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Read-only snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub served: u64,
    pub errors: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub throughput_rps: f64,
    pub queue_p50_us: u64,
    pub queue_p99_us: u64,
    pub compute_p50_us: u64,
    pub compute_p99_us: u64,
    pub total_p50_us: u64,
    pub total_p99_us: u64,
    pub cold_starts: u64,
    pub cold_p50_us: u64,
    /// Worker-observed variant-context switches: a swap is a worker's
    /// batch window executing a `(variant, version)` that was not part of
    /// its previous window (with packed residency this is a pointer flip).
    /// Steady traffic over a fixed mixed set records zero swaps — the
    /// shared-base batched path switches nothing.
    pub swaps: u64,
    /// Control-plane publishes served (alias flips to a new version).
    pub publishes: u64,
    /// Control-plane rollbacks served (alias flips back).
    pub rollbacks: u64,
    /// Variant versions resident in the cache (last observed).
    pub resident_variants: usize,
    /// Bytes charged against the cache budget (packed bytes in fused mode).
    pub resident_bytes: u64,
    /// What the resident set would cost fully materialized; the ratio
    /// `dense_equiv / resident` is the capacity multiplier of the packed
    /// cache.
    pub resident_dense_equiv_bytes: u64,
    /// Per-`(variant, version)` residency breakdown (last observed) — shows
    /// a publish warming `N+1` while `N` ages out.
    pub resident_versions: Vec<VersionResidency>,
    pub per_variant: BTreeMap<String, u64>,
    /// Base-weight GEMMs executed (process-wide, from
    /// [`exec::counters`](crate::exec::counters)); the batched path runs
    /// one per module per mixed-variant window.
    pub base_gemms: u64,
    /// Artifact bytes read by the loader (packed `.pawd` payloads).
    pub loader_bytes: u64,
    /// Per-module section reads during artifact loads.
    pub module_reads: u64,
    /// Modules inherited from a resident parent instead of re-read — the
    /// patch-chain cache-sharing win.
    pub modules_inherited: u64,
    /// Bytes moved by replication transports (fs + http).
    pub wire_bytes: u64,
    /// Files fetched by replication transports.
    pub wire_files: u64,
    /// Activation rows traversed by fused kernels; the prefix cache exists
    /// to shrink this.
    pub activation_row_reads: u64,
    /// Compute-pool chunks executed (process-wide, from
    /// [`exec::counters`](crate::exec::counters)). Zero means every kernel
    /// ran on its caller thread (serial widths / tiny inputs).
    pub pool_tasks: u64,
    /// Nanoseconds pool workers spent parked waiting for work — the
    /// idle/steal budget the continuous engine is meant to shrink.
    pub pool_steal_or_idle_ns: u64,
    /// Engine step boundaries that flushed a window to a worker.
    pub engine_steps: u64,
    /// HTTP requests served by the network plane (0 when no front-end is
    /// attached to this process).
    pub http_requests: u64,
    /// Manifest long-polls that parked waiting for a registry change.
    pub http_long_polls: u64,
    /// Sequences that resumed from the cross-window prefix cache.
    pub prefix_cache_hits: u64,
    /// Cacheable prefixes that had to be computed cold.
    pub prefix_cache_misses: u64,
    /// Bytes resident in the prefix cache (gauge).
    pub prefix_cache_bytes: u64,
    /// Stacked activation rows skipped because a cached prefix supplied
    /// their K/V and logits.
    pub prefix_rows_skipped: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        let m = Metrics::default();
        m.inner.lock().unwrap().started = Some(Instant::now());
        m
    }

    pub fn record_request(
        &self,
        variant: &str,
        queue: Duration,
        compute: Duration,
        total: Duration,
        error: bool,
    ) {
        let mut i = self.inner.lock().unwrap();
        i.queue.record(queue);
        i.compute.record(compute);
        i.total.record(total);
        i.served += 1;
        if error {
            i.errors += 1;
        }
        *i.per_variant.entry(variant.to_string()).or_insert(0) += 1;
    }

    pub fn record_batch(&self, size: usize) {
        let mut i = self.inner.lock().unwrap();
        i.batches += 1;
        i.batch_size_sum += size as u64;
    }

    pub fn record_cold_start(&self, d: Duration) {
        self.inner.lock().unwrap().cold_start.record(d);
    }

    /// A worker entered a variant context that was not part of its
    /// previous batch window.
    pub fn record_swap(&self) {
        self.inner.lock().unwrap().swaps += 1;
    }

    /// A publish flipped (or, for a pinned variant, recorded) a new version.
    pub fn record_publish(&self) {
        self.inner.lock().unwrap().publishes += 1;
    }

    /// A rollback flipped the alias back.
    pub fn record_rollback(&self) {
        self.inner.lock().unwrap().rollbacks += 1;
    }

    /// Update the residency gauges (workers call this after cache access).
    pub fn set_residency(&self, r: Residency) {
        self.inner.lock().unwrap().residency = r;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let i = self.inner.lock().unwrap();
        snapshot_inner(&i)
    }

    /// Install `r` as the residency gauge and snapshot under a single lock
    /// acquisition — the stats endpoint uses this so a data worker's
    /// concurrent totals-only gauge update can't blank `resident_versions`
    /// between the two steps.
    pub fn snapshot_with_residency(&self, r: Residency) -> MetricsSnapshot {
        let mut i = self.inner.lock().unwrap();
        i.residency = r;
        snapshot_inner(&i)
    }
}

fn snapshot_inner(i: &Inner) -> MetricsSnapshot {
    let elapsed = i.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
    MetricsSnapshot {
        served: i.served,
        errors: i.errors,
        batches: i.batches,
        mean_batch_size: if i.batches > 0 {
            i.batch_size_sum as f64 / i.batches as f64
        } else {
            0.0
        },
        throughput_rps: if elapsed > 0.0 { i.served as f64 / elapsed } else { 0.0 },
        queue_p50_us: i.queue.quantile_us(0.5),
        queue_p99_us: i.queue.quantile_us(0.99),
        compute_p50_us: i.compute.quantile_us(0.5),
        compute_p99_us: i.compute.quantile_us(0.99),
        total_p50_us: i.total.quantile_us(0.5),
        total_p99_us: i.total.quantile_us(0.99),
        cold_starts: i.cold_start.count(),
        cold_p50_us: i.cold_start.quantile_us(0.5),
        swaps: i.swaps,
        publishes: i.publishes,
        rollbacks: i.rollbacks,
        resident_variants: i.residency.variants,
        resident_bytes: i.residency.resident_bytes,
        resident_dense_equiv_bytes: i.residency.dense_equiv_bytes,
        resident_versions: i.residency.per_version.clone(),
        per_variant: i.per_variant.clone(),
        base_gemms: crate::exec::counters::base_gemms(),
        loader_bytes: crate::exec::counters::loader_bytes(),
        module_reads: crate::exec::counters::module_reads(),
        modules_inherited: crate::exec::counters::modules_inherited(),
        wire_bytes: crate::exec::counters::wire_bytes(),
        wire_files: crate::exec::counters::wire_files(),
        activation_row_reads: crate::exec::counters::activation_row_reads(),
        pool_tasks: crate::exec::counters::pool_tasks(),
        pool_steal_or_idle_ns: crate::exec::counters::pool_steal_or_idle_ns(),
        engine_steps: crate::exec::counters::engine_steps(),
        http_requests: crate::exec::counters::http_requests(),
        http_long_polls: crate::exec::counters::http_long_polls(),
        prefix_cache_hits: crate::exec::counters::prefix_cache_hits(),
        prefix_cache_misses: crate::exec::counters::prefix_cache_misses(),
        prefix_cache_bytes: crate::exec::counters::prefix_cache_bytes(),
        prefix_rows_skipped: crate::exec::counters::prefix_rows_skipped(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        let us = Duration::from_micros;
        m.record_request("a", us(10), us(100), us(120), false);
        m.record_request("b", us(20), us(200), us(230), true);
        m.record_batch(2);
        m.record_cold_start(Duration::from_millis(5));
        let s = m.snapshot();
        assert_eq!(s.served, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_size, 2.0);
        assert_eq!(s.cold_starts, 1);
        assert_eq!(s.per_variant["a"], 1);
        assert!(s.total_p99_us >= s.total_p50_us);
    }

    #[test]
    fn residency_and_swap_gauges() {
        let m = Metrics::new();
        m.record_swap();
        m.record_swap();
        m.record_publish();
        m.record_rollback();
        m.set_residency(Residency {
            variants: 5,
            resident_bytes: 1000,
            dense_equiv_bytes: 16000,
            per_version: vec![VersionResidency {
                variant: "a".into(),
                version: 2,
                bytes: 1000,
            }],
        });
        let s = m.snapshot();
        assert_eq!(s.swaps, 2);
        assert_eq!((s.publishes, s.rollbacks), (1, 1));
        assert_eq!(s.resident_variants, 5);
        assert_eq!(s.resident_bytes, 1000);
        assert_eq!(s.resident_dense_equiv_bytes, 16000);
        assert_eq!(s.resident_versions.len(), 1);
        assert_eq!(s.resident_versions[0].version, 2);
    }
}
