//! Patch-aware artifact replication between nodes — the seed of a serving
//! fleet.
//!
//! A **follower** node mirrors a **leader**'s registry: it pulls the
//! leader's JSON manifest (stamped with a monotonic `manifest_seq`), diffs
//! it against its local [`VariantRegistry`], and fetches only the artifact
//! files it is missing. Because the registry ships format-v3 **patch
//! artifacts** (PR 4), a follower that already holds a variant's chain
//! parent moves only the patch over the wire — BitDelta/DeltaZip's ~1/16
//! compression applied *between* versions, so steady-state replication of a
//! ~5%-changed publish costs a few percent of the consolidated bytes. Cold
//! variants fall back to fetching their consolidated chain (the base full
//! artifact plus any patches the leader still serves through).
//!
//! Safety: every fetched delta artifact is decoded and **whole-file
//! crc-verified** before anything is committed, fetched patches must
//! **compose** through [`chain::load_effective`] over their (local or
//! just-fetched) parent chain, and the manifest commit
//! ([`VariantRegistry::apply_replica`]) runs strictly after all of a
//! variant's files are verified and in place. In-flight downloads live
//! under a `.sync.tmp` suffix that neither the loader nor directory
//! adoption will touch, so a crash mid-sync leaves either ignorable temp
//! files or fully verified artifacts — never a manifest record pointing at
//! a partial file.
//!
//! Transport is abstracted behind [`SyncTransport`]; [`FsTransport`] covers
//! shared-filesystem and single-host multi-process topologies (and the
//! tests/bench) without a network stack, and
//! [`HttpTransport`](crate::net::HttpTransport) pulls the same manifest and
//! files over HTTP/1.1 with long-poll manifest waits
//! ([`Replicator::sync_wait`]) instead of interval polling. Wire traffic is
//! recorded in [`exec::counters`](crate::exec::counters)
//! (`wire_bytes`/`wire_files`) so the replication bench can assert the
//! patch-aware transfer structure.
//!
//! Followers are replicas: their registry directory must not take local
//! publishes (a same-version disagreement with the leader fails the sync as
//! "diverged"). Local *reads* — serving, cache warms, local gc of versions
//! the leader retired — are all fine.

use super::cache::VariantCache;
use super::registry::{
    live_file_versions, parse_manifest_view, ArtifactKind, ManifestView, VariantDesc,
    VariantRegistry, VersionRecord, MANIFEST_FILE,
};
use crate::delta::chain::{self, ChainLink};
use crate::delta::format::load_delta;
use crate::delta::types::DeltaModel;
use crate::exec::counters;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Result of a change-aware manifest fetch
/// ([`SyncTransport::fetch_manifest_wait`]).
pub enum ManifestFetch {
    /// The leader manifest bytes (the sequence number is inside them).
    Full(Vec<u8>),
    /// The leader's manifest still sits at the follower's `known_seq`; only
    /// `wire_bytes` bytes of headers moved to learn that (an HTTP 304).
    Unchanged { seq: u64, wire_bytes: u64 },
}

/// How a follower reaches a leader's registry. Implementations move opaque
/// bytes; all verification (crc, chain composition, manifest consistency)
/// happens in the [`Replicator`] regardless of transport.
pub trait SyncTransport: Send + Sync {
    /// Human-readable peer description for logs/status.
    fn describe(&self) -> String;

    /// Fetch the leader's current manifest (`registry.json`) bytes.
    fn fetch_manifest(&self) -> Result<Vec<u8>>;

    /// Change-aware manifest fetch: block up to `timeout` while the leader's
    /// manifest sequence number still equals `known_seq`, then return either
    /// the new manifest or [`ManifestFetch::Unchanged`]. The default
    /// implementation cannot wait (a plain filesystem has no change
    /// notification worth blocking on) and just fetches; transports with a
    /// server on the other end (HTTP long-poll) override it.
    fn fetch_manifest_wait(
        &self,
        known_seq: Option<u64>,
        timeout: Duration,
    ) -> Result<ManifestFetch> {
        let _ = (known_seq, timeout);
        Ok(ManifestFetch::Full(self.fetch_manifest()?))
    }

    /// Fetch the artifact file named `file` (a bare file name inside the
    /// leader's registry directory) into `dest`. Returns the bytes moved.
    fn fetch_file(&self, file: &str, dest: &Path) -> Result<u64>;
}

/// Filesystem/loopback transport: the leader's registry directory is
/// directly readable (same host, NFS, or a synced mount). This is also what
/// single-host multi-process setups and the tests use.
pub struct FsTransport {
    root: PathBuf,
}

impl FsTransport {
    pub fn new(root: &Path) -> FsTransport {
        FsTransport { root: root.to_path_buf() }
    }
}

impl SyncTransport for FsTransport {
    fn describe(&self) -> String {
        format!("fs:{}", self.root.display())
    }

    fn fetch_manifest(&self) -> Result<Vec<u8>> {
        let path = self.root.join(MANIFEST_FILE);
        std::fs::read(&path).with_context(|| format!("fetching leader manifest {}", path.display()))
    }

    fn fetch_file(&self, file: &str, dest: &Path) -> Result<u64> {
        let src = self.root.join(file);
        // fs::copy streams (no whole-artifact buffer) and returns the bytes
        // moved — cold syncs ship multi-MB consolidated artifacts.
        std::fs::copy(&src, dest)
            .with_context(|| format!("fetching artifact {}", src.display()))
    }
}

/// Outcome of one [`Replicator::sync_once`] pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SyncReport {
    /// The leader's manifest sequence number this pass observed.
    pub leader_seq: u64,
    /// `true` when the leader manifest carried nothing new (fast path when
    /// the sequence number is unchanged since the last successful sync).
    pub up_to_date: bool,
    /// Variants whose local state changed (records installed, retired flags
    /// mirrored, or alias moved).
    pub variants_synced: usize,
    /// Version records newly installed locally.
    pub versions_installed: usize,
    /// Artifact files fetched over the transport.
    pub files_fetched: usize,
    /// Of those, how many were patch artifacts (the headline metric: warm
    /// followers should fetch *only* patches).
    pub patch_files_fetched: usize,
    /// Artifact bytes moved over the transport (manifest excluded).
    pub artifact_bytes: u64,
    /// Manifest bytes moved over the transport.
    pub manifest_bytes: u64,
    /// Synced variants whose cache warm-up failed. Warming is best-effort —
    /// the records are committed either way and the variant simply
    /// cold-loads on its first request — so a warm failure must not abort
    /// the pass (the next sync would see the variant identical to the
    /// leader and never retry the warm).
    pub warm_failures: usize,
}

/// A follower's replication engine over one local registry and one
/// transport to a leader. Stateless between passes except for the last
/// successfully applied leader sequence number (the cheap "anything new?"
/// check `--follow` mode polls on).
pub struct Replicator {
    registry: Arc<VariantRegistry>,
    transport: Box<dyn SyncTransport>,
    /// Last leader `manifest_seq` fully applied; `u64::MAX` = never synced.
    last_applied_seq: AtomicU64,
}

impl Replicator {
    pub fn new(registry: Arc<VariantRegistry>, transport: Box<dyn SyncTransport>) -> Replicator {
        Replicator { registry, transport, last_applied_seq: AtomicU64::new(u64::MAX) }
    }

    /// The peer this replicator pulls from.
    pub fn peer(&self) -> String {
        self.transport.describe()
    }

    /// Seed the "anything new?" fast path with a leader sequence number a
    /// previous (possibly dropped) Replicator already applied in full — the
    /// server's admin plane builds a fresh Replicator per `PullFrom` and
    /// carries the sequence across calls so no-op polls stay cheap.
    pub fn resume_from(&self, applied_seq: u64) {
        self.last_applied_seq.store(applied_seq, Ordering::SeqCst);
    }

    /// The last leader sequence number applied in full, if any pass has
    /// completed (the value [`sync_wait`](Self::sync_wait) hands the leader
    /// as its `known_seq`).
    pub fn last_applied_seq(&self) -> Option<u64> {
        match self.last_applied_seq.load(Ordering::SeqCst) {
            u64::MAX => None,
            seq => Some(seq),
        }
    }

    /// Pull the leader manifest, diff, fetch what is missing, verify and
    /// commit. With `cache`, freshly synced variants are warmed on arrival —
    /// a patch version composes onto the resident parent, so the follower's
    /// first request after a sync hits resident weights whose marginal cost
    /// was only what changed.
    pub fn sync_once(&self, cache: Option<&VariantCache>) -> Result<SyncReport> {
        let manifest_bytes = self.transport.fetch_manifest()?;
        self.apply_manifest(manifest_bytes, cache)
    }

    /// [`sync_once`](Self::sync_once), but change-aware: hand the transport
    /// the last fully-applied leader sequence number and let it block up to
    /// `timeout` for a change ([`SyncTransport::fetch_manifest_wait`]). Over
    /// HTTP this is a long-poll — an idle follower's pass moves only the
    /// request/304 headers and returns `up_to_date`, and a leader publish
    /// wakes the waiting request immediately instead of on the next poll
    /// tick. Transports without a waiting side (filesystem) degrade to a
    /// plain fetch, so `--follow` loops can call this unconditionally.
    pub fn sync_wait(
        &self,
        cache: Option<&VariantCache>,
        timeout: Duration,
    ) -> Result<SyncReport> {
        match self.transport.fetch_manifest_wait(self.last_applied_seq(), timeout)? {
            ManifestFetch::Full(bytes) => self.apply_manifest(bytes, cache),
            ManifestFetch::Unchanged { seq, wire_bytes } => {
                counters::record_wire_bytes(wire_bytes);
                Ok(SyncReport {
                    leader_seq: seq,
                    up_to_date: true,
                    manifest_bytes: wire_bytes,
                    ..Default::default()
                })
            }
        }
    }

    /// Diff + fetch + verify + commit against already-fetched leader
    /// manifest bytes (the tail of both sync entry points).
    fn apply_manifest(
        &self,
        manifest_bytes: Vec<u8>,
        cache: Option<&VariantCache>,
    ) -> Result<SyncReport> {
        counters::record_wire_bytes(manifest_bytes.len() as u64);
        let text = std::str::from_utf8(&manifest_bytes)
            .context("leader manifest is not valid UTF-8")?;
        let view: ManifestView = parse_manifest_view(text)
            .with_context(|| format!("parsing leader manifest from {}", self.transport.describe()))?;
        let mut report = SyncReport {
            leader_seq: view.manifest_seq,
            manifest_bytes: manifest_bytes.len() as u64,
            ..Default::default()
        };
        // Sequence fast path: a leader manifest we already applied in full
        // needs no diff. Sequence 0 (pre-replication manifest) always diffs.
        if view.manifest_seq > 0
            && self.last_applied_seq.load(Ordering::SeqCst) == view.manifest_seq
        {
            report.up_to_date = true;
            return Ok(report);
        }
        let local: HashMap<String, VariantDesc> =
            self.registry.list().into_iter().map(|d| (d.name.clone(), d)).collect();
        let mut any_changed = false;
        for leader in &view.variants {
            let local_desc = local.get(&leader.name);
            if !variant_differs(leader, local_desc) {
                continue;
            }
            let (installed, fetched, patch_fetched, bytes) =
                self.sync_variant(leader, local_desc, cache)?;
            report.variants_synced += 1;
            report.versions_installed += installed;
            report.files_fetched += fetched;
            report.patch_files_fetched += patch_fetched;
            report.artifact_bytes += bytes;
            any_changed = true;
            // Warm-on-arrival, immediately after this variant's commit (not
            // after the whole pass: a later variant's failed fetch must not
            // leave an already-committed one cold — the next sync would see
            // it identical to the leader and never warm it). Best-effort:
            // the commit already landed, so a warm failure is reported, not
            // fatal (the variant cold-loads on its first request). The
            // version-addressed get composes a patch version onto the
            // resident parent, so only the patch is read.
            if let Some(cache) = cache {
                if cache.get(&format!("{}@{}", leader.name, leader.active)).is_err() {
                    report.warm_failures += 1;
                }
            }
        }
        report.up_to_date = !any_changed;
        self.last_applied_seq.store(view.manifest_seq, Ordering::SeqCst);
        Ok(report)
    }

    /// Sync one variant: fetch + verify every missing artifact file
    /// (ascending version order, so chain parents always land before their
    /// patches), then commit the leader's records and alias in one manifest
    /// write. Returns `(records_installed, files_fetched, patch_files,
    /// artifact_bytes)`.
    fn sync_variant(
        &self,
        leader: &VariantDesc,
        local: Option<&VariantDesc>,
        cache: Option<&VariantCache>,
    ) -> Result<(usize, usize, usize, u64)> {
        let name = &leader.name;
        let dir = self.registry.dir().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating follower registry dir {}", dir.display()))?;
        let local_by_version: HashMap<u32, &VersionRecord> = local
            .map(|d| d.versions.iter().map(|r| (r.version, r)).collect())
            .unwrap_or_default();
        // Planned post-sync record set (local ∪ leader), for chain walks
        // over versions whose records are not committed yet.
        let planned: HashMap<u32, &VersionRecord> = {
            let mut m: HashMap<u32, &VersionRecord> = local_by_version.clone();
            for rec in &leader.versions {
                m.insert(rec.version, rec);
            }
            m
        };
        // Versions whose files must be on disk to serve: every non-retired
        // version, plus every chain ancestor a live patch composes through
        // (shared with the gc sweep, which pins the same set). Retired
        // versions outside any live chain replicate as records only: their
        // files would never be servable, a local gc would delete them
        // immediately, and fetching them races leader-side gc unlinking the
        // very same files.
        let file_needed =
            live_file_versions(leader.versions.iter(), |p| planned.get(&p).copied());
        let mut installed = 0usize;
        let mut fetched = 0usize;
        let mut patch_fetched = 0usize;
        let mut bytes = 0u64;
        for rec in &leader.versions {
            let need_file = !rec.file.is_empty() && file_needed.contains(&rec.version);
            let need_fetch = match local_by_version.get(&rec.version) {
                None => {
                    installed += 1;
                    need_file // tombstones/dead retired versions: record only
                }
                // The leader consolidated this version in place: the full
                // file replaces the local patch.
                Some(existing) => {
                    need_file && existing.patch && !rec.patch && existing.file != rec.file
                }
            };
            if !need_fetch {
                continue;
            }
            ensure_bare_file_name(&rec.file)?;
            // Resident direct parent as a composition hint: verifying a
            // fetched patch then reads only the patch, not the whole parent
            // chain from disk (the steady-state sync path).
            let parent_hint: Option<Arc<DeltaModel>> = match (cache, rec.patch, rec.parent) {
                (Some(c), true, Some(p)) => c.resident_delta(name, p),
                _ => None,
            };
            let final_path = dir.join(&rec.file);
            if final_path.exists() {
                // Left by an interrupted sync (verified before rename) or a
                // shared filesystem. Never commit it blind: re-verify in
                // place, and fall through to a fresh fetch (atomic rename
                // over it) if the verification fails.
                if verify_fetched(&final_path, rec, name, &planned, &dir, parent_hint.as_deref())
                    .is_ok()
                {
                    continue;
                }
            }
            let tmp = dir.join(format!("{}.sync.tmp", rec.file));
            let n = match self.transport.fetch_file(&rec.file, &tmp) {
                Ok(n) => n,
                Err(e) => {
                    let _ = std::fs::remove_file(&tmp);
                    return Err(e.context(format!("fetching '{name}@{}'", rec.version)));
                }
            };
            counters::record_wire_bytes(n);
            counters::record_wire_file();
            if let Err(e) =
                verify_fetched(&tmp, rec, name, &planned, &dir, parent_hint.as_deref())
            {
                let _ = std::fs::remove_file(&tmp);
                return Err(e);
            }
            std::fs::rename(&tmp, &final_path)
                .with_context(|| format!("installing fetched artifact {}", rec.file))?;
            fetched += 1;
            bytes += n;
            if rec.patch {
                patch_fetched += 1;
            }
        }
        self.registry
            .apply_replica(name, &leader.versions, leader.active, leader.pinned)
            .with_context(|| format!("committing replicated state of '{name}'"))?;
        Ok((installed, fetched, patch_fetched, bytes))
    }
}

/// Whether the leader's view of a variant differs from the local one in any
/// replicated dimension (record set, files, patch/retired flags, alias).
fn variant_differs(leader: &VariantDesc, local: Option<&VariantDesc>) -> bool {
    let Some(local) = local else { return true };
    if leader.active != local.active || leader.pinned != local.pinned {
        return true;
    }
    let local_by_version: HashMap<u32, &VersionRecord> =
        local.versions.iter().map(|r| (r.version, r)).collect();
    leader.versions.iter().any(|rec| match local_by_version.get(&rec.version) {
        None => true,
        Some(e) => {
            // A leader tombstone only matters while the local record is
            // still serving (retired flag mismatch); file presence is a
            // local gc decision.
            (!rec.file.is_empty() && e.file != rec.file)
                || e.patch != rec.patch && !rec.file.is_empty()
                || (rec.retired && !e.retired)
        }
    })
}

/// Verify a fetched artifact before it is renamed into the registry
/// directory: decode + whole-file crc (delta artifacts), meta agreement
/// with the leader record, and — for patches — composition through the
/// planned parent chain (`resident_parent`, when it is the direct parent's
/// effective model, keeps that composition to a single patch read).
fn verify_fetched(
    tmp: &Path,
    rec: &VersionRecord,
    name: &str,
    planned: &HashMap<u32, &VersionRecord>,
    dir: &Path,
    resident_parent: Option<&DeltaModel>,
) -> Result<()> {
    match rec.kind {
        ArtifactKind::Fp16 => {
            let len = std::fs::metadata(tmp).map(|m| m.len()).unwrap_or(0);
            if len == 0 || (rec.bytes > 0 && len != rec.bytes) {
                bail!(
                    "fetched fp16 '{name}@{}' is {len} bytes, leader manifest says {}",
                    rec.version,
                    rec.bytes
                );
            }
            Ok(())
        }
        ArtifactKind::Delta => {
            let model = load_delta(tmp)
                .with_context(|| format!("verifying fetched '{name}@{}'", rec.version))?;
            if model.meta.version != rec.version {
                bail!(
                    "fetched artifact for '{name}@{}' carries embedded version {} \
                     (leader manifest and file out of sync)",
                    rec.version,
                    model.meta.version
                );
            }
            if model.meta.is_patch != rec.patch {
                bail!(
                    "fetched artifact for '{name}@{}' patch flag disagrees with the \
                     leader manifest",
                    rec.version
                );
            }
            if !rec.patch {
                return Ok(());
            }
            // Compose the planned chain ending at this patch: the final
            // link reads from the temp file, ancestors from the registry
            // dir (committed earlier or installed earlier in this pass).
            let mut links = vec![ChainLink {
                version: rec.version,
                path: tmp.to_path_buf(),
                is_patch: true,
            }];
            let mut v = rec.parent;
            while let Some(pv) = v {
                let prec = planned.get(&pv).ok_or_else(|| {
                    anyhow::anyhow!(
                        "patch '{name}@{}' composes through v{pv}, which neither the \
                         follower nor the leader manifest records",
                        rec.version
                    )
                })?;
                if prec.file.is_empty() {
                    bail!(
                        "patch '{name}@{}' composes through v{pv}, which was \
                         garbage-collected on the leader",
                        rec.version
                    );
                }
                links.push(ChainLink {
                    version: pv,
                    path: dir.join(&prec.file),
                    is_patch: prec.patch,
                });
                v = if prec.patch { prec.parent } else { None };
                if links.len() > chain::HARD_CHAIN_BOUND {
                    bail!("replicated chain of '{name}@{}' exceeds the backstop", rec.version);
                }
            }
            links.reverse();
            chain::load_effective(&links, resident_parent)
                .with_context(|| {
                    format!("composing fetched patch '{name}@{}' over its chain", rec.version)
                })
                .map(|_| ())
        }
    }
}

/// Reject artifact file names that could escape the registry directory.
/// Shared with the HTTP file route, which applies the same rule to
/// client-supplied names before touching the filesystem.
pub(crate) fn ensure_bare_file_name(file: &str) -> Result<()> {
    if file.is_empty()
        || file.contains('/')
        || file.contains('\\')
        || file.contains("..")
        || file.starts_with('.')
    {
        bail!("leader manifest names unsafe artifact file '{file}'");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_file_names_enforced() {
        assert!(ensure_bare_file_name("ft@1.pawd").is_ok());
        assert!(ensure_bare_file_name("ft@2-full.pawd").is_ok());
        for bad in ["", "../x.pawd", "a/b.pawd", "..", ".hidden", "c\\d.pawd"] {
            assert!(ensure_bare_file_name(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn variant_differs_detects_each_dimension() {
        let rec = |version: u32, file: &str, patch: bool, retired: bool| VersionRecord {
            version,
            parent: None,
            created_unix: 0,
            file: file.to_string(),
            kind: ArtifactKind::Delta,
            bytes: 1,
            retired,
            patch,
        };
        let leader = VariantDesc {
            name: "ft".into(),
            active: 2,
            pinned: false,
            versions: vec![rec(1, "ft@1.pawd", false, false), rec(2, "ft@2.pawd", true, false)],
        };
        assert!(variant_differs(&leader, None), "unknown variant always syncs");
        let synced = leader.clone();
        assert!(!variant_differs(&leader, Some(&synced)), "identical state skips");
        let mut rolled = synced.clone();
        rolled.active = 1;
        assert!(variant_differs(&leader, Some(&rolled)), "alias move syncs");
        let mut missing = synced.clone();
        missing.versions.pop();
        assert!(variant_differs(&leader, Some(&missing)), "missing version syncs");
        let mut retired_leader = leader.clone();
        retired_leader.versions[0].retired = true;
        retired_leader.active = 2;
        assert!(
            variant_differs(&retired_leader, Some(&synced)),
            "leader-side retire syncs"
        );
        // A leader tombstone of a version the follower retired already does
        // not force a pointless sync.
        let mut tomb_leader = leader.clone();
        tomb_leader.versions[0].file = String::new();
        tomb_leader.versions[0].retired = true;
        let mut tomb_local = synced.clone();
        tomb_local.versions[0].retired = true;
        assert!(!variant_differs(&tomb_leader, Some(&tomb_local)));
    }
}
