//! Variant lifecycle registry: the control-plane source of truth mapping
//! `variant` aliases to versioned artifacts (`variant@N`).
//!
//! The paper's premise is *frequently updated* fine-tunes; this is the piece
//! that makes an update a first-class operation instead of a file rename:
//!
//! * **publish** — assign the next version number, stamp the artifact's
//!   [`ArtifactMeta`] (version / parent / created_unix), write it as
//!   `variant@N.pawd`, and atomically flip the alias so *new* requests
//!   resolve to `N` while in-flight requests finish on the `Arc` of the old
//!   version they already hold.
//! * **publish_incremental** — diff the new effective model against a parent
//!   version and ship a **patch artifact** carrying only the changed
//!   modules (falling back to a full publish when there is no usable parent
//!   or the diff is inexpressible). Loads of a patch version compose the
//!   parent chain ([`chain`](crate::delta::chain)).
//! * **consolidate** — rebase a version's patch chain into a single full
//!   artifact in place (same version number; the record's file is swapped),
//!   bounding chain depth and freeing the lineage for retirement.
//! * **rollback** — flip the alias back to the active version's parent (or
//!   an explicit target).
//! * **pin / unpin** — freeze the alias on one version; publishes still
//!   record new versions but stop moving the alias until unpinned.
//! * **retire** — mark an old version unservable (resolution of `name@N`
//!   fails fast); the active version can never be retired, and neither can
//!   the chain parent of a live patch version (consolidate the child
//!   first).
//! * **gc** — unlink retired versions' artifact files, leaving tombstone
//!   records so version numbering stays monotone across restarts. The
//!   sweep is chain-aware: a retired version whose file still backs a live
//!   patch chain is pinned on disk until the dependents consolidate or
//!   retire.
//!
//! State is a JSON manifest (`registry.json`) in the artifact directory,
//! rewritten atomically (temp file + rename) on every mutation, plus an
//! in-memory index under a mutex. Directories that predate the registry are
//! **adopted**: untracked delta files register under the version stamped in
//! their header (bare pre-v2 files land at version 1), fp16 checkpoints
//! under their `name[@N]` stem.
//!
//! **One process owns a registry directory at a time.** The in-memory index
//! is authoritative between mutations and `persist` rewrites the manifest
//! wholesale from it, so a second process (e.g. `pawd publish` against a
//! live server's directory) would clobber the owner's state — route admin
//! operations through the serving process's control plane
//! ([`AdminOp`](super::request::AdminOp)) instead. Cross-process leases are
//! a ROADMAP follow-up.

use crate::delta::chain::{self, ChainLink, MAX_CHAIN_DEPTH};
use crate::delta::format::{load_delta, peek_meta, save_delta};
use crate::delta::types::{ArtifactMeta, DeltaModel};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Manifest file name inside the registry directory.
pub const MANIFEST_FILE: &str = "registry.json";

/// On-disk representation of one version's artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Packed PAWD delta applied onto the shared base.
    Delta,
    /// Full FP16 checkpoint (baseline path; only ever adopted, not published).
    Fp16,
}

impl ArtifactKind {
    /// Stable wire/manifest label (shared with the HTTP wire codecs).
    pub(crate) fn label(&self) -> &'static str {
        match self {
            ArtifactKind::Delta => "delta",
            ArtifactKind::Fp16 => "fp16",
        }
    }

    pub(crate) fn from_label(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "delta" => ArtifactKind::Delta,
            "fp16" => ArtifactKind::Fp16,
            other => bail!("unknown artifact kind '{other}' in manifest"),
        })
    }
}

/// One version in a variant's history.
#[derive(Clone, Debug)]
pub struct VersionRecord {
    pub version: u32,
    /// Version this one superseded at publish time (rollback target; for
    /// patch versions, also the chain parent the patch composes onto).
    pub parent: Option<u32>,
    /// Publish time, seconds since the Unix epoch (0 for adopted legacy files).
    pub created_unix: u64,
    /// Artifact file name, relative to the registry directory.
    pub file: String,
    pub kind: ArtifactKind,
    /// Artifact size on disk.
    pub bytes: u64,
    /// Retired versions are unservable: `resolve("name@N")` fails fast.
    pub retired: bool,
    /// The artifact is a patch: it carries only the modules changed vs
    /// `parent`; loading it composes the parent chain.
    pub patch: bool,
}

#[derive(Clone, Debug, Default)]
struct VariantState {
    versions: BTreeMap<u32, VersionRecord>,
    active: u32,
    pinned: bool,
    /// High-water mark of version numbers handed to in-flight publishes
    /// (not persisted): lets a publish write its artifact outside the lock
    /// without a concurrent publish taking the same number. A failed
    /// publish leaves a harmless gap in the numbering.
    reserved_max: u32,
}

/// Control-plane view of one variant (the `list` endpoint's row).
#[derive(Clone, Debug)]
pub struct VariantDesc {
    pub name: String,
    pub active: u32,
    pub pinned: bool,
    pub versions: Vec<VersionRecord>,
}

/// Outcome of a [`VariantRegistry::gc`] sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    pub files_removed: usize,
    pub bytes_freed: u64,
}

/// Outcome of a [`VariantRegistry::publish_incremental`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PublishOutcome {
    /// Version assigned to the publish.
    pub version: u32,
    /// `true` when a patch artifact shipped; `false` when the publish fell
    /// back to a full artifact (no parent, inexpressible diff, chain at the
    /// depth bound, or an fp16 parent).
    pub patch: bool,
    /// Bytes written to disk for this publish — the "bytes shipped" a patch
    /// is supposed to shrink.
    pub bytes: u64,
}

/// Outcome of a [`VariantRegistry::consolidate`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConsolidateOutcome {
    pub version: u32,
    /// Size of the artifact now backing the version.
    pub bytes: u64,
    /// Chain links rebased into the full artifact (0 = the version was
    /// already full and nothing changed).
    pub rebased_links: usize,
}

/// What an alias (or explicit `name@N`) resolves to.
#[derive(Clone, Debug)]
pub struct Resolved {
    /// Canonical variant name (alias with any `@N` suffix stripped).
    pub name: String,
    pub version: u32,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    /// Lineage parent (chain parent when `patch` is set).
    pub parent: Option<u32>,
    /// The artifact is a patch; loading it requires composing the parent
    /// chain ([`chain_links`](VariantRegistry::chain_links)).
    pub patch: bool,
}

/// Thread-safe versioned variant registry over one artifact directory.
pub struct VariantRegistry {
    dir: PathBuf,
    inner: Mutex<BTreeMap<String, VariantState>>,
    /// Monotonic manifest sequence number, bumped on every persisted
    /// mutation. Replication followers poll it to detect leader changes
    /// without re-diffing an unchanged manifest.
    seq: AtomicU64,
    /// Pairs with `watch_cv`: manifest-change watchers (the HTTP long-poll
    /// endpoint) park here; [`mutate`](Self::mutate) notifies after every
    /// committed mutation.
    watch_lock: Mutex<()>,
    watch_cv: Condvar,
}

impl VariantRegistry {
    /// Open the registry for `dir`: load the manifest if present, then adopt
    /// any artifact files the manifest doesn't know about. A missing
    /// directory is an empty registry (publishing creates it).
    pub fn open(dir: &Path) -> Result<VariantRegistry> {
        let mut variants: BTreeMap<String, VariantState> = BTreeMap::new();
        let mut seq = 0u64;
        let manifest = dir.join(MANIFEST_FILE);
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            let parsed = parse_manifest(&text)
                .with_context(|| format!("parsing {}", manifest.display()))?;
            variants = parsed.0;
            seq = parsed.1;
        }
        // Only variants with recorded versions count as manifest-tracked;
        // a persisted placeholder (failed publish) shouldn't pin the alias
        // of files adopted later.
        let tracked: std::collections::HashSet<String> = variants
            .iter()
            .filter(|(_, s)| !s.versions.is_empty())
            .map(|(n, _)| n.clone())
            .collect();
        adopt_untracked(dir, &mut variants, &tracked)?;
        Ok(VariantRegistry {
            dir: dir.to_path_buf(),
            inner: Mutex::new(variants),
            seq: AtomicU64::new(seq),
            watch_lock: Mutex::new(()),
            watch_cv: Condvar::new(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Current manifest sequence number: 0 for a registry that has never
    /// persisted, monotonically increasing across mutations (and restarts —
    /// the value is stored in the manifest).
    pub fn manifest_seq(&self) -> u64 {
        self.seq.load(Ordering::SeqCst)
    }

    /// Block until the manifest sequence number differs from `known_seq` or
    /// `timeout` elapses; returns the sequence number observed on wake.
    /// This is what makes HTTP long-poll replication push-shaped: a
    /// follower's manifest request parks here instead of interval-polling,
    /// and every committed mutation (including
    /// [`apply_replica`](Self::apply_replica) on a follower serving as a
    /// sub-leader in a fan-out tree) wakes the watchers.
    ///
    /// The check-then-park runs under `watch_lock`, the same lock `mutate`
    /// notifies under, so a bump landing between the seq read and the park
    /// cannot be missed.
    pub fn wait_manifest_change(&self, known_seq: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut guard = self.watch_lock.lock().unwrap();
        loop {
            let seq = self.manifest_seq();
            if seq != known_seq {
                return seq;
            }
            let now = Instant::now();
            if now >= deadline {
                return seq;
            }
            let (g, _timed_out) =
                self.watch_cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
    }

    /// Resolve an alias. `name` selects the variant's active version;
    /// `name@N` selects version `N` explicitly (pinned experiments, cache
    /// keys). Retired versions do not resolve.
    pub fn resolve(&self, name: &str) -> Result<Resolved> {
        let (base, explicit) = split_versioned_name(name)?;
        let inner = self.inner.lock().unwrap();
        let state = inner
            .get(base)
            .filter(|s| !s.versions.is_empty()) // placeholder from a failed publish
            .ok_or_else(|| anyhow::anyhow!("variant '{base}' not found in {}", self.dir.display()))?;
        let version = explicit.unwrap_or(state.active);
        let rec = state.versions.get(&version).ok_or_else(|| {
            anyhow::anyhow!("variant '{base}' has no version {version}")
        })?;
        if rec.retired {
            bail!("variant '{base}@{version}' is retired");
        }
        Ok(Resolved {
            name: base.to_string(),
            version,
            path: self.dir.join(&rec.file),
            kind: rec.kind,
            parent: rec.parent,
            patch: rec.patch,
        })
    }

    /// The artifact chain backing `name@version`, base-most full artifact
    /// first. A full version is a one-link chain. Chain *parents* are
    /// allowed to be retired (retirement makes a version unservable, not
    /// unreadable) but must not have been garbage-collected — the gc sweep
    /// keeps files of live chains on disk, so a broken chain here means a
    /// hand-edited manifest.
    ///
    /// Length is only checked against the corruption backstop
    /// ([`chain::HARD_CHAIN_BOUND`]), not the [`MAX_CHAIN_DEPTH`] policy
    /// bound: publishes refuse to *grow* a chain past the policy bound, but
    /// an adopted directory may already exceed it and `consolidate` must
    /// still be able to walk and rebase such a chain.
    pub fn chain_links(&self, name: &str, version: u32) -> Result<Vec<ChainLink>> {
        let inner = self.inner.lock().unwrap();
        let state = inner
            .get(name)
            .filter(|s| !s.versions.is_empty())
            .ok_or_else(|| anyhow::anyhow!("variant '{name}' not found in {}", self.dir.display()))?;
        let mut links = Vec::new();
        let mut v = version;
        loop {
            let rec = state.versions.get(&v).ok_or_else(|| {
                anyhow::anyhow!("variant '{name}' has no version {v} (chain broken)")
            })?;
            if rec.kind != ArtifactKind::Delta {
                bail!("chain of '{name}@{version}' passes through non-delta version {v}");
            }
            if rec.file.is_empty() {
                bail!(
                    "'{name}@{v}' was garbage-collected but still backs the chain of \
                     '{name}@{version}'"
                );
            }
            links.push(ChainLink {
                version: v,
                path: self.dir.join(&rec.file),
                is_patch: rec.patch,
            });
            if !rec.patch {
                break;
            }
            let parent = rec.parent.ok_or_else(|| {
                anyhow::anyhow!("patch '{name}@{v}' has no recorded parent version")
            })?;
            // Versions are assigned monotonically, so a well-formed lineage
            // always steps downward; enforcing that here makes parent
            // cycles (hand-edited manifests) impossible by construction.
            if parent >= v {
                bail!(
                    "patch '{name}@{v}' records parent v{parent} — lineage must be \
                     strictly decreasing (corrupt manifest)"
                );
            }
            v = parent;
            if links.len() > chain::HARD_CHAIN_BOUND {
                bail!(
                    "chain of '{name}@{version}' exceeds the corruption backstop {}",
                    chain::HARD_CHAIN_BOUND
                );
            }
        }
        links.reverse();
        Ok(links)
    }

    /// The effective (fully composed) model of `name@version`, read from
    /// disk. Patch chains are composed; full versions load directly.
    pub fn effective_model(&self, name: &str, version: u32) -> Result<DeltaModel> {
        let links = self.chain_links(name, version)?;
        Ok(chain::load_effective(&links, None)?.0)
    }

    /// Publish `model` as the next **full** version of `name`. Stamps the
    /// artifact meta, writes `name@N.pawd`, records the version, and flips
    /// the alias to `N` unless the variant is pinned. Returns the assigned
    /// version. `model` must be an effective (non-patch) model — use
    /// [`publish_incremental`](Self::publish_incremental) to ship only what
    /// changed.
    pub fn publish(&self, name: &str, model: DeltaModel) -> Result<u32> {
        Ok(self.publish_full(name, model)?.version)
    }

    /// [`publish`](Self::publish) returning the full [`PublishOutcome`]
    /// (version + bytes written), for callers that report artifact sizes.
    pub fn publish_full(&self, name: &str, model: DeltaModel) -> Result<PublishOutcome> {
        if model.meta.is_patch {
            bail!(
                "model for '{name}' is a patch (partial module set); publish it through \
                 publish_incremental or compose it first"
            );
        }
        let (version, bytes) = self.publish_model(name, model, None, false)?;
        Ok(PublishOutcome { version, patch: false, bytes })
    }

    /// Publish `child` (an effective, fully-composed model) as the next
    /// version of `name`, shipping a **patch artifact** that carries only
    /// the modules whose packed content changed relative to `parent`
    /// (default: the active version). Falls back to a full publish when
    /// there is no usable parent, the diff cannot be expressed (module
    /// removal), the parent chain already sits at [`MAX_CHAIN_DEPTH`], or
    /// nothing would be saved (every module changed).
    pub fn publish_incremental(
        &self,
        name: &str,
        child: DeltaModel,
        parent: Option<u32>,
    ) -> Result<PublishOutcome> {
        self.publish_incremental_hinted(name, child, parent, |_| None)
    }

    /// [`publish_incremental`](Self::publish_incremental) with a **resident
    /// parent lookup**: `resident` maps a version number to that version's
    /// already-composed effective model when one is held in memory (the
    /// server passes the variant cache's entries). With a hit, diffing the
    /// child reads only the final patch file at most — publish cost stays
    /// proportional to what changed instead of re-reading the consolidated
    /// parent from disk.
    pub fn publish_incremental_hinted(
        &self,
        name: &str,
        child: DeltaModel,
        parent: Option<u32>,
        resident: impl Fn(u32) -> Option<std::sync::Arc<DeltaModel>>,
    ) -> Result<PublishOutcome> {
        validate_name(name)?;
        if child.meta.is_patch {
            bail!("publish_incremental takes the child's *effective* model, not a patch");
        }
        // Pick the diff base under the lock; usability checks (delta kind,
        // not gc'd) fail fast here instead of mid-chain-load. An *explicit*
        // parent that is unusable is an error — silently diffing against
        // something else would ship a patch the caller did not ask for —
        // while an unusable *implicit* (active) parent just means "publish
        // full".
        let parent_v: Option<u32> = {
            let inner = self.inner.lock().unwrap();
            match inner.get(name).filter(|s| !s.versions.is_empty()) {
                None => {
                    if let Some(p) = parent {
                        bail!("variant '{name}' has no version {p} to patch against");
                    }
                    None
                }
                Some(state) => match parent {
                    Some(p) => {
                        let rec = state.versions.get(&p).ok_or_else(|| {
                            anyhow::anyhow!("variant '{name}' has no version {p}")
                        })?;
                        if rec.retired {
                            bail!("cannot patch against retired version {p} of '{name}'");
                        }
                        if rec.kind != ArtifactKind::Delta {
                            bail!("cannot patch against fp16 version {p} of '{name}'");
                        }
                        if rec.file.is_empty() {
                            bail!(
                                "cannot patch against garbage-collected version {p} of '{name}'"
                            );
                        }
                        Some(p)
                    }
                    None => Some(state.active)
                        .filter(|&a| a > 0)
                        .and_then(|a| state.versions.get(&a))
                        .filter(|r| r.kind == ArtifactKind::Delta && !r.file.is_empty())
                        .map(|r| r.version),
                },
            }
        };
        let Some(parent_v) = parent_v else {
            let (version, bytes) = self.publish_model(name, child, None, false)?;
            return Ok(PublishOutcome { version, patch: false, bytes });
        };
        // A patch on a maximal chain would exceed the depth bound at load
        // time; rebase with a full publish instead.
        let links = self.chain_links(name, parent_v)?;
        if links.len() >= MAX_CHAIN_DEPTH {
            let (version, bytes) = self.publish_model(name, child, Some(parent_v), false)?;
            return Ok(PublishOutcome { version, patch: false, bytes });
        }
        // The resident hint short-circuits the whole chain read when it IS
        // the parent's effective model; otherwise load_effective validates
        // and falls back to the cold per-record path on its own.
        let hint = resident(parent_v).filter(|m| !m.meta.is_patch && m.meta.version == parent_v);
        let parent_eff = match hint {
            Some(m) => (*m).clone(),
            None => {
                chain::load_effective(&links, None)
                    .with_context(|| format!("composing parent '{name}@{parent_v}'"))?
                    .0
            }
        };
        match chain::diff(&parent_eff, &child) {
            Ok(patch) if patch.modules.len() < child.modules.len() => {
                let (version, bytes) = self.publish_model(name, patch, Some(parent_v), true)?;
                Ok(PublishOutcome { version, patch: true, bytes })
            }
            // Everything changed (or removal made the diff inexpressible):
            // a patch would be pure overhead — ship the full artifact.
            _ => {
                let (version, bytes) = self.publish_model(name, child, Some(parent_v), false)?;
                Ok(PublishOutcome { version, patch: false, bytes })
            }
        }
    }

    /// Shared publish machinery. Stamps the meta (version reserved under
    /// the lock, `forced_parent` — the diff base for patches — overriding
    /// the default "active version" lineage), writes the artifact and
    /// commits the record. Returns `(version, bytes_written)`.
    ///
    /// The version number is *reserved* under the lock, the artifact is
    /// serialized to a temp file and renamed into place with the lock
    /// released (data-path resolves never wait on the multi-MB artifact
    /// write; they can still briefly contend on the small manifest rewrite
    /// in `persist`), and the index mutates only after the rename — a crash
    /// mid-write leaves a stray `.tmp` file, never a live truncated version.
    fn publish_model(
        &self,
        name: &str,
        mut model: DeltaModel,
        forced_parent: Option<u32>,
        patch: bool,
    ) -> Result<(u32, u64)> {
        validate_name(name)?;
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating registry dir {}", self.dir.display()))?;
        let (version, parent, file) = {
            let mut inner = self.inner.lock().unwrap();
            let state = inner.entry(name.to_string()).or_default();
            let next = state
                .versions
                .keys()
                .next_back()
                .copied()
                .unwrap_or(0)
                .max(state.reserved_max)
                + 1;
            state.reserved_max = next;
            // Pick a filename no existing record (e.g. an adopted mis-named
            // copy sitting at `name@N.pawd`) and no stray disk file owns —
            // the record, not the filename, is authoritative. Fallback names
            // stay namespaced by the (unique, reserved) version, so two
            // concurrent publishes can never converge on one filename.
            let taken: std::collections::HashSet<&str> =
                state.versions.values().map(|r| r.file.as_str()).collect();
            let mut file = format!("{name}@{next}.pawd");
            let mut bump = 0u32;
            while taken.contains(file.as_str()) || self.dir.join(&file).exists() {
                bump += 1;
                file = format!("{name}@{next}-{bump}.pawd");
            }
            let parent = forced_parent.or_else(|| Some(state.active).filter(|&a| a > 0));
            (next, parent, file)
        };
        if patch && parent.is_none() {
            bail!("patch publish of '{name}' has no parent version");
        }
        let created_unix = unix_now();
        model.variant = name.to_string();
        model.meta = ArtifactMeta { version, parent, created_unix, is_patch: patch };
        let tmp = self.dir.join(format!("{file}.tmp"));
        let written = save_delta(&tmp, &model).and_then(|bytes| {
            std::fs::rename(&tmp, self.dir.join(&file))
                .with_context(|| format!("committing artifact {file}"))?;
            Ok(bytes)
        });
        let bytes = match written {
            Ok(b) => b,
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                // The `reserved_max` watermark survives, so later publishes
                // never reuse this number. Empty placeholder entries are
                // invisible to `resolve`/`list`/`names`.
                return Err(e);
            }
        };
        self.mutate(|index| {
            let state = index.entry(name.to_string()).or_default();
            state.versions.insert(
                version,
                VersionRecord {
                    version,
                    parent,
                    created_unix,
                    file,
                    kind: ArtifactKind::Delta,
                    bytes,
                    retired: false,
                    patch,
                },
            );
            // Concurrent publishes can commit out of order (B reserves v4
            // and lands before A's v3): only ever move the alias forward.
            if !state.pinned && version > state.active {
                state.active = version;
            }
            Ok(version)
        })
        .map(|v| (v, bytes))
    }

    /// Publish an existing `.pawd` file as the next full version of `name`
    /// (loads, restamps the meta, re-serializes into the registry dir).
    /// Patch artifacts are refused — their module set is partial and only
    /// meaningful against their original parent chain.
    pub fn publish_file(&self, name: &str, src: &Path) -> Result<u32> {
        let model = load_delta(src)
            .with_context(|| format!("loading artifact to publish from {}", src.display()))?;
        if model.meta.is_patch {
            bail!(
                "{} is a patch artifact; publish the variant's effective model instead",
                src.display()
            );
        }
        self.publish(name, model)
    }

    /// Rebase the patch chain of `name@version` (default: the active
    /// version) into a single full artifact **in place**: the version keeps
    /// its number and lineage, only the backing file changes, so resolved
    /// caches keyed by `(variant, version)` stay valid. The superseded
    /// patch file is unlinked once the manifest commit lands.
    pub fn consolidate(&self, name: &str, version: Option<u32>) -> Result<ConsolidateOutcome> {
        let (target, old_file) = {
            let inner = self.inner.lock().unwrap();
            let state = inner
                .get(name)
                .filter(|s| !s.versions.is_empty())
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' not found in registry"))?;
            let target = version.unwrap_or(state.active);
            let rec = state.versions.get(&target).ok_or_else(|| {
                anyhow::anyhow!("variant '{name}' has no version {target}")
            })?;
            if rec.file.is_empty() {
                bail!("'{name}@{target}' was garbage-collected; nothing to consolidate");
            }
            if !rec.patch {
                return Ok(ConsolidateOutcome {
                    version: target,
                    bytes: rec.bytes,
                    rebased_links: 0,
                });
            }
            (target, rec.file.clone())
        };
        let links = self.chain_links(name, target)?;
        let (effective, _) = chain::load_effective(&links, None)
            .with_context(|| format!("composing '{name}@{target}' for consolidation"))?;
        // Unique filename (records + disk), namespaced by the version.
        let file = {
            let inner = self.inner.lock().unwrap();
            let taken: std::collections::HashSet<String> = inner
                .values()
                .flat_map(|s| s.versions.values().map(|r| r.file.clone()))
                .collect();
            let mut bump = 0u32;
            let mut file = format!("{name}@{target}-full.pawd");
            while taken.contains(&file) || self.dir.join(&file).exists() {
                bump += 1;
                file = format!("{name}@{target}-full-{bump}.pawd");
            }
            file
        };
        let tmp = self.dir.join(format!("{file}.tmp"));
        let bytes = match save_delta(&tmp, &effective).and_then(|b| {
            std::fs::rename(&tmp, self.dir.join(&file))
                .with_context(|| format!("committing consolidated artifact {file}"))?;
            Ok(b)
        }) {
            Ok(b) => b,
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                return Err(e);
            }
        };
        let commit = self.mutate(|index| {
            let state = state_mut(index, name)?;
            let rec = state.versions.get_mut(&target).ok_or_else(|| {
                anyhow::anyhow!("variant '{name}' lost version {target} mid-consolidation")
            })?;
            if rec.file != old_file {
                bail!("'{name}@{target}' changed files mid-consolidation (concurrent admin op)");
            }
            rec.file = file.clone();
            rec.bytes = bytes;
            rec.patch = false;
            Ok(())
        });
        if let Err(e) = commit {
            let _ = std::fs::remove_file(self.dir.join(&file));
            return Err(e);
        }
        // The old patch file is no longer referenced by any record (publish
        // keeps filenames unique); a crash before this unlink only leaves an
        // orphaned file, which adoption skips because its version slot is
        // owned.
        let _ = std::fs::remove_file(self.dir.join(&old_file));
        Ok(ConsolidateOutcome { version: target, bytes, rebased_links: links.len() })
    }

    /// Flip the alias back: to `to` if given, else to the active version's
    /// parent (falling back to the highest non-retired version below the
    /// active one). Returns the version now active.
    pub fn rollback(&self, name: &str, to: Option<u32>) -> Result<u32> {
        self.mutate(|index| {
            let state = state_mut(index, name)?;
            let target = match to {
                Some(v) => v,
                None => {
                    let active = state.active;
                    let parent = state.versions.get(&active).and_then(|r| r.parent);
                    let parent_ok = parent
                        .and_then(|p| state.versions.get(&p))
                        .filter(|r| !r.retired)
                        .map(|r| r.version);
                    match parent_ok.or_else(|| {
                        state
                            .versions
                            .range(..active)
                            .rev()
                            .find(|(_, r)| !r.retired)
                            .map(|(&v, _)| v)
                    }) {
                        Some(v) => v,
                        None => bail!("variant '{name}' has no version to roll back to"),
                    }
                }
            };
            let rec = state
                .versions
                .get(&target)
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' has no version {target}"))?;
            if rec.retired {
                bail!("cannot roll '{name}' back to retired version {target}");
            }
            state.active = target;
            Ok(target)
        })
    }

    /// Freeze the alias on `version`: publishes keep recording new versions
    /// but stop moving the alias until [`unpin`](Self::unpin).
    pub fn pin(&self, name: &str, version: u32) -> Result<()> {
        self.mutate(|index| {
            let state = state_mut(index, name)?;
            let rec = state
                .versions
                .get(&version)
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' has no version {version}"))?;
            if rec.retired {
                bail!("cannot pin '{name}' to retired version {version}");
            }
            state.active = version;
            state.pinned = true;
            Ok(())
        })
    }

    /// Release a pin; the alias stays where it is and the next publish moves
    /// it again.
    pub fn unpin(&self, name: &str) -> Result<()> {
        self.mutate(|index| {
            state_mut(index, name)?.pinned = false;
            Ok(())
        })
    }

    /// Mark a version unservable. The active version cannot be retired —
    /// roll back or publish first. Neither can the chain parent of a live
    /// patch version: the dependent's loads compose through it, so
    /// consolidate (or retire) the dependent first. (Retiring only blocks
    /// *serving*; a retired version's file stays on disk while live chains
    /// need it — see [`gc`](Self::gc).)
    pub fn retire(&self, name: &str, version: u32) -> Result<()> {
        self.mutate(|index| {
            let state = state_mut(index, name)?;
            if state.active == version {
                bail!("refusing to retire the active version {version} of '{name}' (rollback or publish first)");
            }
            if let Some(dep) = state
                .versions
                .values()
                .find(|r| !r.retired && r.patch && r.parent == Some(version))
            {
                bail!(
                    "version {version} of '{name}' is the chain parent of live patch version \
                     {} — consolidate or retire '{name}@{}' first",
                    dep.version,
                    dep.version
                );
            }
            let rec = state
                .versions
                .get_mut(&version)
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' has no version {version}"))?;
            rec.retired = true;
            Ok(())
        })
    }

    /// Garbage-collect retired versions' artifact files for `name` (or for
    /// every variant when `None`). [`retire`](Self::retire) makes a version
    /// unservable but leaves its artifact on disk forever; this sweep
    /// unlinks those files while keeping each record as a **tombstone**
    /// (`file` cleared, `bytes` zeroed), so version numbering stays
    /// monotone across restarts and the history remains listable.
    ///
    /// The tombstones commit to the manifest *before* any file is unlinked
    /// (write-ahead, like every other mutation): a crash mid-sweep can
    /// leave orphaned-but-untracked files on disk (harmless — adoption
    /// skips version slots a record already owns, and retired versions
    /// never resolve), never a live record pointing at a deleted artifact.
    /// In-flight requests still holding the version's `Arc` are unaffected
    /// — the weights are resident, only the disk copy goes away.
    pub fn gc(&self, name: Option<&str>) -> Result<GcReport> {
        // Phase 1 (under the lock, write-ahead): tombstone matching records
        // and collect the doomed paths.
        let doomed: Vec<(PathBuf, u64)> = self.mutate(|index| {
            if let Some(n) = name {
                let known = index.get(n).map(|s| !s.versions.is_empty()).unwrap_or(false);
                if !known {
                    bail!("variant '{n}' not found in registry");
                }
            }
            // Never unlink a file a live (non-retired) record still points
            // at — publish guarantees unique filenames, this is belt and
            // braces against hand-edited manifests. Chain-awareness: a live
            // patch version composes through its ancestors at load time, so
            // every ancestor file on a live chain is pinned on disk even if
            // the ancestor version itself is retired (the retire guard
            // normally prevents that state, but adopted directories and
            // races must not turn it into an unloadable variant).
            let mut live: std::collections::HashSet<String> = std::collections::HashSet::new();
            for state in index.values() {
                let pinned =
                    live_file_versions(state.versions.values(), |p| state.versions.get(&p));
                for v in pinned {
                    if let Some(rec) = state.versions.get(&v) {
                        live.insert(rec.file.clone());
                    }
                }
            }
            let mut doomed = Vec::new();
            for (vname, state) in index.iter_mut() {
                if let Some(n) = name {
                    if n != vname {
                        continue;
                    }
                }
                for rec in state.versions.values_mut() {
                    if rec.retired && !rec.file.is_empty() && !live.contains(&rec.file) {
                        doomed.push((self.dir.join(&rec.file), rec.bytes));
                        rec.file = String::new();
                        rec.bytes = 0;
                    }
                }
            }
            Ok(doomed)
        })?;
        // Phase 2 (outside the lock): unlink. Already-missing files count as
        // collected — the record said retired either way.
        let mut report = GcReport::default();
        for (path, bytes) in doomed {
            match std::fs::remove_file(&path) {
                Ok(()) => {
                    report.files_removed += 1;
                    report.bytes_freed += bytes;
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("removing retired artifact {}", path.display())))
                }
            }
        }
        Ok(report)
    }

    /// All variants with their full version histories, sorted by name.
    /// Version-less placeholder entries (left by failed publishes to keep
    /// their reservation watermark) are omitted.
    pub fn list(&self) -> Vec<VariantDesc> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .filter(|(_, s)| !s.versions.is_empty())
            .map(|(name, s)| VariantDesc {
                name: name.clone(),
                active: s.active,
                pinned: s.pinned,
                versions: s.versions.values().cloned().collect(),
            })
            .collect()
    }

    /// Variant names only (the legacy `VariantStore::list` surface).
    pub fn names(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .filter(|(_, s)| !s.versions.is_empty())
            .map(|(name, _)| name.clone())
            .collect()
    }

    /// Write-ahead commit shared by every mutation: apply `f` to a copy of
    /// the index, persist that copy, and only then swap it in. A failure in
    /// `f` or in the manifest write leaves the live index (and therefore
    /// what the server serves) exactly as the returned error implies, and a
    /// restart reloads the same state.
    fn mutate<R>(
        &self,
        f: impl FnOnce(&mut BTreeMap<String, VariantState>) -> Result<R>,
    ) -> Result<R> {
        let mut inner = self.inner.lock().unwrap();
        let mut next = inner.clone();
        let out = f(&mut next)?;
        self.persist(&next)?;
        *inner = next;
        // Wake manifest watchers only after the new state is committed and
        // swapped in. Taking `watch_lock` here pairs with the check-then-park
        // in `wait_manifest_change`; watchers never take `inner`, so lock
        // order cannot deadlock.
        {
            let _g = self.watch_lock.lock().unwrap();
            self.watch_cv.notify_all();
        }
        Ok(out)
    }

    fn persist(&self, variants: &BTreeMap<String, VariantState>) -> Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        // Reserve the next sequence number up front: a failed write leaves a
        // gap, never a reused number (followers only need monotonicity).
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let tmp = self.dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, render_manifest(variants, seq).to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.dir.join(MANIFEST_FILE))
            .with_context(|| "committing registry manifest")?;
        Ok(())
    }

    /// Mirror one variant's replicated state from a leader manifest: insert
    /// missing version records, apply leader-side `retired` flags and
    /// consolidation file swaps, and move the alias to the leader's active
    /// version — all in a single write-ahead manifest commit. Artifact files
    /// the records point at must already sit in the registry directory (the
    /// replicator fetches and crc-verifies them first).
    ///
    /// Merge rules against an existing local record of the same version:
    /// * identical file → no-op (sync is idempotent);
    /// * leader tombstone (`file` empty) → only the `retired` flag is
    ///   mirrored; the local artifact file is kept until a *local* gc;
    /// * local patch vs leader full of the same version → the leader
    ///   consolidated: the record is swapped to the full file and the
    ///   superseded local patch file is returned for unlinking;
    /// * any other file disagreement → the follower has diverged (e.g. a
    ///   local publish raced the leader's) and the sync fails — follower
    ///   directories must not take local publishes.
    ///
    /// Returns the superseded local files (already unlinked).
    pub fn apply_replica(
        &self,
        name: &str,
        records: &[VersionRecord],
        active: u32,
        pinned: bool,
    ) -> Result<Vec<String>> {
        validate_name(name)?;
        let superseded = self.mutate(|index| {
            let state = index.entry(name.to_string()).or_default();
            let mut superseded = Vec::new();
            for rec in records {
                if !state.versions.contains_key(&rec.version) {
                    if rec.patch {
                        let Some(p) = rec.parent else {
                            bail!("replica patch '{name}@{}' has no parent", rec.version);
                        };
                        let known_parent = state.versions.contains_key(&p)
                            || records.iter().any(|r| r.version == p);
                        if !known_parent {
                            bail!(
                                "replica patch '{name}@{}' arrived without its chain \
                                 parent v{p}",
                                rec.version
                            );
                        }
                    }
                    state.versions.insert(rec.version, rec.clone());
                    continue;
                }
                let existing = state.versions.get_mut(&rec.version).expect("checked above");
                if rec.file.is_empty() || existing.file == rec.file {
                    // Tombstone or identical artifact: mirror flags only.
                    existing.retired = existing.retired || rec.retired;
                } else if existing.patch && !rec.patch {
                    // The leader consolidated this version in place.
                    superseded.push(existing.file.clone());
                    existing.file = rec.file.clone();
                    existing.bytes = rec.bytes;
                    existing.patch = false;
                    existing.retired = existing.retired || rec.retired;
                } else {
                    bail!(
                        "follower diverged from leader: '{name}@{}' is backed by \
                         '{}' locally but '{}' on the leader",
                        rec.version,
                        existing.file,
                        rec.file
                    );
                }
            }
            let target = state.versions.get(&active).ok_or_else(|| {
                anyhow::anyhow!("leader alias '{name}'@{active} is not among the replica records")
            })?;
            if target.retired {
                bail!("leader alias '{name}'@{active} points at a retired version");
            }
            state.active = active;
            state.pinned = pinned;
            Ok(superseded)
        })?;
        for file in &superseded {
            let _ = std::fs::remove_file(self.dir.join(file));
        }
        Ok(superseded)
    }
}

/// Parsed read-only view of a registry manifest — what a replication
/// follower diffs against its own [`VariantRegistry`] after fetching the
/// leader's `registry.json` through a
/// [`SyncTransport`](super::replicate::SyncTransport).
#[derive(Clone, Debug)]
pub struct ManifestView {
    /// The leader's monotonic manifest sequence number (0 for manifests
    /// written before replication landed).
    pub manifest_seq: u64,
    pub variants: Vec<VariantDesc>,
}

/// Parse manifest JSON text (the bytes of a `registry.json`) into a
/// [`ManifestView`]. Used by the replicator on fetched leader manifests;
/// local state goes through [`VariantRegistry::open`] instead.
pub fn parse_manifest_view(text: &str) -> Result<ManifestView> {
    let (variants, manifest_seq) = parse_manifest(text)?;
    let variants = variants
        .into_iter()
        .filter(|(_, s)| !s.versions.is_empty())
        .map(|(name, s)| VariantDesc {
            name,
            active: s.active,
            pinned: s.pinned,
            versions: s.versions.into_values().collect(),
        })
        .collect();
    Ok(ManifestView { manifest_seq, variants })
}

/// Versions whose artifact files must stay readable for one variant: every
/// non-retired version, plus each chain ancestor a live patch composes
/// through (an ancestor may itself be retired — retirement blocks serving,
/// not reading). Shared by the gc sweep (which pins these files on disk)
/// and the replication follower (which fetches exactly these files);
/// `lookup` resolves a version number to its record within the variant.
pub(crate) fn live_file_versions<'a>(
    records: impl Iterator<Item = &'a VersionRecord>,
    lookup: impl Fn(u32) -> Option<&'a VersionRecord>,
) -> std::collections::HashSet<u32> {
    let mut live = std::collections::HashSet::new();
    for rec in records.filter(|r| !r.retired) {
        live.insert(rec.version);
        let mut cur = rec;
        let mut depth = 0usize;
        while cur.patch && depth <= chain::HARD_CHAIN_BOUND {
            let Some(p) = cur.parent else { break };
            live.insert(p);
            let Some(prec) = lookup(p) else { break };
            cur = prec;
            depth += 1;
        }
    }
    live
}

fn state_mut<'a>(
    inner: &'a mut BTreeMap<String, VariantState>,
    name: &str,
) -> Result<&'a mut VariantState> {
    inner
        .get_mut(name)
        .ok_or_else(|| anyhow::anyhow!("variant '{name}' not found in registry"))
}

fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() {
        bail!("variant name must not be empty");
    }
    if name.contains('@') || name.contains('/') || name.starts_with("__") {
        bail!("variant name '{name}' is invalid ('@', '/' and the '__' prefix are reserved)");
    }
    Ok(())
}

/// Split `name[@version]`. An explicit `@0` or non-numeric suffix is an error.
fn split_versioned_name(name: &str) -> Result<(&str, Option<u32>)> {
    match name.rsplit_once('@') {
        None => Ok((name, None)),
        Some((base, v)) => {
            let version: u32 = v
                .parse()
                .ok()
                .filter(|&v| v > 0)
                .ok_or_else(|| anyhow::anyhow!("bad version suffix in '{name}'"))?;
            Ok((base, Some(version)))
        }
    }
}

fn unix_now() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

/// Register artifact files the manifest doesn't cover. Delta files are
/// adopted under the version **stamped in their header** (`peek_meta` — the
/// filename is not trusted, so a mis-named copy cannot flip the alias to a
/// version the loader would then refuse); fp16 checkpoints carry no meta
/// and use their `name[@N]` stem (default 1). Never overwrites a manifest
/// entry; `.pawd` wins over a co-named `.fp16` at the same version. For
/// variants the manifest already `tracked`, adopted files are addressable
/// (`name@N`) but never move the alias — a stray file must not override a
/// persisted rollback or a crashed publish's manifest state.
fn adopt_untracked(
    dir: &Path,
    variants: &mut BTreeMap<String, VariantState>,
    tracked: &std::collections::HashSet<String>,
) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // missing dir = empty registry
    };
    // Files the manifest already references are skipped by name, before any
    // header peek — reopening a healthy registry stays one directory scan.
    let tracked_files: std::collections::HashSet<String> = variants
        .values()
        .flat_map(|s| s.versions.values().map(|r| r.file.clone()))
        .collect();
    let mut files: Vec<(String, ArtifactKind, String, u64, PathBuf)> = Vec::new();
    for entry in entries {
        let p = entry?.path();
        let kind = match p.extension().and_then(|e| e.to_str()) {
            Some("pawd") => ArtifactKind::Delta,
            Some("fp16") => ArtifactKind::Fp16,
            _ => continue,
        };
        let Some(stem) = p.file_stem().and_then(|s| s.to_str()) else { continue };
        let Some(file) = p.file_name().and_then(|s| s.to_str()) else { continue };
        if tracked_files.contains(file) {
            continue;
        }
        let bytes = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
        files.push((stem.to_string(), kind, file.to_string(), bytes, p));
    }
    // Deltas first so a co-named fp16 can't claim the version slot.
    files.sort_by_key(|(_, kind, ..)| matches!(kind, ArtifactKind::Fp16));
    for (stem, kind, file, bytes, path) in files {
        let (name, version, meta) = match (kind, split_versioned_name(&stem)) {
            (ArtifactKind::Delta, Ok((n, _))) => match peek_meta(&path) {
                Ok(meta) => (n.to_string(), meta.version, Some(meta)),
                Err(_) => continue, // unreadable header: leave untracked
            },
            (ArtifactKind::Fp16, Ok((n, v))) => (n.to_string(), v.unwrap_or(1), None),
            // '@' is reserved for version suffixes: a stem like
            // `model@final` can't be addressed through `resolve`, so
            // adopting it would only create an unreachable entry. Leave the
            // file untracked (rename it to drop the '@' to serve it).
            (_, Err(_)) => continue,
        };
        let manifest_tracked = tracked.contains(&name);
        let state = variants.entry(name).or_default();
        if state.versions.contains_key(&version) {
            continue; // manifest (or a delta) already owns this slot
        }
        // Adopted patch artifacts keep their embedded lineage so chain
        // loading can find the parent (which must have been adopted or
        // tracked under its own version for the patch to resolve).
        let (parent, patch) = meta.map(|m| (m.parent, m.is_patch)).unwrap_or((None, false));
        state.versions.insert(
            version,
            VersionRecord {
                version,
                parent,
                created_unix: 0,
                file,
                kind,
                bytes,
                retired: false,
                patch,
            },
        );
        if !manifest_tracked && (state.active == 0 || version > state.active) {
            state.active = version;
        }
    }
    Ok(())
}

// -- manifest (de)serialization -------------------------------------------

fn render_manifest(variants: &BTreeMap<String, VariantState>, seq: u64) -> Json {
    let vs = variants
        .iter()
        .map(|(name, s)| {
            let versions = s
                .versions
                .values()
                .map(|r| {
                    json::obj(vec![
                        ("version", json::n(r.version as f64)),
                        ("parent", json::n(r.parent.unwrap_or(0) as f64)),
                        ("created_unix", json::n(r.created_unix as f64)),
                        ("file", json::s(&r.file)),
                        ("kind", json::s(r.kind.label())),
                        ("bytes", json::n(r.bytes as f64)),
                        ("retired", Json::Bool(r.retired)),
                        ("patch", Json::Bool(r.patch)),
                    ])
                })
                .collect();
            (
                name.as_str(),
                json::obj(vec![
                    ("active", json::n(s.active as f64)),
                    ("pinned", Json::Bool(s.pinned)),
                    ("versions", json::arr(versions)),
                ]),
            )
        })
        .collect::<Vec<_>>();
    json::obj(vec![
        ("format", json::n(1.0)),
        ("manifest_seq", json::n(seq as f64)),
        ("variants", json::obj(vs)),
    ])
}

fn parse_manifest(text: &str) -> Result<(BTreeMap<String, VariantState>, u64)> {
    let j = Json::parse(text)?;
    let format = j.req_usize("format")?;
    if format != 1 {
        bail!("unsupported registry manifest format {format}");
    }
    // Manifests written before replication landed carry no sequence number.
    let seq = j.get("manifest_seq").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
    let mut out = BTreeMap::new();
    for (name, v) in j.req("variants")?.as_obj().context("'variants' is not an object")? {
        let mut state = VariantState {
            versions: BTreeMap::new(),
            active: v.req_usize("active")? as u32,
            pinned: v.req("pinned")?.as_bool().context("'pinned' is not a bool")?,
            reserved_max: 0,
        };
        for rv in v.req_arr("versions")? {
            let version = rv.req_usize("version")? as u32;
            let parent = rv.req_usize("parent")? as u32;
            state.versions.insert(
                version,
                VersionRecord {
                    version,
                    parent: if parent == 0 { None } else { Some(parent) },
                    created_unix: rv.req_usize("created_unix")? as u64,
                    file: rv.req_str("file")?.to_string(),
                    kind: ArtifactKind::from_label(rv.req_str("kind")?)?,
                    bytes: rv.req_usize("bytes")? as u64,
                    retired: rv.req("retired")?.as_bool().context("'retired' is not a bool")?,
                    // Manifests written before incremental publish landed
                    // have no 'patch' key; those versions are all full.
                    patch: rv.get("patch").and_then(|v| v.as_bool()).unwrap_or(false),
                },
            );
        }
        if version_state_invalid(&state) {
            bail!("manifest entry '{name}' is inconsistent (active version missing or retired)");
        }
        out.insert(name.clone(), state);
    }
    Ok((out, seq))
}

fn version_state_invalid(s: &VariantState) -> bool {
    match s.versions.get(&s.active) {
        Some(rec) => rec.retired,
        None => !s.versions.is_empty(), // empty histories get fixed by adoption
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Axis, Codec, DeltaModule};
    use crate::model::{ModuleId, ProjKind};

    fn tiny_model(variant: &str) -> DeltaModel {
        let d = vec![1.0f32; 8 * 8];
        DeltaModel::new(
            variant,
            "tiny",
            vec![DeltaModule {
                id: ModuleId { layer: 0, kind: ProjKind::Q },
                mask: PackedMask::pack(&d, 8, 8),
                axis: Axis::Row,
                scales: vec![0.1; 8],
                codec: Codec::PerAxis,
            }],
        )
    }

    /// A multi-module model whose per-module content is seeded, so tests
    /// can change a controlled subset between "versions".
    fn seeded_model(variant: &str, seeds: &[u64]) -> DeltaModel {
        use crate::util::rng::Rng;
        let kinds = [ProjKind::Q, ProjKind::K, ProjKind::V, ProjKind::O];
        let modules = seeds
            .iter()
            .enumerate()
            .map(|(i, &s)| {
                let mut r = Rng::new(s);
                let d: Vec<f32> = (0..16 * 16).map(|_| r.normal_f32(0.0, 1.0)).collect();
                DeltaModule {
                    id: ModuleId { layer: i / kinds.len(), kind: kinds[i % kinds.len()] },
                    mask: PackedMask::pack(&d, 16, 16),
                    axis: Axis::Row,
                    scales: (0..16).map(|_| r.uniform_in(0.01, 0.2)).collect(),
                    codec: Codec::PerAxis,
                }
            })
            .collect();
        DeltaModel::new(variant, "tiny", modules)
    }

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn publish_assigns_versions_and_flips_alias() {
        let dir = fresh_dir("pawd_test_reg1");
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 1);
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 2);
        let r = reg.resolve("ft").unwrap();
        assert_eq!((r.version, r.name.as_str()), (2, "ft"));
        assert!(r.path.ends_with("ft@2.pawd"));
        // Explicit addressing still reaches the old version.
        assert_eq!(reg.resolve("ft@1").unwrap().version, 1);
        // The published artifact carries its stamped lineage.
        let m = load_delta(&r.path).unwrap();
        assert_eq!(m.meta.version, 2);
        assert_eq!(m.meta.parent, Some(1));
        assert!(m.meta.created_unix > 0);
    }

    #[test]
    fn rollback_restores_parent_and_retire_guards() {
        let dir = fresh_dir("pawd_test_reg2");
        let reg = VariantRegistry::open(&dir).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        assert!(reg.retire("ft", 2).is_err(), "active version must not retire");
        assert_eq!(reg.rollback("ft", None).unwrap(), 1);
        assert_eq!(reg.resolve("ft").unwrap().version, 1);
        reg.retire("ft", 2).unwrap();
        assert!(reg.resolve("ft@2").is_err(), "retired versions must not resolve");
        assert!(reg.rollback("ft", Some(2)).is_err(), "cannot roll onto retired");
        // Publishing after a rollback continues the numbering past the max.
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 3);
        assert_eq!(reg.resolve("ft").unwrap().version, 3);
    }

    #[test]
    fn pin_freezes_alias_across_publish() {
        let dir = fresh_dir("pawd_test_reg3");
        let reg = VariantRegistry::open(&dir).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.pin("ft", 1).unwrap();
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 2);
        assert_eq!(reg.resolve("ft").unwrap().version, 1, "pinned alias must not move");
        reg.unpin("ft").unwrap();
        assert_eq!(reg.resolve("ft").unwrap().version, 1, "unpin alone does not move the alias");
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 3);
        assert_eq!(reg.resolve("ft").unwrap().version, 3);
    }

    #[test]
    fn manifest_survives_reopen() {
        let dir = fresh_dir("pawd_test_reg4");
        {
            let reg = VariantRegistry::open(&dir).unwrap();
            reg.publish("a", tiny_model("a")).unwrap();
            reg.publish("a", tiny_model("a")).unwrap();
            reg.rollback("a", None).unwrap();
            reg.publish("b", tiny_model("b")).unwrap();
            reg.pin("b", 1).unwrap();
        }
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.resolve("a").unwrap().version, 1);
        assert_eq!(reg.resolve("a@2").unwrap().version, 2);
        let descs = reg.list();
        assert_eq!(descs.len(), 2);
        assert!(descs[1].pinned);
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn adopts_legacy_directory_layout() {
        let dir = fresh_dir("pawd_test_reg5");
        std::fs::create_dir_all(&dir).unwrap();
        // A pre-registry directory: bare v1-style names, no manifest.
        save_delta(dir.join("old.pawd"), &tiny_model("old")).unwrap();
        std::fs::write(dir.join("ckpt.fp16"), b"not parsed during adoption").unwrap();
        let reg = VariantRegistry::open(&dir).unwrap();
        let r = reg.resolve("old").unwrap();
        assert_eq!((r.version, r.kind), (1, ArtifactKind::Delta));
        assert_eq!(reg.resolve("ckpt").unwrap().kind, ArtifactKind::Fp16);
        // Publishing on top of an adopted variant continues at version 2.
        assert_eq!(reg.publish("old", tiny_model("old")).unwrap(), 2);
        assert_eq!(reg.resolve("old").unwrap().version, 2);
    }

    #[test]
    fn adoption_trusts_embedded_version_over_filename() {
        let dir = fresh_dir("pawd_test_reg8");
        std::fs::create_dir_all(&dir).unwrap();
        // A default-stamped artifact (meta.version = 1) mis-named as @3 —
        // e.g. a hand-copied file. The filename must not win: the loader
        // would refuse a version-3 resolution of a version-1 artifact.
        save_delta(dir.join("ft@3.pawd"), &tiny_model("ft")).unwrap();
        let reg = VariantRegistry::open(&dir).unwrap();
        let r = reg.resolve("ft").unwrap();
        assert_eq!(r.version, 1, "embedded meta version wins over the filename");
        assert!(r.path.ends_with("ft@3.pawd"));
        assert_eq!(load_delta(&r.path).unwrap().meta.version, 1);
        // Publishing up to version 3 must not clobber the mis-named file
        // that backs version 1: the filename picker detours around it.
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 2);
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 3);
        let v3 = reg.resolve("ft@3").unwrap();
        assert!(v3.path.ends_with("ft@3-1.pawd"), "got {}", v3.path.display());
        assert_eq!(load_delta(&v3.path).unwrap().meta.version, 3);
        // v1 still loads from the untouched original file.
        let v1 = reg.resolve("ft@1").unwrap();
        assert_eq!(load_delta(&v1.path).unwrap().meta.version, 1);
    }

    #[test]
    fn gc_unlinks_retired_files_and_keeps_numbering_monotone() {
        let dir = fresh_dir("pawd_test_reg_gc");
        let reg = VariantRegistry::open(&dir).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("other", tiny_model("other")).unwrap();
        // Nothing retired yet: gc is a no-op.
        assert_eq!(reg.gc(None).unwrap(), GcReport::default());
        assert!(reg.gc(Some("ghost")).is_err(), "unknown variant must error");
        reg.retire("ft", 1).unwrap();
        reg.retire("ft", 2).unwrap();
        let v1_file = dir.join("ft@1.pawd");
        let v2_file = dir.join("ft@2.pawd");
        assert!(v1_file.exists() && v2_file.exists());
        let report = reg.gc(Some("ft")).unwrap();
        assert_eq!(report.files_removed, 2);
        assert!(report.bytes_freed > 0);
        assert!(!v1_file.exists() && !v2_file.exists(), "retired artifacts must be unlinked");
        assert!(dir.join("ft@3.pawd").exists(), "active artifact must survive");
        assert!(dir.join("other@1.pawd").exists(), "other variants untouched by scoped gc");
        // Tombstones: still listed, still retired, bytes zeroed.
        let desc = &reg.list()[0];
        assert_eq!(desc.name, "ft");
        let v1 = &desc.versions[0];
        assert!(v1.retired && v1.file.is_empty() && v1.bytes == 0);
        assert!(reg.resolve("ft@1").is_err());
        // A second sweep finds nothing.
        assert_eq!(reg.gc(None).unwrap(), GcReport::default());
        // Reopen: tombstones persisted, so the next version is 4, not a
        // reuse of a collected number.
        drop(reg);
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.resolve("ft").unwrap().version, 3);
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 4);
    }

    #[test]
    fn incremental_publish_ships_a_patch_and_resolves_through_the_chain() {
        let dir = fresh_dir("pawd_test_reg_inc");
        let reg = VariantRegistry::open(&dir).unwrap();
        // First incremental publish has no parent: falls back to full.
        let v1 = seeded_model("ft", &[1, 2, 3, 4, 5, 6, 7, 8]);
        let out1 = reg.publish_incremental("ft", v1.clone(), None).unwrap();
        assert_eq!((out1.version, out1.patch), (1, false));
        // Change one of eight modules; the patch must ship a fraction.
        let mut v2 = seeded_model("ft", &[1, 2, 3, 4, 5, 6, 7, 8]);
        v2.modules[3] = seeded_model("ft", &[99]).modules[0].clone();
        let mut m3 = (*v2.modules[3]).clone();
        m3.id = v1.modules[3].id; // same slot, new content
        v2.modules[3] = std::sync::Arc::new(m3);
        let out2 = reg.publish_incremental("ft", v2.clone(), None).unwrap();
        assert_eq!((out2.version, out2.patch), (2, true));
        assert!(
            out2.bytes * 4 < out1.bytes,
            "patch ({}B) should be a fraction of full ({}B)",
            out2.bytes,
            out1.bytes
        );
        let r = reg.resolve("ft").unwrap();
        assert_eq!((r.version, r.patch, r.parent), (2, true, Some(1)));
        // The chain resolves and composes to the child's effective model.
        let links = reg.chain_links("ft", 2).unwrap();
        assert_eq!(links.len(), 2);
        assert!(!links[0].is_patch && links[1].is_patch);
        let eff = reg.effective_model("ft", 2).unwrap();
        assert_eq!(eff.modules.len(), 8);
        for (a, b) in eff.modules.iter().zip(&v2.modules) {
            assert!(a.content_eq(b), "module {} must match the published child", a.id);
        }
        // An identical republish produces an empty (tiny) patch.
        let out3 = reg.publish_incremental("ft", v2.clone(), None).unwrap();
        assert!(out3.patch);
        assert!(out3.bytes < 256, "empty patch should be header-sized, got {}", out3.bytes);
        // Explicit parent: diff against v1 again.
        let out4 = reg.publish_incremental("ft", v2, Some(1)).unwrap();
        assert!(out4.patch);
        assert_eq!(reg.chain_links("ft", out4.version).unwrap().len(), 2);
    }

    #[test]
    fn consolidate_rebases_a_chain_in_place() {
        let dir = fresh_dir("pawd_test_reg_consol");
        let reg = VariantRegistry::open(&dir).unwrap();
        let v1 = seeded_model("ft", &[1, 2, 3, 4]);
        reg.publish_incremental("ft", v1, None).unwrap();
        let mut v2 = seeded_model("ft", &[1, 2, 3, 4]);
        let mut changed = (*seeded_model("ft", &[50]).modules[0]).clone();
        changed.id = v2.modules[2].id;
        v2.modules[2] = std::sync::Arc::new(changed);
        let out = reg.publish_incremental("ft", v2.clone(), None).unwrap();
        assert!(out.patch);
        let eff_before = reg.effective_model("ft", 2).unwrap();
        let old_patch_file = {
            let r = reg.resolve("ft@2").unwrap();
            r.path.clone()
        };
        let c = reg.consolidate("ft", None).unwrap();
        assert_eq!((c.version, c.rebased_links), (2, 2));
        // Same version, now full; the old patch file is gone.
        let r = reg.resolve("ft").unwrap();
        assert_eq!((r.version, r.patch), (2, false));
        assert_eq!(reg.chain_links("ft", 2).unwrap().len(), 1);
        assert!(!old_patch_file.exists(), "superseded patch file must be unlinked");
        // Content identical to the pre-consolidation composition, and the
        // consolidated artifact is self-contained on disk.
        let eff_after = load_delta(&r.path).unwrap();
        assert_eq!(eff_after.meta.version, 2);
        assert_eq!(eff_after.modules.len(), eff_before.modules.len());
        for (a, b) in eff_after.modules.iter().zip(&eff_before.modules) {
            assert!(a.content_eq(b), "consolidation must not change {}", a.id);
        }
        // Consolidating a full version is a no-op.
        let again = reg.consolidate("ft", Some(2)).unwrap();
        assert_eq!(again.rebased_links, 0);
        // Survives reopen.
        drop(reg);
        let reg = VariantRegistry::open(&dir).unwrap();
        assert!(!reg.resolve("ft").unwrap().patch);
    }

    #[test]
    fn retire_guards_chain_parents_and_gc_pins_live_chains() {
        let dir = fresh_dir("pawd_test_reg_chainguard");
        let reg = VariantRegistry::open(&dir).unwrap();
        let v1 = seeded_model("ft", &[1, 2, 3]);
        reg.publish_incremental("ft", v1, None).unwrap();
        let mut v2 = seeded_model("ft", &[1, 2, 3]);
        let mut changed = (*seeded_model("ft", &[70]).modules[0]).clone();
        changed.id = v2.modules[0].id;
        v2.modules[0] = std::sync::Arc::new(changed);
        assert!(reg.publish_incremental("ft", v2.clone(), None).unwrap().patch);
        // v1 is the chain parent of live patch v2: retire must refuse.
        let err = reg.retire("ft", 1).unwrap_err().to_string();
        assert!(err.contains("chain parent"), "{err}");
        // Consolidating v2 severs the dependency; then v1 can retire + gc.
        reg.consolidate("ft", Some(2)).unwrap();
        reg.retire("ft", 1).unwrap();
        let v1_file = dir.join("ft@1.pawd");
        assert!(v1_file.exists());
        let report = reg.gc(Some("ft")).unwrap();
        assert_eq!(report.files_removed, 1);
        assert!(!v1_file.exists());
        // v2 still loads (it is self-contained now).
        assert!(reg.effective_model("ft", 2).is_ok());
    }

    #[test]
    fn publish_rejects_patch_models_on_the_full_path() {
        let dir = fresh_dir("pawd_test_reg_patchguard");
        let reg = VariantRegistry::open(&dir).unwrap();
        let mut m = tiny_model("ft");
        m.meta.is_patch = true;
        m.meta.parent = Some(1);
        assert!(reg.publish("ft", m).is_err(), "publish must refuse partial module sets");
    }

    #[test]
    fn adoption_restores_patch_lineage_from_headers() {
        let dir = fresh_dir("pawd_test_reg_adopt_patch");
        std::fs::create_dir_all(&dir).unwrap();
        // Write a full v1 and a patch v2 directly (as a synced-in registry
        // dir would contain), no manifest.
        let mut v1 = seeded_model("ft", &[1, 2, 3]);
        v1.meta = ArtifactMeta { version: 1, parent: None, created_unix: 0, is_patch: false };
        save_delta(dir.join("ft@1.pawd"), &v1).unwrap();
        let mut patch = seeded_model("ft", &[40]);
        let mut m0 = (*patch.modules[0]).clone();
        m0.id = v1.modules[1].id;
        patch.modules = vec![std::sync::Arc::new(m0)];
        patch.meta = ArtifactMeta { version: 2, parent: Some(1), created_unix: 0, is_patch: true };
        save_delta(dir.join("ft@2.pawd"), &patch).unwrap();
        let reg = VariantRegistry::open(&dir).unwrap();
        let r = reg.resolve("ft").unwrap();
        assert_eq!((r.version, r.patch, r.parent), (2, true, Some(1)));
        let eff = reg.effective_model("ft", 2).unwrap();
        assert_eq!(eff.modules.len(), 3);
        assert!(eff.modules[1].content_eq(&patch.modules[0]));
    }

    #[test]
    fn bad_names_and_versions_rejected() {
        let dir = fresh_dir("pawd_test_reg6");
        let reg = VariantRegistry::open(&dir).unwrap();
        assert!(reg.publish("has@at", tiny_model("x")).is_err());
        assert!(reg.publish("__stats__", tiny_model("x")).is_err());
        assert!(reg.publish("", tiny_model("x")).is_err());
        reg.publish("ok", tiny_model("ok")).unwrap();
        assert!(reg.resolve("ok@0").is_err());
        assert!(reg.resolve("ok@nope").is_err());
        assert!(reg.resolve("ok@9").is_err());
        assert!(reg.resolve("ghost").is_err());
    }

    #[test]
    fn corrupt_manifest_is_an_error() {
        let dir = fresh_dir("pawd_test_reg7");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(MANIFEST_FILE), "{not json").unwrap();
        assert!(VariantRegistry::open(&dir).is_err());
    }

    #[test]
    fn manifest_seq_is_monotone_and_survives_reopen() {
        let dir = fresh_dir("pawd_test_reg_seq");
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.manifest_seq(), 0, "never-persisted registry starts at 0");
        reg.publish("ft", tiny_model("ft")).unwrap();
        let s1 = reg.manifest_seq();
        assert!(s1 >= 1);
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.rollback("ft", None).unwrap();
        let s2 = reg.manifest_seq();
        assert!(s2 > s1, "every mutation bumps the sequence");
        drop(reg);
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.manifest_seq(), s2, "sequence persists across reopen");
        reg.pin("ft", 1).unwrap();
        assert!(reg.manifest_seq() > s2);
        // The on-disk manifest parses into the follower-facing view.
        let text = std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
        let view = parse_manifest_view(&text).unwrap();
        assert_eq!(view.manifest_seq, reg.manifest_seq());
        assert_eq!(view.variants.len(), 1);
        assert_eq!(view.variants[0].name, "ft");
        assert_eq!(view.variants[0].active, 1);
        assert!(view.variants[0].pinned);
        assert_eq!(view.variants[0].versions.len(), 2);
    }

    #[test]
    fn apply_replica_installs_records_and_moves_the_alias() {
        // A "leader" registry publishes; its records are mirrored by hand
        // into a follower directory holding copies of the artifact files.
        let leader_dir = fresh_dir("pawd_test_reg_replica_l");
        let leader = VariantRegistry::open(&leader_dir).unwrap();
        leader.publish("ft", tiny_model("ft")).unwrap();
        leader.publish("ft", tiny_model("ft")).unwrap();
        let records = leader.list()[0].versions.clone();

        let follower_dir = fresh_dir("pawd_test_reg_replica_f");
        std::fs::create_dir_all(&follower_dir).unwrap();
        for r in &records {
            std::fs::copy(leader_dir.join(&r.file), follower_dir.join(&r.file)).unwrap();
        }
        let follower = VariantRegistry::open(&follower_dir).unwrap();
        // The copied files were adopted; apply_replica must be idempotent
        // over them and install the leader's alias.
        follower.apply_replica("ft", &records, 2, false).unwrap();
        assert_eq!(follower.resolve("ft").unwrap().version, 2);
        assert_eq!(follower.list()[0].versions.len(), 2);
        // Re-applying the same state is a no-op.
        follower.apply_replica("ft", &records, 2, false).unwrap();
        assert_eq!(follower.list()[0].versions.len(), 2);
        // A leader rollback converges the follower without new records.
        follower.apply_replica("ft", &records, 1, false).unwrap();
        assert_eq!(follower.resolve("ft").unwrap().version, 1);
        // A patch record arriving without its parent is rejected.
        let orphan = VersionRecord {
            version: 9,
            parent: Some(7),
            created_unix: 1,
            file: "ft@9.pawd".into(),
            kind: ArtifactKind::Delta,
            bytes: 10,
            retired: false,
            patch: true,
        };
        let err =
            follower.apply_replica("ft", &[orphan], 1, false).unwrap_err().to_string();
        assert!(err.contains("chain parent"), "{err}");
    }
}
