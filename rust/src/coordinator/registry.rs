//! Variant lifecycle registry: the control-plane source of truth mapping
//! `variant` aliases to versioned artifacts (`variant@N`).
//!
//! The paper's premise is *frequently updated* fine-tunes; this is the piece
//! that makes an update a first-class operation instead of a file rename:
//!
//! * **publish** — assign the next version number, stamp the artifact's
//!   [`ArtifactMeta`] (version / parent / created_unix), write it as
//!   `variant@N.pawd`, and atomically flip the alias so *new* requests
//!   resolve to `N` while in-flight requests finish on the `Arc` of the old
//!   version they already hold.
//! * **rollback** — flip the alias back to the active version's parent (or
//!   an explicit target).
//! * **pin / unpin** — freeze the alias on one version; publishes still
//!   record new versions but stop moving the alias until unpinned.
//! * **retire** — mark an old version unservable (resolution of `name@N`
//!   fails fast); the active version can never be retired.
//! * **gc** — unlink retired versions' artifact files, leaving tombstone
//!   records so version numbering stays monotone across restarts.
//!
//! State is a JSON manifest (`registry.json`) in the artifact directory,
//! rewritten atomically (temp file + rename) on every mutation, plus an
//! in-memory index under a mutex. Directories that predate the registry are
//! **adopted**: untracked delta files register under the version stamped in
//! their header (bare pre-v2 files land at version 1), fp16 checkpoints
//! under their `name[@N]` stem.
//!
//! **One process owns a registry directory at a time.** The in-memory index
//! is authoritative between mutations and `persist` rewrites the manifest
//! wholesale from it, so a second process (e.g. `pawd publish` against a
//! live server's directory) would clobber the owner's state — route admin
//! operations through the serving process's control plane
//! ([`AdminOp`](super::request::AdminOp)) instead. Cross-process leases are
//! a ROADMAP follow-up.

use crate::delta::format::{load_delta, peek_meta, save_delta};
use crate::delta::types::{ArtifactMeta, DeltaModel};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Manifest file name inside the registry directory.
pub const MANIFEST_FILE: &str = "registry.json";

/// On-disk representation of one version's artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Packed PAWD delta applied onto the shared base.
    Delta,
    /// Full FP16 checkpoint (baseline path; only ever adopted, not published).
    Fp16,
}

impl ArtifactKind {
    fn label(&self) -> &'static str {
        match self {
            ArtifactKind::Delta => "delta",
            ArtifactKind::Fp16 => "fp16",
        }
    }

    fn from_label(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "delta" => ArtifactKind::Delta,
            "fp16" => ArtifactKind::Fp16,
            other => bail!("unknown artifact kind '{other}' in manifest"),
        })
    }
}

/// One version in a variant's history.
#[derive(Clone, Debug)]
pub struct VersionRecord {
    pub version: u32,
    /// Version this one superseded at publish time (rollback target).
    pub parent: Option<u32>,
    /// Publish time, seconds since the Unix epoch (0 for adopted legacy files).
    pub created_unix: u64,
    /// Artifact file name, relative to the registry directory.
    pub file: String,
    pub kind: ArtifactKind,
    /// Artifact size on disk.
    pub bytes: u64,
    /// Retired versions are unservable: `resolve("name@N")` fails fast.
    pub retired: bool,
}

#[derive(Clone, Debug, Default)]
struct VariantState {
    versions: BTreeMap<u32, VersionRecord>,
    active: u32,
    pinned: bool,
    /// High-water mark of version numbers handed to in-flight publishes
    /// (not persisted): lets a publish write its artifact outside the lock
    /// without a concurrent publish taking the same number. A failed
    /// publish leaves a harmless gap in the numbering.
    reserved_max: u32,
}

/// Control-plane view of one variant (the `list` endpoint's row).
#[derive(Clone, Debug)]
pub struct VariantDesc {
    pub name: String,
    pub active: u32,
    pub pinned: bool,
    pub versions: Vec<VersionRecord>,
}

/// Outcome of a [`VariantRegistry::gc`] sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    pub files_removed: usize,
    pub bytes_freed: u64,
}

/// What an alias (or explicit `name@N`) resolves to.
#[derive(Clone, Debug)]
pub struct Resolved {
    /// Canonical variant name (alias with any `@N` suffix stripped).
    pub name: String,
    pub version: u32,
    pub path: PathBuf,
    pub kind: ArtifactKind,
}

/// Thread-safe versioned variant registry over one artifact directory.
pub struct VariantRegistry {
    dir: PathBuf,
    inner: Mutex<BTreeMap<String, VariantState>>,
}

impl VariantRegistry {
    /// Open the registry for `dir`: load the manifest if present, then adopt
    /// any artifact files the manifest doesn't know about. A missing
    /// directory is an empty registry (publishing creates it).
    pub fn open(dir: &Path) -> Result<VariantRegistry> {
        let mut variants: BTreeMap<String, VariantState> = BTreeMap::new();
        let manifest = dir.join(MANIFEST_FILE);
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            variants = parse_manifest(&text)
                .with_context(|| format!("parsing {}", manifest.display()))?;
        }
        // Only variants with recorded versions count as manifest-tracked;
        // a persisted placeholder (failed publish) shouldn't pin the alias
        // of files adopted later.
        let tracked: std::collections::HashSet<String> = variants
            .iter()
            .filter(|(_, s)| !s.versions.is_empty())
            .map(|(n, _)| n.clone())
            .collect();
        adopt_untracked(dir, &mut variants, &tracked)?;
        Ok(VariantRegistry { dir: dir.to_path_buf(), inner: Mutex::new(variants) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Resolve an alias. `name` selects the variant's active version;
    /// `name@N` selects version `N` explicitly (pinned experiments, cache
    /// keys). Retired versions do not resolve.
    pub fn resolve(&self, name: &str) -> Result<Resolved> {
        let (base, explicit) = split_versioned_name(name)?;
        let inner = self.inner.lock().unwrap();
        let state = inner
            .get(base)
            .filter(|s| !s.versions.is_empty()) // placeholder from a failed publish
            .ok_or_else(|| anyhow::anyhow!("variant '{base}' not found in {}", self.dir.display()))?;
        let version = explicit.unwrap_or(state.active);
        let rec = state.versions.get(&version).ok_or_else(|| {
            anyhow::anyhow!("variant '{base}' has no version {version}")
        })?;
        if rec.retired {
            bail!("variant '{base}@{version}' is retired");
        }
        Ok(Resolved {
            name: base.to_string(),
            version,
            path: self.dir.join(&rec.file),
            kind: rec.kind,
        })
    }

    /// Publish `model` as the next version of `name`. Stamps the artifact
    /// meta, writes `name@N.pawd`, records the version, and flips the alias
    /// to `N` unless the variant is pinned. Returns the assigned version.
    ///
    /// The version number is *reserved* under the lock, the artifact is
    /// serialized to a temp file and renamed into place with the lock
    /// released (data-path resolves never wait on the multi-MB artifact
    /// write; they can still briefly contend on the small manifest rewrite
    /// in `persist`), and the index mutates only after the rename — a crash
    /// mid-write leaves a stray `.tmp` file, never a live truncated version.
    pub fn publish(&self, name: &str, mut model: DeltaModel) -> Result<u32> {
        validate_name(name)?;
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating registry dir {}", self.dir.display()))?;
        let (version, parent, file) = {
            let mut inner = self.inner.lock().unwrap();
            let state = inner.entry(name.to_string()).or_default();
            let next = state
                .versions
                .keys()
                .next_back()
                .copied()
                .unwrap_or(0)
                .max(state.reserved_max)
                + 1;
            state.reserved_max = next;
            // Pick a filename no existing record (e.g. an adopted mis-named
            // copy sitting at `name@N.pawd`) and no stray disk file owns —
            // the record, not the filename, is authoritative. Fallback names
            // stay namespaced by the (unique, reserved) version, so two
            // concurrent publishes can never converge on one filename.
            let taken: std::collections::HashSet<&str> =
                state.versions.values().map(|r| r.file.as_str()).collect();
            let mut file = format!("{name}@{next}.pawd");
            let mut bump = 0u32;
            while taken.contains(file.as_str()) || self.dir.join(&file).exists() {
                bump += 1;
                file = format!("{name}@{next}-{bump}.pawd");
            }
            (next, Some(state.active).filter(|&a| a > 0), file)
        };
        let created_unix = unix_now();
        model.variant = name.to_string();
        model.meta = ArtifactMeta { version, parent, created_unix };
        let tmp = self.dir.join(format!("{file}.tmp"));
        let written = save_delta(&tmp, &model).and_then(|bytes| {
            std::fs::rename(&tmp, self.dir.join(&file))
                .with_context(|| format!("committing artifact {file}"))?;
            Ok(bytes)
        });
        let bytes = match written {
            Ok(b) => b,
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                // The `reserved_max` watermark survives, so later publishes
                // never reuse this number. Empty placeholder entries are
                // invisible to `resolve`/`list`/`names`.
                return Err(e);
            }
        };
        self.mutate(|index| {
            let state = index.entry(name.to_string()).or_default();
            state.versions.insert(
                version,
                VersionRecord {
                    version,
                    parent,
                    created_unix,
                    file,
                    kind: ArtifactKind::Delta,
                    bytes,
                    retired: false,
                },
            );
            // Concurrent publishes can commit out of order (B reserves v4
            // and lands before A's v3): only ever move the alias forward.
            if !state.pinned && version > state.active {
                state.active = version;
            }
            Ok(version)
        })
    }

    /// Publish an existing `.pawd` file as the next version of `name`
    /// (loads, restamps the meta, re-serializes into the registry dir).
    pub fn publish_file(&self, name: &str, src: &Path) -> Result<u32> {
        let model = load_delta(src)
            .with_context(|| format!("loading artifact to publish from {}", src.display()))?;
        self.publish(name, model)
    }

    /// Flip the alias back: to `to` if given, else to the active version's
    /// parent (falling back to the highest non-retired version below the
    /// active one). Returns the version now active.
    pub fn rollback(&self, name: &str, to: Option<u32>) -> Result<u32> {
        self.mutate(|index| {
            let state = state_mut(index, name)?;
            let target = match to {
                Some(v) => v,
                None => {
                    let active = state.active;
                    let parent = state.versions.get(&active).and_then(|r| r.parent);
                    let parent_ok = parent
                        .and_then(|p| state.versions.get(&p))
                        .filter(|r| !r.retired)
                        .map(|r| r.version);
                    match parent_ok.or_else(|| {
                        state
                            .versions
                            .range(..active)
                            .rev()
                            .find(|(_, r)| !r.retired)
                            .map(|(&v, _)| v)
                    }) {
                        Some(v) => v,
                        None => bail!("variant '{name}' has no version to roll back to"),
                    }
                }
            };
            let rec = state
                .versions
                .get(&target)
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' has no version {target}"))?;
            if rec.retired {
                bail!("cannot roll '{name}' back to retired version {target}");
            }
            state.active = target;
            Ok(target)
        })
    }

    /// Freeze the alias on `version`: publishes keep recording new versions
    /// but stop moving the alias until [`unpin`](Self::unpin).
    pub fn pin(&self, name: &str, version: u32) -> Result<()> {
        self.mutate(|index| {
            let state = state_mut(index, name)?;
            let rec = state
                .versions
                .get(&version)
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' has no version {version}"))?;
            if rec.retired {
                bail!("cannot pin '{name}' to retired version {version}");
            }
            state.active = version;
            state.pinned = true;
            Ok(())
        })
    }

    /// Release a pin; the alias stays where it is and the next publish moves
    /// it again.
    pub fn unpin(&self, name: &str) -> Result<()> {
        self.mutate(|index| {
            state_mut(index, name)?.pinned = false;
            Ok(())
        })
    }

    /// Mark a version unservable. The active version cannot be retired —
    /// roll back or publish first.
    pub fn retire(&self, name: &str, version: u32) -> Result<()> {
        self.mutate(|index| {
            let state = state_mut(index, name)?;
            if state.active == version {
                bail!("refusing to retire the active version {version} of '{name}' (rollback or publish first)");
            }
            let rec = state
                .versions
                .get_mut(&version)
                .ok_or_else(|| anyhow::anyhow!("variant '{name}' has no version {version}"))?;
            rec.retired = true;
            Ok(())
        })
    }

    /// Garbage-collect retired versions' artifact files for `name` (or for
    /// every variant when `None`). [`retire`](Self::retire) makes a version
    /// unservable but leaves its artifact on disk forever; this sweep
    /// unlinks those files while keeping each record as a **tombstone**
    /// (`file` cleared, `bytes` zeroed), so version numbering stays
    /// monotone across restarts and the history remains listable.
    ///
    /// The tombstones commit to the manifest *before* any file is unlinked
    /// (write-ahead, like every other mutation): a crash mid-sweep can
    /// leave orphaned-but-untracked files on disk (harmless — adoption
    /// skips version slots a record already owns, and retired versions
    /// never resolve), never a live record pointing at a deleted artifact.
    /// In-flight requests still holding the version's `Arc` are unaffected
    /// — the weights are resident, only the disk copy goes away.
    pub fn gc(&self, name: Option<&str>) -> Result<GcReport> {
        // Phase 1 (under the lock, write-ahead): tombstone matching records
        // and collect the doomed paths.
        let doomed: Vec<(PathBuf, u64)> = self.mutate(|index| {
            if let Some(n) = name {
                let known = index.get(n).map(|s| !s.versions.is_empty()).unwrap_or(false);
                if !known {
                    bail!("variant '{n}' not found in registry");
                }
            }
            // Never unlink a file a live (non-retired) record still points
            // at — publish guarantees unique filenames, this is belt and
            // braces against hand-edited manifests.
            let live: std::collections::HashSet<String> = index
                .values()
                .flat_map(|s| s.versions.values())
                .filter(|r| !r.retired)
                .map(|r| r.file.clone())
                .collect();
            let mut doomed = Vec::new();
            for (vname, state) in index.iter_mut() {
                if let Some(n) = name {
                    if n != vname {
                        continue;
                    }
                }
                for rec in state.versions.values_mut() {
                    if rec.retired && !rec.file.is_empty() && !live.contains(&rec.file) {
                        doomed.push((self.dir.join(&rec.file), rec.bytes));
                        rec.file = String::new();
                        rec.bytes = 0;
                    }
                }
            }
            Ok(doomed)
        })?;
        // Phase 2 (outside the lock): unlink. Already-missing files count as
        // collected — the record said retired either way.
        let mut report = GcReport::default();
        for (path, bytes) in doomed {
            match std::fs::remove_file(&path) {
                Ok(()) => {
                    report.files_removed += 1;
                    report.bytes_freed += bytes;
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("removing retired artifact {}", path.display())))
                }
            }
        }
        Ok(report)
    }

    /// All variants with their full version histories, sorted by name.
    /// Version-less placeholder entries (left by failed publishes to keep
    /// their reservation watermark) are omitted.
    pub fn list(&self) -> Vec<VariantDesc> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .filter(|(_, s)| !s.versions.is_empty())
            .map(|(name, s)| VariantDesc {
                name: name.clone(),
                active: s.active,
                pinned: s.pinned,
                versions: s.versions.values().cloned().collect(),
            })
            .collect()
    }

    /// Variant names only (the legacy `VariantStore::list` surface).
    pub fn names(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .filter(|(_, s)| !s.versions.is_empty())
            .map(|(name, _)| name.clone())
            .collect()
    }

    /// Write-ahead commit shared by every mutation: apply `f` to a copy of
    /// the index, persist that copy, and only then swap it in. A failure in
    /// `f` or in the manifest write leaves the live index (and therefore
    /// what the server serves) exactly as the returned error implies, and a
    /// restart reloads the same state.
    fn mutate<R>(
        &self,
        f: impl FnOnce(&mut BTreeMap<String, VariantState>) -> Result<R>,
    ) -> Result<R> {
        let mut inner = self.inner.lock().unwrap();
        let mut next = inner.clone();
        let out = f(&mut next)?;
        self.persist(&next)?;
        *inner = next;
        Ok(out)
    }

    fn persist(&self, variants: &BTreeMap<String, VariantState>) -> Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let tmp = self.dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, render_manifest(variants).to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.dir.join(MANIFEST_FILE))
            .with_context(|| "committing registry manifest")?;
        Ok(())
    }
}

fn state_mut<'a>(
    inner: &'a mut BTreeMap<String, VariantState>,
    name: &str,
) -> Result<&'a mut VariantState> {
    inner
        .get_mut(name)
        .ok_or_else(|| anyhow::anyhow!("variant '{name}' not found in registry"))
}

fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() {
        bail!("variant name must not be empty");
    }
    if name.contains('@') || name.contains('/') || name.starts_with("__") {
        bail!("variant name '{name}' is invalid ('@', '/' and the '__' prefix are reserved)");
    }
    Ok(())
}

/// Split `name[@version]`. An explicit `@0` or non-numeric suffix is an error.
fn split_versioned_name(name: &str) -> Result<(&str, Option<u32>)> {
    match name.rsplit_once('@') {
        None => Ok((name, None)),
        Some((base, v)) => {
            let version: u32 = v
                .parse()
                .ok()
                .filter(|&v| v > 0)
                .ok_or_else(|| anyhow::anyhow!("bad version suffix in '{name}'"))?;
            Ok((base, Some(version)))
        }
    }
}

fn unix_now() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

/// Register artifact files the manifest doesn't cover. Delta files are
/// adopted under the version **stamped in their header** (`peek_meta` — the
/// filename is not trusted, so a mis-named copy cannot flip the alias to a
/// version the loader would then refuse); fp16 checkpoints carry no meta
/// and use their `name[@N]` stem (default 1). Never overwrites a manifest
/// entry; `.pawd` wins over a co-named `.fp16` at the same version. For
/// variants the manifest already `tracked`, adopted files are addressable
/// (`name@N`) but never move the alias — a stray file must not override a
/// persisted rollback or a crashed publish's manifest state.
fn adopt_untracked(
    dir: &Path,
    variants: &mut BTreeMap<String, VariantState>,
    tracked: &std::collections::HashSet<String>,
) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // missing dir = empty registry
    };
    // Files the manifest already references are skipped by name, before any
    // header peek — reopening a healthy registry stays one directory scan.
    let tracked_files: std::collections::HashSet<String> = variants
        .values()
        .flat_map(|s| s.versions.values().map(|r| r.file.clone()))
        .collect();
    let mut files: Vec<(String, ArtifactKind, String, u64, PathBuf)> = Vec::new();
    for entry in entries {
        let p = entry?.path();
        let kind = match p.extension().and_then(|e| e.to_str()) {
            Some("pawd") => ArtifactKind::Delta,
            Some("fp16") => ArtifactKind::Fp16,
            _ => continue,
        };
        let Some(stem) = p.file_stem().and_then(|s| s.to_str()) else { continue };
        let Some(file) = p.file_name().and_then(|s| s.to_str()) else { continue };
        if tracked_files.contains(file) {
            continue;
        }
        let bytes = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
        files.push((stem.to_string(), kind, file.to_string(), bytes, p));
    }
    // Deltas first so a co-named fp16 can't claim the version slot.
    files.sort_by_key(|(_, kind, ..)| matches!(kind, ArtifactKind::Fp16));
    for (stem, kind, file, bytes, path) in files {
        let (name, version) = match (kind, split_versioned_name(&stem)) {
            (ArtifactKind::Delta, Ok((n, _))) => match peek_meta(&path) {
                Ok(meta) => (n.to_string(), meta.version),
                Err(_) => continue, // unreadable header: leave untracked
            },
            (ArtifactKind::Fp16, Ok((n, v))) => (n.to_string(), v.unwrap_or(1)),
            // '@' is reserved for version suffixes: a stem like
            // `model@final` can't be addressed through `resolve`, so
            // adopting it would only create an unreachable entry. Leave the
            // file untracked (rename it to drop the '@' to serve it).
            (_, Err(_)) => continue,
        };
        let manifest_tracked = tracked.contains(&name);
        let state = variants.entry(name).or_default();
        if state.versions.contains_key(&version) {
            continue; // manifest (or a delta) already owns this slot
        }
        state.versions.insert(
            version,
            VersionRecord {
                version,
                parent: None,
                created_unix: 0,
                file,
                kind,
                bytes,
                retired: false,
            },
        );
        if !manifest_tracked && (state.active == 0 || version > state.active) {
            state.active = version;
        }
    }
    Ok(())
}

// -- manifest (de)serialization -------------------------------------------

fn render_manifest(variants: &BTreeMap<String, VariantState>) -> Json {
    let vs = variants
        .iter()
        .map(|(name, s)| {
            let versions = s
                .versions
                .values()
                .map(|r| {
                    json::obj(vec![
                        ("version", json::n(r.version as f64)),
                        ("parent", json::n(r.parent.unwrap_or(0) as f64)),
                        ("created_unix", json::n(r.created_unix as f64)),
                        ("file", json::s(&r.file)),
                        ("kind", json::s(r.kind.label())),
                        ("bytes", json::n(r.bytes as f64)),
                        ("retired", Json::Bool(r.retired)),
                    ])
                })
                .collect();
            (
                name.as_str(),
                json::obj(vec![
                    ("active", json::n(s.active as f64)),
                    ("pinned", Json::Bool(s.pinned)),
                    ("versions", json::arr(versions)),
                ]),
            )
        })
        .collect::<Vec<_>>();
    json::obj(vec![("format", json::n(1.0)), ("variants", json::obj(vs))])
}

fn parse_manifest(text: &str) -> Result<BTreeMap<String, VariantState>> {
    let j = Json::parse(text)?;
    let format = j.req_usize("format")?;
    if format != 1 {
        bail!("unsupported registry manifest format {format}");
    }
    let mut out = BTreeMap::new();
    for (name, v) in j.req("variants")?.as_obj().context("'variants' is not an object")? {
        let mut state = VariantState {
            versions: BTreeMap::new(),
            active: v.req_usize("active")? as u32,
            pinned: v.req("pinned")?.as_bool().context("'pinned' is not a bool")?,
            reserved_max: 0,
        };
        for rv in v.req_arr("versions")? {
            let version = rv.req_usize("version")? as u32;
            let parent = rv.req_usize("parent")? as u32;
            state.versions.insert(
                version,
                VersionRecord {
                    version,
                    parent: if parent == 0 { None } else { Some(parent) },
                    created_unix: rv.req_usize("created_unix")? as u64,
                    file: rv.req_str("file")?.to_string(),
                    kind: ArtifactKind::from_label(rv.req_str("kind")?)?,
                    bytes: rv.req_usize("bytes")? as u64,
                    retired: rv.req("retired")?.as_bool().context("'retired' is not a bool")?,
                },
            );
        }
        if version_state_invalid(&state) {
            bail!("manifest entry '{name}' is inconsistent (active version missing or retired)");
        }
        out.insert(name.clone(), state);
    }
    Ok(out)
}

fn version_state_invalid(s: &VariantState) -> bool {
    match s.versions.get(&s.active) {
        Some(rec) => rec.retired,
        None => !s.versions.is_empty(), // empty histories get fixed by adoption
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Axis, DeltaModule};
    use crate::model::{ModuleId, ProjKind};

    fn tiny_model(variant: &str) -> DeltaModel {
        let d = vec![1.0f32; 8 * 8];
        DeltaModel {
            variant: variant.into(),
            base_config: "tiny".into(),
            meta: Default::default(),
            modules: vec![DeltaModule {
                id: ModuleId { layer: 0, kind: ProjKind::Q },
                mask: PackedMask::pack(&d, 8, 8),
                axis: Axis::Row,
                scales: vec![0.1; 8],
            }],
        }
    }

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn publish_assigns_versions_and_flips_alias() {
        let dir = fresh_dir("pawd_test_reg1");
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 1);
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 2);
        let r = reg.resolve("ft").unwrap();
        assert_eq!((r.version, r.name.as_str()), (2, "ft"));
        assert!(r.path.ends_with("ft@2.pawd"));
        // Explicit addressing still reaches the old version.
        assert_eq!(reg.resolve("ft@1").unwrap().version, 1);
        // The published artifact carries its stamped lineage.
        let m = load_delta(&r.path).unwrap();
        assert_eq!(m.meta.version, 2);
        assert_eq!(m.meta.parent, Some(1));
        assert!(m.meta.created_unix > 0);
    }

    #[test]
    fn rollback_restores_parent_and_retire_guards() {
        let dir = fresh_dir("pawd_test_reg2");
        let reg = VariantRegistry::open(&dir).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        assert!(reg.retire("ft", 2).is_err(), "active version must not retire");
        assert_eq!(reg.rollback("ft", None).unwrap(), 1);
        assert_eq!(reg.resolve("ft").unwrap().version, 1);
        reg.retire("ft", 2).unwrap();
        assert!(reg.resolve("ft@2").is_err(), "retired versions must not resolve");
        assert!(reg.rollback("ft", Some(2)).is_err(), "cannot roll onto retired");
        // Publishing after a rollback continues the numbering past the max.
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 3);
        assert_eq!(reg.resolve("ft").unwrap().version, 3);
    }

    #[test]
    fn pin_freezes_alias_across_publish() {
        let dir = fresh_dir("pawd_test_reg3");
        let reg = VariantRegistry::open(&dir).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.pin("ft", 1).unwrap();
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 2);
        assert_eq!(reg.resolve("ft").unwrap().version, 1, "pinned alias must not move");
        reg.unpin("ft").unwrap();
        assert_eq!(reg.resolve("ft").unwrap().version, 1, "unpin alone does not move the alias");
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 3);
        assert_eq!(reg.resolve("ft").unwrap().version, 3);
    }

    #[test]
    fn manifest_survives_reopen() {
        let dir = fresh_dir("pawd_test_reg4");
        {
            let reg = VariantRegistry::open(&dir).unwrap();
            reg.publish("a", tiny_model("a")).unwrap();
            reg.publish("a", tiny_model("a")).unwrap();
            reg.rollback("a", None).unwrap();
            reg.publish("b", tiny_model("b")).unwrap();
            reg.pin("b", 1).unwrap();
        }
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.resolve("a").unwrap().version, 1);
        assert_eq!(reg.resolve("a@2").unwrap().version, 2);
        let descs = reg.list();
        assert_eq!(descs.len(), 2);
        assert!(descs[1].pinned);
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn adopts_legacy_directory_layout() {
        let dir = fresh_dir("pawd_test_reg5");
        std::fs::create_dir_all(&dir).unwrap();
        // A pre-registry directory: bare v1-style names, no manifest.
        save_delta(dir.join("old.pawd"), &tiny_model("old")).unwrap();
        std::fs::write(dir.join("ckpt.fp16"), b"not parsed during adoption").unwrap();
        let reg = VariantRegistry::open(&dir).unwrap();
        let r = reg.resolve("old").unwrap();
        assert_eq!((r.version, r.kind), (1, ArtifactKind::Delta));
        assert_eq!(reg.resolve("ckpt").unwrap().kind, ArtifactKind::Fp16);
        // Publishing on top of an adopted variant continues at version 2.
        assert_eq!(reg.publish("old", tiny_model("old")).unwrap(), 2);
        assert_eq!(reg.resolve("old").unwrap().version, 2);
    }

    #[test]
    fn adoption_trusts_embedded_version_over_filename() {
        let dir = fresh_dir("pawd_test_reg8");
        std::fs::create_dir_all(&dir).unwrap();
        // A default-stamped artifact (meta.version = 1) mis-named as @3 —
        // e.g. a hand-copied file. The filename must not win: the loader
        // would refuse a version-3 resolution of a version-1 artifact.
        save_delta(dir.join("ft@3.pawd"), &tiny_model("ft")).unwrap();
        let reg = VariantRegistry::open(&dir).unwrap();
        let r = reg.resolve("ft").unwrap();
        assert_eq!(r.version, 1, "embedded meta version wins over the filename");
        assert!(r.path.ends_with("ft@3.pawd"));
        assert_eq!(load_delta(&r.path).unwrap().meta.version, 1);
        // Publishing up to version 3 must not clobber the mis-named file
        // that backs version 1: the filename picker detours around it.
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 2);
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 3);
        let v3 = reg.resolve("ft@3").unwrap();
        assert!(v3.path.ends_with("ft@3-1.pawd"), "got {}", v3.path.display());
        assert_eq!(load_delta(&v3.path).unwrap().meta.version, 3);
        // v1 still loads from the untouched original file.
        let v1 = reg.resolve("ft@1").unwrap();
        assert_eq!(load_delta(&v1.path).unwrap().meta.version, 1);
    }

    #[test]
    fn gc_unlinks_retired_files_and_keeps_numbering_monotone() {
        let dir = fresh_dir("pawd_test_reg_gc");
        let reg = VariantRegistry::open(&dir).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("ft", tiny_model("ft")).unwrap();
        reg.publish("other", tiny_model("other")).unwrap();
        // Nothing retired yet: gc is a no-op.
        assert_eq!(reg.gc(None).unwrap(), GcReport::default());
        assert!(reg.gc(Some("ghost")).is_err(), "unknown variant must error");
        reg.retire("ft", 1).unwrap();
        reg.retire("ft", 2).unwrap();
        let v1_file = dir.join("ft@1.pawd");
        let v2_file = dir.join("ft@2.pawd");
        assert!(v1_file.exists() && v2_file.exists());
        let report = reg.gc(Some("ft")).unwrap();
        assert_eq!(report.files_removed, 2);
        assert!(report.bytes_freed > 0);
        assert!(!v1_file.exists() && !v2_file.exists(), "retired artifacts must be unlinked");
        assert!(dir.join("ft@3.pawd").exists(), "active artifact must survive");
        assert!(dir.join("other@1.pawd").exists(), "other variants untouched by scoped gc");
        // Tombstones: still listed, still retired, bytes zeroed.
        let desc = &reg.list()[0];
        assert_eq!(desc.name, "ft");
        let v1 = &desc.versions[0];
        assert!(v1.retired && v1.file.is_empty() && v1.bytes == 0);
        assert!(reg.resolve("ft@1").is_err());
        // A second sweep finds nothing.
        assert_eq!(reg.gc(None).unwrap(), GcReport::default());
        // Reopen: tombstones persisted, so the next version is 4, not a
        // reuse of a collected number.
        drop(reg);
        let reg = VariantRegistry::open(&dir).unwrap();
        assert_eq!(reg.resolve("ft").unwrap().version, 3);
        assert_eq!(reg.publish("ft", tiny_model("ft")).unwrap(), 4);
    }

    #[test]
    fn bad_names_and_versions_rejected() {
        let dir = fresh_dir("pawd_test_reg6");
        let reg = VariantRegistry::open(&dir).unwrap();
        assert!(reg.publish("has@at", tiny_model("x")).is_err());
        assert!(reg.publish("__stats__", tiny_model("x")).is_err());
        assert!(reg.publish("", tiny_model("x")).is_err());
        reg.publish("ok", tiny_model("ok")).unwrap();
        assert!(reg.resolve("ok@0").is_err());
        assert!(reg.resolve("ok@nope").is_err());
        assert!(reg.resolve("ok@9").is_err());
        assert!(reg.resolve("ghost").is_err());
    }

    #[test]
    fn corrupt_manifest_is_an_error() {
        let dir = fresh_dir("pawd_test_reg7");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(MANIFEST_FILE), "{not json").unwrap();
        assert!(VariantRegistry::open(&dir).is_err());
    }
}
