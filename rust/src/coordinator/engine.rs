//! The continuously-stepping serving engine.
//!
//! Replaces the stop-and-go window dispatcher: instead of holding a batch
//! window until it is full or a dispatch deadline expires, the
//! engine *steps* whenever anything changes — a request arrives, an abort
//! lands, or a worker finishes an item. Each step admits a fair-share
//! window (`fair_take`) onto every idle worker slot immediately, so:
//!
//! * an idle host serves a lone request at compute latency, never a
//!   deadline wait (the old dispatcher's idle-latency bug);
//! * a hot window never blocks behind a deadline — new requests are
//!   admitted into the in-flight batch at the next step boundary;
//! * publish / `PullFrom` warms ride the same slots as data windows and
//!   overlap with serving instead of stalling it.
//!
//! There is no dispatch-deadline knob in [`ServerConfig`]:
//! flush-on-idle-slot *is* the deadline policy.
//!
//! [`EngineCore`] holds the pure admission state (pending queue, in-flight
//! slot count) and is directly unit-testable; `engine_loop` wires it to
//! the ingress and work channels on the `pawd-engine` thread.

use super::metrics::Metrics;
use super::request::{DataOp, Payload, Request, Response, Timing, ADMIN_VARIANT};
use super::server::ServerConfig;
use crate::exec::counters;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// One variant's slice of an admitted window (requests in arrival order).
pub struct VariantGroup {
    pub variant: String,
    pub requests: Vec<Request>,
}

/// One unit of worker work.
pub(crate) enum WorkItem {
    /// A single control-plane request (bypasses batching; may carry a
    /// misdirected data payload aimed at a reserved pseudo-variant, which
    /// the worker rejects).
    Admin(Request),
    /// An admitted window of data requests, grouped by variant.
    Window(Vec<VariantGroup>),
}

/// Ingress message driving the engine loop. Every variant is a *step
/// signal*: the loop re-evaluates admission after each one.
pub(crate) enum Ingress {
    /// A new request (data or admin).
    Req(Request),
    /// Abort a pending request by id. In-flight requests complete normally
    /// — only requests still waiting for admission are dropped.
    Abort(u64),
    /// A worker finished one `WorkItem`, freeing a slot.
    StepDone,
    /// Explicit shutdown (live `Client` clones keep the channel open).
    Shutdown,
}

/// Pool idle time per step at or above this marks spare compute capacity:
/// the AIMD target grows additively (wider windows amortize more).
const AIMD_HIGH_IDLE_NS: u64 = 500_000;
/// Pool idle time per step at or below this marks saturation: the target
/// backs off multiplicatively (narrower windows cut queue latency).
const AIMD_LOW_IDLE_NS: u64 = 50_000;

/// Adaptive window-size target fed by the compute pool's
/// `pool_steal_or_idle_ns` counter (the PR 6 follow-up): lots of idle time
/// between jobs means the pool is starved for parallel work, so admit
/// wider windows (+1); near-zero idle means the pool is saturated, so back
/// off (×0.75). Between the thresholds the target holds (dead band — no
/// oscillation on a steady load). The target always stays in
/// `[1, max_batch]`, so the configured cap remains a hard ceiling.
struct AimdBatch {
    target: f64,
    max: usize,
    last_idle_ns: u64,
}

impl AimdBatch {
    fn new(max_batch: usize) -> AimdBatch {
        let max = max_batch.max(1);
        // Start wide: the first windows probe the configured cap and the
        // idle signal walks the target down if the pool saturates.
        AimdBatch { target: max as f64, max, last_idle_ns: 0 }
    }

    /// Feed the *cumulative* pool idle counter; the per-step delta drives
    /// one AIMD move.
    fn observe_idle_total(&mut self, idle_ns_total: u64) {
        let delta = idle_ns_total.saturating_sub(self.last_idle_ns);
        self.last_idle_ns = idle_ns_total;
        if delta >= AIMD_HIGH_IDLE_NS {
            self.target = (self.target + 1.0).min(self.max as f64);
        } else if delta <= AIMD_LOW_IDLE_NS {
            self.target = (self.target * 0.75).max(1.0);
        }
    }

    /// Current admission cap in requests.
    fn target(&self) -> usize {
        (self.target.round() as usize).clamp(1, self.max)
    }
}

/// Pure admission state of the continuous-batching engine: what is waiting
/// and how many worker slots are occupied. All channel I/O lives in
/// `engine_loop`, so this core is deterministic and unit-testable.
pub struct EngineCore {
    pending: VecDeque<Request>,
    in_flight: usize,
    capacity: usize,
    max_batch: usize,
    aimd: AimdBatch,
}

impl EngineCore {
    /// `capacity` is the number of worker slots (≥ 1); `max_batch` caps the
    /// requests admitted per step.
    pub fn new(capacity: usize, max_batch: usize) -> EngineCore {
        EngineCore {
            pending: VecDeque::new(),
            in_flight: 0,
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
            aimd: AimdBatch::new(max_batch),
        }
    }

    /// Feed the cumulative pool steal-or-idle counter into the adaptive
    /// window-size target (called on every `StepDone`).
    pub fn observe_idle(&mut self, idle_ns_total: u64) {
        self.aimd.observe_idle_total(idle_ns_total);
    }

    /// The adaptive per-step admission cap (`<= max_batch`, `>= 1`).
    pub fn batch_target(&self) -> usize {
        self.aimd.target()
    }

    /// Queue a data request for admission at the next step boundary.
    pub fn add_request(&mut self, req: Request) {
        self.pending.push_back(req);
    }

    /// Remove and return a still-pending request by id (`None` if it was
    /// already admitted or never existed).
    pub fn abort(&mut self, id: u64) -> Option<Request> {
        let i = self.pending.iter().position(|r| r.id == id)?;
        self.pending.remove(i)
    }

    /// Account an item handed to the workers outside [`step`](Self::step)
    /// (the admin fast lane).
    pub fn begin_work(&mut self) {
        self.in_flight += 1;
    }

    /// A worker finished one item, freeing a slot.
    pub fn work_done(&mut self) {
        self.in_flight = self.in_flight.saturating_sub(1);
    }

    /// Requests waiting for admission.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Worker slots currently occupied.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// One step: if a worker slot is idle and requests are waiting, admit a
    /// fair-share window immediately (no deadline) and occupy the slot.
    /// Returns `None` when saturated or idle — callers loop until then.
    pub fn step(&mut self) -> Option<Vec<VariantGroup>> {
        if self.pending.is_empty() || self.in_flight >= self.capacity {
            return None;
        }
        let requests = fair_take(&mut self.pending, self.aimd.target());
        self.in_flight += 1;
        Some(group_by_variant(requests))
    }

    /// Flush a window regardless of slot occupancy (shutdown drain).
    pub fn drain(&mut self) -> Option<Vec<VariantGroup>> {
        if self.pending.is_empty() {
            return None;
        }
        Some(group_by_variant(fair_take(&mut self.pending, self.max_batch)))
    }
}

/// The engine thread: blocks for one ingress message, drains the burst
/// behind it, then steps until every idle worker slot is fed. On shutdown
/// the remaining queue is flushed as final windows (the work sender drops
/// on return, so workers drain and exit).
pub(crate) fn engine_loop(
    ingress: mpsc::Receiver<Ingress>,
    work: mpsc::Sender<WorkItem>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
) {
    let mut core = EngineCore::new(cfg.n_workers.max(1), cfg.max_batch);
    let mut open = true;
    while open {
        let first = match ingress.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        if !process(&mut core, &work, &metrics, first) {
            open = false;
        }
        // Drain the burst so one step sees every request already queued —
        // concurrent submitters coalesce into mixed windows exactly like
        // the old deadline flush, minus the waiting.
        loop {
            match ingress.try_recv() {
                Ok(m) => {
                    if !process(&mut core, &work, &metrics, m) {
                        open = false;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        while let Some(groups) = core.step() {
            if send_window(&work, &metrics, groups).is_err() {
                return; // workers gone
            }
        }
    }
    // Shutdown drain: flush everything still pending (responses must not
    // be dropped); the queued items complete before workers see the
    // channel close.
    while let Some(groups) = core.drain() {
        if send_window(&work, &metrics, groups).is_err() {
            return;
        }
    }
}

/// Apply one ingress message to the core; returns `false` on shutdown.
fn process(
    core: &mut EngineCore,
    work: &mpsc::Sender<WorkItem>,
    metrics: &Metrics,
    msg: Ingress,
) -> bool {
    match msg {
        Ingress::Req(req) => {
            // Admin ops (and anything aimed at the reserved admin
            // pseudo-variant) take the fast lane: they never touch an
            // engine, so queuing them behind data admission would only
            // delay alias flips. They still occupy a worker slot so
            // control-plane storms cannot pile unbounded windows into the
            // work channel.
            let admin =
                matches!(req.payload, Payload::Admin(_)) || req.variant == ADMIN_VARIANT;
            if admin {
                core.begin_work();
                let _ = work.send(WorkItem::Admin(req));
            } else {
                core.add_request(req);
            }
        }
        Ingress::Abort(id) => {
            if let Some(req) = core.abort(id) {
                let total = req.submitted.elapsed();
                metrics.record_request(&req.variant, total, Duration::ZERO, total, true);
                let _ = req.resp.send(Response {
                    id: req.id,
                    variant: req.variant.clone(),
                    version: None,
                    result: Err("aborted before dispatch".into()),
                    timing: Timing { queue: total, total, ..Default::default() },
                });
            }
        }
        Ingress::StepDone => {
            core.work_done();
            // Adaptive window sizing: each finished item carries the pool's
            // cumulative steal-or-idle time forward into the AIMD target.
            core.observe_idle(counters::pool_steal_or_idle_ns());
        }
        Ingress::Shutdown => return false,
    }
    true
}

fn send_window(
    work: &mpsc::Sender<WorkItem>,
    metrics: &Metrics,
    groups: Vec<VariantGroup>,
) -> Result<(), ()> {
    let size: usize = groups.iter().map(|g| g.requests.len()).sum();
    metrics.record_batch(size);
    counters::record_engine_step();
    work.send(WorkItem::Window(groups)).map_err(|_| ())
}

/// Pick up to `max` requests from the queue **round-robin across
/// variants** (variants ordered by first appearance, per-variant FIFO
/// preserved), so a variant flooding the ingress cannot fill whole windows
/// and starve a cold variant's lone request. The overall oldest request is
/// always picked (its variant leads the rotation); unpicked requests stay
/// in arrival order.
///
/// Within a variant's turn the pick is **prefix-affine**: once the variant
/// has seated a request this window, a queued request whose leading token
/// block hashes the same is preferred over strict FIFO, so prefix-sharing
/// requests ride one window and the prefix cache serves the whole group
/// from one suffix GEMM. Affinity only reorders *within* one variant's
/// queue — fairness across variants and the oldest-request guarantee are
/// untouched.
pub(crate) fn fair_take(window: &mut VecDeque<Request>, max: usize) -> Vec<Request> {
    if window.len() <= max {
        return window.drain(..).collect();
    }
    // Bucket indices by variant, first-appearance order.
    let mut order: Vec<&str> = Vec::new();
    let mut buckets: HashMap<&str, VecDeque<usize>> = HashMap::new();
    for (i, req) in window.iter().enumerate() {
        let entry = buckets.entry(req.variant.as_str()).or_default();
        if entry.is_empty() && !order.contains(&req.variant.as_str()) {
            order.push(req.variant.as_str());
        }
        entry.push_back(i);
    }
    let hints: Vec<u64> = window.iter().map(prefix_hint).collect();
    let mut last_hint: HashMap<&str, u64> = HashMap::new();
    let mut picked = vec![false; window.len()];
    let mut n = 0usize;
    'rounds: loop {
        let mut any = false;
        for v in &order {
            let Some(b) = buckets.get_mut(v) else { continue };
            let slot = last_hint
                .get(v)
                .and_then(|&h| b.iter().position(|&i| hints[i] == h))
                .unwrap_or(0);
            if let Some(i) = b.remove(slot) {
                picked[i] = true;
                last_hint.insert(*v, hints[i]);
                n += 1;
                any = true;
                if n == max {
                    break 'rounds;
                }
            }
        }
        if !any {
            break;
        }
    }
    // Drain picked indices preserving arrival order on both sides.
    let mut taken = Vec::with_capacity(n);
    let mut rest = VecDeque::with_capacity(window.len() - n);
    for (i, req) in window.drain(..).enumerate() {
        if picked[i] {
            taken.push(req);
        } else {
            rest.push_back(req);
        }
    }
    *window = rest;
    taken
}

/// Hash of a request's leading token block — the co-scheduling signal the
/// prefix cache cares about: two requests with equal hints (almost
/// certainly) share their first [`PREFIX_BLOCK`] tokens, so seating them
/// in one window lets one cached (or once-computed) prefix serve both. A
/// wrong match costs nothing but a missed reorder: correctness never
/// depends on the hint.
///
/// [`PREFIX_BLOCK`]: crate::exec::prefix::PREFIX_BLOCK
fn prefix_hint(req: &Request) -> u64 {
    let text = match &req.payload {
        Payload::Data(DataOp::Score { prompt, .. }) => prompt.as_str(),
        Payload::Data(DataOp::Perplexity { text }) => text.as_str(),
        Payload::Admin(_) => return 0,
    };
    let tokens = crate::data::corpus::encode(text);
    let n = tokens.len().min(crate::exec::prefix::PREFIX_BLOCK);
    crate::exec::prefix::hash_tokens(&tokens[..n])
}

/// Group an admitted window by variant, preserving arrival order both
/// across groups (first appearance) and within each group.
pub(crate) fn group_by_variant(requests: Vec<Request>) -> Vec<VariantGroup> {
    let mut groups: Vec<VariantGroup> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    for req in requests {
        match index.get(&req.variant) {
            Some(&i) => groups[i].requests.push(req),
            None => {
                index.insert(req.variant.clone(), groups.len());
                groups.push(VariantGroup { variant: req.variant.clone(), requests: vec![req] });
            }
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(variant: &str) -> Request {
        Request::new(0, variant, Payload::perplexity("probe text")).0
    }

    fn req_id(id: u64, variant: &str) -> (Request, mpsc::Receiver<Response>) {
        Request::new(id, variant, Payload::perplexity("probe text"))
    }

    #[test]
    fn step_admits_immediately_when_a_slot_is_idle() {
        // The old dispatcher would hold this lone request until a dispatch
        // deadline; the engine admits it on the very next step.
        let mut core = EngineCore::new(2, 8);
        core.add_request(req("a"));
        let groups = core.step().expect("idle slot must admit immediately");
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].requests.len(), 1);
        assert_eq!(core.in_flight(), 1);
        assert_eq!(core.pending_len(), 0);
        assert!(core.step().is_none(), "nothing left to admit");
    }

    #[test]
    fn step_respects_capacity_until_work_done() {
        let mut core = EngineCore::new(1, 2);
        for _ in 0..5 {
            core.add_request(req("a"));
        }
        assert!(core.step().is_some(), "first window takes the only slot");
        assert!(core.step().is_none(), "saturated: no admission");
        assert_eq!(core.pending_len(), 3);
        core.work_done();
        let g = core.step().expect("freed slot admits the next window");
        assert_eq!(g[0].requests.len(), 2);
        assert_eq!(core.pending_len(), 1);
    }

    #[test]
    fn abort_removes_pending_but_not_admitted() {
        let mut core = EngineCore::new(1, 8);
        let (r1, _rx1) = req_id(1, "a");
        let (r2, _rx2) = req_id(2, "a");
        core.add_request(r1);
        core.add_request(r2);
        assert!(core.step().is_some(), "both admitted in one window");
        assert!(core.abort(1).is_none(), "admitted requests cannot be aborted");
        let (r3, _rx3) = req_id(3, "b");
        core.add_request(r3);
        let aborted = core.abort(3).expect("pending request aborts");
        assert_eq!(aborted.id, 3);
        assert_eq!(core.pending_len(), 0);
    }

    #[test]
    fn drain_flushes_ignoring_slots() {
        let mut core = EngineCore::new(1, 4);
        for _ in 0..6 {
            core.add_request(req("a"));
        }
        assert!(core.step().is_some());
        assert!(core.step().is_none(), "saturated");
        let d1 = core.drain().expect("drain ignores slot occupancy");
        assert_eq!(d1[0].requests.len(), 4);
        let d2 = core.drain().expect("second drain window");
        assert_eq!(d2[0].requests.len(), 1);
        assert!(core.drain().is_none());
    }

    #[test]
    fn fair_take_round_robins_so_a_hot_variant_cannot_starve_a_cold_one() {
        // Six "hot" requests arrive before two "cold" ones; a 4-slot flush
        // under strict FIFO would be all hot. Fair share must seat the cold
        // variant's requests in the same window.
        let mut window: VecDeque<Request> = VecDeque::new();
        for _ in 0..6 {
            window.push_back(req("hot"));
        }
        window.push_back(req("cold"));
        window.push_back(req("cold"));
        let taken = fair_take(&mut window, 4);
        assert_eq!(taken.len(), 4);
        let cold_taken = taken.iter().filter(|r| r.variant == "cold").count();
        assert_eq!(cold_taken, 2, "the hot variant must not starve the cold one");
        assert_eq!(taken[0].variant, "hot", "the overall oldest request always flushes");
        // Leftovers keep arrival order so admission order stays FIFO-fair.
        assert_eq!(window.len(), 4);
        assert!(window.iter().all(|r| r.variant == "hot"));
        // A window that fits entirely drains in arrival order.
        let taken = fair_take(&mut window, 8);
        assert_eq!(taken.len(), 4);
        assert!(window.is_empty());
    }

    fn req_text(variant: &str, text: &str) -> Request {
        Request::new(0, variant, Payload::perplexity(text)).0
    }

    #[test]
    fn aimd_grows_on_idle_and_shrinks_on_saturation() {
        let mut a = AimdBatch::new(8);
        assert_eq!(a.target(), 8, "starts at the configured cap");
        // Saturated pool (tiny idle deltas): multiplicative decrease.
        a.observe_idle_total(10_000);
        assert_eq!(a.target(), 6);
        a.observe_idle_total(20_000);
        a.observe_idle_total(30_000);
        assert!(a.target() < 6, "repeated saturation keeps shrinking");
        // Keep shrinking: the floor is 1, never 0.
        for step in 4..40u64 {
            a.observe_idle_total(step * 10_000);
        }
        assert_eq!(a.target(), 1, "multiplicative decrease floors at 1");
        // Starved pool (big idle deltas): additive increase back up.
        let mut total = 400_000u64;
        for _ in 0..20 {
            total += AIMD_HIGH_IDLE_NS;
            a.observe_idle_total(total);
        }
        assert_eq!(a.target(), 8, "additive increase is capped at max_batch");
        // Dead band: a delta between the thresholds holds the target.
        total += 200_000;
        a.observe_idle_total(total);
        assert_eq!(a.target(), 8, "mid-band deltas leave the target alone");
    }

    #[test]
    fn engine_core_admits_using_the_adaptive_target() {
        let mut core = EngineCore::new(1, 4);
        for _ in 0..8 {
            core.add_request(req("a"));
        }
        // Drive the target down to 1 with saturated (zero-delta after
        // first) observations.
        core.observe_idle(1_000);
        core.observe_idle(2_000);
        core.observe_idle(3_000);
        core.observe_idle(4_000);
        core.observe_idle(5_000);
        let t = core.batch_target();
        assert!(t < 4, "saturation must shrink the admission cap, got {t}");
        let g = core.step().expect("window admitted");
        let size: usize = g.iter().map(|vg| vg.requests.len()).sum();
        assert_eq!(size, t, "step admits exactly the adaptive target");
        // drain() ignores the adaptive target (shutdown flushes at full
        // width).
        let d = core.drain().expect("drain flushes");
        let dsize: usize = d.iter().map(|vg| vg.requests.len()).sum();
        assert_eq!(dsize, (8 - size).min(4));
    }

    #[test]
    fn fair_take_prefers_prefix_sharing_requests_within_a_variant() {
        // Variant "a" queues [X, Y, X']: X and X' share a leading token
        // block, Y does not. With room for 3 picks the affinity rule seats
        // X and X' together (Y waits), and variant "b" still gets its fair
        // slot.
        let shared = "common preamble: the quick brown fox jumps over it";
        let other = "zzz totally unrelated text with a different head";
        let mut window: VecDeque<Request> = VecDeque::new();
        window.push_back(req_text("a", shared));
        window.push_back(req_text("a", other));
        window.push_back(req_text("a", &format!("{shared} -- but a longer tail")));
        window.push_back(req_text("b", "whatever"));
        let taken = fair_take(&mut window, 3);
        assert_eq!(taken.len(), 3);
        let a_texts: Vec<&str> = taken
            .iter()
            .filter(|r| r.variant == "a")
            .map(|r| match &r.payload {
                Payload::Data(DataOp::Perplexity { text }) => text.as_str(),
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(a_texts.len(), 2);
        assert!(
            a_texts.iter().all(|t| t.starts_with("common preamble")),
            "prefix-sharing requests must ride one window, got {a_texts:?}"
        );
        // The non-sharing request is left waiting, not dropped.
        assert_eq!(window.len(), 1);
        // Fairness held: variant b seated one request.
        assert!(taken.iter().any(|r| r.variant == "b"));
    }

    #[test]
    fn fair_take_covers_every_variant_when_slots_allow() {
        let mut window: VecDeque<Request> = VecDeque::new();
        for _ in 0..5 {
            window.push_back(req("a"));
        }
        window.push_back(req("b"));
        window.push_back(req("c"));
        window.push_back(req("d"));
        let taken = fair_take(&mut window, 4);
        let variants: std::collections::HashSet<&str> =
            taken.iter().map(|r| r.variant.as_str()).collect();
        assert_eq!(
            variants.len(),
            4,
            "with max_batch >= distinct variants, every waiting variant gets a slot"
        );
    }
}
