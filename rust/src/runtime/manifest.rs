//! AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).
//!
//! The manifest is the contract between the build-time Python side and the
//! serving-time Rust side: every program's file name, input shapes/dtypes,
//! output shapes, and semantic metadata (kind, config, batch/seq bucket).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "i32" | "int32" => DType::I32,
            "u32" | "uint32" => DType::U32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// kind: forward | train_step | lmgrad | delta_apply | fused_delta_matmul
    pub kind: String,
    pub config: Option<String>,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
    pub axis: Option<String>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub programs: BTreeMap<String, ProgramSpec>,
    /// Config name -> n_params (for sanity checks against Rust presets).
    pub config_params: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let mut programs = BTreeMap::new();
        for (name, p) in json.req("programs")?.as_obj().context("programs not an object")? {
            let file = dir.join(p.req_str("file")?);
            if !file.to_string_lossy().ends_with(".hlo.txt") {
                continue; // parity fixtures etc.
            }
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                let mut out = Vec::new();
                for t in p.req_arr(key)? {
                    let shape = t
                        .req_arr("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("bad dim"))
                        .collect::<Result<Vec<_>>>()?;
                    out.push(TensorSpec { shape, dtype: DType::parse(t.req_str("dtype")?)? });
                }
                Ok(out)
            };
            let meta = p.get("meta").cloned().unwrap_or(Json::Null);
            let get_meta_str = |k: &str| meta.get(k).and_then(|v| v.as_str()).map(String::from);
            let get_meta_usize = |k: &str| meta.get(k).and_then(|v| v.as_usize());
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name: name.clone(),
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    kind: get_meta_str("kind").unwrap_or_default(),
                    config: get_meta_str("config"),
                    batch: get_meta_usize("batch"),
                    seq: get_meta_usize("seq"),
                    axis: get_meta_str("axis"),
                },
            );
        }
        let mut config_params = BTreeMap::new();
        if let Some(cfgs) = json.get("configs").and_then(|c| c.as_obj()) {
            for (name, c) in cfgs {
                config_params.insert(name.clone(), c.req_usize("n_params")?);
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), programs, config_params })
    }

    pub fn get(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("program '{name}' not in manifest"))
    }

    /// Forward-program buckets for a config, sorted by (batch, seq).
    pub fn fwd_buckets(&self, config: &str) -> Vec<&ProgramSpec> {
        let mut v: Vec<&ProgramSpec> = self
            .programs
            .values()
            .filter(|p| p.kind == "forward" && p.config.as_deref() == Some(config))
            .collect();
        v.sort_by_key(|p| (p.batch.unwrap_or(0), p.seq.unwrap_or(0)));
        v
    }

    /// Smallest forward bucket that fits (batch, seq), if any.
    pub fn pick_fwd(&self, config: &str, batch: usize, seq: usize) -> Option<&ProgramSpec> {
        self.fwd_buckets(config)
            .into_iter()
            .find(|p| p.batch.unwrap_or(0) >= batch && p.seq.unwrap_or(0) >= seq)
    }

    pub fn find_kind(&self, kind: &str, config: &str) -> Option<&ProgramSpec> {
        self.programs
            .values()
            .find(|p| p.kind == kind && p.config.as_deref() == Some(config))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.programs.contains_key("fwd_tiny_b1_t48"));
        let fwd = m.get("fwd_tiny_b1_t48").unwrap();
        assert_eq!(fwd.kind, "forward");
        assert_eq!(fwd.inputs.len(), 2);
        assert_eq!(fwd.inputs[1].dtype, DType::I32);
        assert_eq!(fwd.inputs[1].shape, vec![1, 48]);
        // Param counts must agree with the Rust presets.
        for (name, &n) in &m.config_params {
            let cfg = crate::model::ModelConfig::preset(name).unwrap();
            assert_eq!(cfg.n_params(), n, "param count mismatch for {name}");
        }
        // Bucket picking.
        assert!(m.pick_fwd("tiny", 1, 32).is_some());
        assert!(m.pick_fwd("tiny", 64, 48).is_none());
        assert!(m.find_kind("train_step", "tiny").is_some());
        assert!(m.find_kind("lmgrad", "tiny").is_some());
    }

    #[test]
    fn missing_manifest_is_informative() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}
