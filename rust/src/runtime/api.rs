//! Typed wrappers over the runtime thread: batched forward with shape
//! bucketing, the AOT train step, and the logit-matching gradient program.

use super::engine::{HostTensor, RuntimeHandle};
use super::manifest::ProgramSpec;
use crate::tensor::Tensor2;
use anyhow::{anyhow, bail, Result};

/// Typed errors for manifest/program-spec problems the runtime wrappers can
/// hit. These used to be `unwrap()` panics on the engine thread — a manifest
/// entry missing its `batch`/`seq` bucket dims must fail the *request*, not
/// kill the worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RuntimeError {
    /// A program spec is missing the `batch`/`seq` bucket metadata its kind
    /// requires (hand-edited or truncated `manifest.json`).
    MissingBucketDims { program: String },
    /// A program spec's declared inputs don't match what its kind requires.
    MalformedSpec { program: String, what: String },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::MissingBucketDims { program } => write!(
                f,
                "program '{program}' has no batch/seq bucket dims in the manifest \
                 (corrupt or hand-edited manifest.json)"
            ),
            RuntimeError::MalformedSpec { program, what } => {
                write!(f, "program '{program}' has a malformed spec: {what}")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

/// The `batch`/`seq` bucket dims of a spec, as a typed error instead of a
/// panic when the manifest entry lacks them.
fn bucket_dims(spec: &ProgramSpec) -> Result<(usize, usize)> {
    match (spec.batch, spec.seq) {
        (Some(b), Some(t)) => Ok((b, t)),
        _ => Err(RuntimeError::MissingBucketDims { program: spec.name.clone() }.into()),
    }
}

/// Run a batch of variable-length sequences through the smallest AOT
/// forward bucket that fits; returns per-sequence `[len, vocab]` logits.
///
/// Padding policy: sequences are right-padded with token 0 and the batch is
/// padded with empty rows; causality guarantees the logits at real
/// positions are unaffected.
pub fn forward_logits(
    h: &RuntimeHandle,
    config: &str,
    params: &[f32],
    seqs: &[Vec<u8>],
) -> Result<Vec<Tensor2>> {
    if seqs.is_empty() {
        return Ok(vec![]);
    }
    let max_len = seqs.iter().map(|s| s.len()).max().unwrap();
    let spec = h
        .manifest()
        .pick_fwd(config, seqs.len(), max_len)
        .ok_or_else(|| {
            anyhow!("no forward bucket for config '{config}' batch {} seq {max_len}", seqs.len())
        })?
        .clone();
    let (b, t) = bucket_dims(&spec)?;
    let mut tokens = vec![0i32; b * t];
    for (i, s) in seqs.iter().enumerate() {
        for (j, &tok) in s.iter().enumerate() {
            tokens[i * t + j] = tok as i32;
        }
    }
    let outs = h.run(
        &spec.name,
        vec![
            HostTensor::F32(params.to_vec(), vec![params.len()]),
            HostTensor::I32(tokens, vec![b, t]),
        ],
    )?;
    let (logits, shape) = outs
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("forward returned no outputs"))?
        .into_f32()?;
    if shape.len() != 3 || shape[0] != b || shape[1] != t {
        bail!("unexpected logits shape {shape:?}");
    }
    let vocab = shape[2];
    let mut result = Vec::with_capacity(seqs.len());
    for (i, s) in seqs.iter().enumerate() {
        let mut out = Tensor2::zeros(s.len(), vocab);
        for pos in 0..s.len() {
            let off = (i * t + pos) * vocab;
            out.row_mut(pos).copy_from_slice(&logits[off..off + vocab]);
        }
        result.push(out);
    }
    Ok(result)
}

/// Optimizer + parameter state for the AOT train step.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> TrainState {
        let n = params.len();
        TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// One fused AdamW step. `windows` must match the train bucket's batch and
/// be `seq + 1` tokens long (inputs + shifted targets). Returns the loss.
pub fn train_step(
    h: &RuntimeHandle,
    config: &str,
    state: &mut TrainState,
    windows: &[Vec<u8>],
    lr: f32,
) -> Result<f32> {
    let spec = h
        .manifest()
        .find_kind("train_step", config)
        .ok_or_else(|| anyhow!("no train_step program for '{config}'"))?
        .clone();
    let (b, t1) = {
        let (b, t) = bucket_dims(&spec)?;
        (b, t + 1)
    };
    if windows.len() != b {
        bail!("train bucket batch {b} != {} windows", windows.len());
    }
    let mut tokens = vec![0i32; b * t1];
    for (i, w) in windows.iter().enumerate() {
        if w.len() != t1 {
            bail!("window {} length {} != bucket {}", i, w.len(), t1);
        }
        for (j, &tok) in w.iter().enumerate() {
            tokens[i * t1 + j] = tok as i32;
        }
    }
    let n = state.params.len();
    let outs = h.run(
        &spec.name,
        vec![
            HostTensor::F32(std::mem::take(&mut state.params), vec![n]),
            HostTensor::F32(std::mem::take(&mut state.m), vec![n]),
            HostTensor::F32(std::mem::take(&mut state.v), vec![n]),
            HostTensor::scalar_i32(state.step),
            HostTensor::scalar_f32(lr),
            HostTensor::I32(tokens, vec![b, t1]),
        ],
    )?;
    let mut it = outs.into_iter();
    let (p, _) = it.next().ok_or_else(|| anyhow!("missing params output"))?.into_f32()?;
    let (m, _) = it.next().ok_or_else(|| anyhow!("missing m output"))?.into_f32()?;
    let (v, _) = it.next().ok_or_else(|| anyhow!("missing v output"))?.into_f32()?;
    let step_out = it.next().ok_or_else(|| anyhow!("missing step output"))?;
    let loss = match it.next().ok_or_else(|| anyhow!("missing loss output"))? {
        HostTensor::F32(vs, _) => vs[0],
        other => bail!("loss has dtype {:?}", other.dtype()),
    };
    state.params = p;
    state.m = m;
    state.v = v;
    state.step = match step_out {
        HostTensor::I32(vs, _) => vs[0],
        _ => state.step + 1,
    };
    Ok(loss)
}

/// Logit-matching loss + flat gradient (Algorithm 2's objective).
/// `seqs` must match the lmgrad bucket batch; `teacher_logits` is
/// `[B, T, V]` flattened.
pub fn lmgrad(
    h: &RuntimeHandle,
    config: &str,
    params: &[f32],
    seqs: &[Vec<u8>],
    teacher_logits: &[f32],
) -> Result<(f32, Vec<f32>)> {
    let spec = h
        .manifest()
        .find_kind("lmgrad", config)
        .ok_or_else(|| anyhow!("no lmgrad program for '{config}'"))?
        .clone();
    let (b, t) = bucket_dims(&spec)?;
    if seqs.len() != b {
        bail!("lmgrad bucket batch {b} != {} seqs", seqs.len());
    }
    let vocab = spec
        .inputs
        .get(2)
        .and_then(|t| t.shape.get(2))
        .copied()
        .ok_or_else(|| RuntimeError::MalformedSpec {
            program: spec.name.clone(),
            what: "teacher-logits input must be rank-3 [B, T, V]".into(),
        })?;
    if teacher_logits.len() != b * t * vocab {
        bail!("teacher logits len {} != {}", teacher_logits.len(), b * t * vocab);
    }
    let mut tokens = vec![0i32; b * t];
    for (i, s) in seqs.iter().enumerate() {
        if s.len() != t {
            bail!("lmgrad sequences must be exactly bucket length {t}, got {}", s.len());
        }
        for (j, &tok) in s.iter().enumerate() {
            tokens[i * t + j] = tok as i32;
        }
    }
    let outs = h.run(
        &spec.name,
        vec![
            HostTensor::F32(params.to_vec(), vec![params.len()]),
            HostTensor::I32(tokens, vec![b, t]),
            HostTensor::F32(teacher_logits.to_vec(), vec![b, t, vocab]),
        ],
    )?;
    let mut it = outs.into_iter();
    let loss = match it.next().ok_or_else(|| anyhow!("missing loss"))? {
        HostTensor::F32(vs, _) => vs[0],
        other => bail!("loss dtype {:?}", other.dtype()),
    };
    let (grad, _) = it.next().ok_or_else(|| anyhow!("missing grad"))?.into_f32()?;
    Ok((loss, grad))
}

/// Pallas delta-apply through the AOT kernel artifact (validation +
/// benchmarking path; the production hot swap uses the native
/// `delta::apply`).
pub fn delta_apply_xla(
    h: &RuntimeHandle,
    axis: &str,
    base: &[f32],
    d_out: usize,
    d_in: usize,
    packed: &[u32],
    scales: &[f32],
) -> Result<Vec<f32>> {
    let name = format!("dapply_{axis}_{d_out}x{d_in}");
    let wpr = d_in.div_ceil(32);
    let outs = h.run(
        &name,
        vec![
            HostTensor::F32(base.to_vec(), vec![d_out, d_in]),
            HostTensor::U32(packed.to_vec(), vec![d_out, wpr]),
            HostTensor::F32(scales.to_vec(), vec![scales.len()]),
        ],
    )?;
    let (v, _) = outs
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("no output"))?
        .into_f32()?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(batch: Option<usize>, seq: Option<usize>) -> ProgramSpec {
        ProgramSpec {
            name: "fwd_test".into(),
            file: std::path::PathBuf::from("fwd_test.hlo.txt"),
            inputs: vec![],
            outputs: vec![],
            kind: "forward".into(),
            config: Some("tiny".into()),
            batch,
            seq,
            axis: None,
        }
    }

    #[test]
    fn missing_bucket_dims_is_a_typed_error_not_a_panic() {
        assert_eq!(bucket_dims(&spec(Some(4), Some(64))).unwrap(), (4, 64));
        for (b, t) in [(None, Some(64)), (Some(4), None), (None, None)] {
            let err = bucket_dims(&spec(b, t)).unwrap_err();
            let typed = err.downcast_ref::<RuntimeError>().expect("typed RuntimeError");
            assert_eq!(
                *typed,
                RuntimeError::MissingBucketDims { program: "fwd_test".into() }
            );
            assert!(err.to_string().contains("batch/seq"), "{err}");
        }
    }
}

/// Fused delta-GEMM through the AOT kernel artifact.
pub fn fused_delta_matmul_xla(
    h: &RuntimeHandle,
    axis: &str,
    x: &[f32],
    n: usize,
    base: &[f32],
    d_out: usize,
    d_in: usize,
    packed: &[u32],
    scales: &[f32],
) -> Result<Vec<f32>> {
    let name = format!("dmm_{axis}_n{n}_{d_out}x{d_in}");
    let wpr = d_in.div_ceil(32);
    let outs = h.run(
        &name,
        vec![
            HostTensor::F32(x.to_vec(), vec![n, d_in]),
            HostTensor::F32(base.to_vec(), vec![d_out, d_in]),
            HostTensor::U32(packed.to_vec(), vec![d_out, wpr]),
            HostTensor::F32(scales.to_vec(), vec![scales.len()]),
        ],
    )?;
    let (v, _) = outs
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("no output"))?
        .into_f32()?;
    Ok(v)
}
