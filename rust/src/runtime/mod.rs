//! Runtime: load + execute the AOT HLO artifacts via the PJRT CPU client.
//!
//! * [`manifest`] — the JSON contract written by `python/compile/aot.py`.
//! * [`engine`] — the dedicated runtime thread owning the (non-`Send`)
//!   `PjRtClient`, with a channel-based [`engine::RuntimeHandle`].
//! * [`api`] — typed wrappers: bucketed batched forward, fused-AdamW train
//!   step, logit-matching gradient, and the Pallas kernel entry points.

pub mod api;
pub mod engine;
pub mod manifest;

pub use api::{forward_logits, lmgrad, train_step, RuntimeError, TrainState};
pub use engine::{start, HostTensor, RuntimeHandle};
pub use manifest::Manifest;
