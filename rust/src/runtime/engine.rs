//! PJRT execution engine.
//!
//! The `xla` crate's `PjRtClient` wraps an `Rc` and is not `Send`/`Sync`,
//! so all XLA state lives on one dedicated **runtime thread**; the rest of
//! the system talks to it through a cloneable [`RuntimeHandle`] carrying
//! plain Rust buffers over channels. Executables are compiled once per
//! program (on first use) and cached for the life of the thread.

use super::manifest::{DType, Manifest, ProgramSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

/// A host-side tensor crossing the runtime-thread boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) | HostTensor::U32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
            HostTensor::U32(..) => DType::U32,
        }
    }

    pub fn n_elems(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::I32(v, _) => v.len(),
            HostTensor::U32(v, _) => v.len(),
        }
    }

    /// Unwrap as f32 data or fail.
    pub fn into_f32(self) -> Result<(Vec<f32>, Vec<usize>)> {
        match self {
            HostTensor::F32(v, s) => Ok((v, s)),
            other => bail!("expected f32 output, got {:?}", other.dtype()),
        }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }
}

enum Job {
    Run { name: String, inputs: Vec<HostTensor>, resp: mpsc::Sender<Result<Vec<HostTensor>>> },
    /// Pre-compile a program (warm the cache) without executing.
    Warm { name: String, resp: mpsc::Sender<Result<()>> },
    Shutdown,
}

/// Cloneable, thread-safe handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Job>,
    manifest: Arc<Manifest>,
}

// mpsc::Sender<Job> is Send but not Sync; wrap sends in a mutex-free clone
// per call site: RuntimeHandle is cheap to clone, and each thread should own
// its clone. For convenience in shared structs we also provide a Mutex'd
// variant in the coordinator.

impl RuntimeHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute a program by manifest name. Validates shapes/dtypes against
    /// the manifest before crossing the thread boundary.
    pub fn run(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?;
        validate_inputs(spec, &inputs)?;
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Job::Run { name: name.to_string(), inputs, resp: tx })
            .map_err(|_| anyhow!("runtime thread terminated"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped response"))?
    }

    /// Compile a program ahead of first use.
    pub fn warm(&self, name: &str) -> Result<()> {
        let _ = self.manifest.get(name)?;
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Job::Warm { name: name.to_string(), resp: tx })
            .map_err(|_| anyhow!("runtime thread terminated"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped response"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Job::Shutdown);
    }
}

fn validate_inputs(spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("program '{}' expects {} inputs, got {}", spec.name, spec.inputs.len(), inputs.len());
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.dtype() != s.dtype {
            bail!("program '{}' input {i}: dtype {:?} != manifest {:?}", spec.name, t.dtype(), s.dtype);
        }
        if t.n_elems() != s.n_elems() {
            bail!(
                "program '{}' input {i}: {} elements != manifest shape {:?}",
                spec.name,
                t.n_elems(),
                s.shape
            );
        }
    }
    Ok(())
}

/// Start the runtime thread over an artifacts directory.
pub fn start(artifacts_dir: &Path) -> Result<RuntimeHandle> {
    let manifest = Arc::new(Manifest::load(artifacts_dir)?);
    let (tx, rx) = mpsc::channel::<Job>();
    let thread_manifest = manifest.clone();
    std::thread::Builder::new()
        .name("pawd-runtime".into())
        .spawn(move || runtime_thread(thread_manifest, rx))
        .context("spawning runtime thread")?;
    Ok(RuntimeHandle { tx, manifest })
}

/// Without the `xla-runtime` feature the crate still links (the native
/// engine covers every test and experiment); runtime jobs fail with a
/// clear error instead of a missing PJRT symbol.
#[cfg(not(feature = "xla-runtime"))]
fn runtime_thread(_manifest: Arc<Manifest>, rx: mpsc::Receiver<Job>) {
    let msg = "pawd was built without the `xla-runtime` feature; \
               rebuild with `--features xla-runtime` to execute AOT artifacts";
    for job in rx {
        match job {
            Job::Run { resp, .. } => {
                let _ = resp.send(Err(anyhow!(msg)));
            }
            Job::Warm { resp, .. } => {
                let _ = resp.send(Err(anyhow!(msg)));
            }
            Job::Shutdown => break,
        }
    }
}

#[cfg(feature = "xla-runtime")]
fn runtime_thread(manifest: Arc<Manifest>, rx: mpsc::Receiver<Job>) {
    use std::collections::HashMap;
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("PjRtClient::cpu failed: {e}");
            for job in rx {
                match job {
                    Job::Run { resp, .. } => {
                        let _ = resp.send(Err(anyhow!(msg.clone())));
                    }
                    Job::Warm { resp, .. } => {
                        let _ = resp.send(Err(anyhow!(msg.clone())));
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::Warm { name, resp } => {
                let r = ensure_compiled(&client, &manifest, &mut cache, &name).map(|_| ());
                let _ = resp.send(r);
            }
            Job::Run { name, inputs, resp } => {
                let r = (|| -> Result<Vec<HostTensor>> {
                    ensure_compiled(&client, &manifest, &mut cache, &name)?;
                    let exe = cache.get(&name).unwrap();
                    let literals = inputs
                        .into_iter()
                        .map(to_literal)
                        .collect::<Result<Vec<_>>>()?;
                    let result = exe
                        .execute::<xla::Literal>(&literals)
                        .with_context(|| format!("executing '{name}'"))?;
                    let tuple = result[0][0]
                        .to_literal_sync()
                        .context("fetching result literal")?;
                    // Programs are lowered with return_tuple=True.
                    let parts = tuple.to_tuple().context("untupling result")?;
                    parts.into_iter().map(from_literal).collect()
                })();
                let _ = resp.send(r);
            }
        }
    }
}

#[cfg(feature = "xla-runtime")]
fn ensure_compiled<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut std::collections::HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(name) {
        let spec = manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text for '{name}': {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling '{name}': {e}"))?;
        cache.insert(name.to_string(), exe);
    }
    Ok(cache.get(name).unwrap())
}

#[cfg(feature = "xla-runtime")]
fn to_literal(t: HostTensor) -> Result<xla::Literal> {
    let mk = |ty: xla::ElementType, shape: &[usize], bytes: &[u8]| {
        xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)
            .map_err(|e| anyhow!("creating literal: {e}"))
    };
    match t {
        HostTensor::F32(v, s) => mk(xla::ElementType::F32, &s, bytes_of(&v)),
        HostTensor::I32(v, s) => mk(xla::ElementType::S32, &s, bytes_of(&v)),
        HostTensor::U32(v, s) => mk(xla::ElementType::U32, &s, bytes_of(&v)),
    }
}

#[cfg(feature = "xla-runtime")]
fn from_literal(l: xla::Literal) -> Result<HostTensor> {
    let shape = l.shape().map_err(|e| anyhow!("literal shape: {e}"))?;
    let arr = match shape {
        xla::Shape::Array(a) => a,
        other => bail!("unexpected non-array output shape {other:?}"),
    };
    let dims: Vec<usize> = arr.dims().iter().map(|&d| d as usize).collect();
    match arr.ty() {
        xla::ElementType::F32 => {
            Ok(HostTensor::F32(l.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?, dims))
        }
        xla::ElementType::S32 => {
            Ok(HostTensor::I32(l.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?, dims))
        }
        xla::ElementType::U32 => {
            Ok(HostTensor::U32(l.to_vec::<u32>().map_err(|e| anyhow!("{e}"))?, dims))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

#[cfg(feature = "xla-runtime")]
fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: plain-old-data reinterpretation for the FFI boundary — `T` is
    // `Copy`, the byte length comes from `size_of_val`, and the borrow pins
    // the source slice for the returned lifetime.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}
