//! End-to-end joint training of all scale vectors (Algorithm 2).
//!
//! The AOT `lmgrad` program returns dL/dW for the *flat student weights*
//! under the logit-matching objective; since `Ŵ = W_b + v ⊙ B` is linear in
//! `v`, the scale gradient is the masked reduction of the weight gradient:
//!
//! * row:    dL/dv_j = Σ_i dL/dW[j,i] · B[j,i]
//! * col:    dL/dv_i = Σ_j dL/dW[j,i] · B[j,i]
//! * scalar: dL/dv   = Σ_{j,i} dL/dW[j,i] · B[j,i]
//! * group:  per-group row sums.
//!
//! Rust drives AdamW over the concatenated scale vectors, re-materializing
//! the student between steps (one fused apply pass per module — cheap
//! relative to the lmgrad forward+backward).

use crate::data::corpus;
use crate::delta::apply::materialize;
use crate::delta::calibrate::AdamW;
use crate::delta::types::{Axis, DeltaModel};
use crate::model::{FlatParams, ModelConfig};
use crate::runtime::{self, RuntimeHandle};
use anyhow::{anyhow, Result};

/// Jointly train all scale vectors of `delta` to match the teacher's
/// logits on the e2e calibration documents. Returns the loss curve.
pub fn e2e_train(
    h: &RuntimeHandle,
    cfg: &ModelConfig,
    base: &FlatParams,
    teacher: &FlatParams,
    delta: &mut DeltaModel,
    e2e_docs: &[String],
    epochs: usize,
    lr: f32,
) -> Result<Vec<f32>> {
    let spec = h
        .manifest()
        .find_kind("lmgrad", &cfg.name)
        .ok_or_else(|| anyhow!("no lmgrad artifact for '{}'", cfg.name))?
        .clone();
    let (b, t) = (spec.batch.unwrap(), spec.seq.unwrap());
    // Fixed-length windows for the lmgrad bucket.
    let windows = corpus::pack_windows(e2e_docs, t - 1, 0x2E2E);
    let batches: Vec<Vec<Vec<u8>>> = corpus::batches(&windows, b)
        .into_iter()
        .map(|batch| batch.into_iter().map(|mut w| {
            w.truncate(t);
            w
        }).collect())
        .collect();
    if batches.is_empty() {
        anyhow::bail!("e2e corpus too small for bucket batch {b} x seq {t}");
    }

    // Teacher logits per batch, computed once (the teacher is frozen).
    let mut teacher_logits: Vec<Vec<f32>> = Vec::with_capacity(batches.len());
    for batch in &batches {
        let ls = runtime::forward_logits(h, &cfg.name, &teacher.data, batch)?;
        let mut flat = Vec::with_capacity(b * t * cfg.vocab);
        for l in &ls {
            flat.extend_from_slice(&l.data);
        }
        teacher_logits.push(flat);
    }

    // Concatenated scale parameter vector + per-module offsets.
    let mut offsets = Vec::with_capacity(delta.modules.len());
    let mut theta: Vec<f32> = Vec::new();
    for m in &delta.modules {
        offsets.push(theta.len());
        theta.extend_from_slice(&m.scales);
    }
    let mut opt = AdamW::new(theta.len(), lr);
    let mut grads = vec![0f32; theta.len()];
    let mut losses = Vec::new();

    for _epoch in 0..epochs {
        for (batch, tl) in batches.iter().zip(&teacher_logits) {
            // Write current scales back into the modules and materialize.
            // `make_mut` clones a module only if its Arc is shared (it never
            // is here: the compressor's output is freshly built).
            for (m, &off) in delta.modules.iter_mut().zip(&offsets) {
                let m = std::sync::Arc::make_mut(m);
                let n = m.scales.len();
                m.scales.copy_from_slice(&theta[off..off + n]);
            }
            let student = materialize(base, &delta.modules);
            let (loss, gflat) = runtime::lmgrad(h, &cfg.name, &student.data, batch, tl)?;
            losses.push(loss);
            // Chain rule: weight grad -> scale grad, per module.
            grads.iter_mut().for_each(|g| *g = 0.0);
            for (m, &off) in delta.modules.iter().zip(&offsets) {
                let (w_off, w_len) = base.layout.module_span(m.id);
                let gw = &gflat[w_off..w_off + w_len];
                let (d_out, d_in) = (m.d_out(), m.d_in());
                match m.axis {
                    Axis::Row => {
                        for j in 0..d_out {
                            let mut s = 0f64;
                            for i in 0..d_in {
                                s += (gw[j * d_in + i] * m.mask.sign(j, i)) as f64;
                            }
                            grads[off + j] = s as f32;
                        }
                    }
                    Axis::Col => {
                        for j in 0..d_out {
                            for i in 0..d_in {
                                grads[off + i] += gw[j * d_in + i] * m.mask.sign(j, i);
                            }
                        }
                    }
                    Axis::Scalar => {
                        let mut s = 0f64;
                        for j in 0..d_out {
                            for i in 0..d_in {
                                s += (gw[j * d_in + i] * m.mask.sign(j, i)) as f64;
                            }
                        }
                        grads[off] = s as f32;
                    }
                    Axis::Group(g) => {
                        let g = g.max(1) as usize;
                        for j in 0..d_out {
                            let mut s = 0f64;
                            for i in 0..d_in {
                                s += (gw[j * d_in + i] * m.mask.sign(j, i)) as f64;
                            }
                            grads[off + j / g] += s as f32;
                        }
                    }
                }
            }
            opt.step(&mut theta, &grads);
        }
    }
    // Final write-back.
    for (m, &off) in delta.modules.iter_mut().zip(&offsets) {
        let m = std::sync::Arc::make_mut(m);
        let n = m.scales.len();
        m.scales.copy_from_slice(&theta[off..off + n]);
    }
    Ok(losses)
}
