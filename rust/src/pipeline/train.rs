//! LM training driver: Rust owns the data pipeline and the loop; each step
//! executes the fused AdamW train-step artifact on the PJRT runtime.

use crate::data::corpus;
use crate::model::ModelConfig;
use crate::runtime::{self, RuntimeHandle};
use anyhow::{anyhow, Result};

/// Train (or continue training) a model on `docs` for `steps` steps.
/// Returns the final flat params and the per-step loss curve.
pub fn train_lm(
    h: &RuntimeHandle,
    cfg: &ModelConfig,
    init_params: Vec<f32>,
    docs: &[String],
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let spec = h
        .manifest()
        .find_kind("train_step", &cfg.name)
        .ok_or_else(|| anyhow!("no train_step artifact for '{}'", cfg.name))?
        .clone();
    let batch = spec.batch.unwrap();
    let seq = spec.seq.unwrap();
    let windows = corpus::pack_windows(docs, seq, seed);
    let batches = corpus::batches(&windows, batch);
    if batches.is_empty() {
        anyhow::bail!("corpus too small: {} windows for batch {batch}", windows.len());
    }
    let mut state = runtime::TrainState::new(init_params);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let b = &batches[step % batches.len()];
        let loss = runtime::train_step(h, &cfg.name, &mut state, b, lr)?;
        losses.push(loss);
    }
    Ok((state.params, losses))
}
