//! End-to-end experiment pipeline (Algorithm 1, all four stages, plus the
//! training runs that *produce* the model pairs).
//!
//! One `run_pair` call reproduces a full Table-1 row group for one model
//! pair: pre-train the base on the synthetic corpus (AOT train step via
//! PJRT), fine-tune on the instruct mixture, compress with every method,
//! jointly train the vector scales end-to-end against teacher logits
//! (Algorithm 2, via the AOT lmgrad program), save artifacts, and evaluate
//! all variants on the five zero-shot suites.

pub mod e2e;
pub mod train;

use crate::data::corpus;
use crate::data::World;
use crate::delta::compress::{compress_model, CompressOptions};
use crate::delta::format::save_delta;
use crate::delta::types::DeltaModel;
use crate::eval::harness::{evaluate_suite, SuiteResult};
use crate::model::checkpoint::save_fp16;
use crate::model::{FlatParams, ModelConfig, Transformer};
use crate::runtime::RuntimeHandle;
use anyhow::{Context, Result};
use std::path::Path;

/// Knobs for one model-pair experiment.
#[derive(Clone, Debug)]
pub struct PairConfig {
    pub config: String,
    pub seed: u64,
    pub world_entities: usize,
    pub base_docs: usize,
    pub instruct_docs: usize,
    pub base_steps: usize,
    pub finetune_steps: usize,
    pub base_lr: f32,
    pub finetune_lr: f32,
    /// Calibration samples for the per-layer caches (paper: 50).
    pub calib_layer_docs: usize,
    /// Calibration samples for the end-to-end objective (paper: 150).
    pub calib_e2e_docs: usize,
    pub e2e_epochs: usize,
    pub e2e_lr: f32,
    pub eval_items_per_family: usize,
}

impl PairConfig {
    /// Scaled-down defaults that run in minutes on CPU; the benches bump
    /// them to the paper protocol (50/150 docs, more steps) under
    /// PAWD_FULL=1.
    pub fn quick(config: &str) -> PairConfig {
        PairConfig {
            config: config.to_string(),
            seed: 42,
            world_entities: 16,
            base_docs: 3000,
            instruct_docs: 3000,
            base_steps: 800,
            finetune_steps: 250,
            base_lr: 3e-3,
            finetune_lr: 5e-4,
            calib_layer_docs: 20,
            calib_e2e_docs: 40,
            e2e_epochs: 2,
            e2e_lr: 1e-3,
            eval_items_per_family: 25,
        }
    }

    /// Paper-faithful calibration budget (50 + 150 samples, 5 epochs).
    pub fn full(config: &str) -> PairConfig {
        PairConfig {
            base_steps: 1500,
            finetune_steps: 400,
            calib_layer_docs: 50,
            calib_e2e_docs: 150,
            e2e_epochs: 5,
            eval_items_per_family: 60,
            ..PairConfig::quick(config)
        }
    }
}

/// One compressed-method outcome within a pair run.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method: String,
    pub suite: SuiteResult,
    pub artifact_bytes: u64,
    pub delta: Option<DeltaModel>,
}

/// Everything a Table-1/2 row group needs.
pub struct PairResult {
    pub config: ModelConfig,
    pub world: World,
    pub base: FlatParams,
    pub teacher: FlatParams,
    pub base_losses: Vec<f32>,
    pub finetune_losses: Vec<f32>,
    pub fp16_bytes: u64,
    pub baseline_suite: SuiteResult,
    pub base_suite: SuiteResult,
    pub methods: Vec<MethodResult>,
}

/// Train the pair, compress with the given (label, options, e2e) methods,
/// evaluate everything. `out_dir` receives `<variant>.pawd` +
/// `teacher.fp16` artifacts.
pub fn run_pair(
    h: &RuntimeHandle,
    pc: &PairConfig,
    methods: &[(&str, CompressOptions, bool)],
    out_dir: &Path,
    mut log: impl FnMut(&str),
) -> Result<PairResult> {
    std::fs::create_dir_all(out_dir)?;
    let cfg = ModelConfig::preset(&pc.config)?;
    let world = World::generate(pc.seed, pc.world_entities);

    // --- Stage 0a: pre-train the base (AOT train step) ---
    log(&format!("[{}] pre-training base for {} steps", cfg.name, pc.base_steps));
    let init = FlatParams::init(&cfg, pc.seed ^ 0xBA5E);
    let base_corpus = corpus::base_corpus(&world, pc.base_docs, pc.seed);
    let (base_params, base_losses) =
        train::train_lm(h, &cfg, init.data, &base_corpus, pc.base_steps, pc.base_lr, pc.seed)
            .context("base pre-training")?;
    let mut base = FlatParams::zeros(&cfg);
    base.data = base_params;

    // --- Stage 0b: fine-tune on the instruct mixture -> teacher ---
    log(&format!("[{}] fine-tuning for {} steps", cfg.name, pc.finetune_steps));
    let instruct = corpus::instruct_corpus(&world, pc.instruct_docs, pc.seed ^ 0x17);
    let (ft_params, finetune_losses) = train::train_lm(
        h,
        &cfg,
        base.data.clone(),
        &instruct,
        pc.finetune_steps,
        pc.finetune_lr,
        pc.seed ^ 0x18,
    )
    .context("fine-tuning")?;
    let mut teacher = FlatParams::zeros(&cfg);
    teacher.data = ft_params;
    let fp16_bytes = save_fp16(out_dir.join("teacher.fp16"), &teacher)?;

    // --- Evaluate the endpoints ---
    let tf = Transformer::new(&cfg);
    log(&format!("[{}] evaluating base + baseline (teacher)", cfg.name));
    let base_suite =
        evaluate_suite("Base (pre-trained)", &tf, &base, &world, pc.eval_items_per_family, pc.seed);
    let baseline_suite = evaluate_suite(
        "Baseline (fine-tuned)",
        &tf,
        &teacher,
        &world,
        pc.eval_items_per_family,
        pc.seed,
    );

    // --- Calibration sets (C4 stand-ins; layer caches + e2e objective) ---
    let layer_docs: Vec<Vec<u8>> =
        corpus::calibration_samples(&world, pc.calib_layer_docs, pc.seed ^ 0x50)
            .iter()
            .map(|d| clamp_doc(d, cfg.max_seq))
            .collect();
    let e2e_docs = corpus::calibration_samples(&world, pc.calib_e2e_docs, pc.seed ^ 0x51);

    // --- Compress with every method ---
    let mut methods_out = Vec::new();
    for (label, opts, do_e2e) in methods {
        log(&format!("[{}] compressing: {label}", cfg.name));
        let variant_name = label.replace([' ', '(', ')', '/'], "_").to_lowercase();
        let (mut delta, _reports, _student) =
            compress_model(&variant_name, &base, &teacher, &layer_docs, opts);
        if *do_e2e {
            log(&format!("[{}] e2e vector training: {label}", cfg.name));
            e2e::e2e_train(h, &cfg, &base, &teacher, &mut delta, &e2e_docs, pc.e2e_epochs, pc.e2e_lr)
                .context("e2e vector training")?;
        }
        let artifact = out_dir.join(format!("{variant_name}.pawd"));
        let artifact_bytes = save_delta(&artifact, &delta)?;
        let student = crate::delta::apply::materialize(&base, &delta.modules);
        log(&format!("[{}] evaluating: {label}", cfg.name));
        let suite = evaluate_suite(label, &tf, &student, &world, pc.eval_items_per_family, pc.seed);
        methods_out.push(MethodResult {
            method: label.to_string(),
            suite,
            artifact_bytes,
            delta: Some(delta),
        });
    }

    Ok(PairResult {
        config: cfg,
        world,
        base,
        teacher,
        base_losses,
        finetune_losses,
        fp16_bytes,
        baseline_suite,
        base_suite,
        methods: methods_out,
    })
}

fn clamp_doc(d: &str, max: usize) -> Vec<u8> {
    let mut t = corpus::encode(d);
    t.truncate(max);
    t
}
