//! Baselines and extensions the paper compares against (or proposes as
//! future work):
//!
//! * **BitDelta (scalar)** — Liu et al. 2024: 1-bit sign mask + a single
//!   learned scalar per matrix, trained with the same pipeline but one
//!   epoch (paper §3.1). Implemented as a [`CompressOptions`] preset over
//!   the shared machinery so the comparison isolates exactly the scale
//!   parameterization.
//! * **Groupwise** — blockwise per-group scales over consecutive output
//!   rows (§5 future work); interpolates between Row (g=1) and Scalar
//!   (g=d_out).
//! * **Magnitude-only** — `mean(|ΔW|)` init without calibration (isolates
//!   the value of activation-aware fitting).
//! * **FP16 full checkpoint** — the uncompressed baseline for storage and
//!   load-time comparisons lives in `model::checkpoint`.

use crate::delta::compress::{CompressOptions, FitMode};
use crate::delta::types::Axis;

/// BitDelta (scalar) protocol: single scalar per matrix, one training epoch.
pub fn bitdelta_options() -> CompressOptions {
    CompressOptions::bitdelta()
}

/// The paper's method: per-row/col vectors, 5 epochs AdamW.
pub fn vector_options() -> CompressOptions {
    CompressOptions::default()
}

/// Groupwise extension with a fixed group size.
pub fn groupwise_options(group: u32) -> CompressOptions {
    CompressOptions { axes: vec![Axis::Group(group)], ..CompressOptions::default() }
}

/// Magnitude-only ablation: no calibration, row axis.
pub fn magnitude_only_options() -> CompressOptions {
    CompressOptions { fit: FitMode::InitOnly, ..CompressOptions::default() }
}

/// Closed-form variant of the paper's method (our extension).
pub fn vector_closed_form_options() -> CompressOptions {
    CompressOptions { fit: FitMode::ClosedForm, ..CompressOptions::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_expected_protocols() {
        let b = bitdelta_options();
        assert_eq!(b.axes, vec![Axis::Scalar]);
        assert_eq!(b.calib.epochs, 1);
        let v = vector_options();
        assert_eq!(v.axes, vec![Axis::Row, Axis::Col]);
        assert_eq!(v.calib.epochs, 5);
        assert_eq!(v.calib.lr, 1e-4);
        let g = groupwise_options(8);
        assert_eq!(g.axes, vec![Axis::Group(8)]);
        assert_eq!(magnitude_only_options().fit, FitMode::InitOnly);
    }
}
