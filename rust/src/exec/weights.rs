//! Variant weight sources: how a served variant's parameters are resident.
//!
//! [`Weights`] is what the transformer forward pass consumes: non-patchable
//! parameters (embeddings, norms, LM head) as a [`FlatParams`] view plus one
//! [`LinearOp`](super::LinearOp) per patchable projection. Two sources
//! implement it:
//!
//! * [`FlatParams`] itself — every projection is a [`DenseLinear`] view
//!   (materialized variants, full checkpoints, the base model).
//! * [`PackedVariant`] — the shared base plus a packed [`DeltaModel`];
//!   projections covered by the delta run [`FusedDeltaLinear`], the rest
//!   fall back to dense views of the base. Nothing is ever materialized.

use super::linear::{AnyLinear, DenseLinear, FusedDeltaLinear};
use crate::delta::types::DeltaModel;
use crate::model::{FlatParams, ModuleId};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// How the serving stack executes variants — the one-flag dense/fused A/B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Materialize `Ŵ = W_b + v ⊙ B` on load and serve dense (the original
    /// behavior; required by the XLA engine, which consumes flat buffers).
    Dense,
    /// Keep deltas packed and execute them in place through
    /// [`FusedDeltaLinear`]; residency per variant is packed bytes.
    Fused,
}

impl ExecMode {
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Dense => "dense",
            ExecMode::Fused => "fused",
        }
    }
}

/// Anything the transformer can run a forward pass against.
pub trait Weights: Sync {
    /// Non-patchable parameters (embeddings, norms, LM head) — and, for
    /// dense sources, the projections too.
    fn flat(&self) -> &FlatParams;

    /// The linear operator for one patchable projection.
    fn op(&self, id: ModuleId) -> AnyLinear<'_>;
}

impl Weights for FlatParams {
    fn flat(&self) -> &FlatParams {
        self
    }

    fn op(&self, id: ModuleId) -> AnyLinear<'_> {
        let (rows, cols) = id.kind.shape(self.cfg());
        AnyLinear::Dense(DenseLinear::new(self.module(id), rows, cols))
    }
}

impl<W: Weights + ?Sized> Weights for &W {
    fn flat(&self) -> &FlatParams {
        (**self).flat()
    }

    fn op(&self, id: ModuleId) -> AnyLinear<'_> {
        (**self).op(id)
    }
}

impl<W: Weights + Send + ?Sized> Weights for Arc<W> {
    fn flat(&self) -> &FlatParams {
        (**self).flat()
    }

    fn op(&self, id: ModuleId) -> AnyLinear<'_> {
        (**self).op(id)
    }
}

/// A variant held as shared base + packed delta. Cheap to clone (three Arc
/// bumps); the cache hands clones to workers, so a hot swap is a pointer
/// flip with no materialize/revert pass.
#[derive(Clone)]
pub struct PackedVariant {
    base: Arc<FlatParams>,
    delta: Arc<DeltaModel>,
    /// ModuleId → index into `delta.modules`.
    by_id: Arc<HashMap<ModuleId, usize>>,
}

impl PackedVariant {
    /// Validate the delta against the base (config name + per-module shapes)
    /// and build the module index.
    pub fn new(base: Arc<FlatParams>, delta: Arc<DeltaModel>) -> Result<PackedVariant> {
        anyhow::ensure!(
            delta.base_config == base.cfg().name,
            "delta '{}' targets base '{}', got '{}'",
            delta.variant,
            delta.base_config,
            base.cfg().name
        );
        let mut by_id = HashMap::with_capacity(delta.modules.len());
        for (i, m) in delta.modules.iter().enumerate() {
            let (rows, cols) = m.id.kind.shape(base.cfg());
            anyhow::ensure!(
                (rows, cols) == (m.d_out(), m.d_in()),
                "delta/module shape mismatch for {}: {}x{} vs {}x{}",
                m.id,
                m.d_out(),
                m.d_in(),
                rows,
                cols
            );
            // A short scale vector would silently truncate the fused Col
            // zip (dropping tail-column deltas) where the dense path
            // panics — reject it up front instead.
            anyhow::ensure!(
                m.scales.len() == m.axis.n_scales(rows, cols),
                "delta {} has {} scales, axis {:?} needs {}",
                m.id,
                m.scales.len(),
                m.axis,
                m.axis.n_scales(rows, cols)
            );
            // Codec-shape invariants the fused kernels rely on (scalar
            // codec ⇒ scalar axis; low-rank factors must match the
            // projection shape or the rank-space zip truncates).
            crate::delta::codec::codec_for(m.codec.kind()).validate(m, rows, cols)?;
            by_id.insert(m.id, i);
        }
        Ok(PackedVariant { base, delta, by_id: Arc::new(by_id) })
    }

    pub fn base(&self) -> &Arc<FlatParams> {
        &self.base
    }

    pub fn delta(&self) -> &Arc<DeltaModel> {
        &self.delta
    }

    /// The packed delta module covering projection `id`, if any (`None`
    /// means the projection executes the shared base unmodified).
    pub fn module(&self, id: ModuleId) -> Option<&crate::delta::types::DeltaModule> {
        self.by_id.get(&id).map(|&i| self.delta.modules[i].as_ref())
    }

    /// The delta's module `Arc`s — the sharing unit the variant cache
    /// charges residency on (a module shared with a resident parent version
    /// is charged once, not per version).
    pub fn module_arcs(&self) -> &[Arc<crate::delta::types::DeltaModule>] {
        &self.delta.modules
    }

    /// Per-variant resident bytes: packed masks + in-memory f32 scales (the
    /// shared base is charged once by the cache, not per variant).
    pub fn resident_bytes(&self) -> u64 {
        self.delta.modules.iter().map(|m| m.resident_bytes()).sum()
    }

    /// Materialize a dense copy (XLA engine path, ground-truth checks).
    pub fn materialize(&self) -> FlatParams {
        crate::delta::apply::materialize(&self.base, &self.delta.modules)
    }
}

impl Weights for PackedVariant {
    fn flat(&self) -> &FlatParams {
        &self.base
    }

    fn op(&self, id: ModuleId) -> AnyLinear<'_> {
        match self.module(id) {
            Some(m) => AnyLinear::Fused(FusedDeltaLinear::new(self.base.module(id), m)),
            None => {
                let (rows, cols) = id.kind.shape(self.base.cfg());
                AnyLinear::Dense(DenseLinear::new(self.base.module(id), rows, cols))
            }
        }
    }
}

/// What the variant cache stores and workers execute against. Every value
/// carries its **version identity**: the registry version the weights were
/// loaded as (`variant@version`), so a response can report which version
/// served it and the cache can key residency per version.
#[derive(Clone)]
pub enum VariantWeights {
    /// Fully materialized parameters (dense mode, FP16 checkpoints), tagged
    /// with the registry version they were resolved as.
    Dense(Arc<FlatParams>, u32),
    /// Shared base + packed delta (fused mode); the version rides in the
    /// delta's [`ArtifactMeta`](crate::delta::ArtifactMeta).
    Packed(PackedVariant),
}

impl VariantWeights {
    pub fn is_packed(&self) -> bool {
        matches!(self, VariantWeights::Packed(_))
    }

    /// Registry version these weights are (`variant@version`).
    pub fn version(&self) -> u32 {
        match self {
            VariantWeights::Dense(_, v) => *v,
            VariantWeights::Packed(pv) => pv.delta().meta.version,
        }
    }

    /// Bytes this variant charges against the cache budget.
    pub fn resident_bytes(&self) -> u64 {
        match self {
            VariantWeights::Dense(p, _) => (p.data.len() * 4) as u64,
            VariantWeights::Packed(pv) => pv.resident_bytes(),
        }
    }

    /// Bytes the same variant would cost if held materialized — the
    /// denominator of the residency-multiplier gauge.
    pub fn dense_equiv_bytes(&self) -> u64 {
        match self {
            VariantWeights::Dense(p, _) => (p.data.len() * 4) as u64,
            VariantWeights::Packed(pv) => (pv.base().data.len() * 4) as u64,
        }
    }

    /// Dense parameters, materializing packed variants on demand (only the
    /// XLA engine and ground-truth comparisons need this).
    pub fn materialized(&self) -> Arc<FlatParams> {
        match self {
            VariantWeights::Dense(p, _) => p.clone(),
            VariantWeights::Packed(pv) => Arc::new(pv.materialize()),
        }
    }
}

impl Weights for VariantWeights {
    fn flat(&self) -> &FlatParams {
        match self {
            VariantWeights::Dense(p, _) => p,
            VariantWeights::Packed(pv) => pv.flat(),
        }
    }

    fn op(&self, id: ModuleId) -> AnyLinear<'_> {
        match self {
            VariantWeights::Dense(p, _) => p.op(id),
            VariantWeights::Packed(pv) => pv.op(id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Axis, Codec, DeltaModule};
    use crate::exec::LinearOp;
    use crate::model::config::ModelConfig;
    use crate::util::rng::Rng;

    fn tiny_packed(n_modules: usize) -> (Arc<FlatParams>, PackedVariant) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 3));
        let ids = base.layout.patchable_modules();
        let mut modules = Vec::new();
        for (i, &id) in ids.iter().take(n_modules).enumerate() {
            let (rows, cols) = id.kind.shape(&cfg);
            let mut r = Rng::new(i as u64 + 1);
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            modules.push(DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis: Axis::Row,
                scales: vec![0.05; rows],
                codec: Codec::PerAxis,
            });
        }
        let delta = Arc::new(DeltaModel::new("t", cfg.name.clone(), modules));
        let pv = PackedVariant::new(base.clone(), delta).unwrap();
        (base, pv)
    }

    #[test]
    fn packed_op_matches_materialized_dense_op() {
        let (base, pv) = tiny_packed(3);
        let dense = Arc::new(pv.materialize());
        let ids = base.layout.patchable_modules();
        let mut r = Rng::new(77);
        for &id in ids.iter().take(5) {
            let (_, d_in) = id.kind.shape(base.cfg());
            let mut x = crate::tensor::Tensor2::zeros(4, d_in);
            r.fill_normal(&mut x.data, 1.0);
            let want = dense.op(id).forward(&x);
            let got = pv.op(id).forward(&x);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() <= 1e-5 * (1.0 + w.abs()), "{id}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn untouched_modules_fall_back_to_base_dense() {
        let (base, pv) = tiny_packed(2);
        let ids = base.layout.patchable_modules();
        let last = *ids.last().unwrap();
        // Beyond the 2 patched modules the op must be a dense view of base.
        assert!(matches!(pv.op(last), AnyLinear::Dense(_)));
        assert!(matches!(pv.op(ids[0]), AnyLinear::Fused(_)));
    }

    #[test]
    fn packed_residency_is_fraction_of_dense() {
        let (_, pv) = tiny_packed(7);
        let w = VariantWeights::Packed(pv);
        assert!(w.resident_bytes() * 8 < w.dense_equiv_bytes());
        assert!(w.is_packed());
    }

    #[test]
    fn rejects_malformed_codec_shapes() {
        use crate::delta::types::LowRank;
        let (base, pv) = tiny_packed(1);
        let good = pv.delta().modules[0].as_ref().clone();
        let (rows, cols) = good.id.kind.shape(base.cfg());
        // Scalar codec on a non-scalar axis.
        let mut scalar_bad = good.clone();
        scalar_bad.codec = Codec::Scalar;
        // Low-rank A factor sized for the wrong rank.
        let mut lr_bad = good.clone();
        lr_bad.codec =
            Codec::LowRank(LowRank { rank: 2, a: vec![0.0; cols], b: vec![0.0; rows * 2] });
        for m in [scalar_bad, lr_bad] {
            let delta =
                Arc::new(DeltaModel::new("bad", base.cfg().name.clone(), vec![m]));
            assert!(PackedVariant::new(base.clone(), delta).is_err());
        }
        // A well-formed low-rank module passes.
        let mut lr_ok = good;
        lr_ok.codec =
            Codec::LowRank(LowRank { rank: 2, a: vec![0.0; 2 * cols], b: vec![0.0; rows * 2] });
        let delta = Arc::new(DeltaModel::new("ok", base.cfg().name.clone(), vec![lr_ok]));
        assert!(PackedVariant::new(base.clone(), delta).is_ok());
    }

    #[test]
    fn rejects_wrong_base_config() {
        let (base, pv) = tiny_packed(1);
        let delta = DeltaModel {
            variant: "x".into(),
            base_config: "not-a-config".into(),
            meta: Default::default(),
            modules: pv.delta().modules.clone(),
        };
        assert!(PackedVariant::new(base, Arc::new(delta)).is_err());
    }
}
