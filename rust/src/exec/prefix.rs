//! Cross-window prefix/activation cache: requests whose token sequences
//! share a leading prefix reuse that prefix's per-layer activations across
//! engine windows instead of recomputing them — the multi-tenant redundancy
//! DeltaZip's serving analysis points at, attacked at the activation level.
//!
//! **What is cached.** A [`PrefixState`]: for one token prefix of length
//! `P`, every layer's post-RoPE K and V rows (`[P, d]` each) plus the
//! prefix's logits (`[P, vocab]`). That is exactly the state a resumed
//! forward needs — suffix rows attend over the cached K/V (memcpy'd, bits
//! preserved) and the full logits are stitched from cached + computed rows.
//! Cut-points sit only at row/layer boundaries, never inside a single FP
//! reduction, so cached == uncached **bitwise** (same rule as the compute
//! pool; the property tests assert exact equality at pool widths 1 and 4).
//!
//! **Keying and invalidation.** Activations depend on the weights that
//! produced them, so entries are keyed by *weights identity* — the base
//! parameter `Arc` plus the executing delta `Arc` (`None` for base/dense
//! rows) — alongside the token-prefix hash and length. Two consequences:
//!
//! * **A delta publish never invalidates anything.** Publishing `variant@N+1`
//!   composes a *new* [`DeltaModel`](crate::delta::DeltaModel) `Arc`; the old
//!   version's entries stay valid for in-flight work and the new version
//!   simply misses into fresh entries. There is no flush path keyed on
//!   publish at all — the tests assert cached bytes survive a
//!   `publish_incremental` and stay bitwise-correct.
//! * **Base-model changes invalidate implicitly and explicitly.** Entries
//!   hold [`Weak`] references; dropping a base (or delta) `Arc` makes its
//!   entries unresumable and they are reaped on lookup. [`invalidate_base`]
//!   drops a base's entries eagerly. The held `Weak` also pins the
//!   allocation, so a recycled address can never alias a dead key (the
//!   classic ABA hazard of raw-pointer keys).
//!
//! [`invalidate_base`]: PrefixCache::invalidate_base
//!
//! **Budget.** Byte-accounted LRU under `ServerConfig::prefix_cache_bytes`
//! (default 64 MiB). Env `PAWD_PREFIX_CACHE` overrides the budget; `0` is
//! the kill-switch — every lookup misses, every insert is dropped, and the
//! serving path degrades to the cold stacked forward (tier-1 CI runs the
//! whole suite once in that mode).

use super::batch::BatchPlan;
use super::counters;
use crate::delta::DeltaModel;
use crate::model::transformer::PlanSeq;
use crate::model::{FlatParams, Transformer};
use crate::tensor::Tensor2;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, Weak};

/// Prefix lengths are quantized to multiples of this block so nearly-equal
/// prompts still share entries and the key space stays small. Eight tokens
/// ≈ one cache line of token bytes; prompts shorter than one block are
/// never cached.
pub const PREFIX_BLOCK: usize = 8;

/// Cached forward state for one token prefix under one weights identity:
/// per-layer post-RoPE K/V rows and the prefix logits. Produced by
/// [`Transformer::forward_plan_prefixed`] `capture`, consumed by its
/// `resume`.
pub struct PrefixState {
    /// The exact prefix tokens (collision guard: lookups compare bytes,
    /// never trust the hash alone).
    pub tokens: Vec<u8>,
    /// Per layer: post-RoPE key rows `[P, d]`.
    pub k: Vec<Tensor2>,
    /// Per layer: value rows `[P, d]`.
    pub v: Vec<Tensor2>,
    /// Prefix logits `[P, vocab]` — resumed sequences stitch these back
    /// into their full output.
    pub logits: Tensor2,
}

impl PrefixState {
    /// Prefix length in tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True when the state covers zero tokens.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Resident bytes: every cached f32 plus the token bytes.
    pub fn bytes(&self) -> u64 {
        let floats: usize = self
            .k
            .iter()
            .chain(self.v.iter())
            .map(|t| t.data.len())
            .sum::<usize>()
            + self.logits.data.len();
        (floats * 4 + self.tokens.len()) as u64
    }
}

/// FNV-1a over the token bytes — stable, dependency-free, and cheap enough
/// to run per request at admission time.
pub fn hash_tokens(tokens: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in tokens {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Largest multiple of [`PREFIX_BLOCK`] that is `<= n`.
fn block_floor(n: usize) -> usize {
    n / PREFIX_BLOCK * PREFIX_BLOCK
}

/// Weights identity: `(base Arc address, delta Arc address or 0)`. Raw
/// addresses alone would be ABA-unsafe; the cache entry's [`Weak`]s pin the
/// allocations and prove liveness, the key only routes to the entry.
type WeightsKey = (usize, usize);

fn weights_key(base: &Arc<FlatParams>, delta: Option<&Arc<DeltaModel>>) -> WeightsKey {
    (Arc::as_ptr(base) as usize, delta.map_or(0, |d| Arc::as_ptr(d) as usize))
}

struct Entry {
    state: Arc<PrefixState>,
    base: Weak<FlatParams>,
    delta: Option<Weak<DeltaModel>>,
    bytes: u64,
    last_used: u64,
}

impl Entry {
    /// True iff this entry was produced by exactly these weight objects:
    /// each `Weak` still upgrades (the allocation is alive *and* strong
    /// refs remain) and the upgraded `Arc` is pointer-equal to the query.
    fn live_for(&self, base: &Arc<FlatParams>, delta: Option<&Arc<DeltaModel>>) -> bool {
        let base_ok = self.base.upgrade().is_some_and(|b| Arc::ptr_eq(&b, base));
        let delta_ok = match (&self.delta, delta) {
            (None, None) => true,
            (Some(w), Some(d)) => w.upgrade().is_some_and(|a| Arc::ptr_eq(&a, d)),
            _ => false,
        };
        base_ok && delta_ok
    }
}

struct Inner {
    map: HashMap<(WeightsKey, u64, usize), Entry>,
    clock: u64,
    used: u64,
    hits: u64,
    misses: u64,
    rows_skipped: u64,
}

/// Point-in-time cache statistics (instance-local; the global
/// [`counters`](super::counters) aggregate across caches).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub hits: u64,
    pub misses: u64,
    pub rows_skipped: u64,
    pub bytes: u64,
    pub entries: usize,
}

/// Byte-budgeted LRU cache of [`PrefixState`]s, keyed by
/// `(weights identity, token-prefix hash, prefix length)`.
pub struct PrefixCache {
    budget: u64,
    inner: Mutex<Inner>,
}

/// Resolve the effective byte budget: the config value unless the
/// `PAWD_PREFIX_CACHE` env var parses as a u64 (then the env wins; `0`
/// disables the cache entirely). Unparsable values fall back to config.
pub fn effective_budget(cfg_bytes: u64, env: Option<&str>) -> u64 {
    match env {
        Some(s) => s.trim().parse::<u64>().unwrap_or(cfg_bytes),
        None => cfg_bytes,
    }
}

impl PrefixCache {
    /// Cache with the configured budget, honoring the `PAWD_PREFIX_CACHE`
    /// env override/kill-switch (the serving path constructor).
    pub fn new(cfg_bytes: u64) -> Self {
        let env = std::env::var("PAWD_PREFIX_CACHE").ok();
        Self::with_budget(effective_budget(cfg_bytes, env.as_deref()))
    }

    /// Cache with exactly this budget, ignoring the environment — tests
    /// asserting cache activity use this so a `PAWD_PREFIX_CACHE=0` CI run
    /// (which must keep the *cold* path green) doesn't flip their behavior.
    pub fn with_budget(budget: u64) -> Self {
        PrefixCache {
            budget,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
                used: 0,
                hits: 0,
                misses: 0,
                rows_skipped: 0,
            }),
        }
    }

    /// False when the kill-switch zeroed the budget: lookups miss, inserts
    /// drop, the serving path runs cold.
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// The byte budget this cache evicts down to.
    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    /// Bytes currently resident.
    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when no entries are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Instance-local statistics snapshot.
    pub fn stats(&self) -> PrefixStats {
        let g = self.inner.lock().unwrap();
        PrefixStats {
            hits: g.hits,
            misses: g.misses,
            rows_skipped: g.rows_skipped,
            bytes: g.used,
            entries: g.map.len(),
        }
    }

    /// Longest cached prefix of `tokens` (at most `max_len` tokens, walked
    /// down in [`PREFIX_BLOCK`] steps) that is resumable under exactly
    /// these weights. Dead entries (weights dropped) and hash collisions
    /// met on the walk are reaped in passing.
    pub fn lookup(
        &self,
        base: &Arc<FlatParams>,
        delta: Option<&Arc<DeltaModel>>,
        tokens: &[u8],
        max_len: usize,
    ) -> Option<Arc<PrefixState>> {
        if !self.enabled() {
            return None;
        }
        let key = weights_key(base, delta);
        let mut g = self.inner.lock().unwrap();
        let mut p = block_floor(max_len.min(tokens.len()));
        while p >= PREFIX_BLOCK {
            let map_key = (key, hash_tokens(&tokens[..p]), p);
            if let Some(e) = g.map.get(&map_key) {
                if e.live_for(base, delta) && e.state.tokens[..] == tokens[..p] {
                    g.clock += 1;
                    let now = g.clock;
                    let e = g.map.get_mut(&map_key).unwrap();
                    e.last_used = now;
                    return Some(e.state.clone());
                }
                // Dead weights or a hash collision: reap and keep walking.
                let dead = g.map.remove(&map_key).unwrap();
                g.used -= dead.bytes;
                counters::set_prefix_cache_bytes(g.used);
            }
            p -= PREFIX_BLOCK;
        }
        None
    }

    /// Insert a captured state under these weights, evicting
    /// least-recently-used entries until it fits. States larger than the
    /// whole budget are dropped (the cold path stays correct regardless).
    pub fn insert(
        &self,
        base: &Arc<FlatParams>,
        delta: Option<&Arc<DeltaModel>>,
        state: Arc<PrefixState>,
    ) {
        let bytes = state.bytes();
        if !self.enabled() || bytes > self.budget || state.len() < PREFIX_BLOCK {
            return;
        }
        let key = weights_key(base, delta);
        let map_key = (key, hash_tokens(&state.tokens), state.len());
        let mut g = self.inner.lock().unwrap();
        if let Some(old) = g.map.remove(&map_key) {
            g.used -= old.bytes;
        }
        while g.used + bytes > self.budget {
            let victim = g.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let e = g.map.remove(&k).unwrap();
                    g.used -= e.bytes;
                }
                None => break,
            }
        }
        g.clock += 1;
        let entry = Entry {
            state,
            base: Arc::downgrade(base),
            delta: delta.map(Arc::downgrade),
            bytes,
            last_used: g.clock,
        };
        g.map.insert(map_key, entry);
        g.used += bytes;
        counters::set_prefix_cache_bytes(g.used);
    }

    /// Eagerly drop every entry produced against this base model. The only
    /// event that must invalidate: swapping the base weights. (Delta
    /// publishes never reach here — new versions are new `Arc`s that miss
    /// into fresh entries while old entries age out.)
    pub fn invalidate_base(&self, base: &Arc<FlatParams>) {
        let mut g = self.inner.lock().unwrap();
        let key = Arc::as_ptr(base) as usize;
        let doomed: Vec<_> = g.map.keys().filter(|(k, _, _)| k.0 == key).copied().collect();
        for k in doomed {
            let e = g.map.remove(&k).unwrap();
            g.used -= e.bytes;
        }
        counters::set_prefix_cache_bytes(g.used);
    }

    /// Fold one window's outcome into the instance stats and the global
    /// counters.
    fn record_use(&self, hits: u64, misses: u64, rows_skipped: u64) {
        {
            let mut g = self.inner.lock().unwrap();
            g.hits += hits;
            g.misses += misses;
            g.rows_skipped += rows_skipped;
        }
        counters::record_prefix_hits(hits);
        counters::record_prefix_misses(misses);
        counters::record_prefix_rows_skipped(rows_skipped);
    }
}

/// Run one engine window's stacked forward through the prefix cache:
/// group the window's sequences by (weights identity, shared block-aligned
/// prefix), resume every group from the longest cached prefix, compute a
/// missed shared prefix **once** for its whole group, and capture new
/// prefixes for future windows. Falls back to the plain cold
/// [`Transformer::forward_plan`] when the cache is disabled or nothing is
/// cacheable — and is bitwise-equal to it in every case.
pub fn run_plan(
    tf: &Transformer,
    plan: &BatchPlan,
    seqs: &[(usize, Vec<u8>)],
    cache: &PrefixCache,
) -> Vec<Tensor2> {
    if !cache.enabled() || seqs.is_empty() {
        return tf.forward_plan(plan, seqs);
    }
    // Group sequence indices by (weights identity, candidate prefix).
    // `cand = block_floor(T-1)` guarantees at least one suffix row, so a
    // full-hit resume never degenerates to zero computed rows.
    let mut groups: HashMap<(WeightsKey, u64, usize), Vec<usize>> = HashMap::new();
    let mut order: Vec<(WeightsKey, u64, usize)> = Vec::new();
    for (i, (entry, tokens)) in seqs.iter().enumerate() {
        let cand = block_floor(tokens.len().saturating_sub(1));
        if cand < PREFIX_BLOCK {
            continue;
        }
        let (base, delta) = plan.entry_weights(*entry);
        let gk = (weights_key(base, delta), hash_tokens(&tokens[..cand]), cand);
        if let Some(members) = groups.get_mut(&gk) {
            // Hash-collision guard within the window: only byte-identical
            // prefixes ride one group.
            let first = members[0];
            if seqs[first].1[..cand] == tokens[..cand] {
                members.push(i);
            }
        } else {
            groups.insert(gk, vec![i]);
            order.push(gk);
        }
    }

    let mut resume: Vec<Option<Arc<PrefixState>>> = vec![None; seqs.len()];
    let mut capture: Vec<usize> = vec![0; seqs.len()];
    let (mut hits, mut misses, mut skipped) = (0u64, 0u64, 0u64);
    for gk in &order {
        let members = &groups[gk];
        let (_, _, cand) = *gk;
        let (entry, tokens) = &seqs[members[0]];
        let (base, delta) = plan.entry_weights(*entry);
        let (base, delta) = (base.clone(), delta.cloned());
        let found = cache.lookup(&base, delta.as_ref(), tokens, cand);
        match found {
            Some(state) if state.len() == cand => {
                for &m in members {
                    resume[m] = Some(state.clone());
                }
                hits += members.len() as u64;
                skipped += (cand * members.len()) as u64;
            }
            shorter => {
                misses += 1;
                let p0 = shorter.as_ref().map_or(0, |s| s.len());
                if members.len() >= 2 {
                    // Compute the shared prefix ONCE for the whole group
                    // (resuming any shorter cached prefix), cache it, then
                    // every member resumes it below.
                    let seq = PlanSeq {
                        entry: *entry,
                        tokens: &tokens[..cand],
                        resume: shorter.as_deref(),
                        capture: cand,
                    };
                    let (_, mut caps) = tf.forward_plan_prefixed(plan, &[seq]);
                    let state = Arc::new(caps.remove(0).expect("capture requested"));
                    cache.insert(&base, delta.as_ref(), state.clone());
                    for &m in members {
                        resume[m] = Some(state.clone());
                    }
                    hits += members.len() as u64 - 1;
                    skipped += (p0 + cand * (members.len() - 1)) as u64;
                } else {
                    // Solo sequence: resume whatever shorter prefix exists
                    // and capture the candidate for future windows.
                    let m = members[0];
                    resume[m] = shorter;
                    capture[m] = cand;
                    skipped += p0 as u64;
                }
            }
        }
    }
    cache.record_use(hits, misses, skipped);

    if resume.iter().all(Option::is_none) && capture.iter().all(|&c| c == 0) {
        return tf.forward_plan(plan, seqs);
    }
    let plan_seqs: Vec<PlanSeq> = seqs
        .iter()
        .enumerate()
        .map(|(i, (entry, tokens))| PlanSeq {
            entry: *entry,
            tokens,
            resume: resume[i].as_deref(),
            capture: capture[i],
        })
        .collect();
    let (logits, caps) = tf.forward_plan_prefixed(plan, &plan_seqs);
    for (i, cap) in caps.into_iter().enumerate() {
        if let Some(state) = cap {
            let (base, delta) = plan.entry_weights(seqs[i].0);
            let (base, delta) = (base.clone(), delta.cloned());
            cache.insert(&base, delta.as_ref(), Arc::new(state));
        }
    }
    logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_state(tokens: Vec<u8>, d: usize) -> Arc<PrefixState> {
        let p = tokens.len();
        Arc::new(PrefixState {
            tokens,
            k: vec![Tensor2::zeros(p, d)],
            v: vec![Tensor2::zeros(p, d)],
            logits: Tensor2::zeros(p, 4),
        })
    }

    #[test]
    fn miri_weak_keyed_identity() {
        // Arc-address identity under Miri's strict provenance (the
        // sanitizers CI lane filters on the miri_ name prefix): a hit
        // requires the very same base allocation, and a content-equal
        // rebuild at a fresh address must miss even though the old entry's
        // Weak still pins the original allocation against address reuse.
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 9));
        let cache = PrefixCache::with_budget(1 << 20);
        cache.insert(&base, None, tiny_state((0..8).collect(), 4));
        let long: Vec<u8> = (0..16).collect();
        assert!(cache.lookup(&base, None, &long, 8).is_some());
        let rebuilt = Arc::new(FlatParams::init(&cfg, 9));
        assert!(cache.lookup(&rebuilt, None, &long, 8).is_none());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hash_and_block_floor_basics() {
        assert_eq!(hash_tokens(b"abc"), hash_tokens(b"abc"));
        assert_ne!(hash_tokens(b"abc"), hash_tokens(b"abd"));
        assert_eq!(block_floor(0), 0);
        assert_eq!(block_floor(7), 0);
        assert_eq!(block_floor(8), 8);
        assert_eq!(block_floor(23), 16);
    }

    #[test]
    fn effective_budget_env_rules() {
        assert_eq!(effective_budget(100, None), 100);
        assert_eq!(effective_budget(100, Some("0")), 0);
        assert_eq!(effective_budget(100, Some("4096")), 4096);
        assert_eq!(effective_budget(100, Some(" 7 ")), 7);
        assert_eq!(effective_budget(100, Some("not-a-number")), 100);
    }

    #[test]
    fn insert_lookup_and_weak_liveness() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 1));
        let cache = PrefixCache::with_budget(1 << 20);
        let toks: Vec<u8> = (0..8).collect();
        cache.insert(&base, None, tiny_state(toks.clone(), 4));
        assert_eq!(cache.len(), 1);
        let long: Vec<u8> = (0..20).collect();
        let hit = cache.lookup(&base, None, &long, 16).expect("prefix hit");
        assert_eq!(hit.len(), 8);
        // A different base Arc (even with identical contents) never hits.
        let other = Arc::new(FlatParams::init(&cfg, 1));
        assert!(cache.lookup(&other, None, &long, 16).is_none());
        // Dropping the base makes the entry dead: its Weak pins the old
        // allocation (no ABA address reuse) but can no longer upgrade, so
        // no future Arc can ever hit it.
        drop(base);
        let base2 = Arc::new(FlatParams::init(&cfg, 2));
        assert!(cache.lookup(&base2, None, &long, 16).is_none());
    }

    #[test]
    fn eviction_keeps_used_within_budget() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 3));
        let one = tiny_state((0..8).collect(), 4).bytes();
        let cache = PrefixCache::with_budget(one * 2);
        for s in 0u8..5 {
            let toks: Vec<u8> = (0..8).map(|i| i + s * 10).collect();
            cache.insert(&base, None, tiny_state(toks, 4));
            assert!(cache.used_bytes() <= cache.budget_bytes());
        }
        assert!(cache.len() <= 2);
        // Most recent entry survives.
        let last: Vec<u8> = (0..9).map(|i| i + 40).collect();
        assert!(cache.lookup(&base, None, &last, 8).is_some());
    }

    #[test]
    fn kill_switch_disables_everything() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 4));
        let cache = PrefixCache::with_budget(0);
        assert!(!cache.enabled());
        cache.insert(&base, None, tiny_state((0..8).collect(), 4));
        assert_eq!(cache.len(), 0);
        let long: Vec<u8> = (0..12).collect();
        assert!(cache.lookup(&base, None, &long, 8).is_none());
    }

    #[test]
    fn invalidate_base_drops_only_that_base() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = Arc::new(FlatParams::init(&cfg, 5));
        let b = Arc::new(FlatParams::init(&cfg, 6));
        let cache = PrefixCache::with_budget(1 << 20);
        cache.insert(&a, None, tiny_state((0..8).collect(), 4));
        cache.insert(&b, None, tiny_state((0..8).collect(), 4));
        assert_eq!(cache.len(), 2);
        cache.invalidate_base(&a);
        assert_eq!(cache.len(), 1);
        let long: Vec<u8> = (0..12).collect();
        assert!(cache.lookup(&a, None, &long, 8).is_none());
        assert!(cache.lookup(&b, None, &long, 8).is_some());
    }
}
