//! Batched multi-variant execution: one shared base GEMM per module for a
//! whole mixed-variant batch, plus per-variant packed mask reductions on
//! row slices.
//!
//! The per-request fused path ([`FusedDeltaLinear`](super::FusedDeltaLinear))
//! already avoids dense reconstruction, but a batch of B requests across V
//! variants of one base still pays B base GEMMs per module — the base
//! activations are read once *per request* even though the weights are
//! shared. [`BatchPlan`] regroups that work: stack every request's
//! activations into one `[ΣT, d]` tensor, run the base projection **once**,
//! then add each variant's `v ⊙ (x·Bᵀ)` term only to the row slice that
//! belongs to it (BitDelta and DeltaZip report the same structure as the
//! key to multi-tenant serving wins — base compute and residency are
//! shared, per-variant work is proportional to the packed delta only).
//!
//! Grouping key: the *base parameter `Arc`*. Packed variants loaded from
//! one store all share the store's base and land in one plan; dense
//! variants only group with other requests holding the same materialized
//! `Arc` (same `(variant, version)` cache entry). The transformer consumes
//! a plan through [`BatchSource`]: per-sequence results are bitwise
//! identical to running each request through its own per-request path —
//! batching regroups work, never the arithmetic.

use super::linear::{add_delta_rows, DenseLinear, LinearOp};
use super::weights::{PackedVariant, VariantWeights, Weights};
use crate::model::{FlatParams, ModuleId};
use crate::tensor::Tensor2;
use std::collections::HashMap;
use std::sync::Arc;

/// Contiguous run of stacked activation rows belonging to one plan entry.
#[derive(Clone, Debug)]
pub struct RowSpan {
    pub start: usize,
    pub end: usize,
    /// Index into the plan's entry list.
    pub entry: usize,
}

/// A weights source the transformer can run a *stacked multi-request*
/// forward against: shared non-patchable parameters plus a per-module
/// batched projection where different row spans may execute different
/// variants.
pub trait BatchSource: Sync {
    /// Shared (non-patchable) parameters: embeddings, norms, LM head.
    fn flat(&self) -> &FlatParams;

    /// Number of entries a [`RowSpan::entry`] may reference
    /// (`usize::MAX` = any index is accepted).
    fn entries(&self) -> usize;

    /// `y = x·Ŵᵀ` for module `id`, where rows `spans[i]` of `x` belong to
    /// entry `spans[i].entry`'s variant. Spans must be disjoint and cover
    /// every row of `x`.
    fn forward_module(&self, id: ModuleId, x: &Tensor2, spans: &[RowSpan], y: &mut Tensor2);
}

/// Run a whole stacked batch through one ordinary [`Weights`] source
/// (single-variant batches, A/B baselines). Row spans are ignored — every
/// row executes the same weights.
pub struct Uniform<W>(pub W);

impl<W: Weights> BatchSource for Uniform<W> {
    fn flat(&self) -> &FlatParams {
        self.0.flat()
    }

    fn entries(&self) -> usize {
        usize::MAX
    }

    fn forward_module(&self, id: ModuleId, x: &Tensor2, _spans: &[RowSpan], y: &mut Tensor2) {
        self.0.op(id).forward_into(x, y);
    }
}

/// How one plan entry contributes to the batched forward.
enum PlanEntry {
    /// The entry *is* the shared base storage (dense weights, no delta).
    Base,
    /// Shared base + this packed delta.
    Packed(PackedVariant),
}

/// Execution plan for one shared-base group of a mixed-variant batch: the
/// base GEMM runs once per module for every row in the stacked batch, each
/// entry's packed mask reduction runs only on its own rows.
pub struct BatchPlan {
    base: Arc<FlatParams>,
    entries: Vec<PlanEntry>,
}

impl BatchPlan {
    /// Group a mixed batch by shared base storage. Every [`VariantWeights`]
    /// whose underlying parameter `Arc` is the same object lands in one
    /// plan: packed variants of one base all do, dense variants only with
    /// requests holding the same materialized `Arc`. Returns each plan with
    /// the input indices it covers, in first-appearance order; plan entry
    /// `j` executes the weights of input index `members[j]`.
    pub fn group(weights: &[VariantWeights]) -> Vec<(BatchPlan, Vec<usize>)> {
        let mut plans: Vec<(BatchPlan, Vec<usize>)> = Vec::new();
        let mut by_base: HashMap<*const FlatParams, usize> = HashMap::new();
        for (i, w) in weights.iter().enumerate() {
            let (key, base, entry) = match w {
                VariantWeights::Packed(pv) => (
                    Arc::as_ptr(pv.base()),
                    pv.base().clone(),
                    PlanEntry::Packed(pv.clone()),
                ),
                VariantWeights::Dense(p, _) => (Arc::as_ptr(p), p.clone(), PlanEntry::Base),
            };
            let slot = match by_base.get(&key) {
                Some(&s) => s,
                None => {
                    by_base.insert(key, plans.len());
                    plans.push((BatchPlan { base, entries: Vec::new() }, Vec::new()));
                    plans.len() - 1
                }
            };
            plans[slot].0.entries.push(entry);
            plans[slot].1.push(i);
        }
        plans
    }

    /// The shared base every entry of this plan executes against.
    pub fn base(&self) -> &Arc<FlatParams> {
        &self.base
    }

    /// How many of this plan's entries carry a packed delta (the rest are
    /// pure base/dense rows).
    pub fn packed_entries(&self) -> usize {
        self.entries.iter().filter(|e| matches!(e, PlanEntry::Packed(_))).count()
    }

    /// The weights identity executed by plan entry `entry`: the shared base
    /// `Arc` plus this entry's delta `Arc` (`None` for base/dense rows).
    /// This pair is the prefix cache's key — activations produced by two
    /// entries are interchangeable iff both `Arc`s are the same objects.
    pub fn entry_weights(
        &self,
        entry: usize,
    ) -> (&Arc<FlatParams>, Option<&Arc<crate::delta::DeltaModel>>) {
        match &self.entries[entry] {
            PlanEntry::Base => (&self.base, None),
            PlanEntry::Packed(pv) => (&self.base, Some(pv.delta())),
        }
    }
}

impl BatchSource for BatchPlan {
    fn flat(&self) -> &FlatParams {
        &self.base
    }

    fn entries(&self) -> usize {
        self.entries.len()
    }

    fn forward_module(&self, id: ModuleId, x: &Tensor2, spans: &[RowSpan], y: &mut Tensor2) {
        // ONE shared base GEMM for every row in the stacked batch…
        let (rows, cols) = id.kind.shape(self.base.cfg());
        DenseLinear::new(self.base.module(id), rows, cols).forward_into(x, y);
        // …then each variant's packed mask reduction on its own rows only.
        for s in spans {
            if let PlanEntry::Packed(pv) = &self.entries[s.entry] {
                if let Some(m) = pv.module(id) {
                    add_delta_rows(m, x, y, s.start..s.end);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Axis, Codec, DeltaModel, DeltaModule};
    use crate::exec::FusedDeltaLinear;
    use crate::model::config::ModelConfig;
    use crate::util::rng::Rng;

    fn packed_variant(base: &Arc<FlatParams>, seed: u64, n_modules: usize) -> PackedVariant {
        let cfg = base.cfg();
        let axes = [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)];
        let ids = base.layout.patchable_modules();
        let mut modules = Vec::new();
        for (i, &id) in ids.iter().take(n_modules).enumerate() {
            let (rows, cols) = id.kind.shape(cfg);
            let mut r = Rng::new(seed * 31 + i as u64);
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let axis = axes[(seed as usize + i) % axes.len()];
            modules.push(DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis,
                scales: (0..axis.n_scales(rows, cols)).map(|_| r.uniform_in(0.01, 0.1)).collect(),
                codec: Codec::PerAxis,
            });
        }
        let delta = DeltaModel::new(format!("s{seed}"), cfg.name.clone(), modules);
        PackedVariant::new(base.clone(), Arc::new(delta)).unwrap()
    }

    #[test]
    fn group_partitions_by_shared_base() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base_a = Arc::new(FlatParams::init(&cfg, 1));
        let base_b = Arc::new(FlatParams::init(&cfg, 2));
        let weights = vec![
            VariantWeights::Packed(packed_variant(&base_a, 1, 2)),
            VariantWeights::Packed(packed_variant(&base_b, 2, 2)),
            VariantWeights::Packed(packed_variant(&base_a, 3, 2)),
            VariantWeights::Dense(base_a.clone(), 1),
        ];
        let plans = BatchPlan::group(&weights);
        // base_a packed variants + the dense Arc of base_a share one plan;
        // base_b gets its own.
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].1, vec![0, 2, 3]);
        assert_eq!(plans[0].0.entries(), 3);
        assert_eq!(plans[0].0.packed_entries(), 2);
        assert_eq!(plans[1].1, vec![1]);
        assert!(Arc::ptr_eq(plans[0].0.base(), &base_a));
        assert!(Arc::ptr_eq(plans[1].0.base(), &base_b));
    }

    #[test]
    fn plan_module_forward_is_bitwise_equal_to_per_entry_ops() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 5));
        let weights = vec![
            VariantWeights::Packed(packed_variant(&base, 7, 3)),
            VariantWeights::Dense(base.clone(), 1),
            VariantWeights::Packed(packed_variant(&base, 8, 3)),
        ];
        let plans = BatchPlan::group(&weights);
        assert_eq!(plans.len(), 1);
        let plan = &plans[0].0;
        let id = base.layout.patchable_modules()[0];
        let (d_out, d_in) = id.kind.shape(&cfg);
        // Stacked input: rows 0..3 entry 0, 3..4 entry 1, 4..7 entry 2.
        let mut r = Rng::new(99);
        let mut x = Tensor2::zeros(7, d_in);
        r.fill_normal(&mut x.data, 1.0);
        let spans = vec![
            RowSpan { start: 0, end: 3, entry: 0 },
            RowSpan { start: 3, end: 4, entry: 1 },
            RowSpan { start: 4, end: 7, entry: 2 },
        ];
        let mut y = Tensor2::zeros(7, d_out);
        plan.forward_module(id, &x, &spans, &mut y);
        for s in &spans {
            let sub = Tensor2::from_vec(
                s.end - s.start,
                d_in,
                x.data[s.start * d_in..s.end * d_in].to_vec(),
            );
            let want = match &weights[plans[0].1[s.entry]] {
                VariantWeights::Packed(pv) => {
                    FusedDeltaLinear::new(base.module(id), pv.module(id).unwrap()).forward(&sub)
                }
                VariantWeights::Dense(p, _) => {
                    DenseLinear::new(p.module(id), d_out, d_in).forward(&sub)
                }
            };
            for (ri, row) in (s.start..s.end).enumerate() {
                for j in 0..d_out {
                    assert_eq!(
                        y.at(row, j).to_bits(),
                        want.at(ri, j).to_bits(),
                        "entry {} row {row} col {j}",
                        s.entry
                    );
                }
            }
        }
    }

    #[test]
    fn uniform_source_runs_one_weights_for_all_rows() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = Arc::new(FlatParams::init(&cfg, 9));
        let pv = packed_variant(&base, 4, 2);
        let id = base.layout.patchable_modules()[0];
        let (d_out, d_in) = id.kind.shape(&cfg);
        let mut r = Rng::new(12);
        let mut x = Tensor2::zeros(5, d_in);
        r.fill_normal(&mut x.data, 1.0);
        let src = Uniform(&pv);
        let mut y = Tensor2::zeros(5, d_out);
        // Spans are ignored by Uniform.
        src.forward_module(id, &x, &[], &mut y);
        let want = pv.op(id).forward(&x);
        assert_eq!(y.data, want.data);
    }
}
