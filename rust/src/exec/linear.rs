//! `LinearOp` backends: dense GEMM vs fused packed-delta GEMM.
//!
//! The fused backend is the paper's "maintain inference efficiency by
//! avoiding dense reconstruction" claim made concrete: for a packed delta
//! `Ŵ = W_b + v ⊙ B` the projection
//!
//! ```text
//! y = x · Ŵᵀ = x · W_bᵀ + (v ⊙ B applied to x)
//! ```
//!
//! is computed straight from the `PackedMask` bitplane, one mask word at a
//! time, with the same branchless IEEE sign-injection trick the apply path
//! uses (`±x` differ only in the sign bit). The dense `Ŵ` never exists:
//!
//! * Row/Scalar/Group axes: the scale is constant along a mask row, so the
//!   delta term is `v_j · Σ_i sign(j,i)·x[t,i]` — one signed reduction of
//!   the activation row per (token, output-row) pair.
//! * Col axis: the scale varies along the row, so `z = v ⊙ x[t]` is formed
//!   once per token and the delta term is `Σ_i sign(j,i)·z_i`.
//!
//! Both terms come out of a *single* traversal of the activation row
//! (`fused_dot_ssum`): the base dot lanes and the signed-sum lanes
//! interleave over the same 8-element groups, so the fused path reads each
//! activation row once per output row where base-then-delta reads it twice
//! — bitwise-equal to the two-pass result by construction.
//!
//! Modules under the low-rank codec ([`Codec::LowRank`]
//! (crate::delta::types::Codec)) carry residual factors `A: [rank, d_in]`,
//! `B: [d_out, rank]`; their term is added as `y += (x·Aᵀ)·Bᵀ` — rank-space
//! coordinates `t = x·Aᵀ` computed once per activation row, then one
//! rank-length dot per output element. The dense `B·A` product never
//! exists, and [`FusedDeltaLinear`] and [`add_delta_rows`] use the *same*
//! accumulation order so the two remain bitwise-equal per element.

use super::counters;
use crate::delta::types::{Axis, DeltaModule};
use crate::tensor::{dot, Tensor2};
use crate::util::par;
use std::sync::OnceLock;

/// A linear operator `y = x · Wᵀ` (`x: [n, d_in] → y: [n, d_out]`), abstract
/// over how `W` is resident: dense f32 rows or base + packed 1-bit delta.
pub trait LinearOp {
    fn d_out(&self) -> usize;
    fn d_in(&self) -> usize;

    /// `y = x · Wᵀ` into a preallocated output.
    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2);

    /// Allocating convenience wrapper around [`LinearOp::forward_into`].
    fn forward(&self, x: &Tensor2) -> Tensor2 {
        let mut y = Tensor2::zeros(x.rows, self.d_out());
        self.forward_into(x, &mut y);
        y
    }

    /// Bytes that must stay resident to execute this op, *excluding* any
    /// storage shared with other ops (the base checkpoint is charged once by
    /// the variant cache, not per module).
    fn resident_bytes(&self) -> u64;
}

/// Dense backend: borrows a row-major `[d_out, d_in]` weight slice (a view
/// into `FlatParams`) and runs the same row-parallel dot-product GEMM as
/// `Tensor2::matmul_bt`, without copying the weights into a `Tensor2`.
pub struct DenseLinear<'a> {
    w: &'a [f32],
    d_out: usize,
    d_in: usize,
}

impl<'a> DenseLinear<'a> {
    pub fn new(w: &'a [f32], d_out: usize, d_in: usize) -> DenseLinear<'a> {
        assert_eq!(w.len(), d_out * d_in, "weight slice/shape mismatch");
        DenseLinear { w, d_out, d_in }
    }
}

impl LinearOp for DenseLinear<'_> {
    fn d_out(&self) -> usize {
        self.d_out
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2) {
        assert_eq!(x.cols, self.d_in, "input dim mismatch");
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out), "output shape mismatch");
        counters::record_base_gemm();
        counters::record_act_row_reads((x.rows * self.d_out) as u64);
        let (k, m) = (self.d_in, self.d_out);
        let a = &x.data;
        let w = self.w;
        par::parallel_rows_mut(&mut y.data, x.rows, m, 8, |row0, chunk| {
            for (ri, yrow) in chunk.chunks_mut(m).enumerate() {
                let xrow = &a[(row0 + ri) * k..(row0 + ri + 1) * k];
                for (j, o) in yrow.iter_mut().enumerate() {
                    *o = dot(xrow, &w[j * k..(j + 1) * k]);
                }
            }
        });
    }

    fn resident_bytes(&self) -> u64 {
        (self.w.len() * 4) as u64
    }
}

/// Fused backend: executes `y = x·W_bᵀ + x·(v ⊙ B)ᵀ` directly from the
/// packed bitplane — the base weights stay shared and the per-variant
/// residency is just the mask words plus the scale vector.
pub struct FusedDeltaLinear<'a> {
    base: &'a [f32],
    module: &'a DeltaModule,
}

impl<'a> FusedDeltaLinear<'a> {
    pub fn new(base: &'a [f32], module: &'a DeltaModule) -> FusedDeltaLinear<'a> {
        assert_eq!(
            base.len(),
            module.d_out() * module.d_in(),
            "base slice/delta shape mismatch for {}",
            module.id
        );
        FusedDeltaLinear { base, module }
    }
}

impl LinearOp for FusedDeltaLinear<'_> {
    fn d_out(&self) -> usize {
        self.module.d_out()
    }

    fn d_in(&self) -> usize {
        self.module.d_in()
    }

    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2) {
        let m = self.module;
        let (d_out, d_in) = (m.d_out(), m.d_in());
        assert_eq!(x.cols, d_in, "input dim mismatch");
        assert_eq!((y.rows, y.cols), (x.rows, d_out), "output shape mismatch");
        counters::record_base_gemm();
        // Single traversal per (activation row, output row): the fused
        // kernel reads the activation row once where base-then-delta would
        // read it twice.
        counters::record_act_row_reads((x.rows * d_out) as u64);
        let base = self.base;
        match m.axis {
            Axis::Col => {
                par::parallel_rows_mut(&mut y.data, x.rows, d_out, 8, |row0, chunk| {
                    let mut z = vec![0f32; d_in]; // v ⊙ x, reused across rows
                    let mut t = lowrank_scratch(m);
                    for (ri, yrow) in chunk.chunks_mut(d_out).enumerate() {
                        let xrow = x.row(row0 + ri);
                        for ((zi, &xi), &vi) in z.iter_mut().zip(xrow).zip(&m.scales) {
                            *zi = vi * xi;
                        }
                        for (j, o) in yrow.iter_mut().enumerate() {
                            let (d, s) = fused_dot_ssum(
                                xrow,
                                &base[j * d_in..(j + 1) * d_in],
                                &z,
                                m.mask.row_words(j),
                            );
                            *o = d + s;
                        }
                        add_lowrank_row(m, xrow, yrow, &mut t);
                    }
                });
            }
            _ => {
                // Row / Scalar / Group: scale constant within each mask row
                // (scale_at ignores the column index for these axes).
                par::parallel_rows_mut(&mut y.data, x.rows, d_out, 8, |row0, chunk| {
                    let mut t = lowrank_scratch(m);
                    for (ri, yrow) in chunk.chunks_mut(d_out).enumerate() {
                        let xrow = x.row(row0 + ri);
                        for (j, o) in yrow.iter_mut().enumerate() {
                            let (d, s) = fused_dot_ssum(
                                xrow,
                                &base[j * d_in..(j + 1) * d_in],
                                xrow,
                                m.mask.row_words(j),
                            );
                            *o = d + m.scale_at(j, 0) * s;
                        }
                        add_lowrank_row(m, xrow, yrow, &mut t);
                    }
                });
            }
        }
    }

    fn resident_bytes(&self) -> u64 {
        self.module.resident_bytes()
    }
}

/// Rank-space scratch for a module's low-rank term (empty for modules
/// without one), allocated once per worker chunk and reused across rows.
#[inline]
fn lowrank_scratch(m: &DeltaModule) -> Vec<f32> {
    m.lowrank().map_or_else(Vec::new, |lr| vec![0f32; lr.rank])
}

/// Add the low-rank residual term `(xrow·Aᵀ)·Bᵀ` of `m` (if any) onto one
/// output row: `t[k] = ⟨xrow, A[k,·]⟩` once per activation row, then
/// `y[j] += ⟨B[j,·], t⟩`. Exactly one `+=` per output element, and the
/// same [`dot`] reduction everywhere — [`FusedDeltaLinear`] and
/// [`add_delta_rows`] both call this, so their outputs stay bitwise-equal.
#[inline]
fn add_lowrank_row(m: &DeltaModule, xrow: &[f32], yrow: &mut [f32], t: &mut [f32]) {
    let Some(lr) = m.lowrank() else { return };
    let d_in = m.d_in();
    for (k, tk) in t.iter_mut().enumerate() {
        *tk = dot(xrow, &lr.a[k * d_in..(k + 1) * d_in]);
    }
    for (j, o) in yrow.iter_mut().enumerate() {
        *o += dot(&lr.b[j * lr.rank..(j + 1) * lr.rank], t);
    }
}

/// `Σ_i sign_i · vals[i]` where `sign_i` is bit `i` of the packed row
/// (1 → +1, 0 → −1) — the per-row mask reduction at the heart of every
/// fused delta path. The sign is injected by XOR-flipping the IEEE sign
/// bit, so ±vals[i] never branches.
///
/// Dispatch: resolved once per process into a cached `OnceLock` function
/// pointer — an AVX2 entry when the CPU has it, otherwise the portable
/// [`signed_sum_u64`] word path — so the hot loop pays one relaxed load
/// instead of a feature probe per invocation. Both paths consume the same
/// u32 bitplane; within one process the same path always runs, so results
/// are reproducible run-to-run.
#[inline]
pub fn signed_sum(vals: &[f32], words: &[u32]) -> f32 {
    static IMPL: OnceLock<fn(&[f32], &[u32]) -> f32> = OnceLock::new();
    let f = *IMPL.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            return signed_sum_dispatch_avx2;
        }
        signed_sum_u64
    });
    f(vals, words)
}

/// The AVX2-capable entry installed by [`signed_sum`]'s cached dispatch:
/// rows too short for a full 32-lane word fall back to the portable path
/// (same cutoff the uncached dispatch used, so numerics are unchanged).
#[cfg(target_arch = "x86_64")]
fn signed_sum_dispatch_avx2(vals: &[f32], words: &[u32]) -> f32 {
    if vals.len() >= 32 {
        // SAFETY: this entry is only installed after AVX2 was detected.
        unsafe { signed_sum_avx2(vals, words) }
    } else {
        signed_sum_u64(vals, words)
    }
}

/// Whether the dispatched [`signed_sum`] takes the AVX2 wide path for rows
/// of `len` values. The fused single-pass kernel keys off this to mirror
/// the *exact* accumulation structure (lane assignment, horizontal-sum
/// order, tail handling) of whichever two-pass reduction would have run,
/// keeping fused output bitwise-equal to `dot(..) + signed_sum(..)`.
#[inline]
fn ssum_wide_path(len: usize) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVX2: OnceLock<bool> = OnceLock::new();
        len >= 32 && *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = len;
        false
    }
}

/// Single-traversal fused kernel: one pass over an activation row computes
/// both the base dot product and the packed-mask signed sum, returning
/// `(dot, ssum)` with bits identical to `(dot(x, w), signed_sum(s_src,
/// words))`. `x` drives the dot against the base row `w`; `s_src` drives
/// the signed reduction (`x` itself for row-constant scale axes, `v ⊙ x`
/// for the Col axis). Halving the activation-row reads is the win on the
/// single-request path, where the row is streamed from memory per output
/// row.
///
/// Bitwise equality holds because each partial accumulator replicates its
/// two-pass counterpart exactly: dot lanes follow [`dot`]'s eight-lane
/// 8-block structure and final reduction tree; ssum lanes follow whichever
/// structure the dispatched [`signed_sum`] would use for this row length —
/// the AVX2 32-lane word grouping (whose per-lane adds are IEEE-identical
/// to this scalar emulation) or the portable 64-lane u64 grouping — then
/// the same horizontal sum and bitwise tail.
fn fused_dot_ssum(x: &[f32], w: &[f32], s_src: &[f32], words: &[u32]) -> (f32, f32) {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(x.len(), s_src.len());
    let n = x.len();
    let chunks = n / 8;
    let mut d = [0f32; 8];
    let mut lanes = [0f32; 8];
    // Fused section: full sign-word blocks, interleaving dot lanes and
    // ssum lanes over the same 8-element groups.
    let (done8, ssum_tail) = if ssum_wide_path(n) {
        let full32 = n / 32;
        for wi in 0..full32 {
            let wrd = words[wi];
            for c in 0..4 {
                let o = wi * 32 + c * 8;
                for l in 0..8 {
                    d[l] += x[o + l] * w[o + l];
                    let flip = (((wrd >> (c * 8 + l)) & 1) ^ 1) << 31;
                    lanes[l] += f32::from_bits(s_src[o + l].to_bits() ^ flip);
                }
            }
        }
        (full32 * 4, full32 * 32)
    } else {
        let full64 = n / 64;
        for wi in 0..full64 {
            let wrd = words[2 * wi] as u64 | (words[2 * wi + 1] as u64) << 32;
            for c in 0..8 {
                let o = wi * 64 + c * 8;
                for l in 0..8 {
                    d[l] += x[o + l] * w[o + l];
                    let flip = ((((wrd >> (c * 8 + l)) as u32) & 1) ^ 1) << 31;
                    lanes[l] += f32::from_bits(s_src[o + l].to_bits() ^ flip);
                }
            }
        }
        (full64 * 8, full64 * 64)
    };
    // Dot remainder: the full 8-blocks past the fused section, then the
    // scalar tail — same order of operations as `dot`.
    for ci in done8..chunks {
        let o = ci * 8;
        for l in 0..8 {
            d[l] += x[o + l] * w[o + l];
        }
    }
    let mut dacc = (d[0] + d[1]) + (d[2] + d[3]) + ((d[4] + d[5]) + (d[6] + d[7]));
    for i in chunks * 8..n {
        dacc += x[i] * w[i];
    }
    // Ssum horizontal sum + bitwise tail — same order as the dispatched
    // signed_sum path.
    let mut sacc = lanes.iter().sum::<f32>();
    for i in ssum_tail..n {
        let wrd = words[i / 32];
        sacc += f32::from_bits(s_src[i].to_bits() ^ ((((wrd >> (i % 32)) & 1) ^ 1) << 31));
    }
    (dacc, sacc)
}

/// Portable word path: two u32 mask words fold into one `u64` bitplane word
/// and a constant-bound 64-lane inner loop accumulates into eight partial
/// sums, so the compiler can keep SIMD lanes busy on any target. The ragged
/// tail past the last full u64 is handled bit by bit.
pub fn signed_sum_u64(vals: &[f32], words: &[u32]) -> f32 {
    debug_assert_eq!(words.len(), vals.len().div_ceil(32), "mask/values length mismatch");
    let d_in = vals.len();
    let full = d_in / 64;
    let mut lanes = [0f32; 8];
    for wi in 0..full {
        let w = words[2 * wi] as u64 | (words[2 * wi + 1] as u64) << 32;
        let v64: &[f32; 64] = vals[wi * 64..wi * 64 + 64].try_into().unwrap();
        for c in 0..8 {
            for l in 0..8 {
                let b = c * 8 + l;
                let flip = ((((w >> b) as u32) & 1) ^ 1) << 31;
                lanes[l] += f32::from_bits(v64[b].to_bits() ^ flip);
            }
        }
    }
    let mut acc = lanes.iter().sum::<f32>();
    for i in full * 64..d_in {
        let w = words[i / 32];
        acc += f32::from_bits(vals[i].to_bits() ^ ((((w >> (i % 32)) & 1) ^ 1) << 31));
    }
    acc
}

/// AVX2 wide path: for each u32 mask word, four 8-lane blocks derive their
/// ±sign masks straight from the word (`srlv` by lane index, XOR against 1,
/// shift into the sign bit) and XOR them onto the loaded values — eight
/// signed accumulations per instruction, no unpacking to ±1.0 floats.
///
/// # Safety
///
/// Callers must have verified AVX2 support (`is_x86_feature_detected!`)
/// before dispatching here; all loads are `loadu` so alignment is free.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn signed_sum_avx2(vals: &[f32], words: &[u32]) -> f32 {
    use std::arch::x86_64::*;
    let d_in = vals.len();
    let full = d_in / 32;
    let lane_idx = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
    let one = _mm256_set1_epi32(1);
    let mut acc = _mm256_setzero_ps();
    for wi in 0..full {
        let w = _mm256_set1_epi32(words[wi] as i32);
        for c in 0..4 {
            let sh = _mm256_add_epi32(lane_idx, _mm256_set1_epi32((c * 8) as i32));
            let bit = _mm256_and_si256(_mm256_srlv_epi32(w, sh), one);
            let flip = _mm256_slli_epi32(_mm256_xor_si256(bit, one), 31);
            let v = _mm256_loadu_ps(vals.as_ptr().add(wi * 32 + c * 8));
            acc = _mm256_add_ps(acc, _mm256_xor_ps(v, _mm256_castsi256_ps(flip)));
        }
    }
    let mut buf = [0f32; 8];
    _mm256_storeu_ps(buf.as_mut_ptr(), acc);
    let mut s: f32 = buf.iter().sum();
    for i in full * 32..d_in {
        let w = words[i / 32];
        s += f32::from_bits(vals[i].to_bits() ^ ((((w >> (i % 32)) & 1) ^ 1) << 31));
    }
    s
}

/// Add the packed-delta term `v ⊙ (x·Bᵀ)` of `m` for rows `rows` of `x`
/// into the same rows of `y`, which already hold the base GEMM result —
/// the per-variant half of a batched shared-base forward
/// ([`BatchPlan`](super::BatchPlan)).
///
/// Each output element gets exactly one `+=` of the delta term, so
/// `base + delta` lands with the same rounding as the single-expression
/// fused path in [`FusedDeltaLinear`]; the batched property tests rely on
/// that bitwise equality.
pub fn add_delta_rows(m: &DeltaModule, x: &Tensor2, y: &mut Tensor2, rows: std::ops::Range<usize>) {
    let (d_out, d_in) = (m.d_out(), m.d_in());
    assert_eq!(x.cols, d_in, "input dim mismatch for {}", m.id);
    assert_eq!(y.cols, d_out, "output dim mismatch for {}", m.id);
    assert!(rows.end <= x.rows && x.rows == y.rows, "row slice out of range");
    if rows.is_empty() {
        return;
    }
    let n_rows = rows.end - rows.start;
    // Second traversal of the activation rows (the base GEMM already read
    // them once) — the per-variant half of the two-pass batched path.
    counters::record_act_row_reads((n_rows * d_out) as u64);
    let y_slice = &mut y.data[rows.start * d_out..rows.end * d_out];
    match m.axis {
        Axis::Col => {
            par::parallel_rows_mut(y_slice, n_rows, d_out, 8, |row0, chunk| {
                let mut z = vec![0f32; d_in]; // v ⊙ x, reused across rows
                let mut t = lowrank_scratch(m);
                for (ri, yrow) in chunk.chunks_mut(d_out).enumerate() {
                    let xrow = x.row(rows.start + row0 + ri);
                    for ((zi, &xi), &vi) in z.iter_mut().zip(xrow).zip(&m.scales) {
                        *zi = vi * xi;
                    }
                    for (j, o) in yrow.iter_mut().enumerate() {
                        *o += signed_sum(&z, m.mask.row_words(j));
                    }
                    add_lowrank_row(m, xrow, yrow, &mut t);
                }
            });
        }
        _ => {
            par::parallel_rows_mut(y_slice, n_rows, d_out, 8, |row0, chunk| {
                let mut t = lowrank_scratch(m);
                for (ri, yrow) in chunk.chunks_mut(d_out).enumerate() {
                    let xrow = x.row(rows.start + row0 + ri);
                    for (j, o) in yrow.iter_mut().enumerate() {
                        *o += m.scale_at(j, 0) * signed_sum(xrow, m.mask.row_words(j));
                    }
                    add_lowrank_row(m, xrow, yrow, &mut t);
                }
            });
        }
    }
}

/// Closed enum over the two backends so call sites get static dispatch
/// without naming lifetimes in trait objects.
pub enum AnyLinear<'a> {
    Dense(DenseLinear<'a>),
    Fused(FusedDeltaLinear<'a>),
}

impl LinearOp for AnyLinear<'_> {
    fn d_out(&self) -> usize {
        match self {
            AnyLinear::Dense(l) => l.d_out(),
            AnyLinear::Fused(l) => l.d_out(),
        }
    }

    fn d_in(&self) -> usize {
        match self {
            AnyLinear::Dense(l) => l.d_in(),
            AnyLinear::Fused(l) => l.d_in(),
        }
    }

    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2) {
        match self {
            AnyLinear::Dense(l) => l.forward_into(x, y),
            AnyLinear::Fused(l) => l.forward_into(x, y),
        }
    }

    fn resident_bytes(&self) -> u64 {
        match self {
            AnyLinear::Dense(l) => l.resident_bytes(),
            AnyLinear::Fused(l) => l.resident_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Codec, CodecKind, LowRank};
    use crate::model::{ModuleId, ProjKind};
    use crate::util::rng::Rng;

    fn mk_module(d_out: usize, d_in: usize, axis: Axis, seed: u64) -> (Vec<f32>, DeltaModule) {
        let mut r = Rng::new(seed);
        let base: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let delta: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 0.1)).collect();
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let scales: Vec<f32> =
            (0..axis.n_scales(d_out, d_in)).map(|_| r.uniform_in(0.01, 0.2)).collect();
        let m = DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::Q },
            mask,
            axis,
            scales,
            codec: Codec::PerAxis,
        };
        (base, m)
    }

    /// `mk_module` re-tagged under `codec`; low-rank gets random factors.
    fn mk_module_codec(
        d_out: usize,
        d_in: usize,
        codec: CodecKind,
        seed: u64,
    ) -> (Vec<f32>, DeltaModule) {
        let axis = if codec == CodecKind::Scalar { Axis::Scalar } else { Axis::Row };
        let (base, mut m) = mk_module(d_out, d_in, axis, seed);
        let mut r = Rng::new(seed ^ 0x5eed);
        m.codec = match codec {
            CodecKind::PerAxis => Codec::PerAxis,
            CodecKind::Scalar => Codec::Scalar,
            CodecKind::LowRank => {
                let rank = 3.min(d_out).min(d_in);
                Codec::LowRank(LowRank {
                    rank,
                    a: (0..rank * d_in).map(|_| r.normal_f32(0.0, 0.05)).collect(),
                    b: (0..d_out * rank).map(|_| r.normal_f32(0.0, 0.05)).collect(),
                })
            }
        };
        (base, m)
    }

    fn rand_x(r: &mut Rng, n: usize, d_in: usize) -> Tensor2 {
        let mut x = Tensor2::zeros(n, d_in);
        r.fill_normal(&mut x.data, 1.0);
        x
    }

    #[test]
    fn miri_signed_sum_u64_matches_reference() {
        // Pinned to the portable word path — no feature probe, no
        // intrinsics — so the XOR sign-flip trick runs under Miri (the
        // sanitizers CI lane filters on the miri_ name prefix). The
        // reference sums sequentially, so compare with a tolerance rather
        // than bitwise (the 8-lane accumulation associates differently).
        let mut r = Rng::new(77);
        for &d_in in &[1usize, 31, 32, 33, 64, 65, 100, 129] {
            let vals: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let words: Vec<u32> = (0..d_in.div_ceil(32)).map(|_| r.next_u32()).collect();
            let want: f64 = (0..d_in)
                .map(|i| {
                    let sign = if (words[i / 32] >> (i % 32)) & 1 == 1 { 1.0 } else { -1.0 };
                    sign * vals[i] as f64
                })
                .sum();
            let got = signed_sum_u64(&vals, &words) as f64;
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "d_in {d_in}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn dense_linear_matches_matmul_bt() {
        let mut r = Rng::new(11);
        for &(n, d_out, d_in) in &[(1, 1, 1), (3, 5, 33), (7, 16, 64), (4, 17, 100)] {
            let w: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let x = rand_x(&mut r, n, d_in);
            let want = x.matmul_bt(&Tensor2::from_vec(d_out, d_in, w.clone()));
            let got = DenseLinear::new(&w, d_out, d_in).forward(&x);
            assert_eq!(got.data, want.data, "shape {n}x{d_out}x{d_in}");
        }
    }

    #[test]
    fn fused_matches_materialize_then_gemm_all_axes() {
        for (k, axis) in
            [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)].into_iter().enumerate()
        {
            // Odd d_in values cover partial mask words (33, 100) alongside
            // exact multiples (32, 64).
            for &(n, d_out, d_in) in &[(1, 1, 1), (5, 7, 33), (3, 8, 32), (6, 13, 100), (2, 9, 64)]
            {
                let (base, m) = mk_module(d_out, d_in, axis, 31 + k as u64 * 7 + d_in as u64);
                let mut r = Rng::new(900 + k as u64);
                let x = rand_x(&mut r, n, d_in);
                let mut dense = vec![0f32; base.len()];
                crate::delta::apply::apply_module_into(&base, &mut dense, &m);
                let want = x.matmul_bt(&Tensor2::from_vec(d_out, d_in, dense));
                let got = FusedDeltaLinear::new(&base, &m).forward(&x);
                for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
                    let tol = 1e-5 * (1.0 + w.abs());
                    assert!(
                        (g - w).abs() <= tol,
                        "axis {axis:?} shape {n}x{d_out}x{d_in} idx {i}: {g} vs {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_matches_materialize_then_gemm_every_codec() {
        // The per-codec half of the execute contract: for each codec,
        // running the fused path must agree with densifying the module
        // (apply path) and running a plain GEMM.
        for (k, codec) in CodecKind::ALL.into_iter().enumerate() {
            for &(n, d_out, d_in) in &[(1, 1, 1), (5, 7, 33), (3, 8, 32), (6, 13, 100)] {
                let (base, m) = mk_module_codec(d_out, d_in, codec, 400 + k as u64);
                let mut r = Rng::new(4400 + k as u64);
                let x = rand_x(&mut r, n, d_in);
                let mut dense = vec![0f32; base.len()];
                crate::delta::apply::apply_module_into(&base, &mut dense, &m);
                let want = x.matmul_bt(&Tensor2::from_vec(d_out, d_in, dense));
                let got = FusedDeltaLinear::new(&base, &m).forward(&x);
                for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
                    let tol = 1e-5 * (1.0 + w.abs());
                    assert!(
                        (g - w).abs() <= tol,
                        "codec {} shape {n}x{d_out}x{d_in} idx {i}: {g} vs {w}",
                        codec.label()
                    );
                }
            }
        }
    }

    #[test]
    fn add_delta_rows_matches_fused_rows_bitwise_every_codec() {
        for (k, codec) in CodecKind::ALL.into_iter().enumerate() {
            let (d_out, d_in) = (9, 100);
            let (base, m) = mk_module_codec(d_out, d_in, codec, 520 + k as u64);
            let mut r = Rng::new(5200 + k as u64);
            let x = rand_x(&mut r, 6, d_in);
            let mut y = DenseLinear::new(&base, d_out, d_in).forward(&x);
            let base_only = y.clone();
            add_delta_rows(&m, &x, &mut y, 2..5);
            let fused = FusedDeltaLinear::new(&base, &m).forward(&x);
            for t in 0..6 {
                for j in 0..d_out {
                    let got = y.at(t, j);
                    let want =
                        if (2..5).contains(&t) { fused.at(t, j) } else { base_only.at(t, j) };
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "codec {} row {t} col {j}: {got} vs {want}",
                        codec.label()
                    );
                }
            }
        }
    }

    #[test]
    fn lowrank_term_never_densifies_and_charges_residency() {
        let (base, m) = mk_module_codec(64, 256, CodecKind::LowRank, 9);
        let fused = FusedDeltaLinear::new(&base, &m);
        let lr = m.lowrank().unwrap();
        // Residency: packed mask + scales + f32 factors, still ≪ dense.
        let factor_bytes = ((lr.a.len() + lr.b.len()) * 4) as u64;
        assert_eq!(
            fused.resident_bytes(),
            m.mask.n_bytes() + (m.scales.len() * 4) as u64 + factor_bytes
        );
        assert!(fused.resident_bytes() * 4 < (base.len() * 4) as u64);
    }

    #[test]
    fn signed_sum_matches_scalar_reference() {
        let mut r = Rng::new(5);
        for d_in in [1usize, 31, 32, 33, 64, 65, 100] {
            let delta: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, 1, d_in);
            let vals: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let want: f32 =
                vals.iter().enumerate().map(|(i, &v)| v * mask.sign(0, i)).sum();
            let got = signed_sum(&vals, mask.row_words(0));
            assert!((got - want).abs() < 1e-4, "d_in {d_in}: {got} vs {want}");
        }
    }

    #[test]
    fn signed_sum_word_path_matches_reference_on_ragged_columns() {
        // The u64 word path folds two mask words at a time; ragged
        // (non-multiple-of-64) columns exercise every tail shape, including
        // the one-full-u32-word-plus-bits case (96, 100) and sub-word rows.
        let mut r = Rng::new(29);
        for d_in in [1usize, 7, 31, 32, 33, 63, 64, 65, 96, 100, 127, 128, 129, 200] {
            let delta: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, 1, d_in);
            let vals: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let want: f32 = vals.iter().enumerate().map(|(i, &v)| v * mask.sign(0, i)).sum();
            let tol = 1e-4 * (1.0 + want.abs());
            let word = signed_sum_u64(&vals, mask.row_words(0));
            assert!((word - want).abs() < tol, "u64 path d_in {d_in}: {word} vs {want}");
            // The dispatched path (AVX2 where available) must agree with the
            // portable word path to reassociation noise.
            let disp = signed_sum(&vals, mask.row_words(0));
            assert!((disp - word).abs() < tol, "dispatch d_in {d_in}: {disp} vs {word}");
        }
    }

    #[test]
    fn fused_kernel_is_bitwise_equal_to_two_pass_reductions() {
        // Every tail shape: sub-8, sub-32 (u64/AVX2 cutoff), one-u32-word,
        // ragged u64 folds, and exact multiples.
        let mut r = Rng::new(41);
        for d_in in [1usize, 7, 8, 31, 32, 33, 63, 64, 65, 96, 100, 127, 128, 129, 200] {
            let delta: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, 1, d_in);
            let x: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let z: Vec<f32> = x.iter().map(|&v| 0.13 * v).collect();
            // Row-constant axes: signed sum over the activation row itself.
            let (df, sf) = fused_dot_ssum(&x, &w, &x, mask.row_words(0));
            assert_eq!(df.to_bits(), dot(&x, &w).to_bits(), "dot d_in {d_in}");
            assert_eq!(
                sf.to_bits(),
                signed_sum(&x, mask.row_words(0)).to_bits(),
                "ssum d_in {d_in}"
            );
            // Col axis: signed sum over a separately scaled source.
            let (dz, sz) = fused_dot_ssum(&x, &w, &z, mask.row_words(0));
            assert_eq!(dz.to_bits(), dot(&x, &w).to_bits(), "dot/z d_in {d_in}");
            assert_eq!(
                sz.to_bits(),
                signed_sum(&z, mask.row_words(0)).to_bits(),
                "ssum/z d_in {d_in}"
            );
        }
    }

    #[test]
    fn fused_forward_reads_activation_rows_once_not_twice() {
        let (d_out, d_in) = (9, 100);
        let (base, m) = mk_module(d_out, d_in, Axis::Row, 77);
        let mut r = Rng::new(78);
        let x = rand_x(&mut r, 4, d_in);
        // Counters are process-global and tests run concurrently, so assert
        // deltas as lower bounds only (the bench does the strict single-pass
        // < two-pass comparison in a process it controls).
        let t0 = counters::activation_row_reads();
        let _ = FusedDeltaLinear::new(&base, &m).forward(&x);
        let t1 = counters::activation_row_reads();
        assert!(t1 - t0 >= (4 * d_out) as u64, "fused pass must record row reads");
        let mut y = DenseLinear::new(&base, d_out, d_in).forward(&x);
        add_delta_rows(&m, &x, &mut y, 0..4);
        let t2 = counters::activation_row_reads();
        assert!(t2 - t1 >= (2 * 4 * d_out) as u64, "two-pass path must record both passes");
    }

    #[test]
    fn add_delta_rows_matches_fused_rows_bitwise() {
        for (k, axis) in
            [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)].into_iter().enumerate()
        {
            let (d_out, d_in) = (9, 100); // ragged: partial mask words
            let (base, m) = mk_module(d_out, d_in, axis, 61 + k as u64);
            let mut r = Rng::new(700 + k as u64);
            let x = rand_x(&mut r, 6, d_in);
            // y starts as the base GEMM for every row; the delta term is then
            // added only to rows 2..5.
            let mut y = DenseLinear::new(&base, d_out, d_in).forward(&x);
            let base_only = y.clone();
            add_delta_rows(&m, &x, &mut y, 2..5);
            let fused = FusedDeltaLinear::new(&base, &m).forward(&x);
            for t in 0..6 {
                for j in 0..d_out {
                    let got = y.at(t, j);
                    let want =
                        if (2..5).contains(&t) { fused.at(t, j) } else { base_only.at(t, j) };
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "axis {axis:?} row {t} col {j}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_residency_is_packed_not_dense() {
        let (base, m) = mk_module(64, 256, Axis::Row, 1);
        let fused = FusedDeltaLinear::new(&base, &m);
        let dense_bytes = (base.len() * 4) as u64;
        // 1 bit/entry + 64 f32 scales ≪ 4 bytes/entry.
        assert!(fused.resident_bytes() * 8 < dense_bytes);
    }
}
