//! `LinearOp` backends: dense GEMM vs fused packed-delta GEMM.
//!
//! The fused backend is the paper's "maintain inference efficiency by
//! avoiding dense reconstruction" claim made concrete: for a packed delta
//! `Ŵ = W_b + v ⊙ B` the projection
//!
//! ```text
//! y = x · Ŵᵀ = x · W_bᵀ + (v ⊙ B applied to x)
//! ```
//!
//! is computed straight from the `PackedMask` bitplane, one mask word at a
//! time, with the same branchless IEEE sign-injection trick the apply path
//! uses (`±x` differ only in the sign bit). The dense `Ŵ` never exists:
//!
//! * Row/Scalar/Group axes: the scale is constant along a mask row, so the
//!   delta term is `v_j · Σ_i sign(j,i)·x[t,i]` — one signed reduction of
//!   the activation row per (token, output-row) pair.
//! * Col axis: the scale varies along the row, so `z = v ⊙ x[t]` is formed
//!   once per token and the delta term is `Σ_i sign(j,i)·z_i`.

use crate::delta::types::{Axis, DeltaModule};
use crate::tensor::{dot, Tensor2};
use crate::util::par;

/// A linear operator `y = x · Wᵀ` (`x: [n, d_in] → y: [n, d_out]`), abstract
/// over how `W` is resident: dense f32 rows or base + packed 1-bit delta.
pub trait LinearOp {
    fn d_out(&self) -> usize;
    fn d_in(&self) -> usize;

    /// `y = x · Wᵀ` into a preallocated output.
    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2);

    /// Allocating convenience wrapper around [`LinearOp::forward_into`].
    fn forward(&self, x: &Tensor2) -> Tensor2 {
        let mut y = Tensor2::zeros(x.rows, self.d_out());
        self.forward_into(x, &mut y);
        y
    }

    /// Bytes that must stay resident to execute this op, *excluding* any
    /// storage shared with other ops (the base checkpoint is charged once by
    /// the variant cache, not per module).
    fn resident_bytes(&self) -> u64;
}

/// Dense backend: borrows a row-major `[d_out, d_in]` weight slice (a view
/// into `FlatParams`) and runs the same row-parallel dot-product GEMM as
/// `Tensor2::matmul_bt`, without copying the weights into a `Tensor2`.
pub struct DenseLinear<'a> {
    w: &'a [f32],
    d_out: usize,
    d_in: usize,
}

impl<'a> DenseLinear<'a> {
    pub fn new(w: &'a [f32], d_out: usize, d_in: usize) -> DenseLinear<'a> {
        assert_eq!(w.len(), d_out * d_in, "weight slice/shape mismatch");
        DenseLinear { w, d_out, d_in }
    }
}

impl LinearOp for DenseLinear<'_> {
    fn d_out(&self) -> usize {
        self.d_out
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2) {
        assert_eq!(x.cols, self.d_in, "input dim mismatch");
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out), "output shape mismatch");
        let (k, m) = (self.d_in, self.d_out);
        let a = &x.data;
        let w = self.w;
        par::parallel_rows_mut(&mut y.data, x.rows, m, 8, |row0, chunk| {
            for (ri, yrow) in chunk.chunks_mut(m).enumerate() {
                let xrow = &a[(row0 + ri) * k..(row0 + ri + 1) * k];
                for (j, o) in yrow.iter_mut().enumerate() {
                    *o = dot(xrow, &w[j * k..(j + 1) * k]);
                }
            }
        });
    }

    fn resident_bytes(&self) -> u64 {
        (self.w.len() * 4) as u64
    }
}

/// Fused backend: executes `y = x·W_bᵀ + x·(v ⊙ B)ᵀ` directly from the
/// packed bitplane — the base weights stay shared and the per-variant
/// residency is just the mask words plus the scale vector.
pub struct FusedDeltaLinear<'a> {
    base: &'a [f32],
    module: &'a DeltaModule,
}

impl<'a> FusedDeltaLinear<'a> {
    pub fn new(base: &'a [f32], module: &'a DeltaModule) -> FusedDeltaLinear<'a> {
        assert_eq!(
            base.len(),
            module.d_out() * module.d_in(),
            "base slice/delta shape mismatch for {}",
            module.id
        );
        FusedDeltaLinear { base, module }
    }
}

impl LinearOp for FusedDeltaLinear<'_> {
    fn d_out(&self) -> usize {
        self.module.d_out()
    }

    fn d_in(&self) -> usize {
        self.module.d_in()
    }

    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2) {
        let m = self.module;
        let (d_out, d_in) = (m.d_out(), m.d_in());
        assert_eq!(x.cols, d_in, "input dim mismatch");
        assert_eq!((y.rows, y.cols), (x.rows, d_out), "output shape mismatch");
        let base = self.base;
        match m.axis {
            Axis::Col => {
                par::parallel_rows_mut(&mut y.data, x.rows, d_out, 8, |row0, chunk| {
                    let mut z = vec![0f32; d_in]; // v ⊙ x, reused across rows
                    for (ri, yrow) in chunk.chunks_mut(d_out).enumerate() {
                        let xrow = x.row(row0 + ri);
                        for ((zi, &xi), &vi) in z.iter_mut().zip(xrow).zip(&m.scales) {
                            *zi = vi * xi;
                        }
                        for (j, o) in yrow.iter_mut().enumerate() {
                            *o = dot(xrow, &base[j * d_in..(j + 1) * d_in])
                                + signed_sum(&z, m.mask.row_words(j));
                        }
                    }
                });
            }
            _ => {
                // Row / Scalar / Group: scale constant within each mask row
                // (scale_at ignores the column index for these axes).
                par::parallel_rows_mut(&mut y.data, x.rows, d_out, 8, |row0, chunk| {
                    for (ri, yrow) in chunk.chunks_mut(d_out).enumerate() {
                        let xrow = x.row(row0 + ri);
                        for (j, o) in yrow.iter_mut().enumerate() {
                            *o = dot(xrow, &base[j * d_in..(j + 1) * d_in])
                                + m.scale_at(j, 0) * signed_sum(xrow, m.mask.row_words(j));
                        }
                    }
                });
            }
        }
    }

    fn resident_bytes(&self) -> u64 {
        self.module.resident_bytes()
    }
}

/// `Σ_i sign_i · vals[i]` where `sign_i` is bit `i` of the packed row
/// (1 → +1, 0 → −1). Word-at-a-time: full 32-bit words run a constant-bound
/// inner loop over fixed-size chunks (vectorizes, same trick as
/// `delta::apply`), the final partial word is handled separately.
#[inline]
fn signed_sum(vals: &[f32], words: &[u32]) -> f32 {
    let d_in = vals.len();
    let full = d_in / 32;
    let mut acc = 0f32;
    for wi in 0..full {
        let w = words[wi];
        let v32: &[f32; 32] = vals[wi * 32..wi * 32 + 32].try_into().unwrap();
        let mut s = 0f32;
        for b in 0..32 {
            s += f32::from_bits(v32[b].to_bits() ^ ((((w >> b) & 1) ^ 1) << 31));
        }
        acc += s;
    }
    for b in 0..d_in - full * 32 {
        let i = full * 32 + b;
        acc += f32::from_bits(vals[i].to_bits() ^ ((((words[full] >> b) & 1) ^ 1) << 31));
    }
    acc
}

/// Closed enum over the two backends so call sites get static dispatch
/// without naming lifetimes in trait objects.
pub enum AnyLinear<'a> {
    Dense(DenseLinear<'a>),
    Fused(FusedDeltaLinear<'a>),
}

impl LinearOp for AnyLinear<'_> {
    fn d_out(&self) -> usize {
        match self {
            AnyLinear::Dense(l) => l.d_out(),
            AnyLinear::Fused(l) => l.d_out(),
        }
    }

    fn d_in(&self) -> usize {
        match self {
            AnyLinear::Dense(l) => l.d_in(),
            AnyLinear::Fused(l) => l.d_in(),
        }
    }

    fn forward_into(&self, x: &Tensor2, y: &mut Tensor2) {
        match self {
            AnyLinear::Dense(l) => l.forward_into(x, y),
            AnyLinear::Fused(l) => l.forward_into(x, y),
        }
    }

    fn resident_bytes(&self) -> u64 {
        match self {
            AnyLinear::Dense(l) => l.resident_bytes(),
            AnyLinear::Fused(l) => l.resident_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::model::{ModuleId, ProjKind};
    use crate::util::rng::Rng;

    fn mk_module(d_out: usize, d_in: usize, axis: Axis, seed: u64) -> (Vec<f32>, DeltaModule) {
        let mut r = Rng::new(seed);
        let base: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let delta: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 0.1)).collect();
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let scales: Vec<f32> =
            (0..axis.n_scales(d_out, d_in)).map(|_| r.uniform_in(0.01, 0.2)).collect();
        (base, DeltaModule { id: ModuleId { layer: 0, kind: ProjKind::Q }, mask, axis, scales })
    }

    fn rand_x(r: &mut Rng, n: usize, d_in: usize) -> Tensor2 {
        let mut x = Tensor2::zeros(n, d_in);
        r.fill_normal(&mut x.data, 1.0);
        x
    }

    #[test]
    fn dense_linear_matches_matmul_bt() {
        let mut r = Rng::new(11);
        for &(n, d_out, d_in) in &[(1, 1, 1), (3, 5, 33), (7, 16, 64), (4, 17, 100)] {
            let w: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let x = rand_x(&mut r, n, d_in);
            let want = x.matmul_bt(&Tensor2::from_vec(d_out, d_in, w.clone()));
            let got = DenseLinear::new(&w, d_out, d_in).forward(&x);
            assert_eq!(got.data, want.data, "shape {n}x{d_out}x{d_in}");
        }
    }

    #[test]
    fn fused_matches_materialize_then_gemm_all_axes() {
        for (k, axis) in
            [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)].into_iter().enumerate()
        {
            // Odd d_in values cover partial mask words (33, 100) alongside
            // exact multiples (32, 64).
            for &(n, d_out, d_in) in &[(1, 1, 1), (5, 7, 33), (3, 8, 32), (6, 13, 100), (2, 9, 64)]
            {
                let (base, m) = mk_module(d_out, d_in, axis, 31 + k as u64 * 7 + d_in as u64);
                let mut r = Rng::new(900 + k as u64);
                let x = rand_x(&mut r, n, d_in);
                let mut dense = vec![0f32; base.len()];
                crate::delta::apply::apply_module_into(&base, &mut dense, &m);
                let want = x.matmul_bt(&Tensor2::from_vec(d_out, d_in, dense));
                let got = FusedDeltaLinear::new(&base, &m).forward(&x);
                for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
                    let tol = 1e-5 * (1.0 + w.abs());
                    assert!(
                        (g - w).abs() <= tol,
                        "axis {axis:?} shape {n}x{d_out}x{d_in} idx {i}: {g} vs {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn signed_sum_matches_scalar_reference() {
        let mut r = Rng::new(5);
        for d_in in [1usize, 31, 32, 33, 64, 65, 100] {
            let delta: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, 1, d_in);
            let vals: Vec<f32> = (0..d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let want: f32 =
                vals.iter().enumerate().map(|(i, &v)| v * mask.sign(0, i)).sum();
            let got = signed_sum(&vals, mask.row_words(0));
            assert!((got - want).abs() < 1e-4, "d_in {d_in}: {got} vs {want}");
        }
    }

    #[test]
    fn fused_residency_is_packed_not_dense() {
        let (base, m) = mk_module(64, 256, Axis::Row, 1);
        let fused = FusedDeltaLinear::new(&base, &m);
        let dense_bytes = (base.len() * 4) as u64;
        // 1 bit/entry + 64 f32 scales ≪ 4 bytes/entry.
        assert!(fused.resident_bytes() * 8 < dense_bytes);
    }
}
