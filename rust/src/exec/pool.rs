//! Persistent intra-host compute pool for the serving hot path.
//!
//! The data-parallel helpers in [`par`](crate::util::par) used to spawn
//! scoped threads per call; at serving granularity (one GEMM per module per
//! window) that spawn cost is paid hundreds of times per request. This
//! module keeps one process-wide set of workers parked on a condvar and
//! hands them *jobs*: a chunked range `0..n` claimed dynamically through an
//! atomic cursor, so uneven chunks load-balance without any per-call thread
//! creation.
//!
//! **Determinism contract.** The pool only changes *who* executes a chunk,
//! never what a chunk computes: callers must keep every reduction inside a
//! single chunk-invocation (parallelize across output rows / row slices /
//! sequences, never across the elements of one accumulation). Under that
//! contract parallel output is bitwise-equal to serial output at any thread
//! count — the property tests in `tests/engine_parallel.rs` assert it.
//!
//! **Thread knobs.** The default width comes from `PAWD_COMPUTE_THREADS`
//! (falling back to the machine parallelism); [`set_thread_limit`] /
//! [`with_thread_limit`] override it per thread (the serving workers apply
//! `ServerConfig::n_compute_threads` this way). A limit of 1 bypasses the
//! pool entirely — the chunk closure runs inline on the caller.

use super::counters;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// One published unit of pool work: `f` over the chunked range `0..n`.
///
/// The closure pointer is lifetime-erased so the job can be shared with
/// long-lived workers; soundness is the claim protocol below — `f` is only
/// ever dereferenced for a chunk index below `n_chunks`, and the publishing
/// caller does not return (and so does not drop `f`) until `pending` hits
/// zero, after which every later claim falls off the end of the range.
struct Job {
    f: *const (dyn Fn(usize, usize) + Sync),
    n: usize,
    chunk: usize,
    n_chunks: usize,
    /// Next chunk index to claim.
    next: AtomicUsize,
    /// Chunks claimed but not yet completed + chunks never claimed.
    pending: AtomicUsize,
    /// Max threads that may execute this job, *including* the caller.
    max_workers: usize,
    /// Pool workers that have joined this job.
    joined: AtomicUsize,
    done_m: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: the raw closure pointer is only dereferenced under the claim
// protocol documented on `Job`; everything else in the struct is Sync.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct State {
    job: Option<Arc<Job>>,
    /// Bumped on every publish so parked workers can tell a new job from
    /// the one they already consumed.
    generation: u64,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// A persistent worker pool. Most callers want the process-wide
/// [`global`] pool; constructing one directly is for tests.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `capacity` parked worker threads (callers always
    /// participate too, so peak parallelism is `capacity + 1`).
    pub fn new(capacity: usize) -> Pool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, generation: 0 }),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..capacity)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("pawd-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { shared, workers }
    }

    /// Run `f(lo, hi)` over disjoint chunks covering `0..n`, on up to
    /// `threads` threads (caller included), each chunk at least
    /// `min_per_chunk` items when the range allows. Blocks until every
    /// chunk has completed. `threads <= 1` (or a single chunk) runs inline.
    pub fn run<F>(&self, n: usize, threads: usize, min_per_chunk: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        // Over-chunk by 4x relative to the thread budget so uneven chunk
        // costs load-balance through the shared cursor.
        let chunk = min_per_chunk.max(n.div_ceil(threads.max(1) * 4)).max(1);
        let n_chunks = n.div_ceil(chunk);
        if threads <= 1 || n_chunks <= 1 {
            f(0, n);
            return;
        }
        let fobj: &(dyn Fn(usize, usize) + Sync) = &f;
        // SAFETY: lifetime erasure only; see the claim protocol on `Job`.
        let fptr: *const (dyn Fn(usize, usize) + Sync) = unsafe { std::mem::transmute(fobj) };
        let job = Arc::new(Job {
            f: fptr,
            n,
            chunk,
            n_chunks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_chunks),
            max_workers: threads,
            joined: AtomicUsize::new(0),
            done_m: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job.clone());
            st.generation += 1;
            self.shared.work_cv.notify_all();
        }
        // The caller always participates, so the job completes even when
        // every pool worker is busy elsewhere (this is also what makes
        // nested `run` calls deadlock-free).
        work_on(&job);
        let mut g = job.done_m.lock().unwrap();
        while job.pending.load(Ordering::Acquire) != 0 {
            g = job.done_cv.wait(g).unwrap();
        }
        drop(g);
        // Retract the slot if no newer job replaced it, so parked workers
        // do not keep the finished job's Arc alive.
        let mut st = self.shared.state.lock().unwrap();
        if st.job.as_ref().is_some_and(|cur| Arc::ptr_eq(cur, &job)) {
            st.job = None;
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        {
            let _st = self.shared.state.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                if st.generation != seen {
                    seen = st.generation;
                    if let Some(j) = &st.job {
                        break j.clone();
                    }
                    continue;
                }
                let parked = Instant::now();
                st = shared.work_cv.wait(st).unwrap();
                counters::record_pool_idle_ns(parked.elapsed().as_nanos() as u64);
            }
        };
        // Honor the job's thread budget: late workers beyond it skip the
        // job (their generation is already consumed, so they re-park).
        if job.joined.fetch_add(1, Ordering::Relaxed) + 1 < job.max_workers {
            work_on(&job);
        }
    }
}

/// Claim and execute chunks of `job` until the cursor runs off the end.
fn work_on(job: &Job) {
    loop {
        let c = job.next.fetch_add(1, Ordering::Relaxed);
        if c >= job.n_chunks {
            return;
        }
        let lo = c * job.chunk;
        let hi = ((c + 1) * job.chunk).min(job.n);
        counters::record_pool_task();
        // SAFETY: `c < n_chunks`, so the publishing caller is still inside
        // `run` and `f` is alive (it cannot observe `pending == 0` before
        // this chunk's decrement below).
        unsafe { (*job.f)(lo, hi) };
        if job.pending.fetch_sub(1, Ordering::Release) == 1 {
            let _g = job.done_m.lock().unwrap();
            job.done_cv.notify_all();
        }
    }
}

/// The process-wide pool. Sized at `max(default_threads(), 4)` workers so
/// thread-limit property tests can exercise 4-way parallelism even on
/// small machines; idle workers cost only a parked thread each.
pub fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool::new(default_threads().max(4)))
}

/// Default compute width: `PAWD_COMPUTE_THREADS` if set (> 0), else the
/// machine parallelism. Read once per process.
pub fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("PAWD_COMPUTE_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

thread_local! {
    /// Per-thread override of the compute width; 0 = use the default.
    static LIMIT: Cell<usize> = const { Cell::new(0) };
}

/// The compute width in effect on this thread.
pub fn current_threads() -> usize {
    let l = LIMIT.with(|c| c.get());
    if l > 0 {
        l
    } else {
        default_threads()
    }
}

/// Set this thread's compute width (0 restores the default). The serving
/// workers call this with `ServerConfig::n_compute_threads` at startup.
pub fn set_thread_limit(n: usize) {
    LIMIT.with(|c| c.set(n));
}

/// Run `f` with this thread's compute width set to `n`, restoring the
/// previous limit afterwards (panic-safe).
pub fn with_thread_limit<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LIMIT.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(LIMIT.with(|c| c.get()));
    LIMIT.with(|c| c.set(n));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn miri_pool_threads_cover_range() {
        // Small enough to finish quickly under Miri's interpreter (the
        // sanitizers CI lane runs `miri test --lib -- miri_`), yet still
        // exercises the full claim protocol: the lifetime-erased job
        // pointer, the shared chunk cursor, and the condvar completion
        // handshake — exactly the unsafe surface the golden inventory pins.
        let pool = Pool::new(2);
        let sum = AtomicU64::new(0);
        pool.run(64, 2, 1, |lo, hi| {
            for i in lo..hi {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 64 * 65 / 2);
    }

    #[test]
    fn run_covers_range_exactly_once() {
        let pool = Pool::new(3);
        let sum = AtomicU64::new(0);
        pool.run(1000, 4, 1, |lo, hi| {
            let mut local = 0u64;
            for i in lo..hi {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn serial_threshold_runs_inline() {
        let pool = Pool::new(2);
        let calls = AtomicU64::new(0);
        pool.run(10, 1, 1, |lo, hi| {
            assert_eq!((lo, hi), (0, 10));
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_runs_complete() {
        let pool = Pool::new(2);
        let sum = AtomicU64::new(0);
        pool.run(8, 3, 1, |lo, hi| {
            for _ in lo..hi {
                // Nested job on the same pool: the inner caller
                // participates, so this cannot deadlock even with every
                // worker busy on the outer job.
                pool.run(16, 3, 1, |a, b| {
                    sum.fetch_add((b - a) as u64, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn zero_items_is_a_no_op() {
        let pool = Pool::new(1);
        pool.run(0, 4, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn thread_limit_scopes_and_restores() {
        let before = current_threads();
        let inside = with_thread_limit(3, current_threads);
        assert_eq!(inside, 3);
        assert_eq!(current_threads(), before);
        let nested = with_thread_limit(2, || with_thread_limit(5, current_threads));
        assert_eq!(nested, 5);
        assert_eq!(current_threads(), before);
    }

    #[test]
    fn global_pool_accepts_work() {
        let hits = AtomicU64::new(0);
        global().run(64, 4, 1, |lo, hi| {
            hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }
}
