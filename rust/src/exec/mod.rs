//! Execution backends: the `LinearOp` abstraction that lets every projection
//! in the forward pass run either dense (materialized `Ŵ`) or fused straight
//! from the packed 1-bit delta (`y = x·W_bᵀ + v ⊙ (x·Bᵀ)` without ever
//! reconstructing `Ŵ`).
//!
//! * [`linear`] — [`LinearOp`] trait, [`DenseLinear`], [`FusedDeltaLinear`]
//!   (word-at-a-time signed accumulation over the mask bitplane).
//! * [`weights`] — [`Weights`] sources: [`FlatParams`](crate::model::FlatParams)
//!   (dense), [`PackedVariant`] (base + packed delta), and the cache-facing
//!   [`VariantWeights`] with packed-byte residency accounting.
//!
//! The serving coordinator picks a backend per [`ExecMode`]; `Fused` is the
//! default and multiplies resident-variant capacity by the compression
//! ratio, because a cached variant is only mask words + scales.

pub mod linear;
pub mod weights;

pub use linear::{AnyLinear, DenseLinear, FusedDeltaLinear, LinearOp};
pub use weights::{ExecMode, PackedVariant, VariantWeights, Weights};
