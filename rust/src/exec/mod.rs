//! Execution backends: the `LinearOp` abstraction that lets every projection
//! in the forward pass run either dense (materialized `Ŵ`) or fused straight
//! from the packed 1-bit delta (`y = x·W_bᵀ + v ⊙ (x·Bᵀ)` without ever
//! reconstructing `Ŵ`).
//!
//! * [`linear`] — [`LinearOp`] trait, [`DenseLinear`], [`FusedDeltaLinear`]
//!   (u64-word / AVX2 signed accumulation over the mask bitplane) and the
//!   slice-wise [`linear::add_delta_rows`] mask reduction.
//! * [`batch`] — [`BatchPlan`]: batched multi-variant execution, one shared
//!   base GEMM per module for a whole mixed-variant batch with per-variant
//!   mask reductions on row slices.
//! * [`counters`] — global op counters (base GEMMs, pool tasks,
//!   activation-row reads, engine steps) the benches use to assert the
//!   shared-base and single-pass structure.
//! * [`prefix`] — the cross-window [`PrefixCache`]: byte-budgeted LRU of
//!   per-layer prefix activations keyed by weights identity + token-prefix
//!   hash, so identical prompt prefixes share GEMM work across windows and
//!   across variants (bitwise-equal to the cold path; `PAWD_PREFIX_CACHE=0`
//!   kill-switch).
//! * [`pool`] — the persistent intra-host compute pool behind
//!   [`par`](crate::util::par): dynamic chunk claiming over parked workers,
//!   width set by `PAWD_COMPUTE_THREADS` / `ServerConfig::n_compute_threads`
//!   and scoped per thread via [`pool::with_thread_limit`].
//! * [`weights`] — [`Weights`] sources: [`FlatParams`](crate::model::FlatParams)
//!   (dense), [`PackedVariant`] (base + packed delta), and the cache-facing
//!   [`VariantWeights`] with packed-byte residency accounting.
//!
//! The serving coordinator picks a backend per [`ExecMode`]; `Fused` is the
//! default and multiplies resident-variant capacity by the compression
//! ratio, because a cached variant is only mask words + scales.

pub mod batch;
pub mod counters;
pub mod linear;
pub mod pool;
pub mod prefix;
pub mod weights;

pub use batch::{BatchPlan, BatchSource, RowSpan, Uniform};
pub use linear::{signed_sum, AnyLinear, DenseLinear, FusedDeltaLinear, LinearOp};
pub use prefix::{PrefixCache, PrefixState};
pub use weights::{ExecMode, PackedVariant, VariantWeights, Weights};
