//! Global execution counters for the serving hot path.
//!
//! The batched-execution benches assert the headline structural claim —
//! "one base GEMM per module per batch, no matter how many variants or
//! requests ride in it" — by reading these counters around a forward pass.
//! A *base GEMM* is one pass of an activation tensor through a resident
//! weight matrix: every [`DenseLinear`](super::DenseLinear) or
//! [`FusedDeltaLinear`](super::FusedDeltaLinear) forward records one, and a
//! [`BatchPlan`](super::BatchPlan) module forward records one for the whole
//! stacked batch (its per-variant mask reductions are not GEMMs and are not
//! counted).
//!
//! Relaxed atomics: the counters are a measurement aid, never
//! synchronization. Absolute values are only meaningful when the caller
//! controls all execution in the process (single-threaded benches); tests
//! that may run concurrently should assert deltas with `>=` at most.

use std::sync::atomic::{AtomicU64, Ordering};

static BASE_GEMMS: AtomicU64 = AtomicU64::new(0);

/// Record one pass of activations through a resident base/dense weight
/// matrix.
pub(crate) fn record_base_gemm() {
    BASE_GEMMS.fetch_add(1, Ordering::Relaxed);
}

/// Total base GEMMs since process start (or the last [`reset`]).
pub fn base_gemms() -> u64 {
    BASE_GEMMS.load(Ordering::Relaxed)
}

/// Reset all counters to zero (benches/tests only).
pub fn reset() {
    BASE_GEMMS.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        // Other tests run concurrently in this process, so only a relative
        // lower bound is safe to assert.
        let before = base_gemms();
        record_base_gemm();
        record_base_gemm();
        assert!(base_gemms() >= before + 2);
    }
}
