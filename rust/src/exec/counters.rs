//! Global execution counters for the serving hot path.
//!
//! The batched-execution benches assert the headline structural claim —
//! "one base GEMM per module per batch, no matter how many variants or
//! requests ride in it" — by reading these counters around a forward pass.
//! A *base GEMM* is one pass of an activation tensor through a resident
//! weight matrix: every [`DenseLinear`](super::DenseLinear) or
//! [`FusedDeltaLinear`](super::FusedDeltaLinear) forward records one, and a
//! [`BatchPlan`](super::BatchPlan) module forward records one for the whole
//! stacked batch (its per-variant mask reductions are not GEMMs and are not
//! counted).
//!
//! Relaxed atomics: the counters are a measurement aid, never
//! synchronization. Absolute values are only meaningful when the caller
//! controls all execution in the process (single-threaded benches); tests
//! that may run concurrently should assert deltas with `>=` at most.

use std::sync::atomic::{AtomicU64, Ordering};

static BASE_GEMMS: AtomicU64 = AtomicU64::new(0);
static LOADER_BYTES: AtomicU64 = AtomicU64::new(0);
static MODULE_READS: AtomicU64 = AtomicU64::new(0);
static MODULES_INHERITED: AtomicU64 = AtomicU64::new(0);
static WIRE_BYTES: AtomicU64 = AtomicU64::new(0);
static WIRE_FILES: AtomicU64 = AtomicU64::new(0);
static POOL_TASKS: AtomicU64 = AtomicU64::new(0);
static POOL_IDLE_NS: AtomicU64 = AtomicU64::new(0);
static ENGINE_STEPS: AtomicU64 = AtomicU64::new(0);
static ACT_ROW_READS: AtomicU64 = AtomicU64::new(0);
static HTTP_REQUESTS: AtomicU64 = AtomicU64::new(0);
static HTTP_LONG_POLLS: AtomicU64 = AtomicU64::new(0);
static PREFIX_HITS: AtomicU64 = AtomicU64::new(0);
static PREFIX_MISSES: AtomicU64 = AtomicU64::new(0);
static PREFIX_BYTES: AtomicU64 = AtomicU64::new(0);
static PREFIX_ROWS_SKIPPED: AtomicU64 = AtomicU64::new(0);

/// Record one pass of activations through a resident base/dense weight
/// matrix.
pub(crate) fn record_base_gemm() {
    BASE_GEMMS.fetch_add(1, Ordering::Relaxed);
}

/// Record `n` artifact bytes read from disk by the delta loader (full
/// reads, header/index peeks and selective section reads all count).
pub(crate) fn record_loader_bytes(n: u64) {
    LOADER_BYTES.fetch_add(n, Ordering::Relaxed);
}

/// Record `n` module records decoded from disk.
pub(crate) fn record_module_reads(n: u64) {
    MODULE_READS.fetch_add(n, Ordering::Relaxed);
}

/// Record `n` modules inherited from an already-resident parent version
/// (chain composition reused the `Arc` instead of touching disk).
pub(crate) fn record_modules_inherited(n: u64) {
    MODULES_INHERITED.fetch_add(n, Ordering::Relaxed);
}

/// Record `n` bytes moved over a replication transport (manifest fetches
/// and artifact fetches both count — the replication bench asserts a
/// patch-aware sync ships a small fraction of the consolidated bytes
/// through this counter).
pub(crate) fn record_wire_bytes(n: u64) {
    WIRE_BYTES.fetch_add(n, Ordering::Relaxed);
}

/// Record one artifact file fetched over a replication transport.
pub(crate) fn record_wire_file() {
    WIRE_FILES.fetch_add(1, Ordering::Relaxed);
}

/// Record one chunk claimed and executed by the compute pool.
pub(crate) fn record_pool_task() {
    POOL_TASKS.fetch_add(1, Ordering::Relaxed);
}

/// Record `n` nanoseconds a pool worker spent parked waiting for work
/// (steal-or-idle time: the gap between jobs, a saturation signal).
pub(crate) fn record_pool_idle_ns(n: u64) {
    POOL_IDLE_NS.fetch_add(n, Ordering::Relaxed);
}

/// Record one engine step: one fair-share window admitted onto an idle
/// worker slot by the continuous-batching loop.
pub(crate) fn record_engine_step() {
    ENGINE_STEPS.fetch_add(1, Ordering::Relaxed);
}

/// Record `n` activation-row reads: one per (activation row × output row)
/// traversal of a resident weight matrix. The single-pass fused kernel
/// halves this against the two-pass base-then-delta path, and the bench
/// asserts that through this counter.
pub(crate) fn record_act_row_reads(n: u64) {
    ACT_ROW_READS.fetch_add(n, Ordering::Relaxed);
}

/// Record one HTTP request parsed and dispatched by the network plane
/// (data, admin, and sync routes all count; rejected frames that never
/// parse do not).
pub(crate) fn record_http_request() {
    HTTP_REQUESTS.fetch_add(1, Ordering::Relaxed);
}

/// Record one manifest long-poll that actually parked (the follower's
/// `known_seq` matched the current manifest, so the request waited for a
/// change or timed out instead of answering immediately).
pub(crate) fn record_http_long_poll() {
    HTTP_LONG_POLLS.fetch_add(1, Ordering::Relaxed);
}

/// Record `n` sequences that resumed from (or inserted into) the prefix
/// cache with a reusable entry.
pub(crate) fn record_prefix_hits(n: u64) {
    PREFIX_HITS.fetch_add(n, Ordering::Relaxed);
}

/// Record `n` cacheable prefixes that had to be computed cold.
pub(crate) fn record_prefix_misses(n: u64) {
    PREFIX_MISSES.fetch_add(n, Ordering::Relaxed);
}

/// Install the current prefix-cache resident byte total. This is a gauge,
/// not an accumulator: each [`PrefixCache`](super::prefix::PrefixCache)
/// stores its post-insert/evict total, so with several caches in one
/// process the value is last-writer-wins (a measurement aid, like every
/// counter here).
pub(crate) fn set_prefix_cache_bytes(n: u64) {
    PREFIX_BYTES.store(n, Ordering::Relaxed);
}

/// Record `n` stacked activation rows skipped because a cached prefix
/// supplied their K/V and logits (per layer work avoided is `n` rows of
/// every projection GEMM).
pub(crate) fn record_prefix_rows_skipped(n: u64) {
    PREFIX_ROWS_SKIPPED.fetch_add(n, Ordering::Relaxed);
}

/// Total base GEMMs since process start (or the last [`reset`]).
pub fn base_gemms() -> u64 {
    BASE_GEMMS.load(Ordering::Relaxed)
}

/// Total artifact bytes the delta loader read from disk — the
/// incremental-publish bench asserts a patch warm-up reads a small fraction
/// of the full-artifact bytes through this counter.
pub fn loader_bytes() -> u64 {
    LOADER_BYTES.load(Ordering::Relaxed)
}

/// Total module records decoded from disk.
pub fn module_reads() -> u64 {
    MODULE_READS.load(Ordering::Relaxed)
}

/// Total modules inherited from resident parent versions without a disk
/// read.
pub fn modules_inherited() -> u64 {
    MODULES_INHERITED.load(Ordering::Relaxed)
}

/// Total bytes moved over replication transports (manifests + artifacts).
pub fn wire_bytes() -> u64 {
    WIRE_BYTES.load(Ordering::Relaxed)
}

/// Total artifact files fetched over replication transports.
pub fn wire_files() -> u64 {
    WIRE_FILES.load(Ordering::Relaxed)
}

/// Total chunks executed by the compute pool.
pub fn pool_tasks() -> u64 {
    POOL_TASKS.load(Ordering::Relaxed)
}

/// Total nanoseconds pool workers spent parked between jobs.
pub fn pool_steal_or_idle_ns() -> u64 {
    POOL_IDLE_NS.load(Ordering::Relaxed)
}

/// Total engine steps (windows admitted by the continuous-batching loop).
pub fn engine_steps() -> u64 {
    ENGINE_STEPS.load(Ordering::Relaxed)
}

/// Total activation-row reads through resident weight matrices.
pub fn activation_row_reads() -> u64 {
    ACT_ROW_READS.load(Ordering::Relaxed)
}

/// Total HTTP requests served by the network plane.
pub fn http_requests() -> u64 {
    HTTP_REQUESTS.load(Ordering::Relaxed)
}

/// Total manifest long-polls that parked waiting for a registry change.
pub fn http_long_polls() -> u64 {
    HTTP_LONG_POLLS.load(Ordering::Relaxed)
}

/// Total sequences served from a cached token prefix.
pub fn prefix_cache_hits() -> u64 {
    PREFIX_HITS.load(Ordering::Relaxed)
}

/// Total cacheable prefixes computed cold.
pub fn prefix_cache_misses() -> u64 {
    PREFIX_MISSES.load(Ordering::Relaxed)
}

/// Bytes currently resident in the prefix cache (gauge; last cache to
/// update wins when several run in one process).
pub fn prefix_cache_bytes() -> u64 {
    PREFIX_BYTES.load(Ordering::Relaxed)
}

/// Total stacked activation rows skipped thanks to cached prefixes.
pub fn prefix_rows_skipped() -> u64 {
    PREFIX_ROWS_SKIPPED.load(Ordering::Relaxed)
}

/// Reset all counters to zero (benches/tests only).
pub fn reset() {
    BASE_GEMMS.store(0, Ordering::Relaxed);
    LOADER_BYTES.store(0, Ordering::Relaxed);
    MODULE_READS.store(0, Ordering::Relaxed);
    MODULES_INHERITED.store(0, Ordering::Relaxed);
    WIRE_BYTES.store(0, Ordering::Relaxed);
    WIRE_FILES.store(0, Ordering::Relaxed);
    POOL_TASKS.store(0, Ordering::Relaxed);
    POOL_IDLE_NS.store(0, Ordering::Relaxed);
    ENGINE_STEPS.store(0, Ordering::Relaxed);
    ACT_ROW_READS.store(0, Ordering::Relaxed);
    HTTP_REQUESTS.store(0, Ordering::Relaxed);
    HTTP_LONG_POLLS.store(0, Ordering::Relaxed);
    PREFIX_HITS.store(0, Ordering::Relaxed);
    PREFIX_MISSES.store(0, Ordering::Relaxed);
    PREFIX_BYTES.store(0, Ordering::Relaxed);
    PREFIX_ROWS_SKIPPED.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        // Other tests run concurrently in this process, so only a relative
        // lower bound is safe to assert.
        let before = base_gemms();
        record_base_gemm();
        record_base_gemm();
        assert!(base_gemms() >= before + 2);
    }
}
