//! Minimal HTTP/1.1 client over std `TcpStream`: one connection per
//! request (`Connection: close`), buffered replies for the JSON planes,
//! and a streaming, crc-verified, range-resuming download path for
//! artifact files. Counts wire bytes (head + body, both directions'
//! received side) so replication accounting reflects real traffic.

use super::http::{fill_until, read_head, HttpError, Method};
use crate::util::crc32;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

/// A `http://host:port` peer address.
#[derive(Clone, Debug)]
pub struct HttpPeer {
    host: String,
    port: u16,
}

impl HttpPeer {
    /// Parse `http://host:port` (a lone trailing `/` is tolerated; a path,
    /// userinfo, or `https` is not).
    pub fn parse(url: &str) -> Result<HttpPeer> {
        let rest = url
            .strip_prefix("http://")
            .ok_or_else(|| anyhow::anyhow!("peer url '{url}' must start with http://"))?;
        let rest = rest.strip_suffix('/').unwrap_or(rest);
        if rest.contains('/') || rest.contains('@') {
            bail!("peer url '{url}' must be bare http://host:port");
        }
        let (host, port) = rest
            .rsplit_once(':')
            .ok_or_else(|| anyhow::anyhow!("peer url '{url}' needs an explicit :port"))?;
        if host.is_empty() {
            bail!("peer url '{url}' has an empty host");
        }
        let port: u16 = port.parse().with_context(|| format!("bad port in '{url}'"))?;
        Ok(HttpPeer { host: host.to_string(), port })
    }

    /// Canonical `http://host:port` form.
    pub fn base(&self) -> String {
        format!("http://{}:{}", self.host, self.port)
    }

    fn connect(&self, cfg: &ClientConfig) -> Result<TcpStream> {
        use std::net::ToSocketAddrs;
        let addrs: Vec<_> = (self.host.as_str(), self.port)
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", self.base()))?
            .collect();
        let mut last = None;
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, cfg.connect_timeout) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    // Socket-level poll granularity; overall deadlines are
                    // enforced by the read loops on top.
                    s.set_read_timeout(Some(Duration::from_millis(250)))
                        .context("setting read timeout")?;
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => Err(anyhow::Error::new(e).context(format!("connecting to {}", self.base()))),
            None => bail!("{} resolved to no addresses", self.base()),
        }
    }
}

/// Client-side time/size bounds.
#[derive(Clone, Copy, Debug)]
pub struct ClientConfig {
    pub connect_timeout: Duration,
    /// Budget for the reply head, and the *stall* budget for bodies: a
    /// download fails only after this long with zero forward progress, so
    /// big artifacts are bounded by throughput, not an absolute cap.
    pub read_timeout: Duration,
    /// Cap on buffered reply bodies (manifests, JSON). Streamed file
    /// downloads are not subject to it.
    pub max_body_bytes: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
            max_body_bytes: 64 << 20,
        }
    }
}

/// One buffered reply.
#[derive(Debug)]
pub struct HttpReply {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Bytes received off the wire for this reply (head + body).
    pub wire_bytes: u64,
}

impl HttpReply {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// The reply body as a short diagnostic string (for error messages).
    pub fn body_text(&self) -> String {
        let text = String::from_utf8_lossy(&self.body);
        let text = text.trim();
        let mut end = text.len().min(200);
        while !text.is_char_boundary(end) {
            end -= 1;
        }
        text[..end].to_string()
    }
}

/// Issue one request and buffer the whole reply.
pub fn http_request(
    peer: &HttpPeer,
    method: Method,
    path_and_query: &str,
    body: Option<(&str, &[u8])>,
    cfg: &ClientConfig,
) -> Result<HttpReply> {
    let mut stream = peer.connect(cfg)?;
    write_request(&mut stream, peer, method, path_and_query, &[], body)
        .with_context(|| format!("sending {} {}", method.as_str(), path_and_query))?;
    let deadline = Instant::now() + cfg.read_timeout;
    let (status, headers, mut rest, head_wire) = read_reply_head(&mut stream, deadline)
        .with_context(|| format!("reading reply to {} {}", method.as_str(), path_and_query))?;
    let declared = content_length(&headers)?;
    let body = match declared {
        Some(len) => {
            if len > cfg.max_body_bytes {
                bail!(
                    "reply body of {len} bytes exceeds the {}-byte client cap",
                    cfg.max_body_bytes
                );
            }
            let len = len as usize;
            if rest.len() < len {
                fill_until(&mut stream, &mut rest, len, Instant::now() + cfg.read_timeout)
                    .map_err(anyhow::Error::new)
                    .with_context(|| format!("reading {len}-byte reply body"))?;
            }
            rest.truncate(len);
            rest
        }
        None => {
            // No Content-Length: body runs to connection close.
            read_to_end_capped(&mut stream, &mut rest, cfg)?;
            rest
        }
    };
    Ok(HttpReply {
        status,
        headers,
        wire_bytes: head_wire + body.len() as u64,
        body,
    })
}

/// Outcome of a [`http_fetch_file`] download.
#[derive(Clone, Copy, Debug)]
pub struct FileFetchOutcome {
    /// Bytes of the assembled file on disk.
    pub file_bytes: u64,
    /// Bytes received off the wire across every attempt (heads + bodies —
    /// more than `file_bytes` only by header overhead and any resumed
    /// overlap).
    pub wire_bytes: u64,
}

/// Download `path` into `dest`, streaming to disk. Mid-stream drops resume
/// with `Range: bytes=N-` (up to a few attempts, as long as each made
/// progress); the assembled file is verified against the server's
/// whole-file `X-Content-Crc32` before returning.
pub fn http_fetch_file(
    peer: &HttpPeer,
    path: &str,
    dest: &Path,
    cfg: &ClientConfig,
) -> Result<FileFetchOutcome> {
    const MAX_ATTEMPTS: usize = 5;
    let mut out = File::create(dest)
        .with_context(|| format!("creating download target {}", dest.display()))?;
    let mut st = FetchState::default();
    let mut attempt = 0;
    loop {
        attempt += 1;
        let before = st.written;
        match fetch_attempt(&mut out, peer, path, cfg, &mut st) {
            Ok(()) => break,
            Err(e) => {
                let progressed = st.written > before;
                if attempt >= MAX_ATTEMPTS || !progressed || !st.resumable {
                    return Err(e.context(format!(
                        "downloading {path} from {} (attempt {attempt})",
                        peer.base()
                    )));
                }
            }
        }
    }
    out.flush().ok();
    drop(out);
    if let Some(total) = st.total {
        if st.written != total {
            bail!("download of {path} ended at {} of {total} bytes", st.written);
        }
    }
    if let Some(expect) = st.crc {
        let data =
            std::fs::read(dest).with_context(|| format!("re-reading {}", dest.display()))?;
        let got = crc32::hash(&data);
        if got != expect {
            bail!(
                "crc mismatch on {path} from {}: file {got:08x}, server declared {expect:08x}",
                peer.base()
            );
        }
    }
    Ok(FileFetchOutcome { file_bytes: st.written, wire_bytes: st.wire })
}

#[derive(Default)]
struct FetchState {
    /// File bytes written so far (== resume offset).
    written: u64,
    /// Wire bytes received across attempts.
    wire: u64,
    /// Full file length, once a reply declared it.
    total: Option<u64>,
    /// Server-declared whole-file crc, once a reply carried it.
    crc: Option<u32>,
    /// Whether a retry makes sense (false before the first reply head —
    /// connect/404 failures should not be retried blind).
    resumable: bool,
}

fn fetch_attempt(
    out: &mut File,
    peer: &HttpPeer,
    path: &str,
    cfg: &ClientConfig,
    st: &mut FetchState,
) -> Result<()> {
    let mut stream = peer.connect(cfg)?;
    let range_header = format!("bytes={}-", st.written);
    let mut extra: Vec<(&str, &str)> = Vec::new();
    if st.written > 0 {
        extra.push(("Range", range_header.as_str()));
    }
    write_request(&mut stream, peer, Method::Get, path, &extra, None)
        .with_context(|| format!("sending GET {path}"))?;
    let deadline = Instant::now() + cfg.read_timeout;
    let (status, headers, mut leftover, head_wire) = read_reply_head(&mut stream, deadline)?;
    st.wire += head_wire;
    match status {
        200 => {
            if st.written > 0 {
                // Peer ignored the Range: start the file over.
                out.set_len(0).context("truncating for full re-download")?;
                out.seek(SeekFrom::Start(0))?;
                st.written = 0;
            }
        }
        206 => {
            let start = headers
                .iter()
                .find(|(n, _)| n == "content-range")
                .and_then(|(_, v)| parse_content_range(v))
                .ok_or_else(|| anyhow::anyhow!("206 reply without a parsable Content-Range"))?;
            if start.0 != st.written {
                bail!("206 resumed at byte {} but {} were requested", start.0, st.written);
            }
            match (st.total, start.1) {
                (Some(a), b) if a != b => {
                    bail!("file length changed mid-download ({a} → {b})")
                }
                _ => st.total = Some(start.1),
            }
        }
        other => {
            // Small diagnostic body; not resumable.
            let _ = fill_until(
                &mut stream,
                &mut leftover,
                leftover.len().max(256).min(4096),
                Instant::now() + Duration::from_millis(500),
            );
            bail!(
                "GET {path} answered {other}: {}",
                String::from_utf8_lossy(&leftover[..leftover.len().min(200)]).trim()
            );
        }
    }
    if let Some(hex) = headers.iter().find(|(n, _)| n == "x-content-crc32").map(|(_, v)| v) {
        let parsed = u32::from_str_radix(hex.trim(), 16)
            .with_context(|| format!("bad X-Content-Crc32 '{hex}'"))?;
        match st.crc {
            Some(c) if c != parsed => bail!("file crc changed mid-download"),
            _ => st.crc = Some(parsed),
        }
    }
    let body_len = content_length(&headers)?
        .ok_or_else(|| anyhow::anyhow!("file reply without Content-Length"))?;
    if status == 200 {
        match st.total {
            Some(t) if t != body_len => {
                bail!("file length changed mid-download ({t} → {body_len})")
            }
            _ => st.total = Some(body_len),
        }
    }
    st.resumable = true;
    // Stream the body to disk: leftover first, then socket chunks. The
    // deadline is a *stall* deadline — it resets on every byte of progress.
    let mut consumed: u64 = 0;
    let keep = leftover.len().min(usize::try_from(body_len).unwrap_or(usize::MAX));
    leftover.truncate(keep);
    if !leftover.is_empty() {
        out.write_all(&leftover).context("writing download chunk")?;
        consumed += leftover.len() as u64;
        st.written += leftover.len() as u64;
        st.wire += leftover.len() as u64;
    }
    let mut stall_deadline = Instant::now() + cfg.read_timeout;
    let mut chunk = [0u8; 64 * 1024];
    while consumed < body_len {
        if Instant::now() >= stall_deadline {
            bail!("download stalled after {consumed} of {body_len} bytes");
        }
        let want = (body_len - consumed).min(chunk.len() as u64) as usize;
        match stream.read(&mut chunk[..want]) {
            Ok(0) => bail!("peer closed after {consumed} of {body_len} body bytes"),
            Ok(n) => {
                out.write_all(&chunk[..n]).context("writing download chunk")?;
                consumed += n as u64;
                st.written += n as u64;
                st.wire += n as u64;
                stall_deadline = Instant::now() + cfg.read_timeout;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(anyhow::Error::new(e).context("reading download body")),
        }
    }
    Ok(())
}

fn write_request(
    stream: &mut TcpStream,
    peer: &HttpPeer,
    method: Method,
    path_and_query: &str,
    extra_headers: &[(&str, &str)],
    body: Option<(&str, &[u8])>,
) -> Result<()> {
    let mut head = format!(
        "{} {} HTTP/1.1\r\nHost: {}:{}\r\nConnection: close\r\n",
        method.as_str(),
        path_and_query,
        peer.host,
        peer.port
    );
    for (name, value) in extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    match body {
        Some((content_type, bytes)) => {
            head.push_str(&format!(
                "Content-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
                bytes.len()
            ));
            stream.write_all(head.as_bytes())?;
            stream.write_all(bytes)?;
        }
        None => {
            head.push_str("\r\n");
            stream.write_all(head.as_bytes())?;
        }
    }
    stream.flush()?;
    Ok(())
}

/// Read a reply's status line + headers. Returns `(status, headers,
/// over-read body bytes, wire bytes consumed so far)`.
fn read_reply_head(
    stream: &mut TcpStream,
    deadline: Instant,
) -> Result<(u16, Vec<(String, String)>, Vec<u8>, u64)> {
    const MAX_REPLY_HEAD: usize = 16 * 1024;
    let parsed = read_head(stream, Vec::new(), MAX_REPLY_HEAD, deadline)
        .map_err(anyhow::Error::new)?
        .ok_or_else(|| anyhow::anyhow!("peer closed before sending a reply"))?;
    let (head, rest) = parsed;
    let wire = head.len() as u64 + 4 + rest.len() as u64;
    let head = std::str::from_utf8(&head).context("reply head is not valid UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let status: u16 = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse()
            .with_context(|| format!("bad status in reply line '{status_line}'"))?,
        _ => bail!("bad reply line '{status_line}'"),
    };
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("reply header line without ':'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok((status, headers, rest, wire))
}

fn content_length(headers: &[(String, String)]) -> Result<Option<u64>> {
    match headers.iter().find(|(n, _)| n == "content-length") {
        None => Ok(None),
        Some((_, v)) => Ok(Some(
            v.parse().with_context(|| format!("bad reply Content-Length '{v}'"))?,
        )),
    }
}

/// Parse `Content-Range: bytes START-END/TOTAL` → `(START, TOTAL)`.
fn parse_content_range(v: &str) -> Option<(u64, u64)> {
    let rest = v.trim().strip_prefix("bytes ")?;
    let (range, total) = rest.split_once('/')?;
    let (start, _end) = range.split_once('-')?;
    Some((start.parse().ok()?, total.parse().ok()?))
}

fn read_to_end_capped(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    cfg: &ClientConfig,
) -> Result<()> {
    let deadline = Instant::now() + cfg.read_timeout;
    let mut chunk = [0u8; 8192];
    loop {
        if buf.len() as u64 > cfg.max_body_bytes {
            bail!("reply body exceeds the {}-byte client cap", cfg.max_body_bytes);
        }
        if Instant::now() >= deadline {
            bail!("reply body did not complete within the read timeout");
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(anyhow::Error::new(e).context("reading reply body")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peer_parsing() {
        let p = HttpPeer::parse("http://127.0.0.1:8080").unwrap();
        assert_eq!(p.base(), "http://127.0.0.1:8080");
        assert_eq!(HttpPeer::parse("http://localhost:9/").unwrap().base(), "http://localhost:9");
        for bad in [
            "https://x:1",
            "http://x",
            "http://:8080",
            "http://x:notaport",
            "http://a:1/path",
            "http://u@h:1",
            "fs:/some/dir",
        ] {
            assert!(HttpPeer::parse(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn content_range_parsing() {
        assert_eq!(parse_content_range("bytes 100-499/500"), Some((100, 500)));
        assert_eq!(parse_content_range(" bytes 0-0/1"), Some((0, 1)));
        assert_eq!(parse_content_range("bytes */500"), None);
        assert_eq!(parse_content_range("items 1-2/3"), None);
    }
}
