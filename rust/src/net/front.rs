//! The serving front-end: one `TcpListener`, thread-per-connection, and a
//! `routes!` table bridging HTTP onto the
//! coordinator's existing seams.
//!
//! Three planes share the listener:
//!
//! * **data** — `POST /v1/query` submits a
//!   [`DataOp`](crate::coordinator::DataOp) through the same
//!   [`Client`] channel the in-process path uses, so an HTTP score is
//!   bitwise-identical to a local one (scores ride the shortest-roundtrip
//!   `f64` JSON encoding).
//! * **admin** — `POST /v1/admin/:op` maps kebab-case op names onto
//!   [`AdminOp`](crate::coordinator::AdminOp) via [`super::wire`].
//! * **sync** — `GET /v1/sync/manifest` (long-poll on `known_seq`) and
//!   `GET /v1/sync/file/:name` (crc-tagged, range-resumable) feed
//!   [`HttpTransport`](super::transport::HttpTransport) followers. A
//!   frontend started without a [`Client`] serves *only* this plane —
//!   useful for pure replication sources.
//!
//! No auth, no TLS: the plane trusts its network (loopback / lab LAN).

use super::http::{HttpConn, HttpError, HttpLimits, HttpRequest, HttpResponse};
use super::router::{routes, RouteParams, Router};
use super::wire;
use crate::coordinator::registry::{parse_manifest_view, VariantRegistry, MANIFEST_FILE};
use crate::coordinator::replicate::ensure_bare_file_name;
use crate::coordinator::{Client, Payload};
use crate::exec::counters;
use crate::util::crc32;
use crate::util::json::{n, obj, s, Json};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Tuning knobs for one frontend. `Default` is sized for tests and
/// single-host serving.
#[derive(Clone, Copy, Debug)]
pub struct FrontConfig {
    /// Per-message parse bounds (head/body size and deadlines).
    pub limits: HttpLimits,
    /// Concurrent connections beyond which new peers get an immediate 503.
    pub max_conns: usize,
    /// Keep-alive requests served per connection before a polite close.
    pub max_requests_per_conn: u32,
    /// Ceiling on one manifest long-poll, whatever `timeout_ms` asks for.
    pub long_poll_cap: Duration,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig {
            limits: HttpLimits::default(),
            max_conns: 64,
            max_requests_per_conn: 1000,
            long_poll_cap: Duration::from_secs(30),
        }
    }
}

/// Shared handler state. Cloned per connection thread (the [`Client`]
/// sender is `Send`, and per-thread clones sidestep any `Sync` question).
#[derive(Clone)]
struct FrontState {
    /// `None` runs the frontend sync-only: query/admin answer 503.
    client: Option<Client>,
    registry: Arc<VariantRegistry>,
    cfg: FrontConfig,
    shutdown: Arc<AtomicBool>,
}

/// A running HTTP frontend. Dropping it (or calling [`shutdown`]) stops the
/// accept loop; in-flight connections notice the flag within one poll slice.
///
/// [`shutdown`]: HttpFrontend::shutdown
pub struct HttpFrontend {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl HttpFrontend {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start serving. `client`
    /// attaches the data/admin planes; `registry` feeds the sync plane.
    pub fn start(
        addr: &str,
        client: Option<Client>,
        registry: Arc<VariantRegistry>,
        cfg: FrontConfig,
    ) -> io::Result<HttpFrontend> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let state = FrontState { client, registry, cfg, shutdown: shutdown.clone() };
        let accept = std::thread::Builder::new()
            .name("pawd-http-accept".into())
            .spawn(move || accept_loop(listener, state))?;
        Ok(HttpFrontend { addr: local, shutdown, accept: Some(accept) })
    }

    /// The bound address (real port even when started on `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `http://host:port` form of [`addr`](Self::addr), ready for
    /// [`HttpTransport::new`](super::transport::HttpTransport::new).
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Stop accepting and join the accept thread. Connection threads see
    /// the flag at their next read slice and drain on their own.
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // The accept loop blocks in accept(); a throwaway self-connect
        // wakes it so it can observe the flag.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, state: FrontState) {
    let active = Arc::new(AtomicUsize::new(0));
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if active.load(Ordering::SeqCst) >= state.cfg.max_conns {
            let mut stream = stream;
            let reject = HttpResponse::error(503, "connection limit reached");
            let _ = reject.write_to(&mut stream, false);
            continue;
        }
        active.fetch_add(1, Ordering::SeqCst);
        let state = state.clone();
        let active = active.clone();
        let spawned = std::thread::Builder::new().name("pawd-http-conn".into()).spawn(move || {
            handle_conn(&state, stream);
            active.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Serve one connection: keep-alive loop, typed-error close, per-request
/// counter. Any write failure just drops the connection — the peer is gone.
fn handle_conn(state: &FrontState, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // Short socket timeout so blocked reads re-check deadlines (and the
    // shutdown flag between requests) instead of hanging on a silent peer.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let router = route_table();
    let mut conn = HttpConn::new(stream);
    let mut served: u32 = 0;
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match conn.read_request(&state.cfg.limits) {
            Ok(None) => return,
            Ok(Some(req)) => {
                counters::record_http_request();
                served += 1;
                let keep_alive = !req.wants_close && served < state.cfg.max_requests_per_conn;
                let resp = router.dispatch(state, &req);
                if resp.write_to(conn.get_mut(), keep_alive).is_err() || !keep_alive {
                    return;
                }
            }
            Err(err) => {
                respond_to_error(&mut conn, &err);
                return;
            }
        }
    }
}

/// Best-effort status line for a parse failure whose [`HttpError::status`]
/// says the peer is still worth answering.
fn respond_to_error(conn: &mut HttpConn<TcpStream>, err: &HttpError) {
    if let Some(status) = err.status() {
        let _ = HttpResponse::error(status, &err.to_string()).write_to(conn.get_mut(), false);
    }
}

fn route_table() -> Router<FrontState> {
    routes! {
        GET  "/v1/healthz"         => health,
        POST "/v1/query"           => query,
        POST "/v1/admin/:op"       => admin,
        GET  "/v1/sync/manifest"   => sync_manifest,
        GET  "/v1/sync/file/:name" => sync_file,
    }
}

fn health(state: &FrontState, _req: &HttpRequest, _params: &RouteParams) -> HttpResponse {
    let role = if state.client.is_some() { "serve" } else { "sync-only" };
    HttpResponse::json(200, &obj(vec![("ok", Json::Bool(true)), ("role", s(role))]))
}

/// `POST /v1/query` — body `{"variant", "op", …}` per [`wire::query_from_json`].
fn query(state: &FrontState, req: &HttpRequest, _params: &RouteParams) -> HttpResponse {
    let Some(client) = &state.client else {
        return HttpResponse::error(503, "serving plane not attached (sync-only frontend)");
    };
    let parsed = std::str::from_utf8(&req.body)
        .map_err(|_| "body is not UTF-8".to_string())
        .and_then(|text| Json::parse(text).map_err(|e| e.to_string()))
        .and_then(|j| wire::query_from_json(&j).map_err(|e| e.to_string()));
    let (variant, op) = match parsed {
        Ok(pair) => pair,
        Err(msg) => return HttpResponse::error(400, &format!("bad query body: {msg}")),
    };
    let rx = client.submit(&variant, Payload::Data(op));
    let resp = match rx.recv() {
        Ok(resp) => resp,
        Err(_) => return HttpResponse::error(503, "engine unavailable"),
    };
    match resp.result {
        Ok(body) => match wire::data_body_to_json(&body) {
            Ok(body_json) => {
                let mut fields = vec![("variant", s(&resp.variant))];
                if let Some(v) = resp.version {
                    fields.push(("version", n(v as f64)));
                }
                fields.push(("body", body_json));
                fields.push(("timing", wire::timing_to_json(&resp.timing)));
                HttpResponse::json(200, &obj(fields))
            }
            Err(e) => HttpResponse::error(500, &format!("unencodable response: {e}")),
        },
        Err(msg) => HttpResponse::error(422, &msg),
    }
}

/// `POST /v1/admin/:op` — kebab-case op routes per [`wire::admin_op_from_route`];
/// the valid segment set is [`wire::admin_routes::ALL`].
fn admin(state: &FrontState, req: &HttpRequest, params: &RouteParams) -> HttpResponse {
    let Some(client) = &state.client else {
        return HttpResponse::error(503, "admin plane not attached (sync-only frontend)");
    };
    let route = params.get(0);
    if !wire::admin_routes::ALL.contains(&route) {
        return HttpResponse::error(
            400,
            &format!(
                "bad admin request: unknown admin route '{route}' (valid: {})",
                wire::admin_routes::ALL.join(", ")
            ),
        );
    }
    let body_json = if req.body.is_empty() {
        Ok(obj(Vec::new()))
    } else {
        std::str::from_utf8(&req.body)
            .map_err(|_| "body is not UTF-8".to_string())
            .and_then(|text| Json::parse(text).map_err(|e| e.to_string()))
    };
    let op = body_json.and_then(|j| {
        wire::admin_op_from_route(params.get(0), &j).map_err(|e| e.to_string())
    });
    let op = match op {
        Ok(op) => op,
        Err(msg) => return HttpResponse::error(400, &format!("bad admin request: {msg}")),
    };
    match client.admin(op) {
        Ok(resp) => HttpResponse::json(200, &wire::admin_resp_to_json(&resp)),
        Err(msg) => HttpResponse::error(422, &msg),
    }
}

/// `GET /v1/sync/manifest[?known_seq=N&timeout_ms=M]`.
///
/// With `known_seq` matching the current sequence and a positive
/// `timeout_ms`, the handler parks on the registry's manifest watch
/// (counted in `http_long_polls`) until a publish bumps the sequence or
/// the timeout lapses. The answer is always taken from the manifest
/// *file* — its embedded `manifest_seq` is what a follower will replay,
/// and the in-memory atomic ticks before the file lands. `304` +
/// `X-Manifest-Seq` means "nothing newer than what you hold", and costs
/// only header bytes on the wire.
fn sync_manifest(state: &FrontState, req: &HttpRequest, _params: &RouteParams) -> HttpResponse {
    let known_seq = match req.query_param("known_seq").map(str::parse::<u64>) {
        None => None,
        Some(Ok(v)) => Some(v),
        Some(Err(_)) => return HttpResponse::error(400, "known_seq must be a non-negative integer"),
    };
    let timeout_ms = match req.query_param("timeout_ms").map(str::parse::<u64>) {
        None => 0,
        Some(Ok(v)) => v,
        Some(Err(_)) => {
            return HttpResponse::error(400, "timeout_ms must be a non-negative integer");
        }
    };
    if let Some(known) = known_seq {
        let wait = Duration::from_millis(timeout_ms).min(state.cfg.long_poll_cap);
        if !wait.is_zero() && state.registry.manifest_seq() == known {
            counters::record_http_long_poll();
            // Park in short slices so a shutdown can't strand the poller
            // for the whole window.
            let deadline = Instant::now() + wait;
            loop {
                if state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let slice = (deadline - now).min(Duration::from_millis(250));
                if state.registry.wait_manifest_change(known, slice) != known {
                    break;
                }
            }
        }
    }
    let manifest_path = state.registry.dir().join(MANIFEST_FILE);
    let bytes = match std::fs::read(&manifest_path) {
        Ok(bytes) => bytes,
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            return HttpResponse::error(404, "manifest not yet persisted");
        }
        Err(e) => return HttpResponse::error(500, &format!("manifest read failed: {e}")),
    };
    let file_seq = match std::str::from_utf8(&bytes).ok().and_then(|t| parse_manifest_view(t).ok())
    {
        Some(view) => view.manifest_seq,
        None => return HttpResponse::error(500, "manifest file is unreadable"),
    };
    let seq_header = file_seq.to_string();
    if known_seq == Some(file_seq) {
        return HttpResponse::empty(304).with_header("X-Manifest-Seq", &seq_header);
    }
    HttpResponse::bytes(200, "application/json", bytes).with_header("X-Manifest-Seq", &seq_header)
}

/// `GET /v1/sync/file/:name` — one artifact out of the registry directory.
///
/// `X-Content-Crc32` always describes the *whole* file (hex), so a client
/// resuming with `Range: bytes=N-` can verify the assembled result. Names
/// pass [`ensure_bare_file_name`] — the same gate the replicator applies —
/// so the route can never walk out of the registry directory.
fn sync_file(state: &FrontState, req: &HttpRequest, params: &RouteParams) -> HttpResponse {
    let name = params.get(0);
    if let Err(e) = ensure_bare_file_name(name) {
        return HttpResponse::error(400, &format!("bad file name: {e}"));
    }
    let data = match std::fs::read(state.registry.dir().join(name)) {
        Ok(data) => data,
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            return HttpResponse::error(404, &format!("no such artifact '{name}'"));
        }
        Err(e) => return HttpResponse::error(500, &format!("artifact read failed: {e}")),
    };
    let total = data.len() as u64;
    let crc = format!("{:08x}", crc32::hash(&data));
    let offset = req.header("range").and_then(parse_range_start).unwrap_or(0);
    if offset > 0 {
        if offset >= total {
            return HttpResponse::error(416, "range start beyond end of file")
                .with_header("Content-Range", &format!("bytes */{total}"))
                .with_header("X-Content-Crc32", &crc);
        }
        let content_range = format!("bytes {offset}-{}/{total}", total - 1);
        let tail = data[offset as usize..].to_vec();
        return HttpResponse::bytes(206, "application/octet-stream", tail)
            .with_header("Content-Range", &content_range)
            .with_header("Accept-Ranges", "bytes")
            .with_header("X-Content-Crc32", &crc);
    }
    HttpResponse::bytes(200, "application/octet-stream", data)
        .with_header("Accept-Ranges", "bytes")
        .with_header("X-Content-Crc32", &crc)
}

/// Parse `bytes=N-` (open-ended resume form). Anything else — multi-range,
/// suffix ranges, other units — is ignored and served as a full `200`,
/// which the resuming client treats as "start over".
fn parse_range_start(value: &str) -> Option<u64> {
    let spec = value.trim().strip_prefix("bytes=")?;
    let start = spec.strip_suffix('-')?;
    start.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_start_parsing() {
        assert_eq!(parse_range_start("bytes=0-"), Some(0));
        assert_eq!(parse_range_start("bytes=1234-"), Some(1234));
        assert_eq!(parse_range_start(" bytes=7- "), Some(7));
        assert_eq!(parse_range_start("bytes=1-5"), None, "closed ranges unsupported");
        assert_eq!(parse_range_start("bytes=-5"), None, "suffix ranges unsupported");
        assert_eq!(parse_range_start("items=3-"), None);
        assert_eq!(parse_range_start("bytes=x-"), None);
    }
}
