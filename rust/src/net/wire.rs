//! JSON wire codecs for the data/admin planes — both directions, so the
//! front-end and the typed [`HttpApiClient`](super::api::HttpApiClient)
//! speak the exact same shapes and a round-trip is testable in-process.
//!
//! Fidelity note: [`Json`] prints `f64` through Rust's shortest-roundtrip
//! `Display`, so scores cross the wire bitwise-exact — the loopback test
//! asserts `POST /v1/query` answers equal the in-process `Client` path to
//! the bit.

use crate::coordinator::cache::VersionResidency;
use crate::coordinator::registry::{ArtifactKind, VersionRecord};
use crate::coordinator::request::Timing;
use crate::coordinator::{
    AdminOp, AdminResp, DataOp, MetricsSnapshot, RespBody, SyncReport, VariantDesc,
};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

// -- data plane ------------------------------------------------------------

/// `POST /v1/query` request body: `{"variant": …, "op": …, …op fields}`.
pub fn query_to_json(variant: &str, op: &DataOp) -> Json {
    let mut pairs = vec![("variant", json::s(variant))];
    match op {
        DataOp::Score { prompt, choices } => {
            pairs.push(("op", json::s("score")));
            pairs.push(("prompt", json::s(prompt)));
            pairs.push(("choices", json::arr(choices.iter().map(|c| json::s(c)).collect())));
        }
        DataOp::Perplexity { text } => {
            pairs.push(("op", json::s("perplexity")));
            pairs.push(("text", json::s(text)));
        }
    }
    json::obj(pairs)
}

pub fn query_from_json(j: &Json) -> Result<(String, DataOp)> {
    let variant = j.req_str("variant")?.to_string();
    let op = match j.req_str("op")? {
        "score" => DataOp::Score {
            prompt: j.req_str("prompt")?.to_string(),
            choices: j
                .req_arr("choices")?
                .iter()
                .map(|c| {
                    c.as_str()
                        .map(str::to_string)
                        .context("'choices' entries must be strings")
                })
                .collect::<Result<Vec<_>>>()?,
        },
        "perplexity" => DataOp::Perplexity { text: j.req_str("text")?.to_string() },
        other => bail!("unknown data op '{other}'"),
    };
    Ok((variant, op))
}

/// Data-plane result body. Admin results never ride this codec — they have
/// their own routes — so hitting one here is a server-side wiring bug.
pub fn data_body_to_json(body: &RespBody) -> Result<Json> {
    Ok(match body {
        RespBody::Score { choice, scores } => json::obj(vec![
            ("kind", json::s("score")),
            ("choice", json::n(*choice as f64)),
            ("scores", json::arr(scores.iter().map(|&v| json::n(v)).collect())),
        ]),
        RespBody::Perplexity { nats_per_token } => json::obj(vec![
            ("kind", json::s("perplexity")),
            ("nats_per_token", json::n(*nats_per_token)),
        ]),
        RespBody::Admin(_) => bail!("admin result on the data-plane codec"),
    })
}

pub fn data_body_from_json(j: &Json) -> Result<RespBody> {
    Ok(match j.req_str("kind")? {
        "score" => RespBody::Score {
            choice: j.req_usize("choice")?,
            scores: j
                .req_arr("scores")?
                .iter()
                .map(|v| v.as_f64().context("'scores' entries must be numbers"))
                .collect::<Result<Vec<_>>>()?,
        },
        "perplexity" => RespBody::Perplexity {
            nats_per_token: j
                .req("nats_per_token")?
                .as_f64()
                .context("'nats_per_token' is not a number")?,
        },
        other => bail!("unknown data result kind '{other}'"),
    })
}

/// Response timing in integer microseconds (diagnostic — not meant to
/// round-trip [`Timing`]'s `Duration`s exactly).
pub fn timing_to_json(t: &Timing) -> Json {
    let mut pairs = vec![
        ("queue_us", json::n(t.queue.as_micros() as f64)),
        ("compute_us", json::n(t.compute.as_micros() as f64)),
        ("total_us", json::n(t.total.as_micros() as f64)),
    ];
    if let Some(cold) = t.cold_start {
        pairs.push(("cold_start_us", json::n(cold.as_micros() as f64)));
    }
    json::obj(pairs)
}

// -- admin plane -----------------------------------------------------------

/// The kebab-case `POST /v1/admin/<route>` segment for every [`AdminOp`] —
/// THE single source of truth shared by the wire codecs below, the HTTP
/// front-end's route validation ([`super::front`]), and the README route
/// table (a unit test asserts no drift between the three).
pub mod admin_routes {
    pub const STATS: &str = "stats";
    pub const PUBLISH: &str = "publish";
    pub const PUBLISH_INCREMENTAL: &str = "publish-incremental";
    pub const CONSOLIDATE: &str = "consolidate";
    pub const ROLLBACK: &str = "rollback";
    pub const PIN: &str = "pin";
    pub const UNPIN: &str = "unpin";
    pub const RETIRE: &str = "retire";
    pub const GC: &str = "gc";
    pub const LIST: &str = "list";
    pub const SYNC_STATUS: &str = "sync-status";
    pub const PULL_FROM: &str = "pull-from";

    /// Every admin route, in `AdminOp` declaration order.
    pub const ALL: [&str; 12] = [
        STATS,
        PUBLISH,
        PUBLISH_INCREMENTAL,
        CONSOLIDATE,
        ROLLBACK,
        PIN,
        UNPIN,
        RETIRE,
        GC,
        LIST,
        SYNC_STATUS,
        PULL_FROM,
    ];
}

/// The `POST /v1/admin/<route>` suffix for an op, plus its body.
pub fn admin_op_to_route(op: &AdminOp) -> (&'static str, Json) {
    use admin_routes as r;
    match op {
        AdminOp::Stats => (r::STATS, json::obj(vec![])),
        AdminOp::Publish { variant, artifact } => (
            r::PUBLISH,
            json::obj(vec![
                ("variant", json::s(variant)),
                ("artifact", path_json(artifact)),
            ]),
        ),
        AdminOp::PublishIncremental { variant, artifact, parent } => (
            r::PUBLISH_INCREMENTAL,
            json::obj(opt_u32(
                vec![("variant", json::s(variant)), ("artifact", path_json(artifact))],
                "parent",
                *parent,
            )),
        ),
        AdminOp::Consolidate { variant, version } => (
            r::CONSOLIDATE,
            json::obj(opt_u32(vec![("variant", json::s(variant))], "version", *version)),
        ),
        AdminOp::Rollback { variant, to } => (
            r::ROLLBACK,
            json::obj(opt_u32(vec![("variant", json::s(variant))], "to", *to)),
        ),
        AdminOp::Pin { variant, version } => (
            r::PIN,
            json::obj(vec![("variant", json::s(variant)), ("version", json::n(*version as f64))]),
        ),
        AdminOp::Unpin { variant } => (r::UNPIN, json::obj(vec![("variant", json::s(variant))])),
        AdminOp::Retire { variant, version } => (
            r::RETIRE,
            json::obj(vec![("variant", json::s(variant)), ("version", json::n(*version as f64))]),
        ),
        AdminOp::Gc { variant } => (
            r::GC,
            match variant {
                Some(v) => json::obj(vec![("variant", json::s(v))]),
                None => json::obj(vec![]),
            },
        ),
        AdminOp::List => (r::LIST, json::obj(vec![])),
        AdminOp::SyncStatus => (r::SYNC_STATUS, json::obj(vec![])),
        AdminOp::PullFrom { dir } => (r::PULL_FROM, json::obj(vec![("dir", path_json(dir))])),
    }
}

/// Inverse of [`admin_op_to_route`]: the route segment names the op, the
/// body carries its fields (an empty body parses as `{}`).
pub fn admin_op_from_route(route: &str, j: &Json) -> Result<AdminOp> {
    use admin_routes as r;
    Ok(match route {
        _ if route == r::STATS => AdminOp::Stats,
        _ if route == r::PUBLISH => AdminOp::Publish {
            variant: j.req_str("variant")?.to_string(),
            artifact: PathBuf::from(j.req_str("artifact")?),
        },
        _ if route == r::PUBLISH_INCREMENTAL => AdminOp::PublishIncremental {
            variant: j.req_str("variant")?.to_string(),
            artifact: PathBuf::from(j.req_str("artifact")?),
            parent: get_u32(j, "parent")?,
        },
        _ if route == r::CONSOLIDATE => AdminOp::Consolidate {
            variant: j.req_str("variant")?.to_string(),
            version: get_u32(j, "version")?,
        },
        _ if route == r::ROLLBACK => AdminOp::Rollback {
            variant: j.req_str("variant")?.to_string(),
            to: get_u32(j, "to")?,
        },
        _ if route == r::PIN => AdminOp::Pin {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
        },
        _ if route == r::UNPIN => AdminOp::Unpin { variant: j.req_str("variant")?.to_string() },
        _ if route == r::RETIRE => AdminOp::Retire {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
        },
        _ if route == r::GC => AdminOp::Gc {
            variant: j.get("variant").and_then(|v| v.as_str()).map(str::to_string),
        },
        _ if route == r::LIST => AdminOp::List,
        _ if route == r::SYNC_STATUS => AdminOp::SyncStatus,
        _ if route == r::PULL_FROM => AdminOp::PullFrom { dir: PathBuf::from(j.req_str("dir")?) },
        other => bail!(
            "unknown admin route '{other}' (valid: {})",
            admin_routes::ALL.join(", ")
        ),
    })
}

pub fn admin_resp_to_json(resp: &AdminResp) -> Json {
    match resp {
        AdminResp::Stats { snapshot } => json::obj(vec![
            ("kind", json::s("stats")),
            ("snapshot", snapshot_to_json(snapshot)),
        ]),
        AdminResp::Published { variant, version, patch, bytes } => json::obj(vec![
            ("kind", json::s("published")),
            ("variant", json::s(variant)),
            ("version", json::n(*version as f64)),
            ("patch", Json::Bool(*patch)),
            ("bytes", json::n(*bytes as f64)),
        ]),
        AdminResp::Consolidated { variant, version, bytes, rebased_links } => json::obj(vec![
            ("kind", json::s("consolidated")),
            ("variant", json::s(variant)),
            ("version", json::n(*version as f64)),
            ("bytes", json::n(*bytes as f64)),
            ("rebased_links", json::n(*rebased_links as f64)),
        ]),
        AdminResp::RolledBack { variant, version } => json::obj(vec![
            ("kind", json::s("rolled-back")),
            ("variant", json::s(variant)),
            ("version", json::n(*version as f64)),
        ]),
        AdminResp::Pinned { variant, version } => json::obj(vec![
            ("kind", json::s("pinned")),
            ("variant", json::s(variant)),
            ("version", json::n(*version as f64)),
        ]),
        AdminResp::Unpinned { variant } => json::obj(vec![
            ("kind", json::s("unpinned")),
            ("variant", json::s(variant)),
        ]),
        AdminResp::Retired { variant, version } => json::obj(vec![
            ("kind", json::s("retired")),
            ("variant", json::s(variant)),
            ("version", json::n(*version as f64)),
        ]),
        AdminResp::Gced { files_removed, bytes_freed } => json::obj(vec![
            ("kind", json::s("gced")),
            ("files_removed", json::n(*files_removed as f64)),
            ("bytes_freed", json::n(*bytes_freed as f64)),
        ]),
        AdminResp::Variants { variants } => json::obj(vec![
            ("kind", json::s("variants")),
            ("variants", json::arr(variants.iter().map(variant_desc_to_json).collect())),
        ]),
        AdminResp::SyncStatus { manifest_seq, variants, versions } => json::obj(vec![
            ("kind", json::s("sync-status")),
            ("manifest_seq", json::n(*manifest_seq as f64)),
            ("variants", json::n(*variants as f64)),
            ("versions", json::n(*versions as f64)),
        ]),
        AdminResp::Synced { peer, report } => json::obj(vec![
            ("kind", json::s("synced")),
            ("peer", json::s(peer)),
            ("report", sync_report_to_json(report)),
        ]),
    }
}

pub fn admin_resp_from_json(j: &Json) -> Result<AdminResp> {
    Ok(match j.req_str("kind")? {
        "stats" => AdminResp::Stats {
            snapshot: Box::new(snapshot_from_json(j.req("snapshot")?)?),
        },
        "published" => AdminResp::Published {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
            patch: j.req("patch")?.as_bool().context("'patch' is not a bool")?,
            bytes: j.req_usize("bytes")? as u64,
        },
        "consolidated" => AdminResp::Consolidated {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
            bytes: j.req_usize("bytes")? as u64,
            rebased_links: j.req_usize("rebased_links")?,
        },
        "rolled-back" => AdminResp::RolledBack {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
        },
        "pinned" => AdminResp::Pinned {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
        },
        "unpinned" => AdminResp::Unpinned { variant: j.req_str("variant")?.to_string() },
        "retired" => AdminResp::Retired {
            variant: j.req_str("variant")?.to_string(),
            version: j.req_usize("version")? as u32,
        },
        "gced" => AdminResp::Gced {
            files_removed: j.req_usize("files_removed")?,
            bytes_freed: j.req_usize("bytes_freed")? as u64,
        },
        "variants" => AdminResp::Variants {
            variants: j
                .req_arr("variants")?
                .iter()
                .map(variant_desc_from_json)
                .collect::<Result<Vec<_>>>()?,
        },
        "sync-status" => AdminResp::SyncStatus {
            manifest_seq: j.req_usize("manifest_seq")? as u64,
            variants: j.req_usize("variants")?,
            versions: j.req_usize("versions")?,
        },
        "synced" => AdminResp::Synced {
            peer: j.req_str("peer")?.to_string(),
            report: sync_report_from_json(j.req("report")?)?,
        },
        other => bail!("unknown admin result kind '{other}'"),
    })
}

// -- shared structs --------------------------------------------------------

pub fn sync_report_to_json(r: &SyncReport) -> Json {
    json::obj(vec![
        ("leader_seq", json::n(r.leader_seq as f64)),
        ("up_to_date", Json::Bool(r.up_to_date)),
        ("variants_synced", json::n(r.variants_synced as f64)),
        ("versions_installed", json::n(r.versions_installed as f64)),
        ("files_fetched", json::n(r.files_fetched as f64)),
        ("patch_files_fetched", json::n(r.patch_files_fetched as f64)),
        ("artifact_bytes", json::n(r.artifact_bytes as f64)),
        ("manifest_bytes", json::n(r.manifest_bytes as f64)),
        ("warm_failures", json::n(r.warm_failures as f64)),
    ])
}

pub fn sync_report_from_json(j: &Json) -> Result<SyncReport> {
    Ok(SyncReport {
        leader_seq: j.req_usize("leader_seq")? as u64,
        up_to_date: j.req("up_to_date")?.as_bool().context("'up_to_date' is not a bool")?,
        variants_synced: j.req_usize("variants_synced")?,
        versions_installed: j.req_usize("versions_installed")?,
        files_fetched: j.req_usize("files_fetched")?,
        patch_files_fetched: j.req_usize("patch_files_fetched")?,
        artifact_bytes: j.req_usize("artifact_bytes")? as u64,
        manifest_bytes: j.req_usize("manifest_bytes")? as u64,
        warm_failures: j.req_usize("warm_failures")?,
    })
}

pub fn variant_desc_to_json(d: &VariantDesc) -> Json {
    json::obj(vec![
        ("name", json::s(&d.name)),
        ("active", json::n(d.active as f64)),
        ("pinned", Json::Bool(d.pinned)),
        (
            "versions",
            json::arr(
                d.versions
                    .iter()
                    .map(|r| {
                        json::obj(vec![
                            ("version", json::n(r.version as f64)),
                            ("parent", json::n(r.parent.unwrap_or(0) as f64)),
                            ("created_unix", json::n(r.created_unix as f64)),
                            ("file", json::s(&r.file)),
                            ("kind", json::s(r.kind.label())),
                            ("bytes", json::n(r.bytes as f64)),
                            ("retired", Json::Bool(r.retired)),
                            ("patch", Json::Bool(r.patch)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub fn variant_desc_from_json(j: &Json) -> Result<VariantDesc> {
    let mut versions = Vec::new();
    for rv in j.req_arr("versions")? {
        let parent = rv.req_usize("parent")? as u32;
        versions.push(VersionRecord {
            version: rv.req_usize("version")? as u32,
            parent: if parent == 0 { None } else { Some(parent) },
            created_unix: rv.req_usize("created_unix")? as u64,
            file: rv.req_str("file")?.to_string(),
            kind: ArtifactKind::from_label(rv.req_str("kind")?)?,
            bytes: rv.req_usize("bytes")? as u64,
            retired: rv.req("retired")?.as_bool().context("'retired' is not a bool")?,
            patch: rv.req("patch")?.as_bool().context("'patch' is not a bool")?,
        });
    }
    Ok(VariantDesc {
        name: j.req_str("name")?.to_string(),
        active: j.req_usize("active")? as u32,
        pinned: j.req("pinned")?.as_bool().context("'pinned' is not a bool")?,
        versions,
    })
}

pub fn snapshot_to_json(s: &MetricsSnapshot) -> Json {
    json::obj(vec![
        ("served", json::n(s.served as f64)),
        ("errors", json::n(s.errors as f64)),
        ("batches", json::n(s.batches as f64)),
        ("mean_batch_size", json::n(s.mean_batch_size)),
        ("throughput_rps", json::n(s.throughput_rps)),
        ("queue_p50_us", json::n(s.queue_p50_us as f64)),
        ("queue_p99_us", json::n(s.queue_p99_us as f64)),
        ("compute_p50_us", json::n(s.compute_p50_us as f64)),
        ("compute_p99_us", json::n(s.compute_p99_us as f64)),
        ("total_p50_us", json::n(s.total_p50_us as f64)),
        ("total_p99_us", json::n(s.total_p99_us as f64)),
        ("cold_starts", json::n(s.cold_starts as f64)),
        ("cold_p50_us", json::n(s.cold_p50_us as f64)),
        ("swaps", json::n(s.swaps as f64)),
        ("publishes", json::n(s.publishes as f64)),
        ("rollbacks", json::n(s.rollbacks as f64)),
        ("resident_variants", json::n(s.resident_variants as f64)),
        ("resident_bytes", json::n(s.resident_bytes as f64)),
        ("resident_dense_equiv_bytes", json::n(s.resident_dense_equiv_bytes as f64)),
        (
            "resident_versions",
            json::arr(
                s.resident_versions
                    .iter()
                    .map(|v| {
                        json::obj(vec![
                            ("variant", json::s(&v.variant)),
                            ("version", json::n(v.version as f64)),
                            ("bytes", json::n(v.bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "per_variant",
            Json::Obj(
                s.per_variant
                    .iter()
                    .map(|(k, &v)| (k.clone(), json::n(v as f64)))
                    .collect(),
            ),
        ),
        ("base_gemms", json::n(s.base_gemms as f64)),
        ("loader_bytes", json::n(s.loader_bytes as f64)),
        ("module_reads", json::n(s.module_reads as f64)),
        ("modules_inherited", json::n(s.modules_inherited as f64)),
        ("wire_bytes", json::n(s.wire_bytes as f64)),
        ("wire_files", json::n(s.wire_files as f64)),
        ("activation_row_reads", json::n(s.activation_row_reads as f64)),
        ("pool_tasks", json::n(s.pool_tasks as f64)),
        ("pool_steal_or_idle_ns", json::n(s.pool_steal_or_idle_ns as f64)),
        ("engine_steps", json::n(s.engine_steps as f64)),
        ("http_requests", json::n(s.http_requests as f64)),
        ("http_long_polls", json::n(s.http_long_polls as f64)),
        ("prefix_cache_hits", json::n(s.prefix_cache_hits as f64)),
        ("prefix_cache_misses", json::n(s.prefix_cache_misses as f64)),
        ("prefix_cache_bytes", json::n(s.prefix_cache_bytes as f64)),
        ("prefix_rows_skipped", json::n(s.prefix_rows_skipped as f64)),
    ])
}

pub fn snapshot_from_json(j: &Json) -> Result<MetricsSnapshot> {
    let mut resident_versions = Vec::new();
    for rv in j.req_arr("resident_versions")? {
        resident_versions.push(VersionResidency {
            variant: rv.req_str("variant")?.to_string(),
            version: rv.req_usize("version")? as u32,
            bytes: rv.req_usize("bytes")? as u64,
        });
    }
    let mut per_variant = std::collections::BTreeMap::new();
    for (k, v) in j.req("per_variant")?.as_obj().context("'per_variant' is not an object")? {
        per_variant.insert(
            k.clone(),
            v.as_usize().context("'per_variant' values must be counts")? as u64,
        );
    }
    Ok(MetricsSnapshot {
        served: j.req_usize("served")? as u64,
        errors: j.req_usize("errors")? as u64,
        batches: j.req_usize("batches")? as u64,
        mean_batch_size: j.req("mean_batch_size")?.as_f64().context("not a number")?,
        throughput_rps: j.req("throughput_rps")?.as_f64().context("not a number")?,
        queue_p50_us: j.req_usize("queue_p50_us")? as u64,
        queue_p99_us: j.req_usize("queue_p99_us")? as u64,
        compute_p50_us: j.req_usize("compute_p50_us")? as u64,
        compute_p99_us: j.req_usize("compute_p99_us")? as u64,
        total_p50_us: j.req_usize("total_p50_us")? as u64,
        total_p99_us: j.req_usize("total_p99_us")? as u64,
        cold_starts: j.req_usize("cold_starts")? as u64,
        cold_p50_us: j.req_usize("cold_p50_us")? as u64,
        swaps: j.req_usize("swaps")? as u64,
        publishes: j.req_usize("publishes")? as u64,
        rollbacks: j.req_usize("rollbacks")? as u64,
        resident_variants: j.req_usize("resident_variants")?,
        resident_bytes: j.req_usize("resident_bytes")? as u64,
        resident_dense_equiv_bytes: j.req_usize("resident_dense_equiv_bytes")? as u64,
        resident_versions,
        per_variant,
        base_gemms: j.req_usize("base_gemms")? as u64,
        loader_bytes: j.req_usize("loader_bytes")? as u64,
        module_reads: j.req_usize("module_reads")? as u64,
        modules_inherited: j.req_usize("modules_inherited")? as u64,
        wire_bytes: j.req_usize("wire_bytes")? as u64,
        wire_files: j.req_usize("wire_files")? as u64,
        activation_row_reads: j.req_usize("activation_row_reads")? as u64,
        pool_tasks: j.req_usize("pool_tasks")? as u64,
        pool_steal_or_idle_ns: j.req_usize("pool_steal_or_idle_ns")? as u64,
        engine_steps: j.req_usize("engine_steps")? as u64,
        http_requests: j.req_usize("http_requests")? as u64,
        http_long_polls: j.req_usize("http_long_polls")? as u64,
        prefix_cache_hits: j.req_usize("prefix_cache_hits")? as u64,
        prefix_cache_misses: j.req_usize("prefix_cache_misses")? as u64,
        prefix_cache_bytes: j.req_usize("prefix_cache_bytes")? as u64,
        prefix_rows_skipped: j.req_usize("prefix_rows_skipped")? as u64,
    })
}

fn path_json(p: &std::path::Path) -> Json {
    json::s(&p.to_string_lossy())
}

fn opt_u32<'a>(
    mut pairs: Vec<(&'a str, Json)>,
    key: &'a str,
    value: Option<u32>,
) -> Vec<(&'a str, Json)> {
    if let Some(v) = value {
        pairs.push((key, json::n(v as f64)));
    }
    pairs
}

fn get_u32(j: &Json, key: &str) -> Result<Option<u32>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(
            v.as_usize().with_context(|| format!("key '{key}' is not a version number"))? as u32,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_roundtrip() {
        let op = DataOp::Score {
            prompt: "once upon".into(),
            choices: vec!["a time".into(), "a dime".into()],
        };
        let j = query_to_json("ft", &op);
        let (variant, parsed) = query_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(variant, "ft");
        match parsed {
            DataOp::Score { prompt, choices } => {
                assert_eq!(prompt, "once upon");
                assert_eq!(choices, vec!["a time".to_string(), "a dime".to_string()]);
            }
            other => panic!("wrong op: {other:?}"),
        }
    }

    #[test]
    fn score_body_is_bitwise_stable() {
        let scores = vec![-12.345678901234567f64, f64::MIN_POSITIVE, -0.0, 1.0 / 3.0];
        let body = RespBody::Score { choice: 0, scores: scores.clone() };
        let j = data_body_to_json(&body).unwrap();
        let parsed = data_body_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        match parsed {
            RespBody::Score { scores: got, .. } => {
                for (a, b) in scores.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
                }
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    #[test]
    fn admin_op_roundtrip_every_variant() {
        let ops = vec![
            AdminOp::Stats,
            AdminOp::Publish { variant: "ft".into(), artifact: PathBuf::from("/tmp/a.pawd") },
            AdminOp::PublishIncremental {
                variant: "ft".into(),
                artifact: PathBuf::from("/tmp/a.pawd"),
                parent: Some(3),
            },
            AdminOp::PublishIncremental {
                variant: "ft".into(),
                artifact: PathBuf::from("/tmp/a.pawd"),
                parent: None,
            },
            AdminOp::Consolidate { variant: "ft".into(), version: Some(2) },
            AdminOp::Rollback { variant: "ft".into(), to: None },
            AdminOp::Pin { variant: "ft".into(), version: 4 },
            AdminOp::Unpin { variant: "ft".into() },
            AdminOp::Retire { variant: "ft".into(), version: 1 },
            AdminOp::Gc { variant: None },
            AdminOp::Gc { variant: Some("ft".into()) },
            AdminOp::List,
            AdminOp::SyncStatus,
            AdminOp::PullFrom { dir: PathBuf::from("/srv/leader") },
        ];
        for op in ops {
            let (route, body) = admin_op_to_route(&op);
            let parsed =
                admin_op_from_route(route, &Json::parse(&body.to_string()).unwrap()).unwrap();
            assert_eq!(format!("{op:?}"), format!("{parsed:?}"));
        }
    }

    /// `admin_routes::ALL` is the single source of truth for the admin
    /// plane's route names: every `AdminOp` must map onto it (exactly, no
    /// duplicates, no strays) and the README route table must list every
    /// entry. A new op or a renamed route fails here until all three agree.
    #[test]
    fn admin_route_table_has_no_drift() {
        let ops = vec![
            AdminOp::Stats,
            AdminOp::Publish { variant: "ft".into(), artifact: PathBuf::from("/tmp/a.pawd") },
            AdminOp::PublishIncremental {
                variant: "ft".into(),
                artifact: PathBuf::from("/tmp/a.pawd"),
                parent: None,
            },
            AdminOp::Consolidate { variant: "ft".into(), version: None },
            AdminOp::Rollback { variant: "ft".into(), to: None },
            AdminOp::Pin { variant: "ft".into(), version: 1 },
            AdminOp::Unpin { variant: "ft".into() },
            AdminOp::Retire { variant: "ft".into(), version: 1 },
            AdminOp::Gc { variant: None },
            AdminOp::List,
            AdminOp::SyncStatus,
            AdminOp::PullFrom { dir: PathBuf::from("/srv/leader") },
        ];
        // Exactly one table entry per op, and every entry reachable.
        let mut seen = std::collections::BTreeSet::new();
        for op in &ops {
            let (route, _) = admin_op_to_route(op);
            assert!(
                admin_routes::ALL.contains(&route),
                "route '{route}' missing from admin_routes::ALL"
            );
            assert!(seen.insert(route), "route '{route}' produced by two different ops");
        }
        assert_eq!(
            seen.len(),
            admin_routes::ALL.len(),
            "admin_routes::ALL lists a route no AdminOp maps to"
        );
        let uniq: std::collections::BTreeSet<_> = admin_routes::ALL.iter().collect();
        assert_eq!(uniq.len(), admin_routes::ALL.len(), "duplicate entry in admin_routes::ALL");

        // The README's `/v1/admin/<op>` row must enumerate every route.
        let readme = include_str!("../../../README.md");
        let row = readme
            .lines()
            .find(|l| l.contains("/v1/admin/<op>"))
            .expect("README is missing the /v1/admin/<op> route-table row");
        for route in admin_routes::ALL {
            assert!(
                row.contains(&format!("`{route}`")),
                "README admin route row does not mention `{route}`"
            );
        }

        // Unknown segments keep erroring (the HTTP 400 path) and the error
        // names the valid set so operators can self-serve.
        let err = admin_op_from_route("bogus-route", &Json::parse("{}").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("bogus-route") && err.contains(admin_routes::SYNC_STATUS));
    }

    #[test]
    fn sync_report_roundtrip() {
        let r = SyncReport {
            leader_seq: 42,
            up_to_date: false,
            variants_synced: 2,
            versions_installed: 3,
            files_fetched: 3,
            patch_files_fetched: 2,
            artifact_bytes: 123456,
            manifest_bytes: 789,
            warm_failures: 1,
        };
        let j = sync_report_to_json(&r);
        let parsed = sync_report_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(r, parsed);
    }
}
