//! L4 network plane — dependency-free HTTP/1.1 over `std::net`, bridging
//! the coordinator's request and replication seams onto the wire.
//!
//! The paper's serving story assumes many hosts tracking one leader's
//! frequent per-axis delta publishes; this module is the transport that
//! makes "many hosts" literal without pulling in an async runtime or an
//! HTTP crate:
//!
//! * [`http`] — vendored HTTP/1.1 message layer: `Content-Length` bodies
//!   only, typed [`HttpError`](http::HttpError)s, byte *and* time bounds on
//!   every read (slow-loris peers hit deadlines, oversized heads hit caps).
//! * [`router`] — tiny typed route table (`routes!` macro, `:param`
//!   captures, 404/405 distinction).
//! * [`front`] — [`HttpFrontend`]: thread-per-connection server exposing
//!   the data plane (`POST /v1/query`), the admin plane
//!   (`POST /v1/admin/:op`), and the sync plane
//!   (`GET /v1/sync/manifest` long-poll + `GET /v1/sync/file/:name`
//!   crc-tagged, range-resumable artifact streaming).
//! * [`client`] — blocking HTTP client primitives: one-shot requests and
//!   resumable, crc-verified file downloads.
//! * [`transport`] — [`HttpTransport`]: a
//!   [`SyncTransport`](crate::coordinator::SyncTransport) over the sync
//!   plane; idle followers long-poll and pay header bytes only.
//! * [`api`] — [`HttpApiClient`]: typed remote twin of the in-process
//!   [`Client`](crate::coordinator::Client); scores round-trip bitwise.
//! * [`wire`] — JSON codecs mapping [`DataOp`](crate::coordinator::DataOp)
//!   / [`AdminOp`](crate::coordinator::AdminOp) / responses onto the wire
//!   (shortest-roundtrip `f64`s keep score transport exact).
//!
//! Security posture: no auth, no TLS — the plane is for loopback and
//! trusted lab networks; hostile *input* is handled (typed rejections,
//! bounded reads), hostile *peers* are not.

pub mod api;
pub mod client;
pub mod front;
pub mod http;
pub mod router;
pub mod transport;
pub mod wire;

pub use api::{HttpApiClient, QueryReply};
pub use client::{ClientConfig, HttpPeer};
pub use front::{FrontConfig, HttpFrontend};
pub use http::{HttpError, HttpLimits};
pub use transport::HttpTransport;
