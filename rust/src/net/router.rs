//! Tiny typed route table: literal and `:param` segments, fn-pointer
//! handlers over a shared state `S`, declared through the `routes!` macro
//! so the whole surface of the plane reads as one table.
//!
//! ```ignore
//! let router: Router<FrontState> = routes! {
//!     GET  "/v1/healthz"           => health,
//!     POST "/v1/query"             => query,
//!     POST "/v1/admin/:op"         => admin,
//!     GET  "/v1/sync/manifest"     => sync_manifest,
//!     GET  "/v1/sync/file/:name"   => sync_file,
//! };
//! ```
//!
//! Dispatch is linear over the table — the plane has a handful of routes,
//! and a `Vec` scan beats a map for that size while keeping registration
//! order as the tiebreak.

use super::http::{HttpRequest, HttpResponse, Method};

/// Positional `:param` captures for one matched route, in pattern order.
pub struct RouteParams(Vec<String>);

impl RouteParams {
    /// The `i`-th capture. Panics on out-of-range — a handler asking for a
    /// capture its own pattern doesn't declare is a programming error, not
    /// input-dependent.
    pub fn get(&self, i: usize) -> &str {
        &self.0[i]
    }
}

/// Handler signature: shared state, parsed request, captures.
pub type Handler<S> = fn(&S, &HttpRequest, &RouteParams) -> HttpResponse;

enum Seg {
    Lit(String),
    Param,
}

struct Route<S> {
    method: Method,
    segs: Vec<Seg>,
    handler: Handler<S>,
}

pub struct Router<S> {
    routes: Vec<Route<S>>,
}

impl<S> Default for Router<S> {
    fn default() -> Self {
        Router::new()
    }
}

impl<S> Router<S> {
    pub fn new() -> Router<S> {
        Router { routes: Vec::new() }
    }

    /// Register `pattern` (absolute, `/`-separated; `:name` segments
    /// capture). Panics on a malformed pattern — patterns are literals in
    /// the route table, so this fires at construction, never per-request.
    pub fn on(&mut self, method: Method, pattern: &str, handler: Handler<S>) {
        assert!(pattern.starts_with('/'), "route pattern '{pattern}' must start with '/'");
        let segs = pattern[1..]
            .split('/')
            .map(|s| {
                if let Some(name) = s.strip_prefix(':') {
                    assert!(!name.is_empty(), "empty ':param' in route pattern '{pattern}'");
                    Seg::Param
                } else {
                    Seg::Lit(s.to_string())
                }
            })
            .collect();
        self.routes.push(Route { method, segs, handler });
    }

    /// Match and invoke. Unknown path → 404; known path, wrong method →
    /// 405 (so probing tools see the distinction).
    pub fn dispatch(&self, state: &S, req: &HttpRequest) -> HttpResponse {
        let segments: Vec<&str> = req.path[1..].split('/').collect();
        let mut path_matched = false;
        for route in &self.routes {
            let Some(params) = match_segs(&route.segs, &segments) else { continue };
            path_matched = true;
            if route.method != req.method {
                continue;
            }
            return (route.handler)(state, req, &RouteParams(params));
        }
        if path_matched {
            HttpResponse::error(405, "method not allowed for this path")
        } else {
            HttpResponse::error(404, &format!("no route for '{}'", req.path))
        }
    }
}

fn match_segs(pattern: &[Seg], path: &[&str]) -> Option<Vec<String>> {
    if pattern.len() != path.len() {
        return None;
    }
    let mut params = Vec::new();
    for (seg, part) in pattern.iter().zip(path) {
        match seg {
            Seg::Lit(lit) if lit == part => {}
            Seg::Lit(_) => return None,
            Seg::Param => {
                // An empty capture ("/v1/sync/file/") is a miss, not a
                // handler's problem.
                if part.is_empty() {
                    return None;
                }
                params.push(part.to_string());
            }
        }
    }
    Some(params)
}

/// Declare a [`Router`] as a table of `METHOD "pattern" => handler` rows.
macro_rules! routes {
    ($($method:ident $pattern:literal => $handler:expr),+ $(,)?) => {{
        let mut router = $crate::net::router::Router::new();
        $(router.on($crate::net::router::method_token(stringify!($method)), $pattern, $handler);)+
        router
    }};
}
pub(crate) use routes;

/// Resolve the macro's bare `GET`/`POST` tokens. Panics on anything else —
/// again a table-construction error, not request-driven.
pub fn method_token(token: &str) -> Method {
    match token {
        "GET" => Method::Get,
        "POST" => Method::Post,
        other => panic!("routes! supports GET/POST, got '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::http::Method;

    fn req(method: Method, path: &str) -> HttpRequest {
        HttpRequest {
            method,
            path: path.to_string(),
            query: Vec::new(),
            headers: Vec::new(),
            body: Vec::new(),
            wants_close: false,
        }
    }

    fn table() -> Router<()> {
        routes! {
            GET  "/v1/healthz"         => |_, _, _| HttpResponse::empty(200),
            POST "/v1/admin/:op"       => |_, _, p| HttpResponse::error(422, p.get(0)),
            GET  "/v1/sync/file/:name" => |_, _, p| HttpResponse::error(410, p.get(0)),
        }
    }

    #[test]
    fn literal_param_404_405() {
        let r = table();
        assert_eq!(r.dispatch(&(), &req(Method::Get, "/v1/healthz")).status, 200);
        let resp = r.dispatch(&(), &req(Method::Post, "/v1/admin/publish"));
        assert_eq!(resp.status, 422);
        assert!(String::from_utf8(resp.body).unwrap().contains("publish"));
        let resp = r.dispatch(&(), &req(Method::Get, "/v1/sync/file/ft@1.pawd"));
        assert_eq!(resp.status, 410);
        assert!(String::from_utf8(resp.body).unwrap().contains("ft@1.pawd"));
        assert_eq!(r.dispatch(&(), &req(Method::Get, "/nope")).status, 404);
        assert_eq!(r.dispatch(&(), &req(Method::Post, "/v1/healthz")).status, 405);
        assert_eq!(r.dispatch(&(), &req(Method::Get, "/v1/sync/file/")).status, 404);
        assert_eq!(r.dispatch(&(), &req(Method::Get, "/v1/sync/file/a/b")).status, 404);
    }
}
