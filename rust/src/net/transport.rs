//! [`SyncTransport`] over the HTTP sync plane — the network twin of
//! [`FsTransport`](crate::coordinator::FsTransport).
//!
//! `fetch_manifest_wait` rides the server's long-poll: an idle follower
//! parks one `GET /v1/sync/manifest?known_seq=N&timeout_ms=M` per window
//! and pays only header bytes (the `304` path) until a publish bumps the
//! sequence. Artifact fetches stream to disk with crc verification and
//! `Range` resume via [`http_fetch_file`](super::client::http_fetch_file).
//!
//! Wire accounting matches the replicator's conventions: the replicator
//! books manifest *bodies* and whatever `fetch_file` returns, so this
//! transport returns true wire bytes from downloads and books the
//! manifest header overhead itself — `wire_bytes` counters stay honest
//! across transports.

use super::client::{http_fetch_file, http_request, ClientConfig, HttpPeer};
use super::http::Method;
use crate::coordinator::{ManifestFetch, SyncTransport};
use crate::exec::counters;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::time::Duration;

/// HTTP client side of replication. Construct with the leader frontend's
/// `http://host:port` base URL and hand to
/// [`Replicator::new`](crate::coordinator::Replicator).
pub struct HttpTransport {
    peer: HttpPeer,
    cfg: ClientConfig,
}

impl HttpTransport {
    pub fn new(url: &str) -> Result<HttpTransport> {
        HttpTransport::with_config(url, ClientConfig::default())
    }

    pub fn with_config(url: &str, cfg: ClientConfig) -> Result<HttpTransport> {
        Ok(HttpTransport { peer: HttpPeer::parse(url)?, cfg })
    }
}

impl SyncTransport for HttpTransport {
    fn describe(&self) -> String {
        self.peer.base()
    }

    fn fetch_manifest(&self) -> Result<Vec<u8>> {
        let reply =
            http_request(&self.peer, Method::Get, "/v1/sync/manifest", None, &self.cfg)
                .with_context(|| format!("fetching manifest from {}", self.peer.base()))?;
        if reply.status != 200 {
            bail!(
                "manifest fetch from {} got HTTP {}: {}",
                self.peer.base(),
                reply.status,
                reply.body_text()
            );
        }
        // The replicator books the manifest body; the header overhead on
        // this reply is the transport's to record.
        counters::record_wire_bytes(reply.wire_bytes.saturating_sub(reply.body.len() as u64));
        Ok(reply.body)
    }

    fn fetch_manifest_wait(
        &self,
        known_seq: Option<u64>,
        timeout: Duration,
    ) -> Result<ManifestFetch> {
        // No baseline to long-poll against — a cold follower wants the
        // manifest now, not after a change.
        let Some(known) = known_seq else {
            return Ok(ManifestFetch::Full(self.fetch_manifest()?));
        };
        let path = format!(
            "/v1/sync/manifest?known_seq={known}&timeout_ms={}",
            timeout.as_millis()
        );
        // The server may hold this reply open for the whole poll window;
        // budget the head read accordingly.
        let mut cfg = self.cfg;
        cfg.read_timeout = self.cfg.read_timeout.saturating_add(timeout);
        let reply = http_request(&self.peer, Method::Get, &path, None, &cfg)
            .with_context(|| format!("long-polling manifest from {}", self.peer.base()))?;
        match reply.status {
            304 => {
                let seq = reply
                    .header("x-manifest-seq")
                    .and_then(|v| v.parse().ok())
                    .context("304 manifest reply without a parseable X-Manifest-Seq")?;
                Ok(ManifestFetch::Unchanged { seq, wire_bytes: reply.wire_bytes })
            }
            200 => {
                counters::record_wire_bytes(
                    reply.wire_bytes.saturating_sub(reply.body.len() as u64),
                );
                Ok(ManifestFetch::Full(reply.body))
            }
            status => bail!(
                "manifest long-poll against {} got HTTP {status}: {}",
                self.peer.base(),
                reply.body_text()
            ),
        }
    }

    fn fetch_file(&self, file: &str, dest: &Path) -> Result<u64> {
        let path = format!("/v1/sync/file/{}", encode_path_segment(file));
        let outcome = http_fetch_file(&self.peer, &path, dest, &self.cfg)
            .with_context(|| format!("fetching artifact '{file}' from {}", self.peer.base()))?;
        // Report true wire traffic (headers + any resumed overlap), which
        // the replicator books verbatim — same contract as FsTransport's
        // bytes-moved.
        Ok(outcome.wire_bytes)
    }
}

/// Percent-encode one path segment. Artifact names are bare file names
/// (`ft@3.pawd-patch`), but nothing stops a variant name from carrying a
/// byte the request line can't — encode everything outside the unreserved
/// set plus `@`.
fn encode_path_segment(seg: &str) -> String {
    let mut out = String::with_capacity(seg.len());
    for b in seg.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'.' | b'_' | b'~' | b'@' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_segment_encoding() {
        assert_eq!(encode_path_segment("ft@3.pawd-patch"), "ft@3.pawd-patch");
        assert_eq!(encode_path_segment("a b"), "a%20b");
        assert_eq!(encode_path_segment("q?x=1"), "q%3Fx%3D1");
        assert_eq!(encode_path_segment("naïve"), "na%C3%AFve");
    }
}
