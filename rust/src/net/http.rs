//! Vendored HTTP/1.1 message layer over any `Read`/`Write` pair.
//!
//! Deliberately tiny: `Content-Length` bodies only (chunked transfer
//! encoding is refused with `501`), two methods, no compression, no TLS.
//! What it *is* careful about is hostile input — every parse failure is a
//! typed [`HttpError`] that maps to a status code and a clean connection
//! drop, and all reads are bounded in both bytes ([`HttpLimits`]) and time
//! (deadlines enforced through the socket's `read_timeout`, so a slow-loris
//! peer trickling one byte per poll still hits the head/body deadline).
//!
//! [`HttpConn`] owns the read side of one connection and carries pipelined
//! leftover bytes between requests, so keep-alive costs nothing extra.

use crate::util::json::Json;
use std::io::{ErrorKind, Read, Write};
use std::time::{Duration, Instant};

/// Request methods the plane serves. Anything else parses into a typed
/// [`HttpError::Unsupported`] (a `501`, not a panic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Get,
    Post,
}

impl Method {
    pub fn as_str(&self) -> &'static str {
        match self {
            Method::Get => "GET",
            Method::Post => "POST",
        }
    }
}

/// Byte/time bounds for reading one message off a connection.
#[derive(Clone, Copy, Debug)]
pub struct HttpLimits {
    /// Cap on request-line + headers bytes (431 beyond it).
    pub max_head_bytes: usize,
    /// Cap on declared `Content-Length` (413 beyond it).
    pub max_body_bytes: u64,
    /// Wall-clock budget to receive the full head (408 beyond it).
    pub head_deadline: Duration,
    /// Wall-clock budget to receive the full body (408 beyond it).
    pub body_deadline: Duration,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_head_bytes: 8 * 1024,
            max_body_bytes: 1 << 20,
            head_deadline: Duration::from_secs(5),
            body_deadline: Duration::from_secs(15),
        }
    }
}

/// Every way reading a message can fail. `status` says what (if anything)
/// is worth telling the peer before dropping the connection.
#[derive(Debug)]
pub enum HttpError {
    /// The peer closed mid-message.
    Truncated,
    /// A head/body deadline expired before the message completed.
    Timeout,
    /// Head grew past [`HttpLimits::max_head_bytes`].
    HeadTooLarge { limit: usize },
    /// Declared body exceeds [`HttpLimits::max_body_bytes`].
    BodyTooLarge { declared: u64, limit: u64 },
    /// Anything structurally wrong: bad request line, bad header, bad
    /// escape, non-UTF-8 head, traversal path…
    Malformed(String),
    /// Structurally valid HTTP the plane chooses not to speak (chunked
    /// bodies, exotic methods, HTTP/2 preludes).
    Unsupported(&'static str),
    /// Transport-level I/O failure.
    Io(std::io::Error),
}

impl HttpError {
    /// Status code worth answering with before the drop; `None` means the
    /// peer is gone (or never spoke HTTP) and writing is pointless.
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::Truncated | HttpError::Io(_) => None,
            HttpError::Timeout => Some(408),
            HttpError::HeadTooLarge { .. } => Some(431),
            HttpError::BodyTooLarge { .. } => Some(413),
            HttpError::Malformed(_) => Some(400),
            HttpError::Unsupported(_) => Some(501),
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Truncated => write!(f, "connection closed mid-message"),
            HttpError::Timeout => write!(f, "message did not complete within the deadline"),
            HttpError::HeadTooLarge { limit } => {
                write!(f, "request head exceeds {limit} bytes")
            }
            HttpError::BodyTooLarge { declared, limit } => {
                write!(f, "declared body of {declared} bytes exceeds the {limit}-byte cap")
            }
            HttpError::Malformed(m) => write!(f, "malformed message: {m}"),
            HttpError::Unsupported(what) => write!(f, "unsupported: {what}"),
            HttpError::Io(e) => write!(f, "i/o: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// One parsed request. `path` and query parts are percent-decoded; header
/// names are lowercased at parse time so lookups are case-insensitive.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: Method,
    pub path: String,
    pub query: Vec<(String, String)>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Peer asked to close after this response (`Connection: close` or
    /// HTTP/1.0 without keep-alive).
    pub wants_close: bool,
}

impl HttpRequest {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// First query parameter with this name.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }
}

/// The read side of one connection, carrying pipelined leftovers between
/// messages.
pub struct HttpConn<R> {
    inner: R,
    carry: Vec<u8>,
}

impl<R: Read> HttpConn<R> {
    pub fn new(inner: R) -> HttpConn<R> {
        HttpConn { inner, carry: Vec::new() }
    }

    pub fn get_mut(&mut self) -> &mut R {
        &mut self.inner
    }

    /// Read and parse one request. `Ok(None)` is a clean close between
    /// requests (keep-alive peer going away); every other shortfall is a
    /// typed error.
    pub fn read_request(&mut self, limits: &HttpLimits) -> Result<Option<HttpRequest>, HttpError> {
        let carry = std::mem::take(&mut self.carry);
        let deadline = Instant::now() + limits.head_deadline;
        let Some((head, mut rest)) =
            read_head(&mut self.inner, carry, limits.max_head_bytes, deadline)?
        else {
            return Ok(None);
        };
        let head = std::str::from_utf8(&head)
            .map_err(|_| HttpError::Malformed("head is not valid UTF-8".into()))?;
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split(' ');
        let first_four = (parts.next(), parts.next(), parts.next(), parts.next());
        let (method, target, version) = match first_four {
            (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
            _ => {
                return Err(HttpError::Malformed(format!(
                    "bad request line '{}'",
                    truncate_for_log(request_line)
                )))
            }
        };
        let method = match method {
            "GET" => Method::Get,
            "POST" => Method::Post,
            _ => return Err(HttpError::Unsupported("method")),
        };
        if version != "HTTP/1.1" && version != "HTTP/1.0" {
            return Err(HttpError::Unsupported("http version"));
        }
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let Some((name, value)) = line.split_once(':') else {
                return Err(HttpError::Malformed(format!(
                    "header line without ':' ('{}')",
                    truncate_for_log(line)
                )));
            };
            let name = name.trim();
            if name.is_empty() || !name.bytes().all(is_token_byte) {
                return Err(HttpError::Malformed("bad header name".into()));
            }
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }
        let (path, query) = parse_target(target)?;
        if headers.iter().any(|(n, _)| n == "transfer-encoding") {
            return Err(HttpError::Unsupported("transfer-encoding"));
        }
        let declared: u64 = match headers.iter().find(|(n, _)| n == "content-length") {
            None => 0,
            Some((_, v)) => v
                .parse()
                .map_err(|_| HttpError::Malformed(format!("bad content-length '{v}'")))?,
        };
        if declared > limits.max_body_bytes {
            return Err(HttpError::BodyTooLarge {
                declared,
                limit: limits.max_body_bytes,
            });
        }
        let declared = declared as usize;
        let mut body;
        if rest.len() >= declared {
            body = rest;
            self.carry = body.split_off(declared);
        } else {
            let body_deadline = Instant::now() + limits.body_deadline;
            fill_until(&mut self.inner, &mut rest, declared, body_deadline)?;
            body = rest;
            self.carry = body.split_off(declared);
        }
        let wants_close = match headers.iter().find(|(n, _)| n == "connection") {
            Some((_, v)) => v.eq_ignore_ascii_case("close"),
            None => version == "HTTP/1.0",
        };
        Ok(Some(HttpRequest { method, path, query, headers, body, wants_close }))
    }
}

/// Accumulate bytes until the `\r\n\r\n` head terminator. Returns the head
/// (terminator stripped) and any over-read bytes, or `None` on a clean
/// close before the first byte.
pub(crate) fn read_head<R: Read>(
    r: &mut R,
    mut buf: Vec<u8>,
    max_head: usize,
    deadline: Instant,
) -> Result<Option<(Vec<u8>, Vec<u8>)>, HttpError> {
    loop {
        if let Some(pos) = find_terminator(&buf) {
            if pos > max_head {
                return Err(HttpError::HeadTooLarge { limit: max_head });
            }
            let rest = buf.split_off(pos + 4);
            buf.truncate(pos);
            return Ok(Some((buf, rest)));
        }
        // Without a terminator in L buffered bytes the head is ≥ L-3 bytes
        // (the terminator could straddle the buffer end), so past this point
        // it is over the cap no matter what arrives next. The buffer may
        // legitimately exceed the head cap when a pipelined peer's next body
        // rides in the carry — that is why the found-terminator branch
        // checks `pos`, not the buffer length.
        if buf.len() > max_head + 3 {
            return Err(HttpError::HeadTooLarge { limit: max_head });
        }
        if Instant::now() >= deadline {
            return Err(HttpError::Timeout);
        }
        let mut chunk = [0u8; 2048];
        match r.read(&mut chunk) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(HttpError::Truncated);
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

/// Read until `buf` holds at least `want` bytes or the deadline expires.
pub(crate) fn fill_until<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    want: usize,
    deadline: Instant,
) -> Result<(), HttpError> {
    while buf.len() < want {
        if Instant::now() >= deadline {
            return Err(HttpError::Timeout);
        }
        let mut chunk = [0u8; 8192];
        match r.read(&mut chunk) {
            Ok(0) => return Err(HttpError::Truncated),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
    Ok(())
}

fn find_terminator(buf: &[u8]) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn is_token_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

/// Split and percent-decode `path[?query]`. Decoded paths must stay inside
/// the route namespace: absolute, no `..` segment, no NUL.
fn parse_target(target: &str) -> Result<(String, Vec<(String, String)>), HttpError> {
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    let path = percent_decode(raw_path, false)?;
    if !path.starts_with('/') || path.contains('\0') {
        return Err(HttpError::Malformed(format!("bad path '{}'", truncate_for_log(&path))));
    }
    if path.split('/').any(|seg| seg == "..") {
        return Err(HttpError::Malformed("path traversal ('..') rejected".into()));
    }
    let mut query = Vec::new();
    if let Some(q) = raw_query {
        for pair in q.split('&').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.push((percent_decode(k, true)?, percent_decode(v, true)?));
        }
    }
    Ok((path, query))
}

/// Percent-decode one component. In query position `+` means space.
fn percent_decode(s: &str, plus_is_space: bool) -> Result<String, HttpError> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes
                    .get(i + 1..i + 3)
                    .and_then(|h| std::str::from_utf8(h).ok())
                    .and_then(|h| u8::from_str_radix(h, 16).ok());
                match hex {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => return Err(HttpError::Malformed("bad percent-escape".into())),
                }
            }
            b'+' if plus_is_space => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out)
        .map_err(|_| HttpError::Malformed("decoded component is not valid UTF-8".into()))
}

fn truncate_for_log(s: &str) -> String {
    const CAP: usize = 80;
    if s.len() <= CAP {
        s.to_string()
    } else {
        let mut end = CAP;
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        format!("{}…", &s[..end])
    }
}

/// One response to serialize. Bodies are in-memory (`Vec<u8>`); artifact
/// files are small enough (MBs) that the file route reads them once — it
/// needs the whole file for the crc header anyway.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn empty(status: u16) -> HttpResponse {
        HttpResponse { status, headers: Vec::new(), body: Vec::new() }
    }

    pub fn json(status: u16, body: &Json) -> HttpResponse {
        HttpResponse::bytes(status, "application/json", body.to_string().into_bytes())
    }

    pub fn bytes(status: u16, content_type: &str, body: Vec<u8>) -> HttpResponse {
        HttpResponse {
            status,
            headers: vec![("Content-Type".into(), content_type.into())],
            body,
        }
    }

    /// `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: &str) -> HttpResponse {
        use crate::util::json::{obj, s};
        HttpResponse::json(status, &obj(vec![("error", s(msg))]))
    }

    pub fn with_header(mut self, name: &str, value: &str) -> HttpResponse {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize onto the wire. Returns bytes written (head + body).
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<u64> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, status_reason(self.status));
        for (name, value) in &self.headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str(&format!("Content-Length: {}\r\n", self.body.len()));
        head.push_str(if keep_alive {
            "Connection: keep-alive\r\n\r\n"
        } else {
            "Connection: close\r\n\r\n"
        });
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok((head.len() + self.body.len()) as u64)
    }
}

pub(crate) fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        204 => "No Content",
        206 => "Partial Content",
        304 => "Not Modified",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        416 => "Range Not Satisfiable",
        422 => "Unprocessable Entity",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Option<HttpRequest>, HttpError> {
        HttpConn::new(Cursor::new(raw.to_vec())).read_request(&HttpLimits::default())
    }

    #[test]
    fn parses_get_with_query() {
        let raw: &[u8] = b"GET /v1/sync/manifest?known_seq=7&timeout_ms=100 HTTP/1.1\r\n\
                           Host: x\r\n\r\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, Method::Get);
        assert_eq!(req.path, "/v1/sync/manifest");
        assert_eq!(req.query_param("known_seq"), Some("7"));
        assert_eq!(req.query_param("timeout_ms"), Some("100"));
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(!req.wants_close);
    }

    #[test]
    fn parses_post_with_body_and_pipelined_leftover() {
        let raw = b"POST /v1/query HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdGET / HTTP/1.1\r\n\r\n";
        let mut conn = HttpConn::new(Cursor::new(raw.to_vec()));
        let limits = HttpLimits::default();
        let first = conn.read_request(&limits).unwrap().unwrap();
        assert_eq!(first.body, b"abcd");
        let second = conn.read_request(&limits).unwrap().unwrap();
        assert_eq!(second.method, Method::Get);
        assert_eq!(second.path, "/");
        assert!(conn.read_request(&limits).unwrap().is_none(), "clean close after pipeline");
    }

    #[test]
    fn percent_decoding_and_plus() {
        let req =
            parse(b"GET /v1/sync/file/ft%401.pawd?q=a+b%21 HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.path, "/v1/sync/file/ft@1.pawd");
        assert_eq!(req.query_param("q"), Some("a b!"));
    }

    #[test]
    fn typed_rejections() {
        assert!(matches!(parse(b"BREW /pot HTTP/1.1\r\n\r\n"), Err(HttpError::Unsupported(_))));
        assert!(matches!(parse(b"GET / HTTP/2.0\r\n\r\n"), Err(HttpError::Unsupported(_))));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::Unsupported(_))
        ));
        assert!(matches!(parse(b"GET /../etc HTTP/1.1\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(
            parse(b"GET /%2e%2e/etc HTTP/1.1\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n"),
            Err(HttpError::BodyTooLarge { .. })
        ));
        assert!(matches!(parse(b"GET / HTT"), Err(HttpError::Truncated)));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(HttpError::Truncated)
        ));
        assert!(parse(b"").unwrap().is_none(), "clean close");
    }

    #[test]
    fn head_size_cap() {
        let mut raw = b"GET / HTTP/1.1\r\nX-Filler: ".to_vec();
        raw.resize(raw.len() + 10_000, b'a');
        raw.extend_from_slice(b"\r\n\r\n");
        assert!(matches!(parse(&raw), Err(HttpError::HeadTooLarge { .. })));
    }

    #[test]
    fn response_roundtrip_shape() {
        let resp = HttpResponse::json(200, &crate::util::json::obj(vec![("ok", Json::Bool(true))]))
            .with_header("X-Manifest-Seq", "9");
        let mut out = Vec::new();
        let n = resp.write_to(&mut out, true).unwrap();
        assert_eq!(n as usize, out.len());
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("X-Manifest-Seq: 9\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("{\"ok\":true}"));
    }
}
