//! Typed HTTP client for the data/admin planes — the network mirror of the
//! in-process [`Client`](crate::coordinator::Client).
//!
//! Scores travel as shortest-roundtrip `f64` JSON (see
//! [`util::json`](crate::util::json)), so a score fetched through here is
//! bitwise-equal to one answered in-process. Engine-level rejections
//! (unknown variant, retired version…) come back as `422` and surface as
//! `Err` with the engine's own message, exactly like the local client's
//! `Result<_, String>` lane.

use super::client::{http_request, ClientConfig, HttpPeer};
use super::http::Method;
use super::wire;
use crate::coordinator::{
    AdminOp, AdminResp, ApiClient, ApiReply, DataOp, MetricsSnapshot, RespBody,
};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::result::Result as StdResult;

/// One data-plane answer: which version actually served, and the body.
#[derive(Debug)]
pub struct QueryReply {
    pub variant: String,
    pub version: Option<u32>,
    pub body: RespBody,
}

/// Remote coordinator handle speaking the `/v1/query` + `/v1/admin/:op`
/// planes of a [`HttpFrontend`](super::front::HttpFrontend).
pub struct HttpApiClient {
    peer: HttpPeer,
    cfg: ClientConfig,
}

impl HttpApiClient {
    pub fn new(url: &str) -> Result<HttpApiClient> {
        HttpApiClient::with_config(url, ClientConfig::default())
    }

    pub fn with_config(url: &str, cfg: ClientConfig) -> Result<HttpApiClient> {
        Ok(HttpApiClient { peer: HttpPeer::parse(url)?, cfg })
    }

    /// Multiple-choice score over HTTP; same contract as
    /// [`Client::score`](crate::coordinator::Client::score).
    pub fn score(&self, variant: &str, prompt: &str, choices: &[String]) -> Result<QueryReply> {
        self.query(
            variant,
            &DataOp::Score { prompt: prompt.to_string(), choices: choices.to_vec() },
        )
    }

    pub fn perplexity(&self, variant: &str, text: &str) -> Result<QueryReply> {
        self.query(variant, &DataOp::Perplexity { text: text.to_string() })
    }

    fn query(&self, variant: &str, op: &DataOp) -> Result<QueryReply> {
        let body = wire::query_to_json(variant, op).to_string().into_bytes();
        let reply = http_request(
            &self.peer,
            Method::Post,
            "/v1/query",
            Some(("application/json", &body)),
            &self.cfg,
        )
        .with_context(|| format!("querying {}", self.peer.base()))?;
        if reply.status != 200 {
            bail!("query got HTTP {}: {}", reply.status, error_text(&reply.body));
        }
        let j = parse_body(&reply.body).context("parsing query reply")?;
        let body = j.get("body").context("query reply missing 'body'")?;
        Ok(QueryReply {
            variant: j.req_str("variant").context("query reply")?.to_string(),
            version: j.get("version").and_then(Json::as_usize).map(|v| v as u32),
            body: wire::data_body_from_json(body)?,
        })
    }

    /// Control-plane op over HTTP; same contract as
    /// [`Client::admin`](crate::coordinator::Client::admin).
    pub fn admin(&self, op: &AdminOp) -> Result<AdminResp> {
        let (route, body_json) = wire::admin_op_to_route(op);
        let body = body_json.to_string().into_bytes();
        let reply = http_request(
            &self.peer,
            Method::Post,
            &format!("/v1/admin/{route}"),
            Some(("application/json", &body)),
            &self.cfg,
        )
        .with_context(|| format!("admin '{route}' against {}", self.peer.base()))?;
        if reply.status != 200 {
            bail!("admin '{route}' got HTTP {}: {}", reply.status, error_text(&reply.body));
        }
        wire::admin_resp_from_json(&parse_body(&reply.body)?)
            .with_context(|| format!("parsing admin '{route}' reply"))
    }

    pub fn stats(&self) -> Result<MetricsSnapshot> {
        match self.admin(&AdminOp::Stats)? {
            AdminResp::Stats { snapshot } => Ok(*snapshot),
            other => bail!("unexpected stats response {other:?}"),
        }
    }

    /// `GET /v1/healthz` — `Ok` when the frontend answers 200.
    pub fn health(&self) -> Result<()> {
        let reply = http_request(&self.peer, Method::Get, "/v1/healthz", None, &self.cfg)?;
        if reply.status != 200 {
            bail!("health check got HTTP {}", reply.status);
        }
        Ok(())
    }
}

/// The transport-agnostic [`ApiClient`] surface: same contract as the
/// in-process impl, with transport failures folded into the `String` error
/// lane (context chain flattened, `{:#}`).
impl ApiClient for HttpApiClient {
    fn score(
        &self,
        variant: &str,
        prompt: &str,
        choices: &[String],
    ) -> StdResult<ApiReply, String> {
        HttpApiClient::score(self, variant, prompt, choices)
            .map(into_reply)
            .map_err(|e| format!("{e:#}"))
    }

    fn perplexity(&self, variant: &str, text: &str) -> StdResult<ApiReply, String> {
        HttpApiClient::perplexity(self, variant, text)
            .map(into_reply)
            .map_err(|e| format!("{e:#}"))
    }

    fn admin(&self, op: AdminOp) -> StdResult<AdminResp, String> {
        HttpApiClient::admin(self, &op).map_err(|e| format!("{e:#}"))
    }

    fn health(&self) -> StdResult<(), String> {
        HttpApiClient::health(self).map_err(|e| format!("{e:#}"))
    }
}

fn into_reply(q: QueryReply) -> ApiReply {
    ApiReply { variant: q.variant, version: q.version, body: q.body }
}

fn parse_body(body: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(body).context("reply body is not UTF-8")?;
    Json::parse(text).context("reply body is not JSON")
}

/// Pull the `{"error": …}` message out of an error reply, falling back to
/// the raw (truncated) body.
fn error_text(body: &[u8]) -> String {
    if let Ok(j) = parse_body(body) {
        if let Some(msg) = j.get("error").and_then(Json::as_str) {
            return msg.to_string();
        }
    }
    let text = String::from_utf8_lossy(body);
    let text = text.trim();
    let mut end = text.len().min(200);
    while !text.is_char_boundary(end) {
        end -= 1;
    }
    text[..end].to_string()
}
