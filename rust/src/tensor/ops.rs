//! Neural-net primitive ops over flat f32 buffers: RMSNorm, softmax,
//! SiLU, RoPE, log-softmax. These mirror `python/compile/model.py` exactly —
//! the native Rust forward pass is the parity oracle for the AOT runtime, so
//! every epsilon and convention here must match the JAX side.

/// RMSNorm: `x * w / sqrt(mean(x^2) + eps)` over the last dimension.
pub const RMS_EPS: f32 = 1e-5;

pub fn rmsnorm_into(x: &[f32], w: &[f32], out: &mut [f32]) {
    let d = w.len();
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(out.len(), d);
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
    let inv = 1.0 / (ms + RMS_EPS as f64).sqrt() as f32;
    for i in 0..d {
        out[i] = x[i] * inv * w[i];
    }
}

/// In-place numerically-stable softmax over a row.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Log-softmax of one row into `out` (used for LM scoring).
pub fn log_softmax_into(row: &[f32], out: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
    for (o, &x) in out.iter_mut().zip(row) {
        *o = x - lse;
    }
}

/// SiLU (a.k.a. swish): `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotary position embedding tables for `max_seq` positions and `head_dim`.
///
/// Convention (matches `model.py`): `inv_freq[i] = base^{-2i/head_dim}` for
/// i in [0, head_dim/2); angle `θ(pos, i) = pos · inv_freq[i]`; cos/sin are
/// laid out `[pos][head_dim]` with the half-table duplicated
/// (`cos[pos][i] == cos[pos][i + head_dim/2]`), and rotate-half:
/// `q' = q·cos + rotate_half(q)·sin`, `rotate_half(q) = [-q2, q1]`.
pub struct RopeTable {
    pub head_dim: usize,
    pub max_seq: usize,
    /// `[max_seq * head_dim]`
    pub cos: Vec<f32>,
    pub sin: Vec<f32>,
}

pub const ROPE_BASE: f32 = 10_000.0;

impl RopeTable {
    pub fn new(head_dim: usize, max_seq: usize) -> Self {
        assert!(head_dim % 2 == 0, "RoPE head_dim must be even");
        let half = head_dim / 2;
        let mut cos = vec![0f32; max_seq * head_dim];
        let mut sin = vec![0f32; max_seq * head_dim];
        for pos in 0..max_seq {
            for i in 0..half {
                let inv_freq = (ROPE_BASE as f64).powf(-2.0 * i as f64 / head_dim as f64);
                let ang = pos as f64 * inv_freq;
                let (s, c) = (ang.sin() as f32, ang.cos() as f32);
                cos[pos * head_dim + i] = c;
                cos[pos * head_dim + half + i] = c;
                sin[pos * head_dim + i] = s;
                sin[pos * head_dim + half + i] = s;
            }
        }
        RopeTable { head_dim, max_seq, cos, sin }
    }

    /// Apply RoPE in place to one head vector `q` at position `pos`.
    pub fn apply(&self, q: &mut [f32], pos: usize) {
        debug_assert_eq!(q.len(), self.head_dim);
        debug_assert!(pos < self.max_seq);
        let half = self.head_dim / 2;
        let cos = &self.cos[pos * self.head_dim..(pos + 1) * self.head_dim];
        let sin = &self.sin[pos * self.head_dim..(pos + 1) * self.head_dim];
        for i in 0..half {
            let a = q[i];
            let b = q[half + i];
            q[i] = a * cos[i] - b * sin[i];
            q[half + i] = b * cos[half + i] + a * sin[half + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0f32; 2];
        rmsnorm_into(&x, &w, &mut out);
        // mean square = 12.5, norm = sqrt(12.5+eps)
        let inv = 1.0 / (12.5f32 + RMS_EPS).sqrt();
        assert!((out[0] - 3.0 * inv).abs() < 1e-6);
        assert!((out[1] - 4.0 * inv).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut row = vec![1000.0f32, 1001.0, 999.0];
        softmax_inplace(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(row.iter().all(|&p| p.is_finite() && p >= 0.0));
        assert!(row[1] > row[0] && row[0] > row[2]);
    }

    #[test]
    fn log_softmax_consistency() {
        let row = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut ls = vec![0f32; 4];
        log_softmax_into(&row, &mut ls);
        let mut sm = row.clone();
        softmax_inplace(&mut sm);
        for (l, p) in ls.iter().zip(&sm) {
            assert!((l.exp() - p).abs() < 1e-5);
        }
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 0.731_058_6).abs() < 1e-5);
        assert!(silu(-20.0).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut table = RopeTable::new(8, 16);
        let q0 = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        for pos in [0usize, 1, 7, 15] {
            let mut q = q0;
            table.apply(&mut q, pos);
            let n0: f32 = q0.iter().map(|x| x * x).sum();
            let n1: f32 = q.iter().map(|x| x * x).sum();
            assert!((n0 - n1).abs() / n0 < 1e-5, "pos {pos}");
        }
        // Position 0 is identity.
        let mut q = q0;
        table.apply(&mut q, 0);
        assert_eq!(q, q0);
        let _ = &mut table;
    }

    #[test]
    fn rope_relative_property() {
        // <RoPE(q,m), RoPE(k,n)> depends only on m-n for same q,k.
        let table = RopeTable::new(4, 64);
        let q0 = [0.3f32, -0.7, 1.1, 0.2];
        let k0 = [-0.5f32, 0.9, 0.4, -1.3];
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let at = |m: usize, n: usize| {
            let mut q = q0;
            let mut k = k0;
            table.apply(&mut q, m);
            table.apply(&mut k, n);
            dot(&q, &k)
        };
        assert!((at(3, 1) - at(10, 8)).abs() < 1e-4);
        assert!((at(5, 5) - at(20, 20)).abs() < 1e-4);
    }
}
