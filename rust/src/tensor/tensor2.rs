//! Row-major 2-D f32 tensor with blocked, multithreaded matmul.
//!
//! This is the CPU math substrate for the native transformer forward pass
//! (the parity oracle for the XLA runtime), the calibration solver and the
//! delta apply path. Weights are stored `[d_out, d_in]` (PyTorch `Linear`
//! convention), so the hot product is `y = x · Wᵀ`, a row-by-row dot that is
//! cache-friendly for both operands without transposition.

use crate::util::par;
use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor2 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor2[{}x{}]", self.rows, self.cols)
    }
}

impl Default for Tensor2 {
    fn default() -> Self {
        Tensor2 { rows: 0, cols: 0, data: Vec::new() }
    }
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor2 { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Tensor2 { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Tensor2 {
        let mut out = Tensor2::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// `self · otherᵀ`: `[m,k] x [n,k] -> [m,n]`.
    ///
    /// The workhorse: `x · Wᵀ` with W stored `[n=d_out, k=d_in]`. Parallel
    /// over output rows; inner dot unrolled 4-wide so LLVM autovectorizes.
    pub fn matmul_bt(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.cols, "matmul_bt inner dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Tensor2::zeros(m, n);
        let a = &self.data;
        let b = &other.data;
        par::parallel_rows_mut(&mut out.data, m, n, 8, |row0, chunk| {
            for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
                let arow = &a[(row0 + ri) * k..(row0 + ri + 1) * k];
                for (j, o) in out_row.iter_mut().enumerate() {
                    *o = dot(arow, &b[j * k..(j + 1) * k]);
                }
            }
        });
        out
    }

    /// `self · other`: `[m,k] x [k,n] -> [m,n]` (used by calibration math).
    pub fn matmul(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.rows, "matmul inner dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor2::zeros(m, n);
        let a = &self.data;
        let b = &other.data;
        par::parallel_rows_mut(&mut out.data, m, n, 8, |row0, chunk| {
            for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
                let arow = &a[(row0 + ri) * k..(row0 + ri + 1) * k];
                // i-k-j loop order: stream b rows, accumulate into out_row.
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in out_row.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        });
        out
    }

    /// Gram matrix `selfᵀ · self` (`[k,k]` for `[m,k]` input), symmetric.
    pub fn gram(&self) -> Tensor2 {
        let (m, k) = (self.rows, self.cols);
        let mut out = Tensor2::zeros(k, k);
        // Accumulate row outer products; parallel over output rows requires
        // a transposed view, so do column-blocked accumulation instead.
        let a = &self.data;
        par::parallel_rows_mut(&mut out.data, k, k, 4, |row0, chunk| {
            let rows_here = chunk.len() / k;
            for mi in 0..m {
                let arow = &a[mi * k..(mi + 1) * k];
                for rloc in 0..rows_here {
                    let i = row0 + rloc;
                    let ai = arow[i];
                    if ai == 0.0 {
                        continue;
                    }
                    let orow = &mut chunk[rloc * k..(rloc + 1) * k];
                    for (o, &aj) in orow.iter_mut().zip(arow) {
                        *o += ai * aj;
                    }
                }
            }
        });
        out
    }

    pub fn add_assign(&mut self, other: &Tensor2) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Tensor2 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Mean squared difference against another tensor.
    pub fn mse(&self, other: &Tensor2) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }
}

/// Unrolled dot product; LLVM vectorizes this to AVX on release builds.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += a[o] * b[o];
        s1 += a[o + 1] * b[o + 1];
        s2 += a[o + 2] * b[o + 2];
        s3 += a[o + 3] * b[o + 3];
        s4 += a[o + 4] * b[o + 4];
        s5 += a[o + 5] * b[o + 5];
        s6 += a[o + 6] * b[o + 6];
        s7 += a[o + 7] * b[o + 7];
    }
    let mut s = (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x` (used in calibration gradient steps).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Solve the symmetric positive-definite system `A·x = b` in place via
/// Cholesky (A is the calibration Gram matrix + ridge). Returns None if A is
/// not positive definite even after the caller's ridge.
pub fn cholesky_solve(a: &Tensor2, b: &[f32]) -> Option<Vec<f32>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(a.rows, b.len());
    let n = a.rows;
    // Lower-triangular factor, row-major.
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    // Forward solve L·y = b.
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // Back solve Lᵀ·x = y.
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    Some(x.into_iter().map(|v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randt(r: &mut Rng, rows: usize, cols: usize) -> Tensor2 {
        let mut t = Tensor2::zeros(rows, cols);
        r.fill_normal(&mut t.data, 1.0);
        t
    }

    fn matmul_naive(a: &Tensor2, b: &Tensor2) -> Tensor2 {
        let mut out = Tensor2::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0f32;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut r = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (16, 32, 8), (33, 17, 65)] {
            let a = randt(&mut r, m, k);
            let b = randt(&mut r, k, n);
            let got = a.matmul(&b);
            let want = matmul_naive(&a, &b);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-3, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn matmul_bt_matches_matmul_of_transpose() {
        let mut r = Rng::new(2);
        for &(m, k, n) in &[(4, 8, 4), (7, 13, 29), (64, 128, 32)] {
            let a = randt(&mut r, m, k);
            let w = randt(&mut r, n, k);
            let got = a.matmul_bt(&w);
            let want = a.matmul(&w.transpose());
            for (g, v) in got.data.iter().zip(&want.data) {
                assert!((g - v).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn gram_is_symmetric_and_correct() {
        let mut r = Rng::new(3);
        let x = randt(&mut r, 37, 11);
        let g = x.gram();
        let want = x.transpose().matmul(&x);
        for i in 0..11 {
            for j in 0..11 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-4);
                assert!((g.at(i, j) - want.at(i, j)).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn dot_matches_scalar_loop() {
        let mut r = Rng::new(4);
        for n in [0, 1, 7, 8, 9, 63, 64, 100] {
            let mut a = vec![0f32; n];
            let mut b = vec![0f32; n];
            r.fill_normal(&mut a, 1.0);
            r.fill_normal(&mut b, 1.0);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-3);
        }
    }

    #[test]
    fn cholesky_solves_spd_system() {
        let mut r = Rng::new(5);
        let n = 24;
        let x = randt(&mut r, 64, n);
        let mut a = x.gram();
        for i in 0..n {
            *a.at_mut(i, i) += 1.0; // ridge -> SPD
        }
        let mut truth = vec![0f32; n];
        r.fill_normal(&mut truth, 1.0);
        // b = A·truth
        let b: Vec<f32> = (0..n).map(|i| dot(a.row(i), &truth)).collect();
        let solved = cholesky_solve(&a, &b).expect("SPD");
        for (s, t) in solved.iter().zip(&truth) {
            assert!((s - t).abs() < 1e-2, "{s} vs {t}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky_solve(&a, &[1.0, 1.0]).is_none());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut r = Rng::new(6);
        let t = randt(&mut r, 5, 9);
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn mse_of_self_is_zero() {
        let mut r = Rng::new(7);
        let t = randt(&mut r, 8, 8);
        assert_eq!(t.mse(&t), 0.0);
    }
}
