//! CPU tensor math substrate: 2-D f32 tensors, blocked matmul, and the
//! neural-net primitives (RMSNorm/softmax/SiLU/RoPE) used by the native
//! transformer forward pass and the calibration solver.

pub mod ops;
pub mod tensor2;

pub use tensor2::{axpy, cholesky_solve, dot, Tensor2};
