//! Model configurations.
//!
//! The paper evaluates three base/fine-tune pairs (Llama-3.1-8B, Qwen3-14B,
//! Phi-4). Offline we cannot load those checkpoints, so each pair is
//! replaced by a *-mini* preset with a distinct width/depth/FF-ratio (the
//! axis-preference statistics of Figure 2 depend on weight aspect ratios,
//! so the presets deliberately differ in that respect). `base-110m` exists
//! for larger-scale runs of the same pipeline.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    /// Byte-level vocabulary (256) in all presets.
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// MLP hidden width.
    pub ff: usize,
    /// Maximum sequence length (RoPE table size, AOT shape bound).
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Total number of f32 parameters in the flat layout.
    pub fn n_params(&self) -> usize {
        let d = self.dim;
        let f = self.ff;
        let v = self.vocab;
        // embed + L * (attn_norm + q,k,v,o + mlp_norm + gate,up,down) + final_norm + lm_head
        v * d + self.n_layers * (d + 4 * d * d + d + 2 * f * d + d * f) + d + v * d
    }

    /// Number of patchable linear modules (attention + MLP projections).
    pub fn n_patchable(&self) -> usize {
        self.n_layers * 7
    }

    pub fn validate(&self) -> Result<()> {
        if self.dim % self.n_heads != 0 {
            bail!("dim {} not divisible by n_heads {}", self.dim, self.n_heads);
        }
        if self.head_dim() % 2 != 0 {
            bail!("head_dim {} must be even for RoPE", self.head_dim());
        }
        if self.vocab == 0 || self.n_layers == 0 || self.max_seq == 0 {
            bail!("degenerate config");
        }
        Ok(())
    }

    /// Named presets. The three *-mini configs are the stand-ins for the
    /// paper's three model pairs; `tiny` is for unit tests; `base-110m`
    /// matches the scale target in the repro instructions.
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let c = match name {
            "tiny" => ModelConfig {
                name: "tiny".into(),
                vocab: 256,
                dim: 64,
                n_layers: 2,
                n_heads: 2,
                ff: 128,
                max_seq: 64,
            },
            // Llama-like FF ratio (~2.7x, SwiGLU style).
            "llama-mini" => ModelConfig {
                name: "llama-mini".into(),
                vocab: 256,
                dim: 256,
                n_layers: 4,
                n_heads: 4,
                ff: 688,
                max_seq: 128,
            },
            // Qwen-like 4x FF ratio, slightly wider/deeper.
            "qwen-mini" => ModelConfig {
                name: "qwen-mini".into(),
                vocab: 256,
                dim: 320,
                n_layers: 5,
                n_heads: 5,
                ff: 1280,
                max_seq: 128,
            },
            // Phi-like: deeper, narrower FF.
            "phi-mini" => ModelConfig {
                name: "phi-mini".into(),
                vocab: 256,
                dim: 288,
                n_layers: 6,
                n_heads: 6,
                ff: 864,
                max_seq: 128,
            },
            "base-110m" => ModelConfig {
                name: "base-110m".into(),
                vocab: 256,
                dim: 768,
                n_layers: 12,
                n_heads: 12,
                ff: 3072,
                max_seq: 256,
            },
            other => bail!("unknown model preset '{other}'"),
        };
        c.validate()?;
        Ok(c)
    }

    pub fn all_pair_presets() -> Vec<&'static str> {
        vec!["llama-mini", "qwen-mini", "phi-mini"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in ["tiny", "llama-mini", "qwen-mini", "phi-mini", "base-110m"] {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.name, name);
            assert!(c.n_params() > 0);
        }
        assert!(ModelConfig::preset("nope").is_err());
    }

    #[test]
    fn param_count_formula_tiny() {
        let c = ModelConfig::preset("tiny").unwrap();
        // embed 256*64 + 2*(64 + 4*64*64 + 64 + 2*128*64 + 64*128) + 64 + 256*64
        let want = 256 * 64 + 2 * (64 + 4 * 64 * 64 + 64 + 2 * 128 * 64 + 64 * 128) + 64 + 256 * 64;
        assert_eq!(c.n_params(), want);
    }

    #[test]
    fn base_110m_is_roughly_110m() {
        let c = ModelConfig::preset("base-110m").unwrap();
        let m = c.n_params() as f64 / 1e6;
        assert!((100.0..130.0).contains(&m), "params = {m}M");
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ModelConfig::preset("tiny").unwrap();
        c.n_heads = 3; // 64 % 3 != 0
        assert!(c.validate().is_err());
    }
}
