//! Native Rust transformer forward pass (decoder-only, pre-RMSNorm, RoPE,
//! SwiGLU MLP — Llama-style, no biases).
//!
//! This mirrors `python/compile/model.py` operation-for-operation and serves
//! two roles: (1) the parity oracle for the AOT/XLA runtime (integration
//! tests compare logits), and (2) a fallback engine so the serving stack and
//! all accuracy experiments run even without artifacts built.
//!
//! Every projection routes through the [`exec::LinearOp`](crate::exec)
//! abstraction: the forward pass is generic over a [`Weights`] source, so
//! the same code serves dense parameters (`FlatParams`) and packed variants
//! (`PackedVariant` — base + 1-bit delta executed in place, never
//! materialized).

use super::config::ModelConfig;
use super::params::FlatParams;
use crate::exec::prefix::PrefixState;
use crate::exec::{BatchSource, LinearOp, RowSpan, Uniform, Weights};
use crate::model::params::{ModuleId, ProjKind};
use crate::tensor::ops::{log_softmax_into, rmsnorm_into, silu, softmax_inplace, RopeTable};
use crate::tensor::{dot, Tensor2};
use crate::util::par;

/// Activations recorded at one layer's seven patchable projections — the
/// native analog of the paper's forward hooks (Algorithm 3). `*_in` is the
/// module input X, `*_out` the module (linear) output Y; q/k/v share one
/// input (the attention RMSNorm output), gate/up share the MLP RMSNorm
/// output.
#[derive(Clone, Debug, Default)]
pub struct LayerTaps {
    pub attn_in: Tensor2,
    pub q_out: Tensor2,
    pub k_out: Tensor2,
    pub v_out: Tensor2,
    pub o_in: Tensor2,
    pub o_out: Tensor2,
    pub mlp_in: Tensor2,
    pub gate_out: Tensor2,
    pub up_out: Tensor2,
    pub down_in: Tensor2,
    pub down_out: Tensor2,
}

impl LayerTaps {
    /// Module input for a projection kind.
    pub fn input(&self, kind: crate::model::params::ProjKind) -> &Tensor2 {
        use crate::model::params::ProjKind::*;
        match kind {
            Q | K | V => &self.attn_in,
            O => &self.o_in,
            Gate | Up => &self.mlp_in,
            Down => &self.down_in,
        }
    }

    /// Module (linear) output for a projection kind.
    pub fn output(&self, kind: crate::model::params::ProjKind) -> &Tensor2 {
        use crate::model::params::ProjKind::*;
        match kind {
            Q => &self.q_out,
            K => &self.k_out,
            V => &self.v_out,
            O => &self.o_out,
            Gate => &self.gate_out,
            Up => &self.up_out,
            Down => &self.down_out,
        }
    }
}

/// One sequence of a prefix-aware stacked forward
/// ([`Transformer::forward_plan_prefixed`]): the full token sequence, the
/// plan entry it executes, an optional cached prefix to resume from, and
/// how many leading rows to capture into a fresh [`PrefixState`].
pub struct PlanSeq<'a> {
    /// Index into the batch plan's entry list.
    pub entry: usize,
    /// The FULL token sequence (resume rows included).
    pub tokens: &'a [u8],
    /// Cached state for `tokens[..resume.len()]`; the forward computes only
    /// the remaining suffix rows. Must satisfy `resume.len() < tokens.len()`
    /// and `tokens[..resume.len()] == resume.tokens`.
    pub resume: Option<&'a PrefixState>,
    /// Capture rows `0..capture` (post-RoPE K/V per layer + logits) into a
    /// new [`PrefixState`]. `0` = no capture; otherwise must exceed the
    /// resume length (a shorter capture already exists) and not exceed the
    /// sequence length.
    pub capture: usize,
}

impl PlanSeq<'_> {
    fn resume_len(&self) -> usize {
        self.resume.map_or(0, |r| r.len())
    }
}

/// Forward-pass workspace reused across calls (avoids per-request allocs on
/// the serving hot path).
pub struct Transformer {
    pub cfg: ModelConfig,
    rope: RopeTable,
}

impl Transformer {
    pub fn new(cfg: &ModelConfig) -> Transformer {
        Transformer { cfg: cfg.clone(), rope: RopeTable::new(cfg.head_dim(), cfg.max_seq) }
    }

    /// Full forward: `tokens` is `[batch][seq]`; returns logits as a vec of
    /// `[seq, vocab]` tensors, one per batch element. Sequences may have
    /// different lengths (each is processed independently — the XLA path
    /// pads to bucket shapes instead).
    pub fn forward_batch<W: Weights>(&self, weights: &W, tokens: &[Vec<u8>]) -> Vec<Tensor2> {
        let mut out: Vec<Option<Tensor2>> = (0..tokens.len()).map(|_| None).collect();
        // Parallelism strategy: across batch if batch > 1, else the matmuls
        // inside the single sequence parallelize internally.
        if tokens.len() > 1 {
            let results: Vec<std::sync::Mutex<Option<Tensor2>>> =
                (0..tokens.len()).map(|_| std::sync::Mutex::new(None)).collect();
            par::parallel_items(tokens.len(), 16, |i| {
                let logits = self.forward_one(weights, &tokens[i]);
                *results[i].lock().unwrap() = Some(logits);
            });
            for (o, r) in out.iter_mut().zip(results) {
                *o = r.into_inner().unwrap();
            }
        } else {
            for (o, t) in out.iter_mut().zip(tokens) {
                *o = Some(self.forward_one(weights, t));
            }
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    /// Forward one sequence: `[T] -> [T, vocab]` logits.
    pub fn forward_one<W: Weights>(&self, weights: &W, tokens: &[u8]) -> Tensor2 {
        self.forward_inner(weights, tokens, None).0
    }

    /// Forward with activation taps at `tap_layer`: records, for each of the
    /// seven patchable projections of that layer, the module *input* and
    /// module *output* activations (the (X, Y) pairs of Algorithm 3 — the
    /// native equivalent of the paper's PyTorch forward hooks).
    pub fn forward_one_tapped<W: Weights>(
        &self,
        weights: &W,
        tokens: &[u8],
        tap_layer: usize,
    ) -> (Tensor2, LayerTaps) {
        let (logits, taps) = self.forward_inner(weights, tokens, Some(tap_layer));
        (logits, taps.expect("tap layer in range"))
    }

    fn forward_inner<W: Weights>(
        &self,
        weights: &W,
        tokens: &[u8],
        tap_layer: Option<usize>,
    ) -> (Tensor2, Option<LayerTaps>) {
        let cfg = &self.cfg;
        let t_len = tokens.len();
        assert!(t_len > 0 && t_len <= cfg.max_seq, "seq len {} out of range", t_len);
        let d = cfg.dim;
        let params = weights.flat();
        let layout = &params.layout;

        // Embedding lookup -> x: [T, d]
        let mut x = Tensor2::zeros(t_len, d);
        for (pos, &tok) in tokens.iter().enumerate() {
            let off = layout.embed + (tok as usize) * d;
            x.row_mut(pos).copy_from_slice(&params.data[off..off + d]);
        }

        let mut taps: Option<LayerTaps> = None;
        let mut normed = Tensor2::zeros(t_len, d);
        for l in 0..cfg.n_layers {
            let tapping = tap_layer == Some(l);
            let lo = layout.layers[l].clone();
            // --- attention block ---
            let norm_w = &params.data[lo.attn_norm..lo.attn_norm + d];
            for pos in 0..t_len {
                let (xr, nr) = (x.row(pos), pos);
                let dst = normed.row_mut(nr);
                rmsnorm_into(xr, norm_w, dst);
            }
            let op = |kind| weights.op(ModuleId { layer: l, kind });
            let mut q = op(ProjKind::Q).forward(&normed); // [T, d]
            let mut k = op(ProjKind::K).forward(&normed);
            let v = op(ProjKind::V).forward(&normed);
            if tapping {
                let t = taps.get_or_insert_with(LayerTaps::default);
                t.attn_in = normed.clone(); // input of q/k/v projections
                t.q_out = q.clone(); // linear outputs, pre-RoPE (hook point)
                t.k_out = k.clone();
                t.v_out = v.clone();
            }
            // RoPE per head on q, k; causal attention head by head.
            self.rope_rows(&mut q, &mut k, 0, t_len);
            let mut attn_out = Tensor2::zeros(t_len, d);
            self.attend_rows(&q, &k, &v, 0, t_len, &mut attn_out);
            let proj = op(ProjKind::O).forward(&attn_out); // [T, d]
            if tapping {
                let t = taps.as_mut().unwrap();
                t.o_in = attn_out.clone();
                t.o_out = proj.clone();
            }
            x.add_assign(&proj);

            // --- MLP block ---
            let norm_w = &params.data[lo.mlp_norm..lo.mlp_norm + d];
            for pos in 0..t_len {
                let src = x.row(pos).to_vec();
                rmsnorm_into(&src, norm_w, normed.row_mut(pos));
            }
            let mut gate = op(ProjKind::Gate).forward(&normed); // [T, ff]
            let up = op(ProjKind::Up).forward(&normed);
            if tapping {
                let t = taps.as_mut().unwrap();
                t.mlp_in = normed.clone(); // input of gate/up projections
                t.gate_out = gate.clone(); // linear output, pre-SiLU
                t.up_out = up.clone();
            }
            for (g, &u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * u;
            }
            let down = op(ProjKind::Down).forward(&gate); // [T, d]
            if tapping {
                let t = taps.as_mut().unwrap();
                t.down_in = gate.clone(); // silu(gate)·up, the down_proj input
                t.down_out = down.clone();
            }
            x.add_assign(&down);
        }

        // Final norm + LM head.
        let fw = &params.data[layout.final_norm..layout.final_norm + d];
        for pos in 0..t_len {
            let src = x.row(pos).to_vec();
            rmsnorm_into(&src, fw, x.row_mut(pos));
        }
        let lm = crate::exec::DenseLinear::new(
            &params.data[layout.lm_head..layout.lm_head + cfg.vocab * d],
            cfg.vocab,
            d,
        );
        (lm.forward(&x), taps) // [T, vocab]
    }

    /// RoPE per head for rows `row0..row0+len` of `q` and `k`, with
    /// positions local to the slice (one sequence of a stacked batch).
    fn rope_rows(&self, q: &mut Tensor2, k: &mut Tensor2, row0: usize, len: usize) {
        let d = self.cfg.dim;
        self.rope_span(
            &mut q.data[row0 * d..(row0 + len) * d],
            &mut k.data[row0 * d..(row0 + len) * d],
            len,
        );
    }

    /// RoPE over one sequence's contiguous `[len, dim]` row slices of the
    /// stacked q/k buffers — the slice-level core of
    /// [`rope_rows`](Self::rope_rows), so a batched forward can hand
    /// disjoint sequences to different pool workers.
    fn rope_span(&self, q_rows: &mut [f32], k_rows: &mut [f32], len: usize) {
        self.rope_span_at(q_rows, k_rows, len, 0);
    }

    /// [`rope_span`](Self::rope_span) with an absolute position offset:
    /// row `i` of the slice rotates as position `pos0 + i`, so a
    /// resume-from-row forward can feed suffix rows whose absolute
    /// positions start after a cached prefix. Bit-identical to rotating
    /// the same rows inside a full-sequence pass (the table lookup is by
    /// absolute position either way).
    fn rope_span_at(&self, q_rows: &mut [f32], k_rows: &mut [f32], len: usize, pos0: usize) {
        let (nh, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let d = self.cfg.dim;
        for pos in 0..len {
            for h in 0..nh {
                let abs = pos0 + pos;
                self.rope.apply(&mut q_rows[pos * d + h * hd..pos * d + (h + 1) * hd], abs);
                self.rope.apply(&mut k_rows[pos * d + h * hd..pos * d + (h + 1) * hd], abs);
            }
        }
    }

    /// Causal attention over rows `row0..row0+len` of `q`/`k`/`v` (one
    /// sequence of a stacked batch), accumulated into the same rows of
    /// `out` (which must be zeroed).
    fn attend_rows(
        &self,
        q: &Tensor2,
        k: &Tensor2,
        v: &Tensor2,
        row0: usize,
        len: usize,
        out: &mut Tensor2,
    ) {
        let d = self.cfg.dim;
        self.attend_span(q, k, v, row0, len, &mut out.data[row0 * d..(row0 + len) * d]);
    }

    /// Attention core writing one sequence's `[len, dim]` output slice —
    /// reads of q/k/v are confined to rows `row0..row0+len`, so disjoint
    /// sequences of a stacked batch can run on different pool workers.
    fn attend_span(
        &self,
        q: &Tensor2,
        k: &Tensor2,
        v: &Tensor2,
        row0: usize,
        len: usize,
        out_rows: &mut [f32],
    ) {
        let (nh, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let d = self.cfg.dim;
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..nh {
            let hs = h * hd;
            let mut scores = vec![0f32; len]; // reused row buffer
            for qi in 0..len {
                let qrow = &q.row(row0 + qi)[hs..hs + hd];
                for ki in 0..=qi {
                    scores[ki] = dot(qrow, &k.row(row0 + ki)[hs..hs + hd]) * scale;
                }
                softmax_inplace(&mut scores[..=qi]);
                let orow = &mut out_rows[qi * d + hs..qi * d + hs + hd];
                for ki in 0..=qi {
                    let w = scores[ki];
                    let vrow = &v.row(row0 + ki)[hs..hs + hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
    }

    /// Causal attention for a resumed sequence: suffix query rows
    /// `q_row0..q_row0+len` of the stacked batch attend over the sequence's
    /// assembled full K/V (`p` cached prefix rows followed by `len`
    /// computed suffix rows). Suffix row `qi` sits at absolute position
    /// `p + qi`, so its score row covers keys `0..=p+qi` — the exact
    /// arithmetic a cold [`attend_span`](Self::attend_span) runs for that
    /// row of the full sequence, in the same `ki` order (bitwise-equal
    /// reductions).
    fn attend_prefixed(
        &self,
        q: &Tensor2,
        q_row0: usize,
        len: usize,
        k_full: &Tensor2,
        v_full: &Tensor2,
        p: usize,
        out_rows: &mut [f32],
    ) {
        let (nh, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let d = self.cfg.dim;
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..nh {
            let hs = h * hd;
            let mut scores = vec![0f32; p + len]; // reused row buffer
            for qi in 0..len {
                let abs = p + qi;
                let qrow = &q.row(q_row0 + qi)[hs..hs + hd];
                for ki in 0..=abs {
                    scores[ki] = dot(qrow, &k_full.row(ki)[hs..hs + hd]) * scale;
                }
                softmax_inplace(&mut scores[..=abs]);
                let orow = &mut out_rows[qi * d + hs..qi * d + hs + hd];
                for ki in 0..=abs {
                    let w = scores[ki];
                    let vrow = &v_full.row(ki)[hs..hs + hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
    }

    /// Stacked multi-sequence forward against a [`BatchSource`]: every
    /// sequence's token rows are concatenated into one activation tensor,
    /// each linear projection runs **once** for the whole batch (one shared
    /// base GEMM per module when `src` is a
    /// [`BatchPlan`](crate::exec::BatchPlan)), and RoPE/attention stay
    /// per-sequence on row slices. `seqs` pairs each token sequence with
    /// the plan entry (variant) it executes.
    ///
    /// Per-sequence logits are bitwise identical to
    /// [`forward_one`](Self::forward_one) against that sequence's own
    /// weights: batching regroups work across requests, never the
    /// arithmetic (the property tests assert exact equality).
    pub fn forward_plan<S: BatchSource>(&self, src: &S, seqs: &[(usize, Vec<u8>)]) -> Vec<Tensor2> {
        if seqs.is_empty() {
            return Vec::new();
        }
        let cfg = &self.cfg;
        let mut spans = Vec::with_capacity(seqs.len());
        let mut total = 0usize;
        for (entry, tokens) in seqs {
            assert!(*entry < src.entries(), "plan entry {entry} out of range");
            let t = tokens.len();
            assert!(t > 0 && t <= cfg.max_seq, "seq len {t} out of range");
            spans.push(RowSpan { start: total, end: total + t, entry: *entry });
            total += t;
        }
        let d = cfg.dim;
        let params = src.flat();
        let layout = &params.layout;

        // Embedding lookup -> x: [ΣT, d] (embeddings are shared parameters).
        let mut x = Tensor2::zeros(total, d);
        for (span, (_, tokens)) in spans.iter().zip(seqs) {
            for (i, &tok) in tokens.iter().enumerate() {
                let off = layout.embed + (tok as usize) * d;
                x.row_mut(span.start + i).copy_from_slice(&params.data[off..off + d]);
            }
        }

        let mut normed = Tensor2::zeros(total, d);
        for l in 0..cfg.n_layers {
            let lo = layout.layers[l].clone();
            // One batched projection per module: the whole stacked batch in
            // one call, with the per-variant row spans threaded through.
            let fwd = |kind: ProjKind, input: &Tensor2| -> Tensor2 {
                let (d_out, _) = kind.shape(cfg);
                let mut y = Tensor2::zeros(total, d_out);
                src.forward_module(ModuleId { layer: l, kind }, input, &spans, &mut y);
                y
            };
            // --- attention block ---
            let norm_w = &params.data[lo.attn_norm..lo.attn_norm + d];
            for pos in 0..total {
                rmsnorm_into(x.row(pos), norm_w, normed.row_mut(pos));
            }
            let mut q = fwd(ProjKind::Q, &normed); // [ΣT, d]
            let mut k = fwd(ProjKind::K, &normed);
            let v = fwd(ProjKind::V, &normed);
            // RoPE + causal attention never cross sequence boundaries, so
            // the spans fan out across the pool; per-sequence arithmetic is
            // untouched, keeping batched output bitwise-equal to the
            // per-request path at any thread count.
            {
                let qp = par::SendMutPtr(q.data.as_mut_ptr());
                let kp = par::SendMutPtr(k.data.as_mut_ptr());
                let spans_ref = &spans;
                par::parallel_items(spans_ref.len(), spans_ref.len(), |i| {
                    let s = &spans_ref[i];
                    let len = s.end - s.start;
                    // SAFETY: spans are disjoint contiguous row ranges of
                    // the stacked batch, and the buffers outlive this call.
                    let (qrows, krows) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(qp.0.add(s.start * d), len * d),
                            std::slice::from_raw_parts_mut(kp.0.add(s.start * d), len * d),
                        )
                    };
                    self.rope_span(qrows, krows, len);
                });
            }
            let mut attn_out = Tensor2::zeros(total, d);
            {
                let op = par::SendMutPtr(attn_out.data.as_mut_ptr());
                let (qr, kr, vr) = (&q, &k, &v);
                let spans_ref = &spans;
                par::parallel_items(spans_ref.len(), spans_ref.len(), |i| {
                    let s = &spans_ref[i];
                    let len = s.end - s.start;
                    // SAFETY: as above — each span writes only its own rows.
                    let orows = unsafe {
                        std::slice::from_raw_parts_mut(op.0.add(s.start * d), len * d)
                    };
                    self.attend_span(qr, kr, vr, s.start, len, orows);
                });
            }
            let proj = fwd(ProjKind::O, &attn_out); // [ΣT, d]
            x.add_assign(&proj);

            // --- MLP block ---
            let norm_w = &params.data[lo.mlp_norm..lo.mlp_norm + d];
            for pos in 0..total {
                rmsnorm_into(x.row(pos), norm_w, normed.row_mut(pos));
            }
            let mut gate = fwd(ProjKind::Gate, &normed); // [ΣT, ff]
            let up = fwd(ProjKind::Up, &normed);
            for (g, &u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * u;
            }
            let down = fwd(ProjKind::Down, &gate); // [ΣT, d]
            x.add_assign(&down);
        }

        // Final norm + LM head (shared parameters), then split per sequence.
        let fw = &params.data[layout.final_norm..layout.final_norm + d];
        for pos in 0..total {
            let src_row = x.row(pos).to_vec();
            rmsnorm_into(&src_row, fw, x.row_mut(pos));
        }
        let lm = crate::exec::DenseLinear::new(
            &params.data[layout.lm_head..layout.lm_head + cfg.vocab * d],
            cfg.vocab,
            d,
        );
        let logits = lm.forward(&x); // [ΣT, vocab]
        spans
            .iter()
            .map(|s| {
                Tensor2::from_vec(
                    s.end - s.start,
                    cfg.vocab,
                    logits.data[s.start * cfg.vocab..s.end * cfg.vocab].to_vec(),
                )
            })
            .collect()
    }

    /// Prefix-aware stacked forward: like
    /// [`forward_plan`](Self::forward_plan), but each sequence may *resume*
    /// from a cached [`PrefixState`] (only its suffix rows enter the
    /// stacked activations — every projection GEMM shrinks by the resumed
    /// rows) and/or *capture* its leading rows into a new state for the
    /// cache. Returns per-sequence FULL logits (`[T, vocab]`, cached prefix
    /// rows stitched back in) plus the captured states.
    ///
    /// Bitwise contract: cut-points sit only at row boundaries — suffix
    /// rows run the exact per-row arithmetic of a cold pass (row-independent
    /// GEMM/rmsnorm/SiLU; RoPE by absolute position; attention over
    /// memcpy'd cached K/V in the same reduction order), so resumed ==
    /// cold == per-request bitwise, at any pool width. The property tests
    /// assert exact equality.
    pub fn forward_plan_prefixed<S: BatchSource>(
        &self,
        src: &S,
        seqs: &[PlanSeq],
    ) -> (Vec<Tensor2>, Vec<Option<PrefixState>>) {
        if seqs.is_empty() {
            return (Vec::new(), Vec::new());
        }
        let cfg = &self.cfg;
        let d = cfg.dim;
        let mut spans = Vec::with_capacity(seqs.len());
        let mut total = 0usize;
        for s in seqs {
            assert!(s.entry < src.entries(), "plan entry {} out of range", s.entry);
            let t = s.tokens.len();
            assert!(t > 0 && t <= cfg.max_seq, "seq len {t} out of range");
            let p = s.resume_len();
            if let Some(r) = s.resume {
                assert!(p < t, "resume must leave at least one suffix row");
                assert_eq!(&s.tokens[..p], &r.tokens[..], "resume tokens mismatch");
                assert_eq!(r.k.len(), cfg.n_layers, "resume layer count mismatch");
            }
            assert!(
                s.capture == 0 || (s.capture > p && s.capture <= t),
                "capture {} out of range (resume {p}, len {t})",
                s.capture
            );
            spans.push(RowSpan { start: total, end: total + (t - p), entry: s.entry });
            total += t - p;
        }
        let params = src.flat();
        let layout = &params.layout;

        // Suffix embedding lookup -> x: [Σ(T−P), d].
        let mut x = Tensor2::zeros(total, d);
        for (span, s) in spans.iter().zip(seqs) {
            for (i, &tok) in s.tokens[s.resume_len()..].iter().enumerate() {
                let off = layout.embed + (tok as usize) * d;
                x.row_mut(span.start + i).copy_from_slice(&params.data[off..off + d]);
            }
        }

        // Per-layer captured K/V, built as the layers run.
        let mut cap_k: Vec<Vec<Tensor2>> = seqs.iter().map(|_| Vec::new()).collect();
        let mut cap_v: Vec<Vec<Tensor2>> = seqs.iter().map(|_| Vec::new()).collect();

        let mut normed = Tensor2::zeros(total, d);
        for l in 0..cfg.n_layers {
            let lo = layout.layers[l].clone();
            let fwd = |kind: ProjKind, input: &Tensor2| -> Tensor2 {
                let (d_out, _) = kind.shape(cfg);
                let mut y = Tensor2::zeros(total, d_out);
                src.forward_module(ModuleId { layer: l, kind }, input, &spans, &mut y);
                y
            };
            // --- attention block ---
            let norm_w = &params.data[lo.attn_norm..lo.attn_norm + d];
            for pos in 0..total {
                rmsnorm_into(x.row(pos), norm_w, normed.row_mut(pos));
            }
            let mut q = fwd(ProjKind::Q, &normed); // [Σ(T−P), d]
            let mut k = fwd(ProjKind::K, &normed);
            let v = fwd(ProjKind::V, &normed);
            // RoPE at absolute positions: a resumed span's rows start at
            // position P, exactly where a cold pass would rotate them.
            {
                let qp = par::SendMutPtr(q.data.as_mut_ptr());
                let kp = par::SendMutPtr(k.data.as_mut_ptr());
                let spans_ref = &spans;
                par::parallel_items(spans_ref.len(), spans_ref.len(), |i| {
                    let s = &spans_ref[i];
                    let len = s.end - s.start;
                    // SAFETY: spans are disjoint contiguous row ranges of
                    // the stacked batch, and the buffers outlive this call.
                    let (qrows, krows) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(qp.0.add(s.start * d), len * d),
                            std::slice::from_raw_parts_mut(kp.0.add(s.start * d), len * d),
                        )
                    };
                    self.rope_span_at(qrows, krows, len, seqs[i].resume_len());
                });
            }
            // Assemble full per-sequence K/V for resumed sequences: cached
            // prefix rows memcpy'd (bits preserved) ahead of the computed
            // suffix rows.
            let kv_full: Vec<Option<(Tensor2, Tensor2)>> = seqs
                .iter()
                .zip(&spans)
                .map(|(s, span)| {
                    s.resume.map(|r| {
                        let p = r.len();
                        let len = span.end - span.start;
                        let mut kf = Tensor2::zeros(p + len, d);
                        let mut vf = Tensor2::zeros(p + len, d);
                        kf.data[..p * d].copy_from_slice(&r.k[l].data);
                        vf.data[..p * d].copy_from_slice(&r.v[l].data);
                        kf.data[p * d..].copy_from_slice(&k.data[span.start * d..span.end * d]);
                        vf.data[p * d..].copy_from_slice(&v.data[span.start * d..span.end * d]);
                        (kf, vf)
                    })
                })
                .collect();
            let mut attn_out = Tensor2::zeros(total, d);
            {
                let op = par::SendMutPtr(attn_out.data.as_mut_ptr());
                let (qr, kr, vr) = (&q, &k, &v);
                let spans_ref = &spans;
                let kvf = &kv_full;
                par::parallel_items(spans_ref.len(), spans_ref.len(), |i| {
                    let s = &spans_ref[i];
                    let len = s.end - s.start;
                    // SAFETY: as above — each span writes only its own rows.
                    let orows = unsafe {
                        std::slice::from_raw_parts_mut(op.0.add(s.start * d), len * d)
                    };
                    match &kvf[i] {
                        Some((kf, vf)) => {
                            let p = kf.rows - len;
                            self.attend_prefixed(qr, s.start, len, kf, vf, p, orows);
                        }
                        None => self.attend_span(qr, kr, vr, s.start, len, orows),
                    }
                });
            }
            // Capture this layer's post-RoPE K/V rows 0..capture.
            for (i, s) in seqs.iter().enumerate() {
                if s.capture == 0 {
                    continue;
                }
                let span = &spans[i];
                let mut kc = Tensor2::zeros(s.capture, d);
                let mut vc = Tensor2::zeros(s.capture, d);
                match &kv_full[i] {
                    Some((kf, vf)) => {
                        kc.data.copy_from_slice(&kf.data[..s.capture * d]);
                        vc.data.copy_from_slice(&vf.data[..s.capture * d]);
                    }
                    None => {
                        let r0 = span.start * d;
                        kc.data.copy_from_slice(&k.data[r0..r0 + s.capture * d]);
                        vc.data.copy_from_slice(&v.data[r0..r0 + s.capture * d]);
                    }
                }
                cap_k[i].push(kc);
                cap_v[i].push(vc);
            }
            let proj = fwd(ProjKind::O, &attn_out); // [Σ(T−P), d]
            x.add_assign(&proj);

            // --- MLP block ---
            let norm_w = &params.data[lo.mlp_norm..lo.mlp_norm + d];
            for pos in 0..total {
                rmsnorm_into(x.row(pos), norm_w, normed.row_mut(pos));
            }
            let mut gate = fwd(ProjKind::Gate, &normed); // [Σ(T−P), ff]
            let up = fwd(ProjKind::Up, &normed);
            for (g, &u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * u;
            }
            let down = fwd(ProjKind::Down, &gate); // [Σ(T−P), d]
            x.add_assign(&down);
        }

        // Final norm + LM head over the suffix rows only.
        let fw = &params.data[layout.final_norm..layout.final_norm + d];
        for pos in 0..total {
            let src_row = x.row(pos).to_vec();
            rmsnorm_into(&src_row, fw, x.row_mut(pos));
        }
        let lm = crate::exec::DenseLinear::new(
            &params.data[layout.lm_head..layout.lm_head + cfg.vocab * d],
            cfg.vocab,
            d,
        );
        let logits = lm.forward(&x); // [Σ(T−P), vocab]

        // Stitch full logits (cached prefix rows ++ computed suffix rows)
        // and package the captured states.
        let vocab = cfg.vocab;
        let mut out_logits = Vec::with_capacity(seqs.len());
        let mut out_caps = Vec::with_capacity(seqs.len());
        for (i, s) in seqs.iter().enumerate() {
            let span = &spans[i];
            let p = s.resume_len();
            let t = s.tokens.len();
            let mut full = Tensor2::zeros(t, vocab);
            if let Some(r) = s.resume {
                full.data[..p * vocab].copy_from_slice(&r.logits.data);
            }
            full.data[p * vocab..]
                .copy_from_slice(&logits.data[span.start * vocab..span.end * vocab]);
            let cap = (s.capture > 0).then(|| {
                let mut lc = Tensor2::zeros(s.capture, vocab);
                lc.data.copy_from_slice(&full.data[..s.capture * vocab]);
                PrefixState {
                    tokens: s.tokens[..s.capture].to_vec(),
                    k: std::mem::take(&mut cap_k[i]),
                    v: std::mem::take(&mut cap_v[i]),
                    logits: lc,
                }
            });
            out_logits.push(full);
            out_caps.push(cap);
        }
        (out_logits, out_caps)
    }

    /// Single-sequence resume/capture forward — the per-request face of
    /// [`forward_plan_prefixed`](Self::forward_plan_prefixed) (a one-item
    /// [`Uniform`] plan), bitwise-equal to
    /// [`forward_one`](Self::forward_one) over the same tokens.
    pub fn forward_one_prefixed<W: Weights>(
        &self,
        weights: &W,
        tokens: &[u8],
        resume: Option<&PrefixState>,
        capture: usize,
    ) -> (Tensor2, Option<PrefixState>) {
        let seq = PlanSeq { entry: 0, tokens, resume, capture };
        let (mut logits, mut caps) = self.forward_plan_prefixed(&Uniform(weights), &[seq]);
        (logits.remove(0), caps.remove(0))
    }

    /// Sum of log p(token[pos] | prefix) over `span`, from precomputed
    /// logits for the full sequence ([`forward_one`](Self::forward_one)'s
    /// output, or one sequence of a batched
    /// [`forward_plan`](Self::forward_plan)).
    pub fn span_logprob(
        &self,
        logits: &Tensor2,
        tokens: &[u8],
        span: std::ops::Range<usize>,
    ) -> f64 {
        assert!(span.start >= 1, "cannot score position 0 (no context)");
        assert!(span.end <= tokens.len());
        let mut lse_buf = vec![0f32; self.cfg.vocab];
        let mut total = 0f64;
        for pos in span {
            log_softmax_into(logits.row(pos - 1), &mut lse_buf);
            total += lse_buf[tokens[pos] as usize] as f64;
        }
        total
    }

    /// Sum of log p(token[i] | tokens[..i]) over `span` (used for MC
    /// scoring: rank answer choices by completion log-likelihood).
    pub fn score_span<W: Weights>(
        &self,
        weights: &W,
        tokens: &[u8],
        span: std::ops::Range<usize>,
    ) -> f64 {
        let logits = self.forward_one(weights, tokens);
        self.span_logprob(&logits, tokens, span)
    }

    /// Per-token cross-entropy (nats) of `tokens` under the model; the
    /// perplexity metric is `exp` of this.
    pub fn cross_entropy<W: Weights>(&self, weights: &W, tokens: &[u8]) -> f64 {
        if tokens.len() < 2 {
            return 0.0;
        }
        -self.score_span(weights, tokens, 1..tokens.len()) / (tokens.len() - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny() -> (ModelConfig, FlatParams, Transformer) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let params = FlatParams::init(&cfg, 42);
        let t = Transformer::new(&cfg);
        (cfg, params, t)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let (cfg, params, t) = tiny();
        let tokens: Vec<u8> = (0..10u8).collect();
        let logits = t.forward_one(&params, &tokens);
        assert_eq!((logits.rows, logits.cols), (10, cfg.vocab));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_is_deterministic() {
        let (_, params, t) = tiny();
        let tokens: Vec<u8> = vec![5, 4, 3, 2, 1];
        let a = t.forward_one(&params, &tokens);
        let b = t.forward_one(&params, &tokens);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position i must not change when the suffix changes.
        let (_, params, t) = tiny();
        let a: Vec<u8> = vec![10, 20, 30, 40, 50];
        let b: Vec<u8> = vec![10, 20, 30, 99, 98];
        let la = t.forward_one(&params, &a);
        let lb = t.forward_one(&params, &b);
        for pos in 0..3 {
            for c in 0..la.cols {
                assert!(
                    (la.at(pos, c) - lb.at(pos, c)).abs() < 1e-4,
                    "pos {pos} col {c}: {} vs {}",
                    la.at(pos, c),
                    lb.at(pos, c)
                );
            }
        }
        // ...but position 3 should change.
        let diff: f32 =
            (0..la.cols).map(|c| (la.at(3, c) - lb.at(3, c)).abs()).fold(0.0, f32::max);
        assert!(diff > 1e-3, "suffix change had no effect");
    }

    #[test]
    fn batch_matches_single() {
        let (_, params, t) = tiny();
        let seqs: Vec<Vec<u8>> = vec![vec![1, 2, 3], vec![9, 8, 7, 6], vec![0, 255]];
        let batch = t.forward_batch(&params, &seqs);
        for (i, s) in seqs.iter().enumerate() {
            let single = t.forward_one(&params, s);
            assert_eq!(batch[i].data, single.data, "seq {i}");
        }
    }

    #[test]
    fn score_span_is_negative_loglik() {
        let (_, params, t) = tiny();
        let tokens: Vec<u8> = vec![1, 2, 3, 4, 5, 6];
        let s = t.score_span(&params, &tokens, 2..5);
        assert!(s < 0.0, "log-likelihood must be negative, got {s}");
        // Scoring subranges adds up.
        let s1 = t.score_span(&params, &tokens, 2..4);
        let s2 = t.score_span(&params, &tokens, 4..5);
        assert!((s - (s1 + s2)).abs() < 1e-4);
    }

    #[test]
    fn cross_entropy_reasonable_for_random_model() {
        let (cfg, params, t) = tiny();
        let tokens: Vec<u8> = (0..32).map(|i| (i * 37 % 256) as u8).collect();
        let ce = t.cross_entropy(&params, &tokens);
        // Random init should be near uniform: ln(256) ≈ 5.55.
        let uniform = (cfg.vocab as f64).ln();
        assert!((ce - uniform).abs() < 1.0, "ce={ce} uniform={uniform}");
    }

    #[test]
    fn tapped_forward_matches_untapped() {
        let (cfg, params, t) = tiny();
        let tokens: Vec<u8> = vec![7, 3, 9, 1, 4, 2];
        let plain = t.forward_one(&params, &tokens);
        let (tapped, taps) = t.forward_one_tapped(&params, &tokens, 1);
        assert_eq!(plain.data, tapped.data);
        // Tap shapes.
        assert_eq!((taps.attn_in.rows, taps.attn_in.cols), (6, cfg.dim));
        assert_eq!((taps.gate_out.rows, taps.gate_out.cols), (6, cfg.ff));
        assert_eq!((taps.down_in.rows, taps.down_in.cols), (6, cfg.ff));
        // Y = X · Wᵀ must hold exactly for the q projection.
        use crate::model::params::{ModuleId, ProjKind};
        let wq = params.module_tensor(ModuleId { layer: 1, kind: ProjKind::Q });
        let want = taps.attn_in.matmul_bt(&wq);
        for (a, b) in want.data.iter().zip(&taps.q_out.data) {
            assert!((a - b).abs() < 1e-5);
        }
        // Same for down_proj (checks the recorded input is pre-projection).
        let wd = params.module_tensor(ModuleId { layer: 1, kind: ProjKind::Down });
        let want = taps.down_in.matmul_bt(&wd);
        for (a, b) in want.data.iter().zip(&taps.down_out.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_weights_forward_matches_materialized() {
        use crate::delta::pack::PackedMask;
        use crate::delta::types::{Axis, Codec, DeltaModel, DeltaModule};
        use crate::exec::PackedVariant;
        use crate::util::rng::Rng;
        use std::sync::Arc;

        let (cfg, base, t) = tiny();
        let base = Arc::new(base);
        // Patch every module, cycling through all four axis modes.
        let axes = [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)];
        let mut modules = Vec::new();
        for (i, &id) in base.layout.patchable_modules().iter().enumerate() {
            let (rows, cols) = id.kind.shape(&cfg);
            let mut r = Rng::new(500 + i as u64);
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let axis = axes[i % axes.len()];
            let n = axis.n_scales(rows, cols);
            modules.push(DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis,
                scales: (0..n).map(|_| r.uniform_in(0.005, 0.05)).collect(),
                codec: Codec::PerAxis,
            });
        }
        let delta = DeltaModel::new("pv", cfg.name.clone(), modules);
        let pv = PackedVariant::new(base.clone(), Arc::new(delta)).unwrap();
        let dense = pv.materialize();

        let tokens: Vec<u8> = vec![7, 3, 9, 1, 4, 2, 8, 5];
        let want = t.forward_one(&dense, &tokens);
        let got = t.forward_one(&pv, &tokens);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // And the packed variant must differ from the base (deltas applied).
        let base_logits = t.forward_one(base.as_ref(), &tokens);
        assert!(got.mse(&base_logits) > 0.0);
    }

    fn mk_packed(base: &std::sync::Arc<FlatParams>, seed: u64) -> crate::exec::PackedVariant {
        use crate::delta::pack::PackedMask;
        use crate::delta::types::{Axis, Codec, DeltaModel, DeltaModule};
        use crate::util::rng::Rng;
        let cfg = base.cfg();
        let axes = [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)];
        let mut modules = Vec::new();
        for (i, &id) in base.layout.patchable_modules().iter().enumerate() {
            let (rows, cols) = id.kind.shape(cfg);
            let mut r = Rng::new(seed * 131 + i as u64);
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let axis = axes[(seed as usize + i) % axes.len()];
            modules.push(DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis,
                scales: (0..axis.n_scales(rows, cols))
                    .map(|_| r.uniform_in(0.005, 0.05))
                    .collect(),
                codec: Codec::PerAxis,
            });
        }
        let delta = DeltaModel::new(format!("pv{seed}"), cfg.name.clone(), modules);
        crate::exec::PackedVariant::new(base.clone(), std::sync::Arc::new(delta)).unwrap()
    }

    #[test]
    fn forward_plan_mixed_variants_is_bitwise_equal_to_forward_one() {
        use crate::exec::{BatchPlan, VariantWeights};
        use std::sync::Arc;
        let (_, base, t) = tiny();
        let base = Arc::new(base);
        let weights = vec![
            VariantWeights::Packed(mk_packed(&base, 1)),
            VariantWeights::Packed(mk_packed(&base, 2)),
            VariantWeights::Dense(base.clone(), 1),
            VariantWeights::Packed(mk_packed(&base, 3)),
        ];
        let plans = BatchPlan::group(&weights);
        assert_eq!(plans.len(), 2, "packed trio shares the base; dense groups alone");
        // Ragged mixed batch: entries interleaved, lengths 1..=8.
        for (plan, members) in &plans {
            let mut seqs: Vec<(usize, Vec<u8>)> = Vec::new();
            for (entry, &wi) in members.iter().enumerate() {
                for rep in 0..2u8 {
                    let len = 1 + ((wi as u8 + rep) % 8) as usize;
                    let tokens: Vec<u8> =
                        (0..len).map(|p| (p as u8).wrapping_mul(37).wrapping_add(rep)).collect();
                    seqs.push((entry, tokens));
                }
            }
            let batched = t.forward_plan(plan, &seqs);
            for ((entry, tokens), got) in seqs.iter().zip(&batched) {
                let want = t.forward_one(&weights[members[*entry]], tokens);
                assert_eq!(
                    got.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    want.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "batched forward must be bitwise-equal to the per-request path"
                );
            }
        }
    }

    #[test]
    fn forward_plan_uniform_matches_forward_one() {
        use crate::exec::Uniform;
        let (_, params, t) = tiny();
        let seqs: Vec<(usize, Vec<u8>)> =
            vec![(0, vec![1, 2, 3]), (0, vec![9, 8, 7, 6, 5]), (0, vec![42])];
        let batched = t.forward_plan(&Uniform(&params), &seqs);
        for ((_, tokens), got) in seqs.iter().zip(&batched) {
            let want = t.forward_one(&params, tokens);
            assert_eq!(got.data, want.data);
        }
    }

    #[test]
    fn weight_perturbation_changes_logits() {
        let (_, mut params, t) = tiny();
        let tokens: Vec<u8> = vec![3, 1, 4, 1, 5];
        let before = t.forward_one(&params, &tokens);
        use crate::model::params::{ModuleId, ProjKind};
        let m = params.module_mut(ModuleId { layer: 0, kind: ProjKind::Q });
        for x in m.iter_mut() {
            *x += 0.05;
        }
        let after = t.forward_one(&params, &tokens);
        assert!(before.mse(&after) > 0.0);
    }
}
