//! Synthetic fine-tune generator.
//!
//! The real pipeline produces fine-tunes by *training* (see
//! `pipeline::train`). For unit tests, ablations, and the isotropy
//! limitation study (§4 of the paper) we also need fine-tunes with
//! *controlled* delta structure. This module perturbs a base model with
//! deltas whose per-row scale distribution is explicitly parameterized:
//!
//! `ΔW[j, i] = row_scale[j] · col_scale[i] · ε[j,i]`,  ε ~ N(0, 1)
//!
//! * `anisotropy = 0`  → all row/col scales equal (isotropic delta): per the
//!   paper's limitation, a single scalar should match per-axis vectors.
//! * `anisotropy > 0`  → log-normal spread of scales across the dominant
//!   axis; per-axis vectors should win. `axis_bias` controls whether rows
//!   or columns carry the spread (drives Figure-2-style axis selection).

use super::config::ModelConfig;
use super::params::{FlatParams, ModuleId, ProjKind};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SynthDeltaSpec {
    /// Base magnitude of the delta relative to typical weight std.
    pub magnitude: f32,
    /// Log-normal sigma of per-axis scales. 0 = isotropic.
    pub anisotropy: f32,
    /// In [0,1]: 1.0 = all structure on rows, 0.0 = all on columns,
    /// 0.5 = both equally.
    pub axis_bias: f32,
    pub seed: u64,
}

impl Default for SynthDeltaSpec {
    fn default() -> Self {
        SynthDeltaSpec { magnitude: 0.02, anisotropy: 1.0, axis_bias: 0.7, seed: 1234 }
    }
}

/// Produce a "fine-tuned" copy of `base` by adding structured deltas to all
/// patchable modules.
pub fn synth_finetune(base: &FlatParams, spec: &SynthDeltaSpec) -> FlatParams {
    let mut ft = base.clone();
    let cfg = base.cfg().clone();
    let mut rng = Rng::new(spec.seed);
    for id in base.layout.patchable_modules() {
        let mut mod_rng = rng.fork(&id.to_string());
        apply_synth_delta(&mut ft, id, &cfg, spec, &mut mod_rng);
    }
    ft
}

/// Per-kind axis bias: mimic the paper's Figure-2 tendencies (q/v/o/down
/// prefer row; gate/up prefer column; k mixed) so axis-selection statistics
/// have real structure to discover.
pub fn kind_axis_bias(kind: ProjKind, spec_bias: f32) -> f32 {
    let kind_shift = match kind {
        ProjKind::Q | ProjKind::V | ProjKind::O | ProjKind::Down => 0.25,
        ProjKind::Gate | ProjKind::Up => -0.25,
        ProjKind::K => 0.0,
    };
    (spec_bias + kind_shift).clamp(0.0, 1.0)
}

fn apply_synth_delta(
    ft: &mut FlatParams,
    id: ModuleId,
    cfg: &ModelConfig,
    spec: &SynthDeltaSpec,
    rng: &mut Rng,
) {
    let (rows, cols) = id.kind.shape(cfg);
    let bias = kind_axis_bias(id.kind, spec.axis_bias);
    let row_sigma = spec.anisotropy * bias;
    let col_sigma = spec.anisotropy * (1.0 - bias);
    let row_scale: Vec<f32> =
        (0..rows).map(|_| (rng.normal_f32(0.0, row_sigma)).exp()).collect();
    let col_scale: Vec<f32> =
        (0..cols).map(|_| (rng.normal_f32(0.0, col_sigma)).exp()).collect();
    let w = ft.module_mut(id);
    for j in 0..rows {
        let rs = spec.magnitude * row_scale[j];
        let row = &mut w[j * cols..(j + 1) * cols];
        for (i, x) in row.iter_mut().enumerate() {
            *x += rs * col_scale[i] * rng.normal_f32(0.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn finetune_differs_only_in_patchable_modules() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 1);
        let ft = synth_finetune(&base, &SynthDeltaSpec::default());
        // Embedding and norms untouched.
        let e0 = base.layout.embed;
        let elen = cfg.vocab * cfg.dim;
        assert_eq!(&base.data[e0..e0 + elen], &ft.data[e0..e0 + elen]);
        let n0 = base.layout.layers[0].attn_norm;
        assert_eq!(&base.data[n0..n0 + cfg.dim], &ft.data[n0..n0 + cfg.dim]);
        // All patchable modules changed.
        for id in base.layout.patchable_modules() {
            assert_ne!(base.module(id), ft.module(id), "{id} unchanged");
        }
    }

    #[test]
    fn magnitude_controls_delta_norm() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 2);
        let small = synth_finetune(
            &base,
            &SynthDeltaSpec { magnitude: 0.001, anisotropy: 0.0, ..Default::default() },
        );
        let large = synth_finetune(
            &base,
            &SynthDeltaSpec { magnitude: 0.1, anisotropy: 0.0, ..Default::default() },
        );
        let id = base.layout.patchable_modules()[0];
        let norm = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
        };
        let ns = norm(base.module(id), small.module(id));
        let nl = norm(base.module(id), large.module(id));
        assert!(nl > ns * 100.0, "ns={ns} nl={nl}");
    }

    #[test]
    fn isotropic_spec_has_uniform_row_energy() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 3);
        let spec = SynthDeltaSpec { anisotropy: 0.0, seed: 9, ..Default::default() };
        let ft = synth_finetune(&base, &spec);
        let id = base.layout.patchable_modules()[0];
        let (rows, cols) = id.kind.shape(&cfg);
        let b = base.module(id);
        let f = ft.module(id);
        let row_energy: Vec<f64> = (0..rows)
            .map(|j| {
                (0..cols)
                    .map(|i| ((f[j * cols + i] - b[j * cols + i]) as f64).powi(2))
                    .sum::<f64>()
                    / cols as f64
            })
            .collect();
        let mean = row_energy.iter().sum::<f64>() / rows as f64;
        let max_dev =
            row_energy.iter().map(|e| (e - mean).abs() / mean).fold(0.0f64, f64::max);
        assert!(max_dev < 0.5, "isotropic rows should have similar energy, max_dev={max_dev}");
    }

    #[test]
    fn anisotropic_spec_has_spread_row_energy() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 3);
        let spec =
            SynthDeltaSpec { anisotropy: 1.5, axis_bias: 1.0, seed: 9, ..Default::default() };
        let ft = synth_finetune(&base, &spec);
        let id = base.layout.patchable_modules()[0]; // q_proj: bias clamps to 1.0 -> rows
        let (rows, cols) = id.kind.shape(&cfg);
        let b = base.module(id);
        let f = ft.module(id);
        let row_energy: Vec<f64> = (0..rows)
            .map(|j| {
                (0..cols)
                    .map(|i| ((f[j * cols + i] - b[j * cols + i]) as f64).powi(2))
                    .sum::<f64>()
                    / cols as f64
            })
            .collect();
        let mx = row_energy.iter().cloned().fold(0.0f64, f64::max);
        let mn = row_energy.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx / mn > 10.0, "expected wide row-energy spread, got {mx}/{mn}");
    }
}
