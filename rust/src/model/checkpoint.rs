//! FP16 full-checkpoint format — the *baseline* the paper compares against
//! for both storage (Table 2, "vs. FP16 weights") and cold-start load time
//! (§3.2: full FP16 load 2.08 s vs delta path 0.80 s).
//!
//! Layout: fixed header, config descriptor, then the flat parameter vector
//! as little-endian IEEE f16, with a trailing crc32 over the payload.

use super::config::ModelConfig;
use super::params::FlatParams;
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PAWDFP16";
const VERSION: u32 = 1;

/// Serialize params as an FP16 checkpoint file.
pub fn save_fp16<P: AsRef<Path>>(path: P, params: &FlatParams) -> Result<u64> {
    let cfg = params.cfg();
    let mut payload = Vec::with_capacity(params.data.len() * 2);
    for &x in &params.data {
        payload.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
    let crc = crate::util::crc32::hash(&payload);

    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    write_str(&mut f, &cfg.name)?;
    for v in [cfg.vocab, cfg.dim, cfg.n_layers, cfg.n_heads, cfg.ff, cfg.max_seq] {
        f.write_all(&(v as u32).to_le_bytes())?;
    }
    f.write_all(&(params.data.len() as u64).to_le_bytes())?;
    f.write_all(&payload)?;
    f.write_all(&crc.to_le_bytes())?;
    f.flush()?;
    Ok(std::fs::metadata(&path)?.len())
}

/// Load an FP16 checkpoint into f32 flat params.
///
/// This is deliberately a *single* sequential read followed by one decode
/// pass — the fair comparison for the delta loader's "single operation per
/// module" claim.
pub fn load_fp16<P: AsRef<Path>>(path: P) -> Result<FlatParams> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading checkpoint {}", path.as_ref().display()))?;
    let mut r = &bytes[..];
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic: not a PAWDFP16 checkpoint");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let name = read_str(&mut r)?;
    let vocab = read_u32(&mut r)? as usize;
    let dim = read_u32(&mut r)? as usize;
    let n_layers = read_u32(&mut r)? as usize;
    let n_heads = read_u32(&mut r)? as usize;
    let ff = read_u32(&mut r)? as usize;
    let max_seq = read_u32(&mut r)? as usize;
    let cfg = ModelConfig { name, vocab, dim, n_layers, n_heads, ff, max_seq };
    cfg.validate()?;
    let n = read_u64(&mut r)? as usize;
    if n != cfg.n_params() {
        bail!("param count {} does not match config ({})", n, cfg.n_params());
    }
    if r.len() < n * 2 + 4 {
        bail!("truncated checkpoint");
    }
    let (payload, tail) = r.split_at(n * 2);
    let stored_crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    if crate::util::crc32::hash(payload) != stored_crc {
        bail!("checkpoint crc mismatch (corrupt file)");
    }
    let mut params = FlatParams::zeros(&cfg);
    for (i, c) in payload.chunks_exact(2).enumerate() {
        params.data[i] = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
    }
    Ok(params)
}

fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut &[u8]) -> Result<String> {
    let len = read_u32(r)? as usize;
    if r.len() < len {
        bail!("truncated string");
    }
    let (s, rest) = r.split_at(len);
    *r = rest;
    Ok(String::from_utf8(s.to_vec())?)
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    if r.len() < 4 {
        bail!("truncated u32");
    }
    let (b, rest) = r.split_at(4);
    *r = rest;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

fn read_u64(r: &mut &[u8]) -> Result<u64> {
    if r.len() < 8 {
        bail!("truncated u64");
    }
    let (b, rest) = r.split_at(8);
    *r = rest;
    Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_values_at_f16_precision() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 3);
        let dir = std::env::temp_dir().join("pawd_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.fp16");
        let size = save_fp16(&path, &p).unwrap();
        assert!(size as usize > p.data.len() * 2);
        let q = load_fp16(&path).unwrap();
        assert_eq!(q.cfg(), p.cfg());
        for (a, b) in p.data.iter().zip(&q.data) {
            let tol = 1e-3 * a.abs().max(1e-3);
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn corrupt_file_detected() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 4);
        let dir = std::env::temp_dir().join("pawd_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.fp16");
        save_fp16(&path, &p).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_fp16(&path).unwrap_err().to_string();
        assert!(err.contains("crc"), "unexpected error: {err}");
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = std::env::temp_dir().join("pawd_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.fp16");
        std::fs::write(&path, b"NOTAFILE________").unwrap();
        assert!(load_fp16(&path).is_err());
    }
}
