//! Flat parameter layout, shared bit-for-bit with `python/compile/model.py`.
//!
//! All parameters live in one contiguous f32 vector so the AOT programs take
//! a single `params` argument and the serving path can materialize a variant
//! with one allocation + one fused apply pass. Layout (offsets in f32s):
//!
//! ```text
//! embed            [vocab, dim]
//! for l in 0..L:
//!   attn_norm      [dim]
//!   wq, wk, wv, wo [dim, dim]        (row-major, [d_out, d_in])
//!   mlp_norm       [dim]
//!   w_gate, w_up   [ff, dim]
//!   w_down         [dim, ff]
//! final_norm       [dim]
//! lm_head          [vocab, dim]
//! ```
//!
//! The seven per-layer projection matrices are the *patchable modules* the
//! paper compresses (attention + MLP projections; norms/embeddings are left
//! untouched, matching §4).

use super::config::ModelConfig;
use crate::tensor::Tensor2;
use crate::util::rng::Rng;
use std::fmt;

/// Kind of patchable linear projection, with the paper's sub-type naming
/// (Figure 2 reports axis counts per sub-type).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProjKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

impl ProjKind {
    pub const ALL: [ProjKind; 7] =
        [ProjKind::Q, ProjKind::K, ProjKind::V, ProjKind::O, ProjKind::Gate, ProjKind::Up, ProjKind::Down];

    pub fn name(&self) -> &'static str {
        match self {
            ProjKind::Q => "q_proj",
            ProjKind::K => "k_proj",
            ProjKind::V => "v_proj",
            ProjKind::O => "o_proj",
            ProjKind::Gate => "gate_proj",
            ProjKind::Up => "up_proj",
            ProjKind::Down => "down_proj",
        }
    }

    pub fn parse(s: &str) -> Option<ProjKind> {
        ProjKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// `[d_out, d_in]` for this projection under `cfg`.
    pub fn shape(&self, cfg: &ModelConfig) -> (usize, usize) {
        let (d, f) = (cfg.dim, cfg.ff);
        match self {
            ProjKind::Q | ProjKind::K | ProjKind::V | ProjKind::O => (d, d),
            ProjKind::Gate | ProjKind::Up => (f, d),
            ProjKind::Down => (d, f),
        }
    }
}

/// Identifier of one patchable module (layer index + projection kind).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModuleId {
    pub layer: usize,
    pub kind: ProjKind,
}

impl fmt::Display for ModuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "layers.{}.{}", self.layer, self.kind.name())
    }
}

impl ModuleId {
    pub fn parse(s: &str) -> Option<ModuleId> {
        let rest = s.strip_prefix("layers.")?;
        let (layer_s, kind_s) = rest.split_once('.')?;
        Some(ModuleId { layer: layer_s.parse().ok()?, kind: ProjKind::parse(kind_s)? })
    }
}

/// Offsets of every parameter tensor within the flat vector.
#[derive(Clone, Debug)]
pub struct Layout {
    pub cfg: ModelConfig,
    pub embed: usize,
    pub layers: Vec<LayerOffsets>,
    pub final_norm: usize,
    pub lm_head: usize,
    pub total: usize,
}

#[derive(Clone, Debug)]
pub struct LayerOffsets {
    pub attn_norm: usize,
    pub wq: usize,
    pub wk: usize,
    pub wv: usize,
    pub wo: usize,
    pub mlp_norm: usize,
    pub w_gate: usize,
    pub w_up: usize,
    pub w_down: usize,
}

impl Layout {
    pub fn new(cfg: &ModelConfig) -> Layout {
        let (v, d, f) = (cfg.vocab, cfg.dim, cfg.ff);
        let mut off = 0usize;
        let mut take = |n: usize| {
            let o = off;
            off += n;
            o
        };
        let embed = take(v * d);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LayerOffsets {
                attn_norm: take(d),
                wq: take(d * d),
                wk: take(d * d),
                wv: take(d * d),
                wo: take(d * d),
                mlp_norm: take(d),
                w_gate: take(f * d),
                w_up: take(f * d),
                w_down: take(d * f),
            });
        }
        let final_norm = take(d);
        let lm_head = take(v * d);
        let layout = Layout { cfg: cfg.clone(), embed, layers, final_norm, lm_head, total: off };
        debug_assert_eq!(layout.total, cfg.n_params());
        layout
    }

    /// Flat offset and length of a patchable module's weight matrix.
    pub fn module_span(&self, id: ModuleId) -> (usize, usize) {
        let l = &self.layers[id.layer];
        let (rows, cols) = id.kind.shape(&self.cfg);
        let off = match id.kind {
            ProjKind::Q => l.wq,
            ProjKind::K => l.wk,
            ProjKind::V => l.wv,
            ProjKind::O => l.wo,
            ProjKind::Gate => l.w_gate,
            ProjKind::Up => l.w_up,
            ProjKind::Down => l.w_down,
        };
        (off, rows * cols)
    }

    /// All patchable modules, in layer order then `ProjKind::ALL` order —
    /// the canonical sweep order for the compression pipeline (Alg. 1).
    pub fn patchable_modules(&self) -> Vec<ModuleId> {
        let mut out = Vec::with_capacity(self.cfg.n_patchable());
        for layer in 0..self.cfg.n_layers {
            for kind in ProjKind::ALL {
                out.push(ModuleId { layer, kind });
            }
        }
        out
    }
}

/// A full set of model parameters in the flat layout.
#[derive(Clone)]
pub struct FlatParams {
    pub layout: Layout,
    pub data: Vec<f32>,
}

impl fmt::Debug for FlatParams {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "FlatParams[{} x f32, cfg={}]", self.data.len(), self.layout.cfg.name)
    }
}

impl FlatParams {
    pub fn zeros(cfg: &ModelConfig) -> FlatParams {
        let layout = Layout::new(cfg);
        let total = layout.total;
        FlatParams { layout, data: vec![0.0; total] }
    }

    /// Deterministic scaled-normal init, matching `model.py::init_params`
    /// in *distribution* (not bit-exact across languages; parity tests use
    /// params generated on one side and shipped to the other).
    pub fn init(cfg: &ModelConfig, seed: u64) -> FlatParams {
        let mut p = FlatParams::zeros(cfg);
        let mut rng = Rng::new(seed);
        let d = cfg.dim;
        let f = cfg.ff;
        let std_d = 1.0 / (d as f32).sqrt();
        let std_f = 1.0 / (f as f32).sqrt();
        // embed
        {
            let (lo, len) = (p.layout.embed, cfg.vocab * d);
            rng.fill_normal(&mut p.data[lo..lo + len], 0.02);
        }
        for l in 0..cfg.n_layers {
            let lo = p.layout.layers[l].clone();
            for x in &mut p.data[lo.attn_norm..lo.attn_norm + d] {
                *x = 1.0;
            }
            for x in &mut p.data[lo.mlp_norm..lo.mlp_norm + d] {
                *x = 1.0;
            }
            rng.fill_normal(&mut p.data[lo.wq..lo.wq + d * d], std_d);
            rng.fill_normal(&mut p.data[lo.wk..lo.wk + d * d], std_d);
            rng.fill_normal(&mut p.data[lo.wv..lo.wv + d * d], std_d);
            rng.fill_normal(&mut p.data[lo.wo..lo.wo + d * d], std_d);
            rng.fill_normal(&mut p.data[lo.w_gate..lo.w_gate + f * d], std_d);
            rng.fill_normal(&mut p.data[lo.w_up..lo.w_up + f * d], std_d);
            rng.fill_normal(&mut p.data[lo.w_down..lo.w_down + d * f], std_f);
        }
        {
            let fnorm = p.layout.final_norm;
            for x in &mut p.data[fnorm..fnorm + d] {
                *x = 1.0;
            }
            let (lo, len) = (p.layout.lm_head, cfg.vocab * d);
            rng.fill_normal(&mut p.data[lo..lo + len], std_d);
        }
        p
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.layout.cfg
    }

    /// Borrow a module's weight matrix as a slice.
    pub fn module(&self, id: ModuleId) -> &[f32] {
        let (off, len) = self.layout.module_span(id);
        &self.data[off..off + len]
    }

    pub fn module_mut(&mut self, id: ModuleId) -> &mut [f32] {
        let (off, len) = self.layout.module_span(id);
        &mut self.data[off..off + len]
    }

    /// Copy a module's weights into a `Tensor2` (for calibration math).
    pub fn module_tensor(&self, id: ModuleId) -> Tensor2 {
        let (rows, cols) = id.kind.shape(self.cfg());
        Tensor2::from_vec(rows, cols, self.module(id).to_vec())
    }

    /// Total parameter bytes at FP16 (the full-checkpoint baseline size).
    pub fn fp16_bytes(&self) -> u64 {
        (self.data.len() * 2) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_offsets_are_disjoint_and_total() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let layout = Layout::new(&cfg);
        assert_eq!(layout.total, cfg.n_params());
        // Module spans must not overlap.
        let mut spans: Vec<(usize, usize)> =
            layout.patchable_modules().iter().map(|&m| layout.module_span(m)).collect();
        spans.sort();
        for w in spans.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "overlapping spans {:?}", w);
        }
    }

    #[test]
    fn patchable_count_matches_config() {
        let cfg = ModelConfig::preset("llama-mini").unwrap();
        let layout = Layout::new(&cfg);
        assert_eq!(layout.patchable_modules().len(), cfg.n_patchable());
    }

    #[test]
    fn module_id_roundtrip() {
        let id = ModuleId { layer: 3, kind: ProjKind::Gate };
        assert_eq!(id.to_string(), "layers.3.gate_proj");
        assert_eq!(ModuleId::parse("layers.3.gate_proj"), Some(id));
        assert_eq!(ModuleId::parse("garbage"), None);
        assert_eq!(ModuleId::parse("layers.x.q_proj"), None);
    }

    #[test]
    fn init_is_deterministic_and_nontrivial() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = FlatParams::init(&cfg, 7);
        let b = FlatParams::init(&cfg, 7);
        assert_eq!(a.data, b.data);
        let c = FlatParams::init(&cfg, 8);
        assert_ne!(a.data, c.data);
        // Norm weights are ones.
        let lo = a.layout.layers[0].attn_norm;
        assert!(a.data[lo..lo + cfg.dim].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn module_views_have_right_shape() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 1);
        let t = p.module_tensor(ModuleId { layer: 1, kind: ProjKind::Up });
        assert_eq!((t.rows, t.cols), (cfg.ff, cfg.dim));
        let t = p.module_tensor(ModuleId { layer: 0, kind: ProjKind::Down });
        assert_eq!((t.rows, t.cols), (cfg.dim, cfg.ff));
    }

    #[test]
    fn module_mut_edits_flat_vector() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let mut p = FlatParams::zeros(&cfg);
        let id = ModuleId { layer: 0, kind: ProjKind::Q };
        p.module_mut(id)[0] = 42.0;
        let (off, _) = p.layout.module_span(id);
        assert_eq!(p.data[off], 42.0);
    }
}
