//! Model substrate: configuration presets, the flat parameter layout shared
//! with the JAX side, a native Rust transformer forward pass (parity oracle
//! and fallback engine), FP16 full checkpoints (the baseline artifact), and
//! a controlled synthetic fine-tune generator.

pub mod checkpoint;
pub mod config;
pub mod params;
pub mod synth;
pub mod transformer;

pub use config::ModelConfig;
pub use params::{FlatParams, Layout, ModuleId, ProjKind};
pub use transformer::{PlanSeq, Transformer};
