//! Calibration caches (paper §2 "Calibration cache", Algorithm 3).
//!
//! For each target layer we collect, over the calibration documents:
//! * `Y` — the **teacher** (fine-tuned model) outputs of each patchable
//!   projection of that layer, and
//! * `X` — the **student** (compressed-so-far model) inputs to the same
//!   projections (the output of the already-compressed stack up to layer
//!   i−1, immediately before entering layer i).
//!
//! Token positions are pooled across documents into one `[n, d]` matrix per
//! module; `n` is capped by deterministic striding so the quadratic col-mode
//! statistics stay cheap.

use crate::model::params::ProjKind;
use crate::model::{FlatParams, Transformer};
use crate::tensor::Tensor2;
use std::collections::BTreeMap;

/// Pooled (X, Y) cache for one module.
#[derive(Clone, Debug)]
pub struct ModuleCache {
    /// `[n, d_in]` student-side inputs.
    pub x: Tensor2,
    /// `[n, d_out]` teacher-side outputs.
    pub y: Tensor2,
}

impl ModuleCache {
    /// Split rows into (train, val) by taking every `1/val_fraction`-th row
    /// as validation (deterministic, interleaved so both shards cover all
    /// documents).
    pub fn split(&self, val_fraction: f32) -> (ModuleCache, ModuleCache) {
        let n = self.x.rows;
        let stride = (1.0 / val_fraction.clamp(0.05, 0.5)).round() as usize;
        let mut tr_x = Vec::new();
        let mut tr_y = Vec::new();
        let mut va_x = Vec::new();
        let mut va_y = Vec::new();
        let mut n_tr = 0;
        let mut n_va = 0;
        for t in 0..n {
            if t % stride == stride - 1 {
                va_x.extend_from_slice(self.x.row(t));
                va_y.extend_from_slice(self.y.row(t));
                n_va += 1;
            } else {
                tr_x.extend_from_slice(self.x.row(t));
                tr_y.extend_from_slice(self.y.row(t));
                n_tr += 1;
            }
        }
        (
            ModuleCache {
                x: Tensor2::from_vec(n_tr, self.x.cols, tr_x),
                y: Tensor2::from_vec(n_tr, self.y.cols, tr_y),
            },
            ModuleCache {
                x: Tensor2::from_vec(n_va, self.x.cols, va_x),
                y: Tensor2::from_vec(n_va, self.y.cols, va_y),
            },
        )
    }
}

/// Build the per-module caches for one layer (Algorithm 3): one teacher
/// forward (tapping module outputs) and one student forward (tapping module
/// inputs) per document.
pub fn build_layer_caches(
    teacher: &FlatParams,
    student: &FlatParams,
    tf: &Transformer,
    layer: usize,
    docs: &[Vec<u8>],
    max_rows: usize,
) -> BTreeMap<ProjKind, ModuleCache> {
    let mut xs: BTreeMap<ProjKind, Vec<f32>> = BTreeMap::new();
    let mut ys: BTreeMap<ProjKind, Vec<f32>> = BTreeMap::new();
    let mut rows = 0usize;
    for doc in docs {
        if doc.len() < 2 {
            continue;
        }
        let (_, t_taps) = tf.forward_one_tapped(teacher, doc, layer);
        let (_, s_taps) = tf.forward_one_tapped(student, doc, layer);
        for kind in ProjKind::ALL {
            xs.entry(kind).or_default().extend_from_slice(&s_taps.input(kind).data);
            ys.entry(kind).or_default().extend_from_slice(&t_taps.output(kind).data);
        }
        rows += doc.len();
    }
    assert!(rows > 0, "empty calibration document set");

    let mut out = BTreeMap::new();
    for kind in ProjKind::ALL {
        let xv = xs.remove(&kind).unwrap();
        let yv = ys.remove(&kind).unwrap();
        let d_in = xv.len() / rows;
        let d_out = yv.len() / rows;
        let mut x = Tensor2::from_vec(rows, d_in, xv);
        let mut y = Tensor2::from_vec(rows, d_out, yv);
        if rows > max_rows {
            let (sx, sy) = stride_subsample(&x, &y, max_rows);
            x = sx;
            y = sy;
        }
        out.insert(kind, ModuleCache { x, y });
    }
    out
}

/// Deterministic stride subsample keeping row pairing.
fn stride_subsample(x: &Tensor2, y: &Tensor2, max_rows: usize) -> (Tensor2, Tensor2) {
    let n = x.rows;
    let stride = n.div_ceil(max_rows);
    let keep: Vec<usize> = (0..n).step_by(stride).collect();
    let mut xv = Vec::with_capacity(keep.len() * x.cols);
    let mut yv = Vec::with_capacity(keep.len() * y.cols);
    for &t in &keep {
        xv.extend_from_slice(x.row(t));
        yv.extend_from_slice(y.row(t));
    }
    (
        Tensor2::from_vec(keep.len(), x.cols, xv),
        Tensor2::from_vec(keep.len(), y.cols, yv),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn caches_satisfy_linear_identity_for_identical_models() {
        // When teacher == student, Y must equal X · Wᵀ exactly.
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 3);
        let tf = Transformer::new(&cfg);
        let docs: Vec<Vec<u8>> = vec![(5..25u8).collect(), (40..80u8).collect()];
        let caches = build_layer_caches(&p, &p, &tf, 0, &docs, 10_000);
        for kind in ProjKind::ALL {
            let c = &caches[&kind];
            let w = p.module_tensor(crate::model::ModuleId { layer: 0, kind });
            let want = c.x.matmul_bt(&w);
            let err = want.mse(&c.y);
            assert!(err < 1e-8, "{kind:?} identity violated: {err}");
        }
    }

    #[test]
    fn cache_rows_pool_across_docs() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 4);
        let tf = Transformer::new(&cfg);
        let docs: Vec<Vec<u8>> = vec![vec![1; 10], vec![2; 15]];
        let caches = build_layer_caches(&p, &p, &tf, 1, &docs, 10_000);
        assert_eq!(caches[&ProjKind::Q].x.rows, 25);
    }

    #[test]
    fn subsampling_caps_rows() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 5);
        let tf = Transformer::new(&cfg);
        let docs: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8 + 1; 20]).collect();
        let caches = build_layer_caches(&p, &p, &tf, 0, &docs, 30);
        let n = caches[&ProjKind::Up].x.rows;
        assert!(n <= 40 && n >= 20, "n={n}");
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let p = FlatParams::init(&cfg, 6);
        let tf = Transformer::new(&cfg);
        let docs: Vec<Vec<u8>> = vec![vec![3; 30]];
        let caches = build_layer_caches(&p, &p, &tf, 0, &docs, 10_000);
        let c = &caches[&ProjKind::V];
        let (tr, va) = c.split(0.2);
        assert_eq!(tr.x.rows + va.x.rows, c.x.rows);
        assert!(va.x.rows >= c.x.rows / 6);
    }
}
