//! Core delta types: scaling axis, per-module delta, whole-model delta.
//!
//! Modules inside a [`DeltaModel`] are held behind `Arc` so a *resolved*
//! version is a cheap composition: loading `variant@N+1` as a patch on
//! `variant@N` reuses the already-resident module Arcs of `@N` for every
//! module the patch does not carry (see [`chain`](super::chain)), and the
//! variant cache charges the bytes of a shared module only once no matter
//! how many resident versions hold it.

use super::pack::PackedMask;
use crate::model::{ModuleId, ProjKind};
use crate::util::f16::encode_f16_slice;
use std::sync::Arc;

/// Scale parameterization for the 1-bit delta of one weight matrix
/// `[d_out, d_in]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    /// One scale per output row: `Ŵ[j,·] = W_b[j,·] + v[j]·B[j,·]`
    /// (the paper's "row" mode).
    Row,
    /// One scale per input column: `Ŵ[·,i] = W_b[·,i] + v[i]·B[·,i]`
    /// (the paper's "col" mode).
    Col,
    /// Single scalar per matrix — the BitDelta baseline (Liu et al., 2024).
    Scalar,
    /// Blockwise per-group scales over consecutive output rows (the paper's
    /// §5 future-work extension); `group = 1` degenerates to `Row`,
    /// `group >= d_out` to `Scalar`.
    Group(u32),
}

impl Axis {
    /// Number of scale values for a `[d_out, d_in]` matrix.
    pub fn n_scales(&self, d_out: usize, d_in: usize) -> usize {
        match self {
            Axis::Row => d_out,
            Axis::Col => d_in,
            Axis::Scalar => 1,
            Axis::Group(g) => d_out.div_ceil((*g).max(1) as usize),
        }
    }

    pub fn code(&self) -> u8 {
        match self {
            Axis::Row => 0,
            Axis::Col => 1,
            Axis::Scalar => 2,
            Axis::Group(_) => 3,
        }
    }

    pub fn from_code(code: u8, group: u32) -> anyhow::Result<Axis> {
        Ok(match code {
            0 => Axis::Row,
            1 => Axis::Col,
            2 => Axis::Scalar,
            3 => Axis::Group(group),
            other => anyhow::bail!("unknown axis code {other}"),
        })
    }

    pub fn label(&self) -> String {
        match self {
            Axis::Row => "row".into(),
            Axis::Col => "col".into(),
            Axis::Scalar => "scalar".into(),
            Axis::Group(g) => format!("group{g}"),
        }
    }
}

/// Codec discriminant as carried on the wire (format v4 section table) and
/// in admin/inspect surfaces. [`Codec`] holds the per-module payload; this
/// enum is the cheap tag shared by format, registry, and reporting code.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CodecKind {
    /// The paper's sign-bitplane + per-axis FP16 scales (format v1–v3 and
    /// the v4 default).
    PerAxis,
    /// BitDelta-style scalar scale (Liu et al., 2024): the per-axis record
    /// layout restricted to `Axis::Scalar`.
    Scalar,
    /// Per-axis bitplane plus a low-rank residual correction, executed as
    /// `y += (x·Aᵀ)·Bᵀ` and never densified (D-QRELO-style residual
    /// repair).
    LowRank,
}

impl CodecKind {
    pub const ALL: [CodecKind; 3] = [CodecKind::PerAxis, CodecKind::Scalar, CodecKind::LowRank];

    /// Wire byte in the format-v4 section table.
    pub fn code(&self) -> u8 {
        match self {
            CodecKind::PerAxis => 0,
            CodecKind::Scalar => 1,
            CodecKind::LowRank => 2,
        }
    }

    pub fn from_code(code: u8) -> anyhow::Result<CodecKind> {
        Ok(match code {
            0 => CodecKind::PerAxis,
            1 => CodecKind::Scalar,
            2 => CodecKind::LowRank,
            other => anyhow::bail!("unknown codec code {other}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            CodecKind::PerAxis => "per-axis",
            CodecKind::Scalar => "scalar",
            CodecKind::LowRank => "lowrank",
        }
    }
}

/// Low-rank residual factors for the [`CodecKind::LowRank`] codec:
/// `Δ̂ = v ⊙ B + Bᵣ·A` with `A = [rank, d_in]` and `Bᵣ = [d_out, rank]`,
/// both row-major. Stored FP16 on disk, f32 in memory; the exec layer adds
/// the term as `y += (x·Aᵀ)·Bᵣᵀ` without ever densifying `Bᵣ·A`.
#[derive(Clone, Debug)]
pub struct LowRank {
    pub rank: usize,
    /// `[rank, d_in]` row-major input-side factor.
    pub a: Vec<f32>,
    /// `[d_out, rank]` row-major output-side factor.
    pub b: Vec<f32>,
}

/// Per-module codec payload. `PerAxis` and `Scalar` carry no extra data —
/// their entire payload lives in the shared mask/scales fields of
/// [`DeltaModule`]; `LowRank` adds the residual factors.
#[derive(Clone, Debug)]
pub enum Codec {
    PerAxis,
    Scalar,
    LowRank(LowRank),
}

impl Codec {
    pub fn kind(&self) -> CodecKind {
        match self {
            Codec::PerAxis => CodecKind::PerAxis,
            Codec::Scalar => CodecKind::Scalar,
            Codec::LowRank(_) => CodecKind::LowRank,
        }
    }
}

/// Compressed delta for one patchable module.
#[derive(Clone, Debug)]
pub struct DeltaModule {
    pub id: ModuleId,
    pub mask: PackedMask,
    pub axis: Axis,
    /// Scale vector, length `axis.n_scales(d_out, d_in)`. Stored FP16 on
    /// disk (paper: "vectors v are FP16"), f32 in memory.
    pub scales: Vec<f32>,
    /// Codec this module is encoded under; `Codec::PerAxis` for every
    /// v1–v3 artifact and the v4 default.
    pub codec: Codec,
}

impl DeltaModule {
    pub fn d_out(&self) -> usize {
        self.mask.d_out
    }

    pub fn d_in(&self) -> usize {
        self.mask.d_in
    }

    /// Scale applying to entry (j, i).
    #[inline]
    pub fn scale_at(&self, j: usize, i: usize) -> f32 {
        match self.axis {
            Axis::Row => self.scales[j],
            Axis::Col => self.scales[i],
            Axis::Scalar => self.scales[0],
            Axis::Group(g) => self.scales[j / g.max(1) as usize],
        }
    }

    /// The low-rank residual factors, when this module carries them.
    #[inline]
    pub fn lowrank(&self) -> Option<&LowRank> {
        match &self.codec {
            Codec::LowRank(lr) => Some(lr),
            _ => None,
        }
    }

    /// On-disk payload bytes (mask + FP16 scales, plus FP16 low-rank
    /// factors for the low-rank codec), excluding record header.
    pub fn payload_bytes(&self) -> u64 {
        let base = self.mask.n_bytes() + (self.scales.len() * 2) as u64;
        match &self.codec {
            Codec::LowRank(lr) => base + 4 + ((lr.a.len() + lr.b.len()) * 2) as u64,
            _ => base,
        }
    }

    /// In-memory bytes when served packed (mask words + f32 scales + f32
    /// low-rank factors) — the single source of truth for the exec layer's
    /// residency accounting.
    pub fn resident_bytes(&self) -> u64 {
        let base = self.mask.n_bytes() + (self.scales.len() * 4) as u64;
        match &self.codec {
            Codec::LowRank(lr) => base + ((lr.a.len() + lr.b.len()) * 4) as u64,
            _ => base,
        }
    }

    /// On-disk content equality: same module, codec, axis, mask bits and
    /// the same *FP16* scale (and low-rank factor) bits. This is what the
    /// incremental publisher diffs on — two modules that serialize to
    /// identical record payloads are "the same" even when their in-memory
    /// f32 values differ below f16 precision, so a republish of unchanged
    /// weights produces an empty patch instead of spuriously shipping every
    /// module.
    pub fn content_eq(&self, other: &DeltaModule) -> bool {
        if self.id != other.id
            || self.codec.kind() != other.codec.kind()
            || self.axis != other.axis
            || self.mask != other.mask
            || encode_f16_slice(&self.scales) != encode_f16_slice(&other.scales)
        {
            return false;
        }
        match (&self.codec, &other.codec) {
            (Codec::LowRank(a), Codec::LowRank(b)) => {
                a.rank == b.rank
                    && encode_f16_slice(&a.a) == encode_f16_slice(&b.a)
                    && encode_f16_slice(&a.b) == encode_f16_slice(&b.b)
            }
            _ => true,
        }
    }
}

/// Lifecycle metadata carried by format-v2+ artifacts: where a delta sits in
/// its variant's version history. V1 artifacts (and in-memory models built
/// by the compressor before publication) use the `Default` value; the
/// registry stamps real values at publish time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Version of the variant this artifact is (`variant@version`). Versions
    /// start at 1; the registry assigns them monotonically per variant.
    pub version: u32,
    /// Version this delta was published to supersede (rollback target; for
    /// patch artifacts, also the version the patch composes onto).
    pub parent: Option<u32>,
    /// Publish wall-clock time, seconds since the Unix epoch (0 = unknown,
    /// e.g. a v1 artifact adopted from a pre-registry directory).
    pub created_unix: u64,
    /// Format-v3 **patch** artifacts carry only the modules whose packed
    /// content changed relative to `parent`; every other module is inherited
    /// from the parent's effective model at load time
    /// ([`chain::compose`](super::chain::compose)). `false` for full
    /// artifacts and for every v1/v2 artifact.
    pub is_patch: bool,
}

impl Default for ArtifactMeta {
    fn default() -> ArtifactMeta {
        ArtifactMeta { version: 1, parent: None, created_unix: 0, is_patch: false }
    }
}

/// Whole-model compressed delta (one fine-tuned variant). For a **patch**
/// model (`meta.is_patch`), `modules` holds only the changed modules; the
/// effective model is recovered by composing onto the parent version.
#[derive(Clone, Debug)]
pub struct DeltaModel {
    /// Name of the fine-tuned variant this delta reconstructs.
    pub variant: String,
    /// Base model config name (the delta only applies on that base).
    pub base_config: String,
    /// Version/lineage metadata (format v2+; defaulted for v1 artifacts).
    pub meta: ArtifactMeta,
    /// Per-module deltas behind `Arc` so chain composition and the variant
    /// cache can share unchanged modules across versions without copying.
    pub modules: Vec<Arc<DeltaModule>>,
}

impl DeltaModel {
    /// Build a full (non-patch) model with default lifecycle meta, wrapping
    /// each module in its sharing `Arc`.
    pub fn new(
        variant: impl Into<String>,
        base_config: impl Into<String>,
        modules: Vec<DeltaModule>,
    ) -> DeltaModel {
        DeltaModel {
            variant: variant.into(),
            base_config: base_config.into(),
            meta: ArtifactMeta::default(),
            modules: modules.into_iter().map(Arc::new).collect(),
        }
    }

    /// The module covering `id`, if any.
    pub fn module(&self, id: ModuleId) -> Option<&Arc<DeltaModule>> {
        self.modules.iter().find(|m| m.id == id)
    }

    /// Total payload bytes across modules.
    pub fn payload_bytes(&self) -> u64 {
        self.modules.iter().map(|m| m.payload_bytes()).sum()
    }

    /// Count of modules per (sub-type, axis) — the Figure 2 statistic.
    pub fn axis_counts_by_kind(&self) -> Vec<(ProjKind, usize, usize)> {
        ProjKind::ALL
            .iter()
            .map(|&kind| {
                let row = self
                    .modules
                    .iter()
                    .filter(|m| m.id.kind == kind && m.axis == Axis::Row)
                    .count();
                let col = self
                    .modules
                    .iter()
                    .filter(|m| m.id.kind == kind && m.axis == Axis::Col)
                    .count();
                (kind, row, col)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_scales_per_axis() {
        assert_eq!(Axis::Row.n_scales(8, 16), 8);
        assert_eq!(Axis::Col.n_scales(8, 16), 16);
        assert_eq!(Axis::Scalar.n_scales(8, 16), 1);
        assert_eq!(Axis::Group(4).n_scales(8, 16), 2);
        assert_eq!(Axis::Group(3).n_scales(8, 16), 3); // ceil(8/3)
        assert_eq!(Axis::Group(100).n_scales(8, 16), 1);
    }

    #[test]
    fn axis_code_roundtrip() {
        for a in [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(32)] {
            let g = if let Axis::Group(g) = a { g } else { 0 };
            assert_eq!(Axis::from_code(a.code(), g).unwrap(), a);
        }
        assert!(Axis::from_code(9, 0).is_err());
    }

    #[test]
    fn scale_at_indexing() {
        use crate::model::{ModuleId, ProjKind};
        let mask = PackedMask::pack(&vec![1.0; 6 * 4], 6, 4);
        let m = DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::Q },
            mask,
            axis: Axis::Group(2),
            scales: vec![10.0, 20.0, 30.0],
            codec: Codec::PerAxis,
        };
        assert_eq!(m.scale_at(0, 3), 10.0);
        assert_eq!(m.scale_at(1, 0), 10.0);
        assert_eq!(m.scale_at(2, 0), 20.0);
        assert_eq!(m.scale_at(5, 1), 30.0);
    }

    #[test]
    fn codec_code_roundtrip() {
        for k in CodecKind::ALL {
            assert_eq!(CodecKind::from_code(k.code()).unwrap(), k);
        }
        assert!(CodecKind::from_code(9).is_err());
    }

    #[test]
    fn lowrank_bytes_and_content_eq() {
        use crate::model::{ModuleId, ProjKind};
        let mk = |codec: Codec| DeltaModule {
            id: ModuleId { layer: 0, kind: ProjKind::Q },
            mask: PackedMask::pack(&vec![1.0; 6 * 4], 6, 4),
            axis: Axis::Row,
            scales: vec![1.0; 6],
            codec,
        };
        let pa = mk(Codec::PerAxis);
        let lr = mk(Codec::LowRank(LowRank {
            rank: 2,
            a: vec![0.5; 2 * 4],
            b: vec![0.25; 6 * 2],
        }));
        // Codec kinds differ even though mask/scales match.
        assert!(!pa.content_eq(&lr));
        assert!(lr.content_eq(&lr.clone()));
        // Low-rank payload: +4 rank header + 2 bytes per f16 factor entry.
        assert_eq!(lr.payload_bytes(), pa.payload_bytes() + 4 + 2 * (2 * 4 + 6 * 2));
        assert_eq!(lr.resident_bytes(), pa.resident_bytes() + 4 * (2 * 4 + 6 * 2));
    }
}
