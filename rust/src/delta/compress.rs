//! Per-module compression with row/col axis selection (Algorithm 6) and the
//! layer-by-layer model sweep (Algorithm 1 stages 1–2).
//!
//! Encoding dispatches through the pluggable codec registry
//! ([`codec_for`](super::codec::codec_for)): [`CodecChoice`] in the options
//! selects which [`DeltaCodec`](super::codec::DeltaCodec) encodes each
//! module, with `Auto` running a per-module shoot-out on held-out
//! validation MSE.

use super::cache::{build_layer_caches, ModuleCache};
use super::calibrate::{
    adamw_col, adamw_rowfam, closed_form_col, closed_form_rowfam, col_stats, init_scales,
    mse_col, mse_rowfam, residual, row_stats, CalibConfig,
};
use super::codec::codec_for;
use super::pack::PackedMask;
use super::types::{Axis, Codec, CodecKind, DeltaModel, DeltaModule};
use crate::model::{FlatParams, ModuleId, Transformer};
use crate::tensor::Tensor2;

/// How scale vectors are fitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitMode {
    /// Paper-faithful: AdamW on the layer MSE (Alg. 4).
    AdamW,
    /// Our extension: exact least-squares minimizer of the same objective.
    ClosedForm,
    /// No calibration at all: keep the `mean(|ΔW|)` init (ablation).
    InitOnly,
}

/// Which codec encodes each module (CLI `--codec` values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecChoice {
    /// The paper's per-axis scheme (axis slate from `CompressOptions::axes`).
    PerAxis,
    /// BitDelta-style single scalar scale per module.
    Scalar,
    /// Per-axis plus a low-rank residual correction.
    LowRank,
    /// Run every codec and keep the one with the lowest held-out validation
    /// MSE; ties (and anything not strictly better) fall back to per-axis.
    Auto,
}

impl CodecChoice {
    pub fn parse(s: &str) -> Option<CodecChoice> {
        match s {
            "per-axis" => Some(CodecChoice::PerAxis),
            "scalar" => Some(CodecChoice::Scalar),
            "lowrank" => Some(CodecChoice::LowRank),
            "auto" => Some(CodecChoice::Auto),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CodecChoice::PerAxis => "per-axis",
            CodecChoice::Scalar => "scalar",
            CodecChoice::LowRank => "lowrank",
            CodecChoice::Auto => "auto",
        }
    }
}

#[derive(Clone, Debug)]
pub struct CompressOptions {
    pub calib: CalibConfig,
    pub fit: FitMode,
    /// Candidate axes; the best by validation MSE wins. The paper uses
    /// `[Row, Col]`; baselines/ablations pass `[Scalar]` or `[Group(g)]`.
    pub axes: Vec<Axis>,
    /// Cap on pooled calibration rows per module.
    pub max_cache_rows: usize,
    /// Codec (or per-module auto-selection) used to encode each module.
    pub codec: CodecChoice,
    /// Rank of the low-rank residual term (clamped per module to
    /// `min(d_out, d_in)`); only read by the `lowrank` codec.
    pub lowrank_rank: usize,
}

impl Default for CompressOptions {
    fn default() -> Self {
        CompressOptions {
            calib: CalibConfig::default(),
            fit: FitMode::AdamW,
            axes: vec![Axis::Row, Axis::Col],
            max_cache_rows: 2048,
            codec: CodecChoice::PerAxis,
            lowrank_rank: 4,
        }
    }
}

impl CompressOptions {
    /// BitDelta baseline protocol: single scalar, one epoch (paper §3.1).
    pub fn bitdelta() -> Self {
        let mut o = CompressOptions::default();
        o.axes = vec![Axis::Scalar];
        o.calib.epochs = 1;
        o
    }
}

/// One codec's entry in a per-module shoot-out: what it costs on the wire
/// against how well it reconstructs held-out activations.
#[derive(Clone, Debug)]
pub struct CodecCandidate {
    pub kind: CodecKind,
    pub val_mse: f64,
    pub payload_bytes: u64,
}

/// Outcome report for one module (feeds Figure 2 and the ablation benches).
#[derive(Clone, Debug)]
pub struct ModuleReport {
    pub id: ModuleId,
    pub chosen: Axis,
    /// (axis, train-MSE, val-MSE) for every candidate.
    pub candidates: Vec<(Axis, f64, f64)>,
    /// Val MSE of the base model alone (no delta) — the "do nothing" floor.
    pub base_mse: f64,
    /// Codec the module was actually encoded under.
    pub codec: CodecKind,
    /// Every codec that competed for this module (one entry when a codec
    /// was forced, all of them under `CodecChoice::Auto`).
    pub codec_candidates: Vec<CodecCandidate>,
}

/// Fit one candidate axis on the train shard; return (scales, val_mse).
fn fit_axis(
    axis: Axis,
    delta: &[f32],
    d_out: usize,
    d_in: usize,
    mask: &PackedMask,
    w_base: &Tensor2,
    train: &ModuleCache,
    val: &ModuleCache,
    opts: &CompressOptions,
) -> (Vec<f32>, f64, f64) {
    let r_tr = residual(&train.x, &train.y, w_base);
    let r_va = residual(&val.x, &val.y, w_base);
    let init = init_scales(delta, d_out, d_in, axis);
    match axis {
        Axis::Col => {
            let st_tr = col_stats(&train.x, &r_tr, mask);
            let v = match opts.fit {
                FitMode::AdamW => adamw_col(&st_tr, init, &opts.calib),
                FitMode::ClosedForm => closed_form_col(&st_tr, opts.calib.ridge),
                FitMode::InitOnly => init,
            };
            let train_mse = mse_col(&st_tr, &v);
            let st_va = col_stats(&val.x, &r_va, mask);
            let val_mse = mse_col(&st_va, &v);
            (v, train_mse, val_mse)
        }
        _ => {
            let st_tr = row_stats(&train.x, &r_tr, mask);
            let v = match opts.fit {
                FitMode::AdamW => adamw_rowfam(&st_tr, axis, init, &opts.calib),
                FitMode::ClosedForm => closed_form_rowfam(&st_tr, axis),
                FitMode::InitOnly => init,
            };
            let train_mse = mse_rowfam(&st_tr, axis, &v);
            let st_va = row_stats(&val.x, &r_va, mask);
            let val_mse = mse_rowfam(&st_va, axis, &v);
            (v, train_mse, val_mse)
        }
    }
}

/// Core per-axis encoder: pack the sign mask, fit every axis in `axes`,
/// pick the best by held-out validation MSE (Alg. 6 selection rule as
/// stated in §2: "the axis is selected by validation MSE on the held-out
/// shard"). The per-axis and scalar codecs both funnel through here with
/// different axis slates; `tag` stamps the resulting module and report.
pub(crate) fn encode_with_axes(
    id: ModuleId,
    w_base: &[f32],
    w_ft: &[f32],
    cache: &ModuleCache,
    opts: &CompressOptions,
    axes: &[Axis],
    tag: CodecKind,
) -> (DeltaModule, ModuleReport) {
    let d_in = cache.x.cols;
    let d_out = cache.y.cols;
    assert_eq!(w_base.len(), d_out * d_in);
    assert_eq!(w_ft.len(), d_out * d_in);
    let delta: Vec<f32> = w_ft.iter().zip(w_base).map(|(f, b)| f - b).collect();
    let mask = PackedMask::pack(&delta, d_out, d_in);
    let wb_t = Tensor2::from_vec(d_out, d_in, w_base.to_vec());
    let (train, val) = cache.split(opts.calib.val_fraction);

    // "Do nothing" floor: val MSE of the base weights alone.
    let base_mse = {
        let r = residual(&val.x, &val.y, &wb_t);
        r.frob_sq() / (val.x.rows * d_out).max(1) as f64
    };

    let mut best: Option<(Axis, Vec<f32>, f64)> = None;
    let mut candidates = Vec::new();
    for &axis in axes {
        let (v, tr_mse, va_mse) =
            fit_axis(axis, &delta, d_out, d_in, &mask, &wb_t, &train, &val, opts);
        candidates.push((axis, tr_mse, va_mse));
        if best.as_ref().map_or(true, |(_, _, m)| va_mse < *m) {
            best = Some((axis, v, va_mse));
        }
    }
    let (axis, scales, best_val) = best.expect("at least one candidate axis");
    let codec = match tag {
        CodecKind::Scalar => Codec::Scalar,
        _ => Codec::PerAxis,
    };
    let m = DeltaModule { id, mask, axis, scales, codec };
    let cand = CodecCandidate { kind: tag, val_mse: best_val, payload_bytes: m.payload_bytes() };
    let rep = ModuleReport {
        id,
        chosen: axis,
        candidates,
        base_mse,
        codec: tag,
        codec_candidates: vec![cand],
    };
    (m, rep)
}

/// Compress one module under the codec selected by
/// [`CompressOptions::codec`], dispatching through the codec registry.
pub fn compress_module(
    id: ModuleId,
    w_base: &[f32],
    w_ft: &[f32],
    cache: &ModuleCache,
    opts: &CompressOptions,
) -> (DeltaModule, ModuleReport) {
    match opts.codec {
        CodecChoice::PerAxis => codec_for(CodecKind::PerAxis).encode(id, w_base, w_ft, cache, opts),
        CodecChoice::Scalar => codec_for(CodecKind::Scalar).encode(id, w_base, w_ft, cache, opts),
        CodecChoice::LowRank => codec_for(CodecKind::LowRank).encode(id, w_base, w_ft, cache, opts),
        CodecChoice::Auto => super::codec::encode_auto(id, w_base, w_ft, cache, opts),
    }
}

/// Whole-model compression (Algorithm 1 stages 1–2): sweep layers in order;
/// for each layer build the calibration cache against the *current* student
/// (so layer i sees the inputs produced by the already-compressed stack up
/// to i−1), compress all seven projections, install them into the student,
/// and continue.
pub fn compress_model(
    variant: &str,
    base: &FlatParams,
    finetuned: &FlatParams,
    calib_docs: &[Vec<u8>],
    opts: &CompressOptions,
) -> (DeltaModel, Vec<ModuleReport>, FlatParams) {
    let cfg = base.cfg().clone();
    assert_eq!(cfg, finetuned.cfg().clone(), "base/finetuned config mismatch");
    let tf = Transformer::new(&cfg);
    let mut student = base.clone();
    let mut modules = Vec::with_capacity(cfg.n_patchable());
    let mut reports = Vec::with_capacity(cfg.n_patchable());
    for layer in 0..cfg.n_layers {
        let caches =
            build_layer_caches(finetuned, &student, &tf, layer, calib_docs, opts.max_cache_rows);
        for kind in crate::model::ProjKind::ALL {
            let id = ModuleId { layer, kind };
            let (m, rep) =
                compress_module(id, base.module(id), finetuned.module(id), &caches[&kind], opts);
            // Install the reconstructed module into the student immediately
            // (paper: "the original layer is replaced with the better
            // variant"), so later layers calibrate against the stacked
            // student.
            let mut out = vec![0f32; base.module(id).len()];
            super::apply::apply_module_into(base.module(id), &mut out, &m);
            student.module_mut(id).copy_from_slice(&out);
            modules.push(m);
            reports.push(rep);
        }
    }
    (DeltaModel::new(variant, cfg.name.clone(), modules), reports, student)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};
    use crate::model::ProjKind;

    fn setup() -> (FlatParams, FlatParams, Vec<Vec<u8>>) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 10);
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { magnitude: 0.02, anisotropy: 1.2, axis_bias: 0.8, seed: 20 },
        );
        let docs: Vec<Vec<u8>> =
            (0..6).map(|i| (0..40).map(|t| ((t * 7 + i * 13) % 250 + 1) as u8).collect()).collect();
        (base, ft, docs)
    }

    #[test]
    fn module_compression_beats_base_floor() {
        let (base, ft, docs) = setup();
        let cfg = base.cfg().clone();
        let tf = Transformer::new(&cfg);
        let caches = build_layer_caches(&ft, &base, &tf, 0, &docs, 2048);
        let id = ModuleId { layer: 0, kind: ProjKind::Q };
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let (_m, rep) = compress_module(id, base.module(id), ft.module(id), &caches[&ProjKind::Q], &opts);
        let best_val = rep
            .candidates
            .iter()
            .map(|&(_, _, v)| v)
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_val < rep.base_mse,
            "calibrated delta ({best_val}) should beat the no-delta floor ({})",
            rep.base_mse
        );
    }

    #[test]
    fn row_biased_delta_selects_row_axis_mostly() {
        let (base, ft, docs) = setup();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let (model, reports, _student) = compress_model("ft-test", &base, &ft, &docs, &opts);
        assert_eq!(model.modules.len(), base.cfg().n_patchable());
        // axis_bias=0.8 makes rows carry the anisotropy for most kinds.
        let row_count = reports.iter().filter(|r| r.chosen == Axis::Row).count();
        assert!(
            row_count * 2 > reports.len(),
            "expected mostly Row selections, got {row_count}/{}",
            reports.len()
        );
    }

    #[test]
    fn student_tracks_finetuned_better_than_base() {
        let (base, ft, docs) = setup();
        let cfg = base.cfg().clone();
        let tf = Transformer::new(&cfg);
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let (_model, _reports, student) = compress_model("ft-test", &base, &ft, &docs, &opts);
        // Compare end-to-end logits on a held-out prompt.
        let probe: Vec<u8> = (1..35).map(|t| (t * 11 % 250 + 1) as u8).collect();
        let l_teacher = tf.forward_one(&ft, &probe);
        let l_base = tf.forward_one(&base, &probe);
        let l_student = tf.forward_one(&student, &probe);
        let e_base = l_teacher.mse(&l_base);
        let e_student = l_teacher.mse(&l_student);
        assert!(
            e_student < e_base * 0.75,
            "student logit error {e_student} should be well under base {e_base}"
        );
        // And per-layer stacking should at least halve the error of most
        // modules; the end-to-end vector training stage (pipeline) tightens
        // this further.
    }

    #[test]
    fn bitdelta_options_use_scalar_axis() {
        let (base, ft, docs) = setup();
        let opts = CompressOptions { fit: FitMode::ClosedForm, ..CompressOptions::bitdelta() };
        let (model, reports, _) = compress_model("ft-scalar", &base, &ft, &docs, &opts);
        assert!(model.modules.iter().all(|m| m.axis == Axis::Scalar));
        assert!(reports.iter().all(|r| r.candidates.len() == 1));
        assert!(model.modules.iter().all(|m| m.scales.len() == 1));
    }

    #[test]
    fn vector_val_mse_beats_scalar_on_anisotropic_model() {
        // Table-1 mechanism test at module level: per-axis < scalar val MSE
        // for most modules when deltas are anisotropic.
        let (base, ft, docs) = setup();
        let opts_v = CompressOptions { fit: FitMode::ClosedForm, ..Default::default() };
        let opts_s = CompressOptions { fit: FitMode::ClosedForm, ..CompressOptions::bitdelta() };
        let (_, rep_v, _) = compress_model("v", &base, &ft, &docs, &opts_v);
        let (_, rep_s, _) = compress_model("s", &base, &ft, &docs, &opts_s);
        let mut wins = 0;
        for (rv, rs) in rep_v.iter().zip(&rep_s) {
            let v_best = rv.candidates.iter().map(|c| c.2).fold(f64::INFINITY, f64::min);
            let s_best = rs.candidates.iter().map(|c| c.2).fold(f64::INFINITY, f64::min);
            if v_best < s_best {
                wins += 1;
            }
        }
        assert!(
            wins * 10 >= rep_v.len() * 9,
            "vector should beat scalar on ~all modules, won {wins}/{}",
            rep_v.len()
        );
    }
}
