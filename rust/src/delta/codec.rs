//! Pluggable delta codecs: the trait every compression scheme implements
//! and the static registry the rest of the stack dispatches through.
//!
//! A codec owns the full life of a module delta: encoding it from a weight
//! residual plus calibration cache, accounting its packed/resident bytes,
//! deciding content equality for chain compose/diff, and validating the
//! shapes the fused kernels rely on. Three codecs ship:
//!
//! * [`PerAxisCodec`] — the paper's scheme (1-bit mask + per-axis FP16
//!   scales, axis slate from [`CompressOptions::axes`]).
//! * [`ScalarCodec`] — BitDelta-style single scalar scale per module.
//! * [`LowRankCodec`] — per-axis plus a low-rank residual correction
//!   `Δ̂ = v ⊙ B + Bᵣ·A`, executed fused as `y += (x·Aᵀ)·Bᵣᵀ` and never
//!   densified at serve time.
//!
//! [`encode_auto`] runs every codec on a module and keeps the winner by
//! held-out validation MSE, falling back to per-axis on ties — the
//! calibration-error-driven selector behind `--codec auto`.

use super::cache::ModuleCache;
use super::calibrate::residual;
use super::compress::{encode_with_axes, CodecCandidate, CompressOptions, ModuleReport};
use super::types::{Axis, Codec, CodecKind, DeltaModule, LowRank};
use crate::model::ModuleId;
use crate::tensor::{dot, Tensor2};
use crate::util::rng::Rng;
use anyhow::Result;

/// One pluggable compression scheme for module deltas.
///
/// Byte accounting and content equality have defaults that delegate to the
/// [`DeltaModule`] payload (which already dispatches on its codec tag);
/// codecs override `encode` and `validate`.
pub trait DeltaCodec: Sync {
    /// Wire tag this codec encodes to.
    fn kind(&self) -> CodecKind;

    /// Human label (matches the CLI `--codec` values).
    fn label(&self) -> &'static str {
        self.kind().label()
    }

    /// Encode one module from base/fine-tuned weights and its calibration
    /// cache. The returned report carries this codec's shoot-out entry in
    /// `codec_candidates`.
    fn encode(
        &self,
        id: ModuleId,
        w_base: &[f32],
        w_ft: &[f32],
        cache: &ModuleCache,
        opts: &CompressOptions,
    ) -> (DeltaModule, ModuleReport);

    /// Packed on-the-wire bytes of an encoded module.
    fn payload_bytes(&self, m: &DeltaModule) -> u64 {
        debug_assert_eq!(m.codec.kind(), self.kind());
        m.payload_bytes()
    }

    /// In-memory bytes the cache charges for a resident module.
    fn resident_bytes(&self, m: &DeltaModule) -> u64 {
        debug_assert_eq!(m.codec.kind(), self.kind());
        m.resident_bytes()
    }

    /// Payload equality as the chain compose/diff identity sees it.
    fn content_eq(&self, a: &DeltaModule, b: &DeltaModule) -> bool {
        debug_assert_eq!(a.codec.kind(), self.kind());
        a.content_eq(b)
    }

    /// Check the codec-specific shape invariants the fused kernels rely on
    /// for a module targeting a `d_out x d_in` projection.
    fn validate(&self, m: &DeltaModule, d_out: usize, d_in: usize) -> Result<()>;
}

/// The paper's per-axis scheme: 1-bit mask + FP16 scales along the best of
/// the configured candidate axes.
pub struct PerAxisCodec;

impl DeltaCodec for PerAxisCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::PerAxis
    }

    fn encode(
        &self,
        id: ModuleId,
        w_base: &[f32],
        w_ft: &[f32],
        cache: &ModuleCache,
        opts: &CompressOptions,
    ) -> (DeltaModule, ModuleReport) {
        encode_with_axes(id, w_base, w_ft, cache, opts, &opts.axes, CodecKind::PerAxis)
    }

    fn validate(&self, _m: &DeltaModule, _d_out: usize, _d_in: usize) -> Result<()> {
        // Axis/scale-length invariants are codec-independent and checked by
        // the caller; per-axis has no extra payload to constrain.
        Ok(())
    }
}

/// BitDelta-style scalar codec: one FP16 scale for the whole module.
pub struct ScalarCodec;

impl DeltaCodec for ScalarCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::Scalar
    }

    fn encode(
        &self,
        id: ModuleId,
        w_base: &[f32],
        w_ft: &[f32],
        cache: &ModuleCache,
        opts: &CompressOptions,
    ) -> (DeltaModule, ModuleReport) {
        encode_with_axes(id, w_base, w_ft, cache, opts, &[Axis::Scalar], CodecKind::Scalar)
    }

    fn validate(&self, m: &DeltaModule, _d_out: usize, _d_in: usize) -> Result<()> {
        anyhow::ensure!(
            m.axis == Axis::Scalar,
            "delta {} is scalar-codec but axis {:?}",
            m.id,
            m.axis
        );
        Ok(())
    }
}

/// Per-axis plus a rank-`r` residual correction fitted on the weight
/// residual the 1-bit reconstruction leaves behind.
pub struct LowRankCodec;

impl DeltaCodec for LowRankCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::LowRank
    }

    fn encode(
        &self,
        id: ModuleId,
        w_base: &[f32],
        w_ft: &[f32],
        cache: &ModuleCache,
        opts: &CompressOptions,
    ) -> (DeltaModule, ModuleReport) {
        let (mut m, mut rep) = encode_with_axes(
            id,
            w_base,
            w_ft,
            cache,
            opts,
            &opts.axes,
            CodecKind::PerAxis,
        );
        let d_in = cache.x.cols;
        let d_out = cache.y.cols;
        let rank = opts.lowrank_rank.clamp(1, d_out.min(d_in));

        // Weight residual the 1-bit reconstruction leaves: R = Δ − v ⊙ B.
        // Densifying here is encode-time only; serving never materializes.
        let mut r_w = vec![0f32; d_out * d_in];
        for j in 0..d_out {
            for i in 0..d_in {
                let k = j * d_in + i;
                r_w[k] = (w_ft[k] - w_base[k]) - m.scale_at(j, i) * m.mask.sign(j, i);
            }
        }
        let (a, b) = fit_low_rank(&r_w, d_out, d_in, rank, id.layer as u64);

        // Validation MSE of the combined delta, computed densely — the same
        // activation-space quantity the stats-based per-axis/scalar MSEs
        // measure, so the shoot-out compares like with like.
        let (_, val) = cache.split(opts.calib.val_fraction);
        let wb_t = Tensor2::from_vec(d_out, d_in, w_base.to_vec());
        let r_va = residual(&val.x, &val.y, &wb_t);
        let mut d_full = vec![0f32; d_out * d_in];
        for j in 0..d_out {
            for i in 0..d_in {
                let mut acc = m.scale_at(j, i) * m.mask.sign(j, i);
                for k in 0..rank {
                    acc += b[j * rank + k] * a[k * d_in + i];
                }
                d_full[j * d_in + i] = acc;
            }
        }
        let pred = val.x.matmul_bt(&Tensor2::from_vec(d_out, d_in, d_full));
        let val_mse = r_va.sub(&pred).frob_sq() / (val.x.rows * d_out).max(1) as f64;

        m.codec = Codec::LowRank(LowRank { rank, a, b });
        rep.codec = CodecKind::LowRank;
        rep.codec_candidates = vec![CodecCandidate {
            kind: CodecKind::LowRank,
            val_mse,
            payload_bytes: m.payload_bytes(),
        }];
        (m, rep)
    }

    fn validate(&self, m: &DeltaModule, d_out: usize, d_in: usize) -> Result<()> {
        let lr = m.lowrank().ok_or_else(|| {
            anyhow::anyhow!("delta {} tagged lowrank but carries no factors", m.id)
        })?;
        anyhow::ensure!(
            lr.rank >= 1
                && lr.rank <= d_out.min(d_in)
                && lr.a.len() == lr.rank * d_in
                && lr.b.len() == d_out * lr.rank,
            "delta {} low-rank factors malformed: rank {} a {} b {} for {}x{}",
            m.id,
            lr.rank,
            lr.a.len(),
            lr.b.len(),
            d_out,
            d_in
        );
        Ok(())
    }
}

static PER_AXIS: PerAxisCodec = PerAxisCodec;
static SCALAR: ScalarCodec = ScalarCodec;
static LOW_RANK: LowRankCodec = LowRankCodec;

/// The codec registry: every [`CodecKind`] maps to a static codec instance.
pub fn codec_for(kind: CodecKind) -> &'static dyn DeltaCodec {
    match kind {
        CodecKind::PerAxis => &PER_AXIS,
        CodecKind::Scalar => &SCALAR,
        CodecKind::LowRank => &LOW_RANK,
    }
}

/// Per-module codec shoot-out: encode under every registered codec and keep
/// the winner by held-out validation MSE. Per-axis is the incumbent — a
/// challenger must be *strictly* better to displace it, so auto-selection
/// never ships a module with higher calibration error than per-axis.
pub fn encode_auto(
    id: ModuleId,
    w_base: &[f32],
    w_ft: &[f32],
    cache: &ModuleCache,
    opts: &CompressOptions,
) -> (DeltaModule, ModuleReport) {
    let mut encoded: Vec<(DeltaModule, ModuleReport)> = CodecKind::ALL
        .iter()
        .map(|&k| codec_for(k).encode(id, w_base, w_ft, cache, opts))
        .collect();
    let all_cands: Vec<CodecCandidate> =
        encoded.iter().flat_map(|(_, r)| r.codec_candidates.clone()).collect();
    // CodecKind::ALL starts with PerAxis, so index 0 is the incumbent and
    // strict `<` keeps it on ties.
    let mut best = 0;
    for (i, c) in all_cands.iter().enumerate().skip(1) {
        if c.val_mse < all_cands[best].val_mse {
            best = i;
        }
    }
    let (m, mut rep) = encoded.swap_remove(best);
    rep.codec_candidates = all_cands;
    (m, rep)
}

/// Best-effort rank-`r` factorization of `r_w` (`d_out x d_in`) by
/// orthogonal (subspace) iteration: returns `(a, b)` with `a` `[rank,
/// d_in]`, `b` `[d_out, rank]` row-major so `b · a ≈ r_w`. Deterministic:
/// the starting subspace is seeded from the layer index only.
fn fit_low_rank(
    r_w: &[f32],
    d_out: usize,
    d_in: usize,
    rank: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xC0DEC ^ seed);
    let mut a = vec![0f32; rank * d_in];
    rng.fill_normal(&mut a, 1.0);
    orthonormalize_rows(&mut a, rank, d_in);
    let mut y = vec![0f32; d_out * rank]; // R·Aᵀ
    for _ in 0..4 {
        for j in 0..d_out {
            let rrow = &r_w[j * d_in..(j + 1) * d_in];
            for k in 0..rank {
                y[j * rank + k] = dot(rrow, &a[k * d_in..(k + 1) * d_in]);
            }
        }
        // A ← orth(Yᵀ·R) — the updated row space.
        a.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..d_out {
            let rrow = &r_w[j * d_in..(j + 1) * d_in];
            for k in 0..rank {
                let w = y[j * rank + k];
                for (av, &rv) in a[k * d_in..(k + 1) * d_in].iter_mut().zip(rrow) {
                    *av += w * rv;
                }
            }
        }
        orthonormalize_rows(&mut a, rank, d_in);
    }
    // With A's rows orthonormal, the least-squares B is simply R·Aᵀ.
    for j in 0..d_out {
        let rrow = &r_w[j * d_in..(j + 1) * d_in];
        for k in 0..rank {
            y[j * rank + k] = dot(rrow, &a[k * d_in..(k + 1) * d_in]);
        }
    }
    (a, y)
}

/// Modified Gram–Schmidt over the `rank` rows of `a` (each `d_in` long).
/// Degenerate rows are replaced with deterministic unit basis vectors so
/// the subspace always has full rank.
fn orthonormalize_rows(a: &mut [f32], rank: usize, d_in: usize) {
    for k in 0..rank {
        for p in 0..k {
            let proj = {
                let (head, tail) = a.split_at(k * d_in);
                dot(&head[p * d_in..(p + 1) * d_in], &tail[..d_in])
            };
            let prev: Vec<f32> = a[p * d_in..(p + 1) * d_in].to_vec();
            for (v, pv) in a[k * d_in..(k + 1) * d_in].iter_mut().zip(&prev) {
                *v -= proj * pv;
            }
        }
        let row = &mut a[k * d_in..(k + 1) * d_in];
        let norm = dot(row, row).sqrt();
        if norm > 1e-6 {
            let inv = 1.0 / norm;
            row.iter_mut().for_each(|v| *v *= inv);
        } else {
            row.iter_mut().for_each(|v| *v = 0.0);
            row[k % d_in] = 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::cache::build_layer_caches;
    use crate::delta::compress::{CodecChoice, FitMode};
    use crate::model::config::ModelConfig;
    use crate::model::synth::{synth_finetune, SynthDeltaSpec};
    use crate::model::{FlatParams, ProjKind, Transformer};

    fn setup() -> (FlatParams, FlatParams, Vec<Vec<u8>>) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 10);
        let ft = synth_finetune(
            &base,
            &SynthDeltaSpec { magnitude: 0.02, anisotropy: 1.2, axis_bias: 0.8, seed: 20 },
        );
        let docs: Vec<Vec<u8>> =
            (0..6).map(|i| (0..40).map(|t| ((t * 7 + i * 13) % 250 + 1) as u8).collect()).collect();
        (base, ft, docs)
    }

    fn module_under(codec: CodecChoice) -> (DeltaModule, ModuleReport) {
        let (base, ft, docs) = setup();
        let cfg = base.cfg().clone();
        let tf = Transformer::new(&cfg);
        let caches = build_layer_caches(&ft, &base, &tf, 0, &docs, 2048);
        let id = ModuleId { layer: 0, kind: ProjKind::Q };
        let opts = CompressOptions { fit: FitMode::ClosedForm, codec, ..Default::default() };
        super::super::compress::compress_module(
            id,
            base.module(id),
            ft.module(id),
            &caches[&ProjKind::Q],
            &opts,
        )
    }

    #[test]
    fn registry_covers_every_kind() {
        for k in CodecKind::ALL {
            assert_eq!(codec_for(k).kind(), k);
            assert_eq!(codec_for(k).label(), k.label());
        }
    }

    #[test]
    fn each_codec_encodes_with_its_own_tag() {
        for (choice, kind) in [
            (CodecChoice::PerAxis, CodecKind::PerAxis),
            (CodecChoice::Scalar, CodecKind::Scalar),
            (CodecChoice::LowRank, CodecKind::LowRank),
        ] {
            let (m, rep) = module_under(choice);
            assert_eq!(m.codec.kind(), kind);
            assert_eq!(rep.codec, kind);
            assert_eq!(rep.codec_candidates.len(), 1);
            assert_eq!(rep.codec_candidates[0].kind, kind);
            codec_for(kind).validate(&m, m.d_out(), m.d_in()).unwrap();
        }
    }

    #[test]
    fn scalar_codec_uses_one_scale() {
        let (m, _) = module_under(CodecChoice::Scalar);
        assert_eq!(m.axis, Axis::Scalar);
        assert_eq!(m.scales.len(), 1);
    }

    #[test]
    fn lowrank_strictly_improves_on_its_per_axis_base() {
        let (m, rep) = module_under(CodecChoice::LowRank);
        let lr = m.lowrank().expect("lowrank factors");
        assert_eq!(lr.rank, 4.min(m.d_out()).min(m.d_in()));
        // The rank-r term is a least-squares fit (in weight space) of the
        // residual the per-axis reconstruction leaves; on this synthetic
        // model it should track or beat per-axis on the activation metric.
        let (_, pa_rep) = module_under(CodecChoice::PerAxis);
        let pa_val = pa_rep.codec_candidates[0].val_mse;
        let lr_val = rep.codec_candidates[0].val_mse;
        assert!(
            lr_val.is_finite() && lr_val <= pa_val * 1.05,
            "lowrank val {lr_val} should not materially exceed per-axis {pa_val}"
        );
        assert!(rep.codec_candidates[0].payload_bytes > pa_rep.codec_candidates[0].payload_bytes);
    }

    #[test]
    fn auto_never_beats_itself_with_worse_calibration_error() {
        let (m, rep) = module_under(CodecChoice::Auto);
        assert_eq!(rep.codec_candidates.len(), CodecKind::ALL.len());
        let pa = rep
            .codec_candidates
            .iter()
            .find(|c| c.kind == CodecKind::PerAxis)
            .expect("per-axis candidate present");
        let chosen = rep
            .codec_candidates
            .iter()
            .find(|c| c.kind == m.codec.kind())
            .expect("chosen candidate present");
        assert!(chosen.val_mse <= pa.val_mse, "auto must never lose to per-axis");
        assert_eq!(rep.codec, m.codec.kind());
    }

    #[test]
    fn subspace_iteration_recovers_exact_low_rank_matrix() {
        // R built as rank-2 exactly: the fit must reconstruct it ~exactly.
        let (d_out, d_in, rank) = (12, 9, 2);
        let mut rng = Rng::new(99);
        let mut u = vec![0f32; d_out * rank];
        let mut v = vec![0f32; rank * d_in];
        rng.fill_normal(&mut u, 1.0);
        rng.fill_normal(&mut v, 1.0);
        let mut r_w = vec![0f32; d_out * d_in];
        for j in 0..d_out {
            for i in 0..d_in {
                for k in 0..rank {
                    r_w[j * d_in + i] += u[j * rank + k] * v[k * d_in + i];
                }
            }
        }
        let (a, b) = fit_low_rank(&r_w, d_out, d_in, rank, 0);
        let mut err = 0f64;
        let mut nrm = 0f64;
        for j in 0..d_out {
            for i in 0..d_in {
                let mut acc = 0f32;
                for k in 0..rank {
                    acc += b[j * rank + k] * a[k * d_in + i];
                }
                let d = (acc - r_w[j * d_in + i]) as f64;
                err += d * d;
                nrm += (r_w[j * d_in + i] as f64).powi(2);
            }
        }
        assert!(err < nrm * 1e-6, "relative error {} too large", err / nrm);
    }
}
