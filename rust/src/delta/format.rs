//! PAWD on-disk delta artifact format and the streamlined loader.
//!
//! The paper's systems contribution: "a streamlined loader that transfers
//! packed deltas in a single operation per module reduces cold-start
//! latency". Here each module is one **contiguous record** (header, FP16
//! scale vector, packed mask, crc32), the file is read with a single
//! `fs::read`, and application is one fused pass per module — masks stay
//! packed end-to-end; the dense `Ŵ` only ever exists in the destination
//! buffer.
//!
//! Format **v2** layout (little-endian):
//! ```text
//! magic "PAWDELTA" | format u32 (=2) | variant str | base_config str |
//! version u32 | parent u32 (0 = none) | created_unix u64 |
//! n_modules u32 |
//!   per module: name str | d_out u32 | d_in u32 | axis u8 | group u32 |
//!               n_scales u32 | scales (n_scales × f16) |
//!               mask (d_out · ceil(d_in/32) × u32) | crc32 u32
//! file_crc u32
//! ```
//! Strings are `u32 length + bytes`. Each record's crc covers its header and
//! payload, so corruption is localized to a module; `file_crc` covers every
//! byte before it, so header tampering (e.g. a rewritten version field) is
//! also detected.
//!
//! The `version / parent / created_unix` triple is the variant-lifecycle
//! metadata consumed by the coordinator's
//! [`VariantRegistry`](crate::coordinator::VariantRegistry): `version` is the
//! artifact's position in its variant's history (`variant@version`), `parent`
//! the version it superseded (the rollback target).
//!
//! **v1** artifacts (no meta triple, no file crc) are still read: the loader
//! dispatches on the format word and fills the default [`ArtifactMeta`].

use super::pack::PackedMask;
use super::types::{ArtifactMeta, Axis, DeltaModel, DeltaModule};
use crate::model::ModuleId;
use crate::util::crc32;
use crate::util::f16::{decode_f16_slice, encode_f16_slice};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 8] = b"PAWDELTA";
/// Current writer format. Readers accept `1..=VERSION`.
const VERSION: u32 = 2;

/// Serialize a delta model (always format v2). Returns the file size in
/// bytes. The model's [`ArtifactMeta`] is written verbatim — the registry
/// stamps it before publishing; standalone saves keep the default.
pub fn save_delta<P: AsRef<Path>>(path: P, model: &DeltaModel) -> Result<u64> {
    let mut buf: Vec<u8> = Vec::with_capacity(model.payload_bytes() as usize + 4096);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    put_str(&mut buf, &model.variant);
    put_str(&mut buf, &model.base_config);
    buf.extend_from_slice(&model.meta.version.to_le_bytes());
    buf.extend_from_slice(&model.meta.parent.unwrap_or(0).to_le_bytes());
    buf.extend_from_slice(&model.meta.created_unix.to_le_bytes());
    buf.extend_from_slice(&(model.modules.len() as u32).to_le_bytes());
    for m in &model.modules {
        write_module_record(&mut buf, m);
    }
    let file_crc = crc32::hash(&buf);
    buf.extend_from_slice(&file_crc.to_le_bytes());
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(&buf)?;
    f.flush()?;
    Ok(buf.len() as u64)
}

/// Load a delta model: one sequential read, then zero-copy record parsing.
pub fn load_delta<P: AsRef<Path>>(path: P) -> Result<DeltaModel> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading delta artifact {}", path.as_ref().display()))?;
    parse_delta(&bytes)
}

/// Parse a delta model from an in-memory buffer (separated from `load_delta`
/// so benches can isolate disk vs decode time). Accepts formats v1 and v2.
pub fn parse_delta(bytes: &[u8]) -> Result<DeltaModel> {
    let mut r = Reader { b: bytes, i: 0 };
    let (variant, base_config, meta, format) = parse_header(&mut r)?;
    let n_modules = r.u32()? as usize;
    let mut modules = Vec::with_capacity(n_modules);
    for _ in 0..n_modules {
        let rec_start = r.i;
        let name = r.str()?;
        let id = ModuleId::parse(&name)
            .ok_or_else(|| anyhow::anyhow!("bad module name '{name}'"))?;
        let d_out = r.u32()? as usize;
        let d_in = r.u32()? as usize;
        let axis_code = r.u8()?;
        let group = r.u32()?;
        let axis = Axis::from_code(axis_code, group)?;
        let n_scales = r.u32()? as usize;
        if n_scales != axis.n_scales(d_out, d_in) {
            bail!("scale count {n_scales} inconsistent with axis {axis:?} and shape {d_out}x{d_in}");
        }
        let scales = decode_f16_slice(r.take(n_scales * 2)?);
        let mask_bytes = d_out * PackedMask::words_per_row_for(d_in) * 4;
        let mask = PackedMask::from_bytes(d_out, d_in, r.take(mask_bytes)?)?;
        let rec_end = r.i;
        if r.u32()? != crc32::hash(&bytes[rec_start..rec_end]) {
            bail!("crc mismatch in module record '{name}' (corrupt artifact)");
        }
        modules.push(DeltaModule { id, mask, axis, scales });
    }
    if format >= 2 {
        let body_end = r.i;
        if r.u32()? != crc32::hash(&bytes[..body_end]) {
            bail!("whole-artifact crc mismatch (corrupt or tampered header)");
        }
    }
    if r.i != bytes.len() {
        bail!("trailing bytes after last module record");
    }
    Ok(DeltaModel { variant, base_config, meta, modules })
}

/// Read just the artifact header of the file at `path` — magic, format,
/// names, lifecycle meta — without decoding module records. The registry
/// uses this to adopt untracked files under their *embedded* version (the
/// filename is not trusted; a mis-named copy must not brick the alias).
/// Only a bounded prefix is read from disk, so adopting a directory of
/// multi-MB artifacts stays cheap.
pub fn peek_meta<P: AsRef<Path>>(path: P) -> Result<ArtifactMeta> {
    use std::io::Read;
    // magic + format + two length-prefixed names + meta triple; 64 KiB is
    // orders of magnitude beyond any real header.
    const MAX_HEADER_BYTES: u64 = 64 * 1024;
    let f = std::fs::File::open(&path)
        .with_context(|| format!("reading delta artifact {}", path.as_ref().display()))?;
    let mut bytes = Vec::with_capacity(4096);
    f.take(MAX_HEADER_BYTES).read_to_end(&mut bytes)?;
    let mut r = Reader { b: &bytes, i: 0 };
    let (_, _, meta, _) = parse_header(&mut r)?;
    Ok(meta)
}

/// Shared header parse: magic, format word, variant/base names, meta triple
/// (defaulted for v1). Leaves the reader positioned at `n_modules`.
fn parse_header(r: &mut Reader<'_>) -> Result<(String, String, ArtifactMeta, u32)> {
    let magic = r.take(8)?;
    if magic != MAGIC {
        bail!("bad magic: not a PAWDELTA artifact");
    }
    let format = r.u32()?;
    if format == 0 || format > VERSION {
        bail!("unsupported delta format {format} (this build reads 1..={VERSION})");
    }
    let variant = r.str()?;
    let base_config = r.str()?;
    let meta = if format >= 2 {
        let version = r.u32()?;
        if version == 0 {
            bail!("artifact version 0 is invalid (versions start at 1)");
        }
        let parent_raw = r.u32()?;
        let created_unix = r.u64()?;
        ArtifactMeta {
            version,
            parent: if parent_raw == 0 { None } else { Some(parent_raw) },
            created_unix,
        }
    } else {
        ArtifactMeta::default()
    };
    Ok((variant, base_config, meta, format))
}

/// Serialize `model` in the **v1** layout (no meta triple, no file crc)
/// exactly as the PR-1 writer emitted it. Only used to produce back-compat
/// fixtures for tests — the production writer always emits v2.
pub fn save_delta_v1_bytes(model: &DeltaModel) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&1u32.to_le_bytes());
    put_str(&mut buf, &model.variant);
    put_str(&mut buf, &model.base_config);
    buf.extend_from_slice(&(model.modules.len() as u32).to_le_bytes());
    for m in &model.modules {
        write_module_record(&mut buf, m);
    }
    buf
}

/// One contiguous module record (header, f16 scales, packed mask, record
/// crc) — byte-identical in formats v1 and v2.
fn write_module_record(buf: &mut Vec<u8>, m: &DeltaModule) {
    let rec_start = buf.len();
    put_str(buf, &m.id.to_string());
    buf.extend_from_slice(&(m.d_out() as u32).to_le_bytes());
    buf.extend_from_slice(&(m.d_in() as u32).to_le_bytes());
    buf.push(m.axis.code());
    let group = if let Axis::Group(g) = m.axis { g } else { 0 };
    buf.extend_from_slice(&group.to_le_bytes());
    buf.extend_from_slice(&(m.scales.len() as u32).to_le_bytes());
    buf.extend_from_slice(&encode_f16_slice(&m.scales));
    buf.extend_from_slice(&m.mask.to_bytes());
    let crc = crc32::hash(&buf[rec_start..]);
    buf.extend_from_slice(&crc.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated artifact at offset {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > 1 << 20 {
            bail!("unreasonable string length {len}");
        }
        Ok(String::from_utf8(self.take(len)?.to_vec())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProjKind;
    use crate::util::rng::Rng;

    fn sample_model() -> DeltaModel {
        let mut rng = Rng::new(42);
        let mut modules = Vec::new();
        for (layer, kind, axis, d_out, d_in) in [
            (0usize, ProjKind::Q, Axis::Row, 64usize, 64usize),
            (0, ProjKind::Up, Axis::Col, 160, 64),
            (1, ProjKind::Down, Axis::Scalar, 64, 160),
            (1, ProjKind::K, Axis::Group(4), 64, 64),
        ] {
            let delta: Vec<f32> =
                (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, d_out, d_in);
            let n = axis.n_scales(d_out, d_in);
            let scales: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.01, 0.5)).collect();
            modules.push(DeltaModule { id: ModuleId { layer, kind }, mask, axis, scales });
        }
        DeltaModel {
            variant: "ft-a".into(),
            base_config: "tiny".into(),
            meta: ArtifactMeta { version: 3, parent: Some(2), created_unix: 1_753_000_000 },
            modules,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pawd_test_fmt");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_everything_but_f16_scales() {
        let model = sample_model();
        let p = tmp("roundtrip.pawd");
        let size = save_delta(&p, &model).unwrap();
        assert!(size > model.payload_bytes());
        let loaded = load_delta(&p).unwrap();
        assert_eq!(loaded.variant, model.variant);
        assert_eq!(loaded.base_config, model.base_config);
        assert_eq!(loaded.meta, model.meta);
        assert_eq!(loaded.modules.len(), model.modules.len());
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.axis, b.axis);
            assert_eq!(a.mask, b.mask);
            for (x, y) in a.scales.iter().zip(&b.scales) {
                assert!((x - y).abs() <= 5e-4 * y.abs().max(1e-3), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn v1_artifacts_load_with_default_meta() {
        // Golden v1 bytes: written by the historical layout, read by the v2
        // loader. Module payloads must survive; meta defaults to version 1.
        let model = sample_model();
        let v1 = save_delta_v1_bytes(&model);
        let loaded = parse_delta(&v1).unwrap();
        assert_eq!(loaded.variant, model.variant);
        assert_eq!(loaded.base_config, model.base_config);
        assert_eq!(loaded.meta, ArtifactMeta::default());
        assert_eq!(loaded.modules.len(), model.modules.len());
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!((a.id, a.axis, &a.mask), (b.id, b.axis, &b.mask));
        }
    }

    #[test]
    fn v1_fixed_golden_prefix_is_stable() {
        // The bytes of a module-less v1 artifact are fully determined by the
        // header fields; pin them so an accidental layout change to the
        // legacy writer (and thus the compat reader) cannot slip through.
        let model = DeltaModel {
            variant: "v".into(),
            base_config: "c".into(),
            meta: ArtifactMeta::default(),
            modules: vec![],
        };
        let bytes = save_delta_v1_bytes(&model);
        let golden: &[u8] = &[
            b'P', b'A', b'W', b'D', b'E', b'L', b'T', b'A', // magic
            1, 0, 0, 0, // format = 1
            1, 0, 0, 0, b'v', // variant
            1, 0, 0, 0, b'c', // base_config
            0, 0, 0, 0, // n_modules = 0
        ];
        assert_eq!(bytes, golden);
        assert!(parse_delta(&bytes).is_ok());
    }

    #[test]
    fn meta_parent_zero_roundtrips_as_none() {
        let mut model = sample_model();
        model.meta = ArtifactMeta { version: 1, parent: None, created_unix: 7 };
        let p = tmp("meta_none.pawd");
        save_delta(&p, &model).unwrap();
        assert_eq!(load_delta(&p).unwrap().meta, model.meta);
    }

    #[test]
    fn corruption_is_detected_per_record() {
        let model = sample_model();
        let p = tmp("corrupt.pawd");
        save_delta(&p, &model).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip one bit inside the mask region of some record.
        let mid = bytes.len() * 3 / 4;
        bytes[mid] ^= 0x10;
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("crc") || err.contains("truncated"), "{err}");
    }

    #[test]
    fn header_tampering_is_detected_by_file_crc() {
        let model = sample_model();
        let p = tmp("tamper.pawd");
        save_delta(&p, &model).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // The version field sits right after magic+format+strings; rewrite it
        // (record crcs don't cover the header, the file crc must catch it).
        let version_off = 8 + 4 + (4 + model.variant.len()) + (4 + model.base_config.len());
        bytes[version_off] ^= 0x04;
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("whole-artifact crc"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let model = sample_model();
        let p = tmp("trunc.pawd");
        save_delta(&p, &model).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let cut = &bytes[..bytes.len() - 7];
        assert!(parse_delta(cut).is_err());
    }

    #[test]
    fn artifact_much_smaller_than_fp16_dense() {
        // Storage ratio sanity: 1 bit + per-row f16 vs 16-bit dense.
        let model = sample_model();
        let p = tmp("size.pawd");
        let size = save_delta(&p, &model).unwrap();
        let dense_fp16: u64 = model
            .modules
            .iter()
            .map(|m| (m.d_out() * m.d_in() * 2) as u64)
            .sum();
        assert!(
            size * 10 < dense_fp16,
            "delta artifact {size} should be >10x smaller than dense fp16 {dense_fp16}"
        );
    }

    #[test]
    fn garbage_rejected() {
        assert!(parse_delta(b"garbage").is_err());
        assert!(parse_delta(b"").is_err());
    }

    #[test]
    fn future_format_rejected() {
        let model = sample_model();
        let p = tmp("future.pawd");
        save_delta(&p, &model).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8] = 99; // format word
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported delta format"), "{err}");
    }
}
