//! PAWD on-disk delta artifact format and the streamlined loader.
//!
//! The paper's systems contribution: "a streamlined loader that transfers
//! packed deltas in a single operation per module reduces cold-start
//! latency". Here each module is one **contiguous record** (header, FP16
//! scale vector, packed mask, crc32), the file is read with a single
//! `fs::read`, and application is one fused pass per module — masks stay
//! packed end-to-end; the dense `Ŵ` only ever exists in the destination
//! buffer.
//!
//! Format **v4** layout (little-endian):
//! ```text
//! magic "PAWDELTA" | format u32 (=4) | variant str | base_config str |
//! version u32 | parent u32 (0 = none) | created_unix u64 |
//! kind u8 (0 = full, 1 = patch) |
//! n_modules u32 |
//!   section table, per module: name str | offset u64 | len u64 | codec u8 |
//!   per module: name str | d_out u32 | d_in u32 | axis u8 | group u32 |
//!               n_scales u32 | scales (n_scales × f16) |
//!               mask (d_out · ceil(d_in/32) × u32) |
//!               [codec = lowrank only: rank u32 | A (rank·d_in × f16) |
//!                B (d_out·rank × f16)] | crc32 u32
//! file_crc u32
//! ```
//! Strings are `u32 length + bytes`. Each record's crc covers its header and
//! payload, so corruption is localized to a module; `file_crc` covers every
//! byte before it, so header tampering (e.g. a rewritten version field) is
//! also detected.
//!
//! The **section table** maps each module name to its record's absolute
//! `offset`/`len`, so a chain-aware loader can read *only* the records it
//! needs ([`read_index`] + [`load_modules`]) instead of the whole file.
//! Partial loads verify per-record crcs; the whole-file crc is only checked
//! on full sequential reads.
//!
//! **Codecs.** v4 stamps each section-table entry with the module's
//! [`CodecKind`] byte. Per-axis and scalar records are byte-identical to
//! their v3 serialization (an all-per-axis v4 artifact carries the exact v3
//! record bytes); low-rank records append the residual factors before the
//! record crc. **v3** artifacts (no codec byte) decode every module as
//! [`Codec::PerAxis`], as do v1/v2.
//!
//! **Patch artifacts** (`kind = 1`) carry only the modules whose packed
//! content changed relative to the `parent` version; every other module is
//! inherited by composing the parent chain
//! ([`chain`](super::chain)). A patch without a parent is malformed.
//!
//! The `version / parent / created_unix` triple is the variant-lifecycle
//! metadata consumed by the coordinator's
//! [`VariantRegistry`](crate::coordinator::VariantRegistry): `version` is the
//! artifact's position in its variant's history (`variant@version`), `parent`
//! the version it superseded (the rollback target, and for patches the
//! composition base).
//!
//! **v1** artifacts (no meta triple, no file crc), **v2** artifacts (meta
//! triple + file crc, no kind byte, no section table) and **v3** artifacts
//! (section table without codec bytes) are still read: the loader
//! dispatches on the format word; v1 fills the default [`ArtifactMeta`],
//! v2 reads as a full artifact.
//!
//! Every read path reports bytes/records touched to
//! [`exec::counters`](crate::exec::counters) so benches can assert that
//! warming a patch version does not re-read unchanged modules.

use super::pack::PackedMask;
use super::types::{ArtifactMeta, Axis, Codec, CodecKind, DeltaModel, DeltaModule, LowRank};
use crate::exec::counters;
use crate::model::ModuleId;
use crate::util::crc32;
use crate::util::f16::{decode_f16_slice, encode_f16_slice};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"PAWDELTA";
/// Current writer format. Readers accept `1..=VERSION`.
const VERSION: u32 = 4;

/// Serialize a delta model (always format v4). Returns the file size in
/// bytes. The model's [`ArtifactMeta`] is written verbatim — the registry
/// stamps it before publishing; standalone saves keep the default. A patch
/// model (`meta.is_patch`) must carry a parent version.
pub fn save_delta<P: AsRef<Path>>(path: P, model: &DeltaModel) -> Result<u64> {
    let buf = save_delta_bytes(model)?;
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(&buf)?;
    f.flush()?;
    Ok(buf.len() as u64)
}

/// Serialize a delta model to the v4 byte layout (the in-memory half of
/// [`save_delta`], split out so patch size can be measured without a file).
pub fn save_delta_bytes(model: &DeltaModel) -> Result<Vec<u8>> {
    if model.meta.is_patch && model.meta.parent.is_none() {
        bail!("patch artifact '{}' has no parent version", model.variant);
    }
    // Serialize every record first so the section table can carry real
    // offsets/lengths in one pass.
    let mut records: Vec<Vec<u8>> = Vec::with_capacity(model.modules.len());
    for m in &model.modules {
        let mut rec = Vec::with_capacity(m.payload_bytes() as usize + 64);
        write_module_record(&mut rec, m);
        records.push(rec);
    }
    let mut buf: Vec<u8> = Vec::with_capacity(
        records.iter().map(|r| r.len()).sum::<usize>() + 4096,
    );
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    put_str(&mut buf, &model.variant);
    put_str(&mut buf, &model.base_config);
    buf.extend_from_slice(&model.meta.version.to_le_bytes());
    buf.extend_from_slice(&model.meta.parent.unwrap_or(0).to_le_bytes());
    buf.extend_from_slice(&model.meta.created_unix.to_le_bytes());
    buf.push(model.meta.is_patch as u8);
    buf.extend_from_slice(&(model.modules.len() as u32).to_le_bytes());
    // The table's own size depends only on the (known) name lengths.
    let table_bytes: usize = model
        .modules
        .iter()
        .map(|m| 4 + m.id.to_string().len() + 8 + 8 + 1)
        .sum();
    let mut offset = buf.len() + table_bytes;
    for (m, rec) in model.modules.iter().zip(&records) {
        put_str(&mut buf, &m.id.to_string());
        buf.extend_from_slice(&(offset as u64).to_le_bytes());
        buf.extend_from_slice(&(rec.len() as u64).to_le_bytes());
        buf.push(m.codec.kind().code());
        offset += rec.len();
    }
    for rec in &records {
        buf.extend_from_slice(rec);
    }
    let file_crc = crc32::hash(&buf);
    buf.extend_from_slice(&file_crc.to_le_bytes());
    Ok(buf)
}

/// Load a delta model: one sequential read, then zero-copy record parsing.
pub fn load_delta<P: AsRef<Path>>(path: P) -> Result<DeltaModel> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading delta artifact {}", path.as_ref().display()))?;
    counters::record_loader_bytes(bytes.len() as u64);
    let model = parse_delta(&bytes)?;
    counters::record_module_reads(model.modules.len() as u64);
    Ok(model)
}

/// Parse a delta model from an in-memory buffer (separated from `load_delta`
/// so benches can isolate disk vs decode time). Accepts formats v1..v3.
pub fn parse_delta(bytes: &[u8]) -> Result<DeltaModel> {
    let mut r = Reader { b: bytes, i: 0 };
    let (variant, base_config, meta, format) = parse_header(&mut r)?;
    let n_modules = r.u32()? as usize;
    // v3+: skip over the section table (records are parsed sequentially on a
    // full read; the table is for selective loads), but keep the offsets to
    // sanity-check table/record agreement — and, for v4, the codec byte each
    // record must be decoded under.
    let sections =
        if format >= 3 { Some(parse_section_table(&mut r, n_modules, format)?) } else { None };
    let mut modules = Vec::with_capacity(n_modules);
    for k in 0..n_modules {
        let rec_start = r.i;
        if let Some(secs) = &sections {
            if secs[k].offset != rec_start as u64 {
                bail!(
                    "section table offset {} disagrees with record position {rec_start} \
                     for module '{}'",
                    secs[k].offset,
                    secs[k].name
                );
            }
        }
        let codec = sections.as_ref().map_or(CodecKind::PerAxis, |secs| secs[k].codec);
        let (module, consumed) = parse_module_record(&bytes[rec_start..], codec)?;
        if let Some(secs) = &sections {
            if secs[k].len != consumed as u64 {
                bail!("section table length mismatch for module '{}'", secs[k].name);
            }
        }
        r.i += consumed;
        modules.push(Arc::new(module));
    }
    if format >= 2 {
        let body_end = r.i;
        if r.u32()? != crc32::hash(&bytes[..body_end]) {
            bail!("whole-artifact crc mismatch (corrupt or tampered header)");
        }
    }
    if r.i != bytes.len() {
        bail!("trailing bytes after last module record");
    }
    Ok(DeltaModel { variant, base_config, meta, modules })
}

/// One entry of a v3/v4 artifact's section table: the absolute byte range
/// of a module record plus (v4) the codec it is encoded under. v3 tables
/// carry no codec byte; their entries decode as [`CodecKind::PerAxis`].
#[derive(Clone, Debug)]
pub struct SectionEntry {
    pub name: String,
    pub offset: u64,
    pub len: u64,
    pub codec: CodecKind,
}

/// Parsed artifact header + section table (no module payloads decoded).
/// For v1/v2 artifacts `sections` is empty — they predate the table and can
/// only be read in full.
#[derive(Clone, Debug)]
pub struct ArtifactIndex {
    pub variant: String,
    pub base_config: String,
    pub meta: ArtifactMeta,
    pub format: u32,
    pub sections: Vec<SectionEntry>,
}

impl ArtifactIndex {
    /// Whether the artifact supports selective section reads.
    pub fn has_sections(&self) -> bool {
        !self.sections.is_empty() || self.format >= 3
    }
}

/// Read just the artifact header and (for v3) the section table of the file
/// at `path` — a bounded prefix read, so indexing a directory of multi-MB
/// artifacts stays cheap. The chain loader uses this to decide which
/// records each link must contribute before reading any payload bytes.
pub fn read_index<P: AsRef<Path>>(path: P) -> Result<ArtifactIndex> {
    use std::io::Read;
    // Header + table: ~30 bytes per module; 1 MiB covers tens of thousands
    // of modules, orders of magnitude beyond any real model.
    const MAX_INDEX_BYTES: u64 = 1 << 20;
    let f = std::fs::File::open(&path)
        .with_context(|| format!("reading delta artifact {}", path.as_ref().display()))?;
    let mut bytes = Vec::with_capacity(8192);
    f.take(MAX_INDEX_BYTES).read_to_end(&mut bytes)?;
    let mut r = Reader { b: &bytes, i: 0 };
    let (variant, base_config, meta, format) = parse_header(&mut r)
        .with_context(|| format!("indexing {}", path.as_ref().display()))?;
    let sections = if format >= 3 {
        let n_modules = r.u32()? as usize;
        parse_section_table(&mut r, n_modules, format)
            .with_context(|| format!("section table of {}", path.as_ref().display()))?
    } else {
        Vec::new()
    };
    counters::record_loader_bytes(r.i as u64);
    Ok(ArtifactIndex { variant, base_config, meta, format, sections })
}

/// Selectively load the module records at `wanted` (indices into
/// `index.sections`) from a v3 artifact: one bounded read per record,
/// per-record crc verified. The indices are visited in ascending file
/// offset so the reads stay sequential on disk; the returned modules are in
/// `wanted` order.
pub fn load_modules<P: AsRef<Path>>(
    path: P,
    index: &ArtifactIndex,
    wanted: &[usize],
) -> Result<Vec<Arc<DeltaModule>>> {
    use std::io::{Read, Seek, SeekFrom};
    if wanted.is_empty() {
        return Ok(Vec::new());
    }
    anyhow::ensure!(
        index.format >= 3,
        "artifact {} (format v{}) has no section table; use load_delta",
        path.as_ref().display(),
        index.format
    );
    let mut by_offset: Vec<usize> = wanted.to_vec();
    by_offset.sort_by_key(|&k| index.sections[k].offset);
    let mut f = std::fs::File::open(&path)
        .with_context(|| format!("reading delta artifact {}", path.as_ref().display()))?;
    // Bound every section against the real file size before allocating —
    // a corrupt table must fail cleanly, not balloon memory.
    let file_len = f.metadata().map(|m| m.len()).unwrap_or(0);
    let mut out: Vec<(usize, Arc<DeltaModule>)> = Vec::with_capacity(wanted.len());
    let mut buf = Vec::new();
    for &k in &by_offset {
        let sec = &index.sections[k];
        let fits = matches!(sec.offset.checked_add(sec.len), Some(end) if end <= file_len);
        if !fits {
            bail!("section '{}' extends past the end of the artifact", sec.name);
        }
        buf.clear();
        buf.resize(sec.len as usize, 0);
        f.seek(SeekFrom::Start(sec.offset))?;
        f.read_exact(&mut buf)
            .with_context(|| format!("reading section '{}'", sec.name))?;
        let (module, consumed) = parse_module_record(&buf, sec.codec)
            .with_context(|| format!("decoding section '{}'", sec.name))?;
        if consumed != buf.len() {
            bail!("section '{}' has trailing bytes", sec.name);
        }
        if module.id.to_string() != sec.name {
            bail!("section '{}' holds record for '{}'", sec.name, module.id);
        }
        counters::record_loader_bytes(sec.len);
        out.push((k, Arc::new(module)));
    }
    counters::record_module_reads(wanted.len() as u64);
    // Restore the caller's order.
    let mut result = vec![None; wanted.len()];
    for (k, m) in out {
        let pos = wanted.iter().position(|&w| w == k).expect("wanted index");
        result[pos] = Some(m);
    }
    Ok(result.into_iter().map(|m| m.expect("all sections loaded")).collect())
}

/// Read just the artifact header of the file at `path` — magic, format,
/// names, lifecycle meta — without decoding module records. The registry
/// uses this to adopt untracked files under their *embedded* version (the
/// filename is not trusted; a mis-named copy must not brick the alias).
/// Only a bounded prefix is read from disk, so adopting a directory of
/// multi-MB artifacts stays cheap.
pub fn peek_meta<P: AsRef<Path>>(path: P) -> Result<ArtifactMeta> {
    use std::io::Read;
    // magic + format + two length-prefixed names + meta triple + kind; 64
    // KiB is orders of magnitude beyond any real header.
    const MAX_HEADER_BYTES: u64 = 64 * 1024;
    let f = std::fs::File::open(&path)
        .with_context(|| format!("reading delta artifact {}", path.as_ref().display()))?;
    let mut bytes = Vec::with_capacity(4096);
    f.take(MAX_HEADER_BYTES).read_to_end(&mut bytes)?;
    let mut r = Reader { b: &bytes, i: 0 };
    let (_, _, meta, _) = parse_header(&mut r)?;
    Ok(meta)
}

/// Shared header parse: magic, format word, variant/base names, meta triple
/// (defaulted for v1), patch kind byte (v3+). Leaves the reader positioned
/// at `n_modules`.
fn parse_header(r: &mut Reader<'_>) -> Result<(String, String, ArtifactMeta, u32)> {
    let magic = r.take(8)?;
    if magic != MAGIC {
        bail!("bad magic: not a PAWDELTA artifact");
    }
    let format = r.u32()?;
    if format == 0 || format > VERSION {
        bail!("unsupported delta format {format} (this build reads 1..={VERSION})");
    }
    let variant = r.str()?;
    let base_config = r.str()?;
    let meta = if format >= 2 {
        let version = r.u32()?;
        if version == 0 {
            bail!("artifact version 0 is invalid (versions start at 1)");
        }
        let parent_raw = r.u32()?;
        let created_unix = r.u64()?;
        let is_patch = if format >= 3 {
            match r.u8()? {
                0 => false,
                1 => true,
                other => bail!("unknown artifact kind byte {other}"),
            }
        } else {
            false
        };
        if is_patch && parent_raw == 0 {
            bail!("patch artifact has no parent version");
        }
        ArtifactMeta {
            version,
            parent: if parent_raw == 0 { None } else { Some(parent_raw) },
            created_unix,
            is_patch,
        }
    } else {
        ArtifactMeta::default()
    };
    Ok((variant, base_config, meta, format))
}

fn parse_section_table(
    r: &mut Reader<'_>,
    n_modules: usize,
    format: u32,
) -> Result<Vec<SectionEntry>> {
    let mut sections = Vec::with_capacity(n_modules);
    for _ in 0..n_modules {
        let name = r.str()?;
        let offset = r.u64()?;
        let len = r.u64()?;
        let codec = if format >= 4 { CodecKind::from_code(r.u8()?)? } else { CodecKind::PerAxis };
        sections.push(SectionEntry { name, offset, len, codec });
    }
    Ok(sections)
}

/// Parse one contiguous module record (header, f16 scales, packed mask,
/// optional low-rank factors, trailing crc) from the start of `bytes`;
/// returns the module and the total bytes consumed including the crc. The
/// `codec` comes from the section table (v4) or defaults to per-axis
/// (v1–v3). Shared by the sequential parser and the selective section
/// reader.
fn parse_module_record(bytes: &[u8], codec: CodecKind) -> Result<(DeltaModule, usize)> {
    let mut r = Reader { b: bytes, i: 0 };
    let name = r.str()?;
    let id = ModuleId::parse(&name)
        .ok_or_else(|| anyhow::anyhow!("bad module name '{name}'"))?;
    let d_out = r.u32()? as usize;
    let d_in = r.u32()? as usize;
    let axis_code = r.u8()?;
    let group = r.u32()?;
    let axis = Axis::from_code(axis_code, group)?;
    let n_scales = r.u32()? as usize;
    if n_scales != axis.n_scales(d_out, d_in) {
        bail!("scale count {n_scales} inconsistent with axis {axis:?} and shape {d_out}x{d_in}");
    }
    let scales = decode_f16_slice(r.take(n_scales * 2)?);
    let mask_bytes = d_out * PackedMask::words_per_row_for(d_in) * 4;
    let mask = PackedMask::from_bytes(d_out, d_in, r.take(mask_bytes)?)?;
    let codec = match codec {
        CodecKind::PerAxis => Codec::PerAxis,
        CodecKind::Scalar => {
            if axis != Axis::Scalar {
                bail!("scalar-codec record '{name}' carries non-scalar axis {axis:?}");
            }
            Codec::Scalar
        }
        CodecKind::LowRank => {
            let rank = r.u32()? as usize;
            // The rank bound keeps a corrupt record from requesting an
            // allocation beyond the (already buffer-bounded) matrix shape.
            if rank == 0 || rank > d_out.min(d_in) {
                bail!("low-rank record '{name}' has invalid rank {rank} for {d_out}x{d_in}");
            }
            let a = decode_f16_slice(r.take(rank * d_in * 2)?);
            let b = decode_f16_slice(r.take(d_out * rank * 2)?);
            Codec::LowRank(LowRank { rank, a, b })
        }
    };
    let rec_end = r.i;
    if r.u32()? != crc32::hash(&bytes[..rec_end]) {
        bail!("crc mismatch in module record '{name}' (corrupt artifact)");
    }
    Ok((DeltaModule { id, mask, axis, scales, codec }, r.i))
}

/// Serialize `model` in the **v1** layout (no meta triple, no file crc)
/// exactly as the PR-1 writer emitted it. Only used to produce back-compat
/// fixtures for tests — the production writer always emits v3.
pub fn save_delta_v1_bytes(model: &DeltaModel) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&1u32.to_le_bytes());
    put_str(&mut buf, &model.variant);
    put_str(&mut buf, &model.base_config);
    buf.extend_from_slice(&(model.modules.len() as u32).to_le_bytes());
    for m in &model.modules {
        write_module_record(&mut buf, m);
    }
    buf
}

/// Serialize `model` in the **v2** layout (meta triple + whole-file crc, no
/// kind byte, no section table) exactly as the PR-2 writer emitted it.
/// Back-compat fixtures only.
pub fn save_delta_v2_bytes(model: &DeltaModel) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&2u32.to_le_bytes());
    put_str(&mut buf, &model.variant);
    put_str(&mut buf, &model.base_config);
    buf.extend_from_slice(&model.meta.version.to_le_bytes());
    buf.extend_from_slice(&model.meta.parent.unwrap_or(0).to_le_bytes());
    buf.extend_from_slice(&model.meta.created_unix.to_le_bytes());
    buf.extend_from_slice(&(model.modules.len() as u32).to_le_bytes());
    for m in &model.modules {
        write_module_record(&mut buf, m);
    }
    let file_crc = crc32::hash(&buf);
    buf.extend_from_slice(&file_crc.to_le_bytes());
    buf
}

/// Serialize `model` in the **v3** layout (section table without codec
/// bytes) exactly as the PR-4 writer emitted it. Back-compat fixtures only;
/// v3 cannot represent non-per-axis modules.
pub fn save_delta_v3_bytes(model: &DeltaModel) -> Result<Vec<u8>> {
    if model.meta.is_patch && model.meta.parent.is_none() {
        bail!("patch artifact '{}' has no parent version", model.variant);
    }
    for m in &model.modules {
        if m.codec.kind() != CodecKind::PerAxis {
            bail!("format v3 cannot carry a {} module", m.codec.kind().label());
        }
    }
    let mut records: Vec<Vec<u8>> = Vec::with_capacity(model.modules.len());
    for m in &model.modules {
        let mut rec = Vec::with_capacity(m.payload_bytes() as usize + 64);
        write_module_record(&mut rec, m);
        records.push(rec);
    }
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&3u32.to_le_bytes());
    put_str(&mut buf, &model.variant);
    put_str(&mut buf, &model.base_config);
    buf.extend_from_slice(&model.meta.version.to_le_bytes());
    buf.extend_from_slice(&model.meta.parent.unwrap_or(0).to_le_bytes());
    buf.extend_from_slice(&model.meta.created_unix.to_le_bytes());
    buf.push(model.meta.is_patch as u8);
    buf.extend_from_slice(&(model.modules.len() as u32).to_le_bytes());
    let table_bytes: usize =
        model.modules.iter().map(|m| 4 + m.id.to_string().len() + 8 + 8).sum();
    let mut offset = buf.len() + table_bytes;
    for (m, rec) in model.modules.iter().zip(&records) {
        put_str(&mut buf, &m.id.to_string());
        buf.extend_from_slice(&(offset as u64).to_le_bytes());
        buf.extend_from_slice(&(rec.len() as u64).to_le_bytes());
        offset += rec.len();
    }
    for rec in &records {
        buf.extend_from_slice(rec);
    }
    let file_crc = crc32::hash(&buf);
    buf.extend_from_slice(&file_crc.to_le_bytes());
    Ok(buf)
}

/// One contiguous module record (header, f16 scales, packed mask, optional
/// low-rank factors, record crc). Per-axis and scalar records are
/// byte-identical in formats v1 through v4; only the low-rank codec (v4)
/// appends its factor trailer before the crc.
fn write_module_record(buf: &mut Vec<u8>, m: &DeltaModule) {
    let rec_start = buf.len();
    put_str(buf, &m.id.to_string());
    buf.extend_from_slice(&(m.d_out() as u32).to_le_bytes());
    buf.extend_from_slice(&(m.d_in() as u32).to_le_bytes());
    buf.push(m.axis.code());
    let group = if let Axis::Group(g) = m.axis { g } else { 0 };
    buf.extend_from_slice(&group.to_le_bytes());
    buf.extend_from_slice(&(m.scales.len() as u32).to_le_bytes());
    buf.extend_from_slice(&encode_f16_slice(&m.scales));
    buf.extend_from_slice(&m.mask.to_bytes());
    if let Some(lr) = m.lowrank() {
        buf.extend_from_slice(&(lr.rank as u32).to_le_bytes());
        buf.extend_from_slice(&encode_f16_slice(&lr.a));
        buf.extend_from_slice(&encode_f16_slice(&lr.b));
    }
    let crc = crc32::hash(&buf[rec_start..]);
    buf.extend_from_slice(&crc.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated artifact at offset {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > 1 << 20 {
            bail!("unreasonable string length {len}");
        }
        Ok(String::from_utf8(self.take(len)?.to_vec())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ProjKind;
    use crate::util::rng::Rng;

    fn sample_model() -> DeltaModel {
        let mut rng = Rng::new(42);
        let mut modules = Vec::new();
        for (layer, kind, axis, d_out, d_in) in [
            (0usize, ProjKind::Q, Axis::Row, 64usize, 64usize),
            (0, ProjKind::Up, Axis::Col, 160, 64),
            (1, ProjKind::Down, Axis::Scalar, 64, 160),
            (1, ProjKind::K, Axis::Group(4), 64, 64),
        ] {
            let delta: Vec<f32> =
                (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, d_out, d_in);
            let n = axis.n_scales(d_out, d_in);
            let scales: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.01, 0.5)).collect();
            modules.push(DeltaModule {
                id: ModuleId { layer, kind },
                mask,
                axis,
                scales,
                codec: Codec::PerAxis,
            });
        }
        let mut model = DeltaModel::new("ft-a", "tiny", modules);
        model.meta = ArtifactMeta {
            version: 3,
            parent: Some(2),
            created_unix: 1_753_000_000,
            is_patch: false,
        };
        model
    }

    /// A deterministic model mixing all three codecs: per-axis, scalar
    /// (BitDelta) and low-rank residual.
    fn sample_model_mixed() -> DeltaModel {
        let mut rng = Rng::new(7);
        let mut modules = Vec::new();
        for (layer, kind, axis, codec_kind, d_out, d_in) in [
            (0usize, ProjKind::Q, Axis::Row, CodecKind::PerAxis, 32usize, 48usize),
            (0, ProjKind::K, Axis::Scalar, CodecKind::Scalar, 32, 48),
            (1, ProjKind::Up, Axis::Row, CodecKind::LowRank, 40, 32),
        ] {
            let delta: Vec<f32> =
                (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mask = PackedMask::pack(&delta, d_out, d_in);
            let n = axis.n_scales(d_out, d_in);
            let scales: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.01, 0.5)).collect();
            let codec = match codec_kind {
                CodecKind::PerAxis => Codec::PerAxis,
                CodecKind::Scalar => Codec::Scalar,
                CodecKind::LowRank => {
                    let rank = 3;
                    Codec::LowRank(LowRank {
                        rank,
                        a: (0..rank * d_in).map(|_| rng.normal_f32(0.0, 0.1)).collect(),
                        b: (0..d_out * rank).map(|_| rng.normal_f32(0.0, 0.1)).collect(),
                    })
                }
            };
            modules.push(DeltaModule { id: ModuleId { layer, kind }, mask, axis, scales, codec });
        }
        DeltaModel::new("ft-mixed", "tiny", modules)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pawd_test_fmt");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_everything_but_f16_scales() {
        let model = sample_model();
        let p = tmp("roundtrip.pawd");
        let size = save_delta(&p, &model).unwrap();
        assert!(size > model.payload_bytes());
        let loaded = load_delta(&p).unwrap();
        assert_eq!(loaded.variant, model.variant);
        assert_eq!(loaded.base_config, model.base_config);
        assert_eq!(loaded.meta, model.meta);
        assert_eq!(loaded.modules.len(), model.modules.len());
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.axis, b.axis);
            assert_eq!(a.mask, b.mask);
            for (x, y) in a.scales.iter().zip(&b.scales) {
                assert!((x - y).abs() <= 5e-4 * y.abs().max(1e-3), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn patch_artifacts_roundtrip_with_parent() {
        let mut model = sample_model();
        model.meta = ArtifactMeta {
            version: 4,
            parent: Some(3),
            created_unix: 9,
            is_patch: true,
        };
        model.modules.truncate(1); // a patch carries only changed modules
        let p = tmp("patch.pawd");
        save_delta(&p, &model).unwrap();
        let loaded = load_delta(&p).unwrap();
        assert!(loaded.meta.is_patch);
        assert_eq!(loaded.meta.parent, Some(3));
        assert_eq!(loaded.modules.len(), 1);
        // peek sees the patch flag without decoding payloads.
        assert!(peek_meta(&p).unwrap().is_patch);
    }

    #[test]
    fn patch_without_parent_rejected_by_writer_and_reader() {
        let mut model = sample_model();
        model.meta = ArtifactMeta { version: 2, parent: None, created_unix: 0, is_patch: true };
        assert!(save_delta_bytes(&model).is_err(), "writer must refuse an orphan patch");
        // Hand-craft the same corruption: write as full, flip the kind byte.
        model.meta.is_patch = false;
        let mut bytes = save_delta_bytes(&model).unwrap();
        let kind_off = 8 + 4 + (4 + model.variant.len()) + (4 + model.base_config.len()) + 16;
        assert_eq!(bytes[kind_off], 0);
        bytes[kind_off] = 1;
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("no parent"), "{err}");
    }

    #[test]
    fn section_table_supports_selective_reads() {
        let model = sample_model();
        let p = tmp("sections.pawd");
        save_delta(&p, &model).unwrap();
        let index = read_index(&p).unwrap();
        assert_eq!(index.meta, model.meta);
        assert_eq!(index.sections.len(), model.modules.len());
        for (sec, m) in index.sections.iter().zip(&model.modules) {
            assert_eq!(sec.name, m.id.to_string());
        }
        // Read two records (out of file order) and compare against the full
        // load. (Counters are global and other tests run concurrently, so
        // only a lower bound is safe here; the strict "reads exactly the
        // wanted sections" equality is asserted by the single-process
        // incremental_publish bench.)
        let before = crate::exec::counters::loader_bytes();
        let got = load_modules(&p, &index, &[2, 0]).unwrap();
        let read = crate::exec::counters::loader_bytes() - before;
        let expected = index.sections[2].len + index.sections[0].len;
        assert!(read >= expected, "selective read recorded {read} < section bytes {expected}");
        let full = load_delta(&p).unwrap();
        assert_eq!(got[0].id, full.modules[2].id);
        assert_eq!(got[0].mask, full.modules[2].mask);
        assert_eq!(got[1].id, full.modules[0].id);
        assert_eq!(
            encode_f16_slice(&got[1].scales),
            encode_f16_slice(&full.modules[0].scales)
        );
    }

    #[test]
    fn v1_artifacts_load_with_default_meta() {
        // Golden v1 bytes: written by the historical layout, read by the v3
        // loader. Module payloads must survive; meta defaults to version 1.
        let model = sample_model();
        let v1 = save_delta_v1_bytes(&model);
        let loaded = parse_delta(&v1).unwrap();
        assert_eq!(loaded.variant, model.variant);
        assert_eq!(loaded.base_config, model.base_config);
        assert_eq!(loaded.meta, ArtifactMeta::default());
        assert_eq!(loaded.modules.len(), model.modules.len());
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!((a.id, a.axis, &a.mask), (b.id, b.axis, &b.mask));
        }
    }

    #[test]
    fn v2_artifacts_load_with_meta_and_no_patch_flag() {
        // Golden v2 bytes: the PR-2 layout (meta triple + file crc, no kind
        // byte, no section table) must keep loading through the v3 reader.
        let model = sample_model();
        let v2 = save_delta_v2_bytes(&model);
        let loaded = parse_delta(&v2).unwrap();
        assert_eq!(loaded.variant, model.variant);
        assert_eq!(loaded.meta.version, model.meta.version);
        assert_eq!(loaded.meta.parent, model.meta.parent);
        assert_eq!(loaded.meta.created_unix, model.meta.created_unix);
        assert!(!loaded.meta.is_patch, "v2 artifacts are always full");
        assert_eq!(loaded.modules.len(), model.modules.len());
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!((a.id, a.axis, &a.mask), (b.id, b.axis, &b.mask));
        }
    }

    #[test]
    fn v1_fixed_golden_prefix_is_stable() {
        // The bytes of a module-less v1 artifact are fully determined by the
        // header fields; pin them so an accidental layout change to the
        // legacy writer (and thus the compat reader) cannot slip through.
        let model = DeltaModel::new("v", "c", vec![]);
        let bytes = save_delta_v1_bytes(&model);
        let golden: &[u8] = &[
            b'P', b'A', b'W', b'D', b'E', b'L', b'T', b'A', // magic
            1, 0, 0, 0, // format = 1
            1, 0, 0, 0, b'v', // variant
            1, 0, 0, 0, b'c', // base_config
            0, 0, 0, 0, // n_modules = 0
        ];
        assert_eq!(bytes, golden);
        assert!(parse_delta(&bytes).is_ok());
    }

    #[test]
    fn v2_fixed_golden_prefix_is_stable() {
        // Same pin for the v2 legacy writer: header fields + file crc.
        let model = DeltaModel::new("v", "c", vec![]);
        let bytes = save_delta_v2_bytes(&model);
        let mut golden: Vec<u8> = vec![
            b'P', b'A', b'W', b'D', b'E', b'L', b'T', b'A', // magic
            2, 0, 0, 0, // format = 2
            1, 0, 0, 0, b'v', // variant
            1, 0, 0, 0, b'c', // base_config
            1, 0, 0, 0, // version = 1
            0, 0, 0, 0, // parent = none
            0, 0, 0, 0, 0, 0, 0, 0, // created_unix = 0
            0, 0, 0, 0, // n_modules = 0
        ];
        let crc = crc32::hash(&golden);
        golden.extend_from_slice(&crc.to_le_bytes());
        assert_eq!(bytes, golden);
        assert!(parse_delta(&bytes).is_ok());
    }

    #[test]
    fn meta_parent_zero_roundtrips_as_none() {
        let mut model = sample_model();
        model.meta = ArtifactMeta { version: 1, parent: None, created_unix: 7, is_patch: false };
        let p = tmp("meta_none.pawd");
        save_delta(&p, &model).unwrap();
        assert_eq!(load_delta(&p).unwrap().meta, model.meta);
    }

    #[test]
    fn corruption_is_detected_per_record() {
        let model = sample_model();
        let p = tmp("corrupt.pawd");
        save_delta(&p, &model).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip one bit inside the mask region of some record.
        let mid = bytes.len() * 3 / 4;
        bytes[mid] ^= 0x10;
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("crc") || err.contains("truncated"), "{err}");
    }

    #[test]
    fn header_tampering_is_detected_by_file_crc() {
        let model = sample_model();
        let p = tmp("tamper.pawd");
        save_delta(&p, &model).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // The version field sits right after magic+format+strings; rewrite it
        // (record crcs don't cover the header, the file crc must catch it).
        let version_off = 8 + 4 + (4 + model.variant.len()) + (4 + model.base_config.len());
        bytes[version_off] ^= 0x04;
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("whole-artifact crc"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let model = sample_model();
        let p = tmp("trunc.pawd");
        save_delta(&p, &model).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let cut = &bytes[..bytes.len() - 7];
        assert!(parse_delta(cut).is_err());
    }

    #[test]
    fn artifact_much_smaller_than_fp16_dense() {
        // Storage ratio sanity: 1 bit + per-row f16 vs 16-bit dense.
        let model = sample_model();
        let p = tmp("size.pawd");
        let size = save_delta(&p, &model).unwrap();
        let dense_fp16: u64 = model
            .modules
            .iter()
            .map(|m| (m.d_out() * m.d_in() * 2) as u64)
            .sum();
        assert!(
            size * 10 < dense_fp16,
            "delta artifact {size} should be >10x smaller than dense fp16 {dense_fp16}"
        );
    }

    #[test]
    fn garbage_rejected() {
        assert!(parse_delta(b"garbage").is_err());
        assert!(parse_delta(b"").is_err());
    }

    #[test]
    fn future_format_rejected() {
        let model = sample_model();
        let p = tmp("future.pawd");
        save_delta(&p, &model).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8] = 99; // format word
        let err = parse_delta(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported delta format"), "{err}");
    }

    #[test]
    fn mixed_codec_artifact_roundtrips_bitwise() {
        let model = sample_model_mixed();
        let bytes = save_delta_bytes(&model).unwrap();
        let loaded = parse_delta(&bytes).unwrap();
        assert_eq!(loaded.modules.len(), model.modules.len());
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!(a.codec.kind(), b.codec.kind());
            assert!(a.content_eq(b), "module {} changed across the roundtrip", b.id);
        }
        // The decode→re-encode cycle is bitwise stable (f16 quantization is
        // idempotent), so replication can compare artifacts byte-for-byte.
        assert_eq!(save_delta_bytes(&loaded).unwrap(), bytes);
    }

    #[test]
    fn mixed_codec_selective_read_decodes_lowrank_section() {
        let model = sample_model_mixed();
        let p = tmp("mixed_sections.pawd");
        save_delta(&p, &model).unwrap();
        let index = read_index(&p).unwrap();
        assert_eq!(
            index.sections.iter().map(|s| s.codec).collect::<Vec<_>>(),
            vec![CodecKind::PerAxis, CodecKind::Scalar, CodecKind::LowRank]
        );
        let got = load_modules(&p, &index, &[2]).unwrap();
        assert!(got[0].content_eq(&model.modules[2]));
        let lr = got[0].lowrank().expect("lowrank payload survived the selective read");
        assert_eq!(lr.rank, 3);
    }

    #[test]
    fn v3_artifacts_decode_as_per_axis_through_codec_path() {
        // A v3 fixture (codec-less section table) must decode every module
        // into the per-axis codec with byte-identical payloads: re-encoding
        // the loaded model as v3 reproduces the fixture exactly.
        let model = sample_model();
        let v3 = save_delta_v3_bytes(&model).unwrap();
        let loaded = parse_delta(&v3).unwrap();
        assert_eq!(loaded.meta, model.meta);
        for (a, b) in loaded.modules.iter().zip(&model.modules) {
            assert_eq!(a.codec.kind(), CodecKind::PerAxis);
            assert!(a.content_eq(b));
        }
        assert_eq!(save_delta_v3_bytes(&loaded).unwrap(), v3, "v3 decode→encode not bitwise");
        // Same proof for the v1 and v2 fixtures.
        for legacy in [save_delta_v1_bytes(&model), save_delta_v2_bytes(&model)] {
            let loaded = parse_delta(&legacy).unwrap();
            for m in &loaded.modules {
                assert_eq!(m.codec.kind(), CodecKind::PerAxis);
            }
        }
        // And v3 cannot carry the new codecs at all.
        assert!(save_delta_v3_bytes(&sample_model_mixed()).is_err());
    }

    #[test]
    fn all_per_axis_v4_records_byte_identical_to_v3() {
        // The v4 bump only adds the table codec byte: for an all-per-axis
        // model every module *record* must be the exact bytes v3 wrote.
        let model = sample_model();
        let v4 = save_delta_bytes(&model).unwrap();
        let v3 = save_delta_v3_bytes(&model).unwrap();
        let idx4 = parse_delta_index(&v4);
        let idx3 = parse_delta_index(&v3);
        for (s4, s3) in idx4.iter().zip(&idx3) {
            let r4 = &v4[s4.offset as usize..(s4.offset + s4.len) as usize];
            let r3 = &v3[s3.offset as usize..(s3.offset + s3.len) as usize];
            assert_eq!(r4, r3, "record bytes for '{}' drifted from v3", s4.name);
        }
    }

    /// Test helper: section table of an in-memory artifact.
    fn parse_delta_index(bytes: &[u8]) -> Vec<SectionEntry> {
        let mut r = Reader { b: bytes, i: 0 };
        let (_, _, _, format) = parse_header(&mut r).unwrap();
        let n = r.u32().unwrap() as usize;
        parse_section_table(&mut r, n, format).unwrap()
    }

    #[test]
    fn v3_fixed_golden_prefix_is_stable() {
        // Pin the module-less v3 layout the same way v1/v2 are pinned, so
        // the legacy writer (and thus the compat reader) cannot drift.
        let model = DeltaModel::new("v", "c", vec![]);
        let bytes = save_delta_v3_bytes(&model).unwrap();
        let mut golden: Vec<u8> = vec![
            b'P', b'A', b'W', b'D', b'E', b'L', b'T', b'A', // magic
            3, 0, 0, 0, // format = 3
            1, 0, 0, 0, b'v', // variant
            1, 0, 0, 0, b'c', // base_config
            1, 0, 0, 0, // version = 1
            0, 0, 0, 0, // parent = none
            0, 0, 0, 0, 0, 0, 0, 0, // created_unix = 0
            0, // kind = full
            0, 0, 0, 0, // n_modules = 0
        ];
        let crc = crc32::hash(&golden);
        golden.extend_from_slice(&crc.to_le_bytes());
        assert_eq!(bytes, golden);
        assert!(parse_delta(&bytes).is_ok());
    }

    #[test]
    fn v4_fixed_golden_prefix_is_stable() {
        // The current writer's module-less layout, pinned byte-for-byte.
        let model = DeltaModel::new("v", "c", vec![]);
        let bytes = save_delta_bytes(&model).unwrap();
        let mut golden: Vec<u8> = vec![
            b'P', b'A', b'W', b'D', b'E', b'L', b'T', b'A', // magic
            4, 0, 0, 0, // format = 4
            1, 0, 0, 0, b'v', // variant
            1, 0, 0, 0, b'c', // base_config
            1, 0, 0, 0, // version = 1
            0, 0, 0, 0, // parent = none
            0, 0, 0, 0, 0, 0, 0, 0, // created_unix = 0
            0, // kind = full
            0, 0, 0, 0, // n_modules = 0
        ];
        let crc = crc32::hash(&golden);
        golden.extend_from_slice(&crc.to_le_bytes());
        assert_eq!(bytes, golden);
        assert!(parse_delta(&bytes).is_ok());
    }

    #[test]
    fn v4_golden_table_entry_layout_carries_codec_byte() {
        // Pin the v4 section-table entry layout (name str | offset u64 |
        // len u64 | codec u8) against the serialized mixed-codec artifact:
        // walk the raw bytes by hand and compare each field to the index.
        let model = sample_model_mixed();
        let bytes = save_delta_bytes(&model).unwrap();
        let index = parse_delta_index(&bytes);
        let mut off = 8 + 4; // magic + format
        for s in ["ft-mixed", "tiny"] {
            off += 4 + s.len();
        }
        off += 4 + 4 + 8 + 1 + 4; // version + parent + created + kind + n_modules
        for (k, sec) in index.iter().enumerate() {
            let name = model.modules[k].id.to_string();
            let nlen =
                u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            assert_eq!(nlen, name.len());
            assert_eq!(&bytes[off + 4..off + 4 + nlen], name.as_bytes());
            off += 4 + nlen;
            assert_eq!(
                u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()),
                sec.offset
            );
            off += 8;
            assert_eq!(
                u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()),
                sec.len
            );
            off += 8;
            assert_eq!(bytes[off], model.modules[k].codec.kind().code());
            off += 1;
        }
        // The table ends exactly where the first record begins.
        assert_eq!(off as u64, index[0].offset);
    }

    #[test]
    fn lowrank_record_with_invalid_rank_rejected() {
        let model = sample_model_mixed();
        let m = &model.modules[2];
        let lr = m.lowrank().unwrap();
        let mut rec = Vec::new();
        write_module_record(&mut rec, m);
        // Locate the rank field (just before the f16 factors + crc) and
        // zero it, re-stamping the record crc so only the rank check trips.
        let rank_off = rec.len() - 4 - 2 * (lr.a.len() + lr.b.len()) - 4;
        assert_eq!(
            u32::from_le_bytes(rec[rank_off..rank_off + 4].try_into().unwrap()),
            lr.rank as u32
        );
        rec[rank_off..rank_off + 4].copy_from_slice(&0u32.to_le_bytes());
        let crc_at = rec.len() - 4;
        let crc = crc32::hash(&rec[..crc_at]);
        rec[crc_at..].copy_from_slice(&crc.to_le_bytes());
        let err = parse_module_record(&rec, CodecKind::LowRank).unwrap_err().to_string();
        assert!(err.contains("invalid rank"), "{err}");
    }
}
