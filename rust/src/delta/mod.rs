//! The paper's core contribution: 1-bit weight deltas with learned per-axis
//! (row/column) FP16 scales.
//!
//! * [`pack`] — sign extraction + bit packing (1 bit along the input axis).
//! * [`types`] — [`Axis`], [`DeltaModule`], [`DeltaModel`], [`ArtifactMeta`].
//! * [`calibrate`] — activation-aware scale fitting (AdamW per the paper,
//!   plus exact closed-form — the objective is quadratic in `v`).
//! * [`cache`] — calibration (X, Y) caches via forward taps (Alg. 3).
//! * [`codec`] — the pluggable [`DeltaCodec`](codec::DeltaCodec) trait and
//!   registry: per-axis (the paper), BitDelta-style scalar, and a low-rank
//!   residual codec, plus per-module auto-selection by calibration error.
//! * [`compress`] — per-module row/col selection (Alg. 6) and the
//!   layer-by-layer model sweep (Alg. 1), dispatching through the codecs.
//! * [`apply`] — the serving hot path: `Ŵ = W_b + v ⊙ B` materialization,
//!   in-place swap/revert.
//! * [`format`] — PAWD on-disk artifact (v3: section table + patch
//!   artifacts) + single-read and selective-section loaders.
//! * [`chain`] — version-chain resolution: compose patch chains into
//!   effective models, diff effective models into patches, bounded depth.
//! * [`stats`] — delta anisotropy statistics (§4 limitation study).

pub mod apply;
pub mod cache;
pub mod calibrate;
pub mod chain;
pub mod codec;
pub mod compress;
pub mod format;
pub mod pack;
pub mod stats;
pub mod types;

pub use chain::{ChainLink, LoadStats, MAX_CHAIN_DEPTH};
pub use codec::{codec_for, DeltaCodec};
pub use compress::{
    compress_model, compress_module, CodecCandidate, CodecChoice, CompressOptions, FitMode,
    ModuleReport,
};
pub use pack::PackedMask;
pub use types::{ArtifactMeta, Axis, Codec, CodecKind, DeltaModel, DeltaModule};
