//! Version-chain resolution: compose a parent chain of patch artifacts into
//! the **effective model** of a version, diff two effective models into a
//! patch, and consolidate a chain back into a full artifact.
//!
//! The paper's premise is *frequent* updates, and between two adjacent
//! fine-tune checkpoints most modules' packed bitplane/scales do not move.
//! A v3 **patch artifact** therefore ships only the changed modules
//! (DeltaZip and BitDelta make the same observation for multi-tenant
//! serving: structure *between* checkpoints is where the storage and
//! cold-start wins live). The effective model of `variant@N` is recovered
//! by walking `N`'s parent chain down to the nearest full artifact and
//! overlaying each patch in order.
//!
//! Composition is **Arc-sharing**: modules the patch does not carry are the
//! *same* `Arc<DeltaModule>` as the parent's, so when the parent's effective
//! model is already resident, loading `@N+1` allocates and reads only what
//! actually changed. The cold path (no resident ancestor) uses the v3
//! section table to read each record **once** from the newest link that
//! carries it — a module rewritten by three successive patches is read from
//! the newest patch only.
//!
//! Chains are bounded by [`MAX_CHAIN_DEPTH`]; the registry's `consolidate`
//! op rebases a deep chain into a single full artifact
//! ([`VariantRegistry::consolidate`](crate::coordinator::VariantRegistry::consolidate)).
//!
//! Determinism: composition preserves the base artifact's module order and
//! appends genuinely new modules in (link, record) order, so composing a
//! chain and loading a consolidated full artifact of the same version yield
//! bitwise-identical models (packed mask words, f16 scale bits and
//! therefore eval logits) — the invariant the `incremental_chain`
//! integration tests pin.

use super::format::{load_delta, load_modules, read_index};
use super::types::{ArtifactMeta, DeltaModel, DeltaModule};
use crate::exec::counters;
use crate::model::ModuleId;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Policy bound on chain length (full artifact + patches): the registry
/// refuses to *grow* a chain past this — `publish_incremental` falls back
/// to a full artifact instead. Keeps worst-case cold-load fan-out and
/// patch-lineage fragility bounded.
pub const MAX_CHAIN_DEPTH: usize = 8;

/// Hard backstop on chain length for the loaders. Deliberately far above
/// [`MAX_CHAIN_DEPTH`]: registry-built chains never get near it, but an
/// adopted or hand-synced directory may exceed the policy bound, and
/// `consolidate` must still be able to *load* such a chain to rebase it —
/// the remedy has to work on the disease. Only a cyclic or absurdly deep
/// lineage (corruption) trips this.
pub const HARD_CHAIN_BOUND: usize = 64;

/// One link of a version chain, base-most first: the artifact file backing
/// one version of a variant.
#[derive(Clone, Debug)]
pub struct ChainLink {
    pub version: u32,
    pub path: PathBuf,
    /// Whether the artifact is a patch (carries only changed modules).
    pub is_patch: bool,
}

/// Accounting for one effective-model load — what the chain loader actually
/// touched, so callers (cache, benches) can assert that warming a patch
/// version costs proportionally to what changed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoadStats {
    /// Artifact bytes read from disk (headers, section tables, records).
    pub bytes_read: u64,
    /// Module records decoded from disk.
    pub modules_read: usize,
    /// Modules reused from a resident parent's `Arc` without any disk read.
    pub modules_inherited: usize,
}

/// Overlay `patch` onto the `parent` **effective** model: modules the patch
/// carries replace the parent's in place (or append, for modules the parent
/// never covered); everything else is inherited as the parent's own `Arc`.
/// The result is the child's effective (full) model.
pub fn compose(parent: &DeltaModel, patch: &DeltaModel) -> Result<DeltaModel> {
    anyhow::ensure!(patch.meta.is_patch, "compose: '{}' is not a patch", patch.variant);
    anyhow::ensure!(
        !parent.meta.is_patch,
        "compose: parent '{}' must be an effective (full) model",
        parent.variant
    );
    anyhow::ensure!(
        patch.meta.parent == Some(parent.meta.version),
        "compose: patch of '{}' targets parent v{:?}, got v{}",
        patch.variant,
        patch.meta.parent,
        parent.meta.version
    );
    anyhow::ensure!(
        patch.base_config == parent.base_config,
        "compose: base config mismatch ('{}' vs '{}')",
        patch.base_config,
        parent.base_config
    );
    let mut modules = parent.modules.clone();
    let by_id: HashMap<ModuleId, usize> =
        modules.iter().enumerate().map(|(i, m)| (m.id, i)).collect();
    for pm in &patch.modules {
        match by_id.get(&pm.id) {
            Some(&i) => modules[i] = pm.clone(),
            None => modules.push(pm.clone()),
        }
    }
    Ok(DeltaModel {
        variant: patch.variant.clone(),
        base_config: patch.base_config.clone(),
        meta: ArtifactMeta { is_patch: false, ..patch.meta },
        modules,
    })
}

/// Diff two effective models into the patch that turns `parent` into
/// `child`: the child modules whose on-disk content
/// ([`DeltaModule::content_eq`]) differs from the parent's, plus any module
/// the parent never covered. Returns an error when `child` drops a module
/// the parent has — the patch format cannot express removal, so such a
/// publish must ship a full artifact instead.
pub fn diff(parent: &DeltaModel, child: &DeltaModel) -> Result<DeltaModel> {
    anyhow::ensure!(
        !parent.meta.is_patch && !child.meta.is_patch,
        "diff operates on effective (full) models"
    );
    anyhow::ensure!(
        parent.base_config == child.base_config,
        "diff: base config mismatch ('{}' vs '{}')",
        parent.base_config,
        child.base_config
    );
    let child_ids: HashMap<ModuleId, &Arc<DeltaModule>> =
        child.modules.iter().map(|m| (m.id, m)).collect();
    for pm in &parent.modules {
        if !child_ids.contains_key(&pm.id) {
            bail!(
                "child drops module {} — patches cannot express removal, publish a full artifact",
                pm.id
            );
        }
    }
    let parent_ids: HashMap<ModuleId, &Arc<DeltaModule>> =
        parent.modules.iter().map(|m| (m.id, m)).collect();
    let modules: Vec<Arc<DeltaModule>> = child
        .modules
        .iter()
        .filter(|cm| match parent_ids.get(&cm.id) {
            Some(pm) => !pm.content_eq(cm),
            None => true,
        })
        .cloned()
        .collect();
    Ok(DeltaModel {
        variant: child.variant.clone(),
        base_config: child.base_config.clone(),
        meta: ArtifactMeta {
            version: child.meta.version,
            parent: Some(parent.meta.version),
            created_unix: child.meta.created_unix,
            is_patch: true,
        },
        modules,
    })
}

/// Load the effective model of the **last** link of `chain` (base-most
/// first).
///
/// * With a `resident_parent` whose version is the direct parent link, only
///   the final patch file is read and composed on — the hot path behind a
///   publish, where `@N` is still resident when `@N+1` warms.
/// * Cold, the v3 section tables let every module record be read exactly
///   once, from the newest link that carries it.
///
/// Returns the composed model plus the [`LoadStats`] of what was actually
/// read vs inherited.
pub fn load_effective(
    chain: &[ChainLink],
    resident_parent: Option<&DeltaModel>,
) -> Result<(DeltaModel, LoadStats)> {
    anyhow::ensure!(!chain.is_empty(), "empty version chain");
    anyhow::ensure!(
        chain.len() <= HARD_CHAIN_BOUND,
        "version chain depth {} exceeds the corruption backstop {HARD_CHAIN_BOUND}",
        chain.len()
    );
    anyhow::ensure!(!chain[0].is_patch, "chain must start at a full artifact");
    for link in &chain[1..] {
        anyhow::ensure!(
            link.is_patch,
            "non-patch artifact v{} in the middle of a chain",
            link.version
        );
    }
    let last = chain.last().unwrap();
    if chain.len() == 1 {
        let model = load_delta(&last.path)?;
        let stats = LoadStats {
            bytes_read: std::fs::metadata(&last.path).map(|m| m.len()).unwrap_or(0),
            modules_read: model.modules.len(),
            modules_inherited: 0,
        };
        return Ok((model, stats));
    }
    // Hot path: the direct parent's effective model is already resident —
    // read only the final patch and compose onto it.
    if let Some(parent) = resident_parent {
        let direct_parent = chain[chain.len() - 2].version;
        if !parent.meta.is_patch && parent.meta.version == direct_parent {
            let patch = load_delta(&last.path)
                .with_context(|| format!("loading patch {}", last.path.display()))?;
            let patch_modules = patch.modules.len();
            let model = compose(parent, &patch)?;
            let inherited = model.modules.len() - patch_modules;
            counters::record_modules_inherited(inherited as u64);
            let stats = LoadStats {
                bytes_read: std::fs::metadata(&last.path).map(|m| m.len()).unwrap_or(0),
                modules_read: patch_modules,
                modules_inherited: inherited,
            };
            return Ok((model, stats));
        }
    }
    // Cold path: index every link, then read each module record once, from
    // the newest link that carries it.
    let mut stats = LoadStats::default();
    let mut indexes = Vec::with_capacity(chain.len());
    for link in chain {
        // v1/v2 artifacts predate the section table; they can only be the
        // base of a chain (patches are v3-only) and are loaded in full.
        let index = read_index(&link.path)
            .with_context(|| format!("indexing chain link {}", link.path.display()))?;
        anyhow::ensure!(
            index.meta.version == link.version,
            "chain link {} carries version {} but the registry expected v{}",
            link.path.display(),
            index.meta.version,
            link.version
        );
        anyhow::ensure!(
            index.meta.is_patch == link.is_patch,
            "chain link {} patch flag disagrees with the registry record",
            link.path.display()
        );
        stats.bytes_read += index_bytes(&index);
        indexes.push(index);
    }
    for w in indexes.windows(2) {
        anyhow::ensure!(
            w[0].base_config == w[1].base_config,
            "base config changes mid-chain ('{}' vs '{}')",
            w[0].base_config,
            w[1].base_config
        );
    }
    // Winner per module name: the newest link carrying it. (v1/v2 links —
    // only ever the base — have no section table; their names resolve via
    // the full-load fallback below.)
    let mut winner: HashMap<&str, (usize, usize)> = HashMap::new(); // name -> (link, section)
    for (li, index) in indexes.iter().enumerate() {
        for (si, sec) in index.sections.iter().enumerate() {
            winner.insert(sec.name.as_str(), (li, si)); // later links overwrite
        }
    }
    // Load each link's winning records (selectively where the table allows).
    let mut loaded: Vec<HashMap<String, Arc<DeltaModule>>> = Vec::with_capacity(chain.len());
    let mut base_full: Option<DeltaModel> = None;
    for (li, (link, index)) in chain.iter().zip(&indexes).enumerate() {
        if index.format < 3 {
            // v1/v2 base artifact (patches are v3-only): full sequential
            // read, modules addressed by name in the assembly below.
            let model = load_delta(&link.path)?;
            stats.bytes_read += std::fs::metadata(&link.path).map(|m| m.len()).unwrap_or(0);
            stats.modules_read += model.modules.len();
            loaded.push(model.modules.iter().map(|m| (m.id.to_string(), m.clone())).collect());
            base_full = Some(model);
            continue;
        }
        let wanted: Vec<usize> = index
            .sections
            .iter()
            .enumerate()
            .filter(|(si, sec)| winner.get(sec.name.as_str()) == Some(&(li, *si)))
            .map(|(si, _)| si)
            .collect();
        let modules = load_modules(&link.path, index, &wanted)?;
        stats.bytes_read += wanted.iter().map(|&si| index.sections[si].len).sum::<u64>();
        stats.modules_read += modules.len();
        loaded.push(
            wanted
                .iter()
                .zip(&modules)
                .map(|(&si, m)| (index.sections[si].name.clone(), m.clone()))
                .collect(),
        );
    }
    // Assemble in composition order: the base artifact's record order with
    // winners substituted in place, then each patch's genuinely new names in
    // (link, record) order — exactly what iterated `compose` would produce.
    let mut order: Vec<String> = Vec::new();
    let mut seen: std::collections::HashSet<String> = std::collections::HashSet::new();
    match &base_full {
        Some(model) => {
            for m in &model.modules {
                let name = m.id.to_string();
                if seen.insert(name.clone()) {
                    order.push(name);
                }
            }
        }
        None => {
            for sec in &indexes[0].sections {
                if seen.insert(sec.name.clone()) {
                    order.push(sec.name.clone());
                }
            }
        }
    }
    for index in &indexes[1..] {
        for sec in &index.sections {
            if seen.insert(sec.name.clone()) {
                order.push(sec.name.clone());
            }
        }
    }
    let mut modules = Vec::with_capacity(order.len());
    for name in &order {
        // Names absent from the winner map can only come from a v1/v2 base
        // (it has no section table, so it never entered the map) — take
        // them from its full load.
        let li = winner.get(name.as_str()).map(|&(li, _)| li).unwrap_or(0);
        let m = loaded[li]
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("module '{name}' missing from chain link {li}"))?;
        modules.push(m.clone());
    }
    let last_index = indexes.last().unwrap();
    Ok((
        DeltaModel {
            variant: last_index.variant.clone(),
            base_config: last_index.base_config.clone(),
            meta: ArtifactMeta { is_patch: false, ..last_index.meta },
            modules,
        },
        stats,
    ))
}

/// Approximate on-disk size of an artifact's header + section table (what
/// [`read_index`](super::format::read_index) consumes).
fn index_bytes(index: &super::format::ArtifactIndex) -> u64 {
    let header = 8 + 4 + (4 + index.variant.len()) + (4 + index.base_config.len()) + 17 + 4;
    // v4 table entries carry a trailing codec byte.
    let entry_extra = if index.format >= 4 { 1 } else { 0 };
    let table: usize =
        index.sections.iter().map(|s| 4 + s.name.len() + 8 + 8 + entry_extra).sum();
    (header + table) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::format::save_delta;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Axis, Codec};
    use crate::model::{ModuleId, ProjKind};
    use crate::util::rng::Rng;

    fn mk_module(layer: usize, kind: ProjKind, seed: u64) -> DeltaModule {
        let (d_out, d_in) = (16, 48);
        let mut r = Rng::new(seed);
        let delta: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
        DeltaModule {
            id: ModuleId { layer, kind },
            mask: PackedMask::pack(&delta, d_out, d_in),
            axis: Axis::Row,
            scales: (0..d_out).map(|_| r.uniform_in(0.01, 0.2)).collect(),
            codec: Codec::PerAxis,
        }
    }

    fn full_model(version: u32, seeds: &[u64]) -> DeltaModel {
        let kinds = [ProjKind::Q, ProjKind::K, ProjKind::V, ProjKind::O];
        let modules: Vec<DeltaModule> = seeds
            .iter()
            .enumerate()
            .map(|(i, &s)| mk_module(i / kinds.len(), kinds[i % kinds.len()], s))
            .collect();
        let mut m = DeltaModel::new("ft", "tiny", modules);
        m.meta.version = version;
        m
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("pawd_test_chain").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn diff_then_compose_roundtrips_and_shares_arcs() {
        let parent = full_model(1, &[1, 2, 3, 4]);
        let mut child = full_model(2, &[1, 2, 3, 4]);
        // Change exactly one module's content.
        child.modules[2] = Arc::new(mk_module(0, ProjKind::V, 99));
        child.meta.parent = Some(1);

        let patch = diff(&parent, &child).unwrap();
        assert!(patch.meta.is_patch);
        assert_eq!(patch.meta.parent, Some(1));
        assert_eq!(patch.modules.len(), 1, "only the changed module ships");

        let composed = compose(&parent, &patch).unwrap();
        assert!(!composed.meta.is_patch);
        assert_eq!(composed.meta.version, 2);
        assert_eq!(composed.modules.len(), 4);
        for (i, (cm, pm)) in composed.modules.iter().zip(&parent.modules).enumerate() {
            if i == 2 {
                assert!(!cm.content_eq(pm));
            } else {
                // Inherited modules are the SAME Arc, not a copy.
                assert!(Arc::ptr_eq(cm, pm), "module {i} must be shared");
            }
        }
    }

    #[test]
    fn diff_of_identical_models_is_empty() {
        let parent = full_model(1, &[5, 6, 7]);
        let mut child = parent.clone();
        child.meta.version = 2;
        let patch = diff(&parent, &child).unwrap();
        assert!(patch.modules.is_empty(), "identical content must produce an empty patch");
    }

    #[test]
    fn diff_refuses_module_removal() {
        let parent = full_model(1, &[5, 6, 7]);
        let mut child = parent.clone();
        child.meta.version = 2;
        child.modules.pop();
        let err = diff(&parent, &child).unwrap_err().to_string();
        assert!(err.contains("removal"), "{err}");
    }

    #[test]
    fn compose_rejects_wrong_parent() {
        let parent = full_model(3, &[1, 2]);
        let mut child = full_model(4, &[1, 9]);
        child.meta.parent = Some(3);
        let patch = diff(&parent, &child).unwrap();
        let mut wrong = parent.clone();
        wrong.meta.version = 2;
        assert!(compose(&wrong, &patch).is_err());
    }

    #[test]
    fn load_effective_matches_iterated_compose_cold_and_hot() {
        let dir = tmp_dir("chain_eq");
        let v1 = full_model(1, &[10, 11, 12, 13, 14, 15]);
        save_delta(dir.join("v1.pawd"), &v1).unwrap();
        // v2 patches modules 1 and 4; v3 patches modules 1 (again) and 5.
        let mut eff2 = v1.clone();
        eff2.meta = ArtifactMeta { version: 2, parent: Some(1), created_unix: 5, is_patch: false };
        eff2.modules[1] = Arc::new(mk_module(0, ProjKind::K, 100));
        eff2.modules[4] = Arc::new(mk_module(1, ProjKind::Q, 101));
        let p2 = diff(&v1, &eff2).unwrap();
        assert_eq!(p2.modules.len(), 2);
        save_delta(dir.join("v2.pawd"), &p2).unwrap();
        let mut eff3 = eff2.clone();
        eff3.meta = ArtifactMeta { version: 3, parent: Some(2), created_unix: 6, is_patch: false };
        eff3.modules[1] = Arc::new(mk_module(0, ProjKind::K, 102));
        eff3.modules[5] = Arc::new(mk_module(1, ProjKind::K, 103));
        let p3 = diff(&eff2, &eff3).unwrap();
        save_delta(dir.join("v3.pawd"), &p3).unwrap();

        let chain = vec![
            ChainLink { version: 1, path: dir.join("v1.pawd"), is_patch: false },
            ChainLink { version: 2, path: dir.join("v2.pawd"), is_patch: true },
            ChainLink { version: 3, path: dir.join("v3.pawd"), is_patch: true },
        ];
        // Cold load (no resident ancestor).
        let (cold, cold_stats) = load_effective(&chain, None).unwrap();
        assert_eq!(cold.meta.version, 3);
        assert_eq!(cold.modules.len(), 6);
        // Module 1 was patched twice: only the newest record is read, so the
        // cold path reads 6 winners, not 6 + 2 + 2 records.
        assert_eq!(cold_stats.modules_read, 6);
        // Reference: iterated compose from full loads.
        let r1 = load_delta(dir.join("v1.pawd")).unwrap();
        let r2 = compose(&r1, &load_delta(dir.join("v2.pawd")).unwrap()).unwrap();
        let r3 = compose(&r2, &load_delta(dir.join("v3.pawd")).unwrap()).unwrap();
        assert_model_bitwise_eq(&cold, &r3);
        // Hot load: the parent's effective model is resident.
        let (hot, hot_stats) = load_effective(&chain, Some(&r2)).unwrap();
        assert_model_bitwise_eq(&hot, &r3);
        assert_eq!(hot_stats.modules_read, 2, "only the final patch is read");
        assert_eq!(hot_stats.modules_inherited, 4);
        assert!(hot_stats.bytes_read < cold_stats.bytes_read);
        // Inherited modules are the parent's own Arcs.
        for (i, m) in hot.modules.iter().enumerate() {
            if ![1usize, 5].contains(&i) {
                assert!(Arc::ptr_eq(m, &r2.modules[i]), "module {i} must be inherited");
            }
        }
    }

    #[test]
    fn chains_compose_over_a_v2_base_artifact() {
        // A pre-v3 base has no section table: the cold path must fall back
        // to a full read of the base and still compose correctly.
        let dir = tmp_dir("chain_v2base");
        let v1 = full_model(1, &[20, 21, 22]);
        std::fs::write(
            dir.join("v1.pawd"),
            crate::delta::format::save_delta_v2_bytes(&v1),
        )
        .unwrap();
        let mut eff2 = v1.clone();
        eff2.meta = ArtifactMeta { version: 2, parent: Some(1), created_unix: 0, is_patch: false };
        eff2.modules[0] = Arc::new(mk_module(0, ProjKind::Q, 200));
        let p2 = diff(&v1, &eff2).unwrap();
        save_delta(dir.join("v2.pawd"), &p2).unwrap();
        let chain = vec![
            ChainLink { version: 1, path: dir.join("v1.pawd"), is_patch: false },
            ChainLink { version: 2, path: dir.join("v2.pawd"), is_patch: true },
        ];
        let (cold, stats) = load_effective(&chain, None).unwrap();
        assert_eq!(cold.modules.len(), 3);
        // The v2 base cannot be read selectively: all 3 base records load,
        // plus the 1 patch record.
        assert_eq!(stats.modules_read, 4);
        let r1 = load_delta(dir.join("v1.pawd")).unwrap();
        let r2 = compose(&r1, &load_delta(dir.join("v2.pawd")).unwrap()).unwrap();
        assert_model_bitwise_eq(&cold, &r2);
    }

    #[test]
    fn chain_depth_backstop_rejects_absurd_chains() {
        let links: Vec<ChainLink> = (0..HARD_CHAIN_BOUND + 1)
            .map(|i| ChainLink {
                version: i as u32 + 1,
                path: PathBuf::from("/nonexistent"),
                is_patch: i > 0,
            })
            .collect();
        let err = load_effective(&links, None).unwrap_err().to_string();
        assert!(err.contains("backstop"), "{err}");
    }

    fn assert_model_bitwise_eq(a: &DeltaModel, b: &DeltaModel) {
        assert_eq!(a.modules.len(), b.modules.len());
        for (x, y) in a.modules.iter().zip(&b.modules) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.axis, y.axis);
            assert_eq!(x.mask, y.mask);
            assert_eq!(
                crate::util::f16::encode_f16_slice(&x.scales),
                crate::util::f16::encode_f16_slice(&y.scales),
                "scale bits of {}",
                x.id
            );
            assert!(x.content_eq(y), "codec payload of {}", x.id);
        }
    }

    #[test]
    fn diff_ships_module_whose_codec_changed() {
        // Same mask and scales, but the child re-encoded one module under
        // the low-rank codec: the diff must carry it, and composing the
        // patch back must reproduce the child bitwise.
        use crate::delta::types::LowRank;
        let parent = full_model(1, &[1, 2, 3]);
        let mut child = parent.clone();
        child.meta.version = 2;
        let m0 = &child.modules[0];
        let (d_out, d_in) = (m0.d_out(), m0.d_in());
        let mut recoded = (**m0).clone();
        recoded.codec = Codec::LowRank(LowRank {
            rank: 2,
            a: vec![0.125; 2 * d_in],
            b: vec![0.25; d_out * 2],
        });
        child.modules[0] = Arc::new(recoded);
        let patch = diff(&parent, &child).unwrap();
        assert_eq!(patch.modules.len(), 1);
        assert_eq!(patch.modules[0].codec.kind(), crate::delta::types::CodecKind::LowRank);
        let recomposed = compose(&parent, &patch).unwrap();
        assert_model_bitwise_eq(&recomposed, &child);
    }
}
