//! 1-bit sign masks: extraction and bit packing.
//!
//! `B = sign(W_f − W_b) ∈ {−1,+1}^{d_out×d_in}` is packed 1 bit per entry
//! **along the input axis** (paper §2, "Masks stay packed end-to-end, 1 bit
//! along input axis"): each output row j occupies `ceil(d_in/32)` u32 words,
//! bit i of word w being the sign of `ΔW[j, 32w+i]` (1 → +1, 0 → −1; ties
//! `ΔW == 0` map to +1, matching `jnp.where(delta >= 0, 1, -1)` on the
//! Python side).
//!
//! u32 words (not u64) so the packed buffer can cross the PJRT boundary as
//! a u32 literal and be expanded in-kernel by the Pallas delta kernels.

/// Packed sign mask for one weight matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMask {
    pub d_out: usize,
    pub d_in: usize,
    /// Words per output row = ceil(d_in / 32).
    pub words_per_row: usize,
    /// `d_out * words_per_row` little-bit-endian words.
    pub words: Vec<u32>,
}

impl PackedMask {
    pub fn words_per_row_for(d_in: usize) -> usize {
        d_in.div_ceil(32)
    }

    /// Pack the signs of `delta` (row-major `[d_out, d_in]`).
    pub fn pack(delta: &[f32], d_out: usize, d_in: usize) -> PackedMask {
        assert_eq!(delta.len(), d_out * d_in);
        let wpr = Self::words_per_row_for(d_in);
        let mut words = vec![0u32; d_out * wpr];
        for j in 0..d_out {
            let row = &delta[j * d_in..(j + 1) * d_in];
            let out = &mut words[j * wpr..(j + 1) * wpr];
            for (i, &x) in row.iter().enumerate() {
                // sign(0) -> +1 (bit set), matching the jnp reference.
                if x >= 0.0 || x.is_nan() {
                    out[i / 32] |= 1 << (i % 32);
                }
            }
        }
        PackedMask { d_out, d_in, words_per_row: wpr, words }
    }

    /// Sign at (j, i) as ±1.0.
    #[inline]
    pub fn sign(&self, j: usize, i: usize) -> f32 {
        debug_assert!(j < self.d_out && i < self.d_in);
        let w = self.words[j * self.words_per_row + i / 32];
        // Branchless ±1.0: bit set -> 0x3F800000 (+1.0), clear -> 0xBF800000.
        f32::from_bits(0x3F80_0000 | (((w >> (i % 32)) & 1) ^ 1) << 31)
    }

    /// Raw words of row j.
    #[inline]
    pub fn row_words(&self, j: usize) -> &[u32] {
        &self.words[j * self.words_per_row..(j + 1) * self.words_per_row]
    }

    /// Expand row j into ±1.0 values (length `d_in`). Used by tests and the
    /// reference apply path; the optimized path consumes words directly.
    pub fn unpack_row(&self, j: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.d_in);
        let words = self.row_words(j);
        for (i, o) in out.iter_mut().enumerate() {
            let bit = (words[i / 32] >> (i % 32)) & 1;
            *o = f32::from_bits(0x3F80_0000 | (bit ^ 1) << 31);
        }
    }

    /// Dense ±1.0 matrix (test/debug only — defeats the whole point!).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.d_out * self.d_in];
        for j in 0..self.d_out {
            self.unpack_row(j, &mut out[j * self.d_in..(j + 1) * self.d_in]);
        }
        out
    }

    /// Packed payload as little-endian bytes (for the PAWD file format).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 4);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(d_out: usize, d_in: usize, bytes: &[u8]) -> anyhow::Result<PackedMask> {
        let wpr = Self::words_per_row_for(d_in);
        let expect = d_out * wpr * 4;
        anyhow::ensure!(
            bytes.len() == expect,
            "packed mask byte length {} != expected {expect}",
            bytes.len()
        );
        let words = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(PackedMask { d_out, d_in, words_per_row: wpr, words })
    }

    /// Bytes of storage used by the packed mask.
    pub fn n_bytes(&self) -> u64 {
        (self.words.len() * 4) as u64
    }

    /// Fraction of +1 bits (useful delta statistic).
    pub fn positive_fraction(&self) -> f64 {
        let mut ones = 0u64;
        for j in 0..self.d_out {
            for (wi, &w) in self.row_words(j).iter().enumerate() {
                // Mask out padding bits in the last word of each row.
                let valid = if (wi + 1) * 32 <= self.d_in {
                    32
                } else {
                    self.d_in - wi * 32
                };
                let mask = if valid == 32 { u32::MAX } else { (1u32 << valid) - 1 };
                ones += (w & mask).count_ones() as u64;
            }
        }
        ones as f64 / (self.d_out * self.d_in) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrips_signs() {
        let mut r = Rng::new(1);
        for &(d_out, d_in) in &[(1, 1), (3, 31), (4, 32), (5, 33), (16, 100)] {
            let delta: Vec<f32> =
                (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let m = PackedMask::pack(&delta, d_out, d_in);
            let dense = m.unpack();
            for (i, (&d, &s)) in delta.iter().zip(&dense).enumerate() {
                let want = if d >= 0.0 { 1.0 } else { -1.0 };
                assert_eq!(s, want, "idx {i}, d={d}");
            }
        }
    }

    #[test]
    fn zero_maps_to_plus_one() {
        let m = PackedMask::pack(&[0.0, -0.0, 1.0, -1.0], 1, 4);
        // IEEE: -0.0 >= 0.0 is true, so both zeros -> +1.
        assert_eq!(m.unpack(), vec![1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn sign_accessor_matches_unpack() {
        let mut r = Rng::new(2);
        let (d_out, d_in) = (7, 45);
        let delta: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let m = PackedMask::pack(&delta, d_out, d_in);
        let dense = m.unpack();
        for j in 0..d_out {
            for i in 0..d_in {
                assert_eq!(m.sign(j, i), dense[j * d_in + i]);
            }
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let mut r = Rng::new(3);
        let (d_out, d_in) = (9, 70);
        let delta: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let m = PackedMask::pack(&delta, d_out, d_in);
        let b = m.to_bytes();
        let m2 = PackedMask::from_bytes(d_out, d_in, &b).unwrap();
        assert_eq!(m, m2);
        assert!(PackedMask::from_bytes(d_out, d_in, &b[1..]).is_err());
    }

    #[test]
    fn storage_is_one_bit_per_entry_plus_padding() {
        let m = PackedMask::pack(&vec![1.0; 128 * 256], 128, 256);
        assert_eq!(m.n_bytes(), 128 * 256 / 8);
        // Non-multiple-of-32 rows pad to the word boundary.
        let m = PackedMask::pack(&vec![1.0; 10 * 33], 10, 33);
        assert_eq!(m.n_bytes(), (10 * 2 * 4) as u64);
    }

    #[test]
    fn positive_fraction_balanced_for_random() {
        let mut r = Rng::new(4);
        let delta: Vec<f32> = (0..64 * 100).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let m = PackedMask::pack(&delta, 64, 100);
        let f = m.positive_fraction();
        assert!((f - 0.5).abs() < 0.03, "fraction {f}");
        // Padding bits must not count.
        let all_neg = PackedMask::pack(&vec![-1.0; 5 * 33], 5, 33);
        assert_eq!(all_neg.positive_fraction(), 0.0);
    }
}
