//! Activation-aware calibration of scale vectors (paper §2, Algorithms 4/6).
//!
//! For a module with base weights `W_b [d_out, d_in]`, sign mask `B`, and a
//! calibration cache of `(X [n, d_in], Y [n, d_out])` pairs (student-side
//! inputs, teacher-side outputs), the layer objective is
//!
//! `L(v) = (1/(n·d_out)) · ‖Y − X·(W_b + v⊙B)ᵀ‖²`.
//!
//! `L` is a *quadratic* in `v` for every axis mode, so we precompute
//! sufficient statistics once per module and then both training modes are
//! cheap:
//!
//! * **AdamW** (paper-faithful, Alg. 4: lr 1e-4, 5 epochs) — full-batch
//!   gradients from the statistics, bit-identical objective to minibatch
//!   sweeps over the cache in expectation;
//! * **closed form** (our extension) — row mode decouples per output unit
//!   (1-D least squares); col mode solves one ridge-regularized SPD system.
//!
//! Row statistics also serve the `Scalar` (BitDelta) and `Group` modes,
//! which constrain row scales to be shared.

use super::pack::PackedMask;
use super::types::Axis;
use crate::tensor::{cholesky_solve, dot, Tensor2};
use crate::util::par;

/// Hyper-parameters for scale training (paper defaults).
#[derive(Clone, Debug)]
pub struct CalibConfig {
    pub lr: f32,
    pub epochs: usize,
    /// Gradient steps per epoch (the paper sweeps the 50-sample cache in
    /// minibatches; with precomputed statistics each step is full-batch, so
    /// steps ≈ minibatches/epoch gives the same optimization budget).
    pub steps_per_epoch: usize,
    /// Held-out fraction of cache rows used for axis selection (Alg. 6's
    /// "validation MSE on the held-out shard").
    pub val_fraction: f32,
    /// Ridge added to the col-mode normal equations (numerical safety).
    pub ridge: f32,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { lr: 1e-4, epochs: 5, steps_per_epoch: 10, val_fraction: 0.2, ridge: 1e-4 }
    }
}

/// Initial scales = `mean(|ΔW|, axis)` (Alg. 6 lines 3/5).
pub fn init_scales(delta: &[f32], d_out: usize, d_in: usize, axis: Axis) -> Vec<f32> {
    assert_eq!(delta.len(), d_out * d_in);
    match axis {
        Axis::Row => (0..d_out)
            .map(|j| {
                delta[j * d_in..(j + 1) * d_in].iter().map(|x| x.abs() as f64).sum::<f64>()
                    / d_in as f64
            })
            .map(|x| x as f32)
            .collect(),
        Axis::Col => {
            let mut acc = vec![0f64; d_in];
            for j in 0..d_out {
                for (i, &x) in delta[j * d_in..(j + 1) * d_in].iter().enumerate() {
                    acc[i] += x.abs() as f64;
                }
            }
            acc.into_iter().map(|x| (x / d_out as f64) as f32).collect()
        }
        Axis::Scalar => {
            let m = delta.iter().map(|x| x.abs() as f64).sum::<f64>() / delta.len() as f64;
            vec![m as f32]
        }
        Axis::Group(g) => {
            let g = g.max(1) as usize;
            (0..d_out.div_ceil(g))
                .map(|grp| {
                    let j0 = grp * g;
                    let j1 = (j0 + g).min(d_out);
                    let cnt = ((j1 - j0) * d_in) as f64;
                    delta[j0 * d_in..j1 * d_in].iter().map(|x| x.abs() as f64).sum::<f64>() / cnt
                })
                .map(|x| x as f32)
                .collect()
        }
    }
}

/// Row-axis sufficient statistics (also serve Scalar and Group modes):
/// with `u_j = X·B[j,:]ᵀ` and `R = Y − X·W_bᵀ`,
/// `L(v) = (Σ_j ‖R_j‖² − 2 v_j·b_j + v_j²·a_j) / (n·d_out)`
/// where `a_j = ‖u_j‖²`, `b_j = ⟨R_j, u_j⟩`.
#[derive(Clone, Debug)]
pub struct RowStats {
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    /// `‖R_j‖²` per output unit.
    pub rr: Vec<f64>,
    /// Total element count `n · d_out`.
    pub n_elems: f64,
}

/// Col-axis sufficient statistics:
/// `L(v) = (‖R‖² − 2 vᵀc + vᵀGv) / (n·d_out)` with
/// `G = (XᵀX) ⊙ (BᵀB)` and `c_i = Σ_j B[j,i]·(XᵀR)[i,j]`.
#[derive(Clone, Debug)]
pub struct ColStats {
    pub g: Tensor2,
    pub c: Vec<f64>,
    pub rr_total: f64,
    pub n_elems: f64,
}

/// Compute the residual `R = Y − X·W_bᵀ` once per module.
pub fn residual(x: &Tensor2, y: &Tensor2, w_base: &Tensor2) -> Tensor2 {
    let base_out = x.matmul_bt(w_base); // [n, d_out]
    y.sub(&base_out)
}

/// Build row statistics from the cache.
pub fn row_stats(x: &Tensor2, r: &Tensor2, mask: &PackedMask) -> RowStats {
    let n = x.rows;
    let d_out = mask.d_out;
    let d_in = mask.d_in;
    assert_eq!(x.cols, d_in);
    assert_eq!((r.rows, r.cols), (n, d_out));
    // One (a, b, rr) triple per output unit; `parallel_rows_mut` hands each
    // thread a disjoint mutable chunk, keeping this in safe Rust.
    let mut triples = vec![0f64; d_out * 3];
    par::parallel_rows_mut(&mut triples, d_out, 3, 4, |row0, chunk| {
        let mut sign_row = vec![0f32; d_in];
        for (rloc, tri) in chunk.chunks_mut(3).enumerate() {
            let j = row0 + rloc;
            mask.unpack_row(j, &mut sign_row);
            let (mut aj, mut bj, mut rrj) = (0f64, 0f64, 0f64);
            for t in 0..n {
                let u = dot(x.row(t), &sign_row) as f64;
                let rv = r.at(t, j) as f64;
                aj += u * u;
                bj += rv * u;
                rrj += rv * rv;
            }
            tri[0] = aj;
            tri[1] = bj;
            tri[2] = rrj;
        }
    });
    let a = (0..d_out).map(|j| triples[j * 3]).collect();
    let b = (0..d_out).map(|j| triples[j * 3 + 1]).collect();
    let rr = (0..d_out).map(|j| triples[j * 3 + 2]).collect();
    RowStats { a, b, rr, n_elems: (n * d_out) as f64 }
}

/// Build col statistics from the cache.
pub fn col_stats(x: &Tensor2, r: &Tensor2, mask: &PackedMask) -> ColStats {
    let d_out = mask.d_out;
    let d_in = mask.d_in;
    // G = (XᵀX) ⊙ (BᵀB); BᵀB via dense unpack (transient).
    let xtx = x.gram(); // [d_in, d_in]
    let dense_b = Tensor2::from_vec(d_out, d_in, mask.unpack());
    let btb = dense_b.gram(); // [d_in, d_in]
    let mut g = Tensor2::zeros(d_in, d_in);
    for idx in 0..d_in * d_in {
        g.data[idx] = xtx.data[idx] * btb.data[idx];
    }
    // c_i = Σ_j B[j,i] (XᵀR)[i,j]; XᵀR is [d_in, d_out].
    let xtr = x.transpose().matmul(r);
    let mut c = vec![0f64; d_in];
    for i in 0..d_in {
        let mut acc = 0f64;
        for j in 0..d_out {
            acc += (mask.sign(j, i) * xtr.at(i, j)) as f64;
        }
        c[i] = acc;
    }
    ColStats { g, c, rr_total: r.frob_sq(), n_elems: (x.rows * d_out) as f64 }
}

// ---------------------------------------------------------------------------
// Objective evaluation
// ---------------------------------------------------------------------------

/// Layer MSE for row-family axes (Row/Scalar/Group) given row stats.
pub fn mse_rowfam(stats: &RowStats, axis: Axis, scales: &[f32]) -> f64 {
    let d_out = stats.a.len();
    let mut total = 0f64;
    for j in 0..d_out {
        let v = scale_for_row(axis, scales, j) as f64;
        total += stats.rr[j] - 2.0 * v * stats.b[j] + v * v * stats.a[j];
    }
    total / stats.n_elems
}

/// Layer MSE for col axis given col stats.
pub fn mse_col(stats: &ColStats, v: &[f32]) -> f64 {
    let d_in = v.len();
    let mut quad = 0f64;
    for i in 0..d_in {
        let gi = stats.g.row(i);
        let mut gv = 0f64;
        for (k, &g) in gi.iter().enumerate() {
            gv += g as f64 * v[k] as f64;
        }
        quad += v[i] as f64 * gv;
    }
    let lin: f64 = v.iter().zip(&stats.c).map(|(&vi, &ci)| vi as f64 * ci).sum();
    (stats.rr_total - 2.0 * lin + quad) / stats.n_elems
}

#[inline]
fn scale_for_row(axis: Axis, scales: &[f32], j: usize) -> f32 {
    match axis {
        Axis::Row => scales[j],
        Axis::Scalar => scales[0],
        Axis::Group(g) => scales[j / g.max(1) as usize],
        Axis::Col => unreachable!("col handled separately"),
    }
}

// ---------------------------------------------------------------------------
// Closed-form solutions (extension; the quadratic objective has an exact
// minimizer)
// ---------------------------------------------------------------------------

/// Row family closed form: per-row `v_j = b_j / a_j`; Scalar/Group pool the
/// statistics over the shared rows.
pub fn closed_form_rowfam(stats: &RowStats, axis: Axis) -> Vec<f32> {
    let d_out = stats.a.len();
    match axis {
        Axis::Row => (0..d_out)
            .map(|j| if stats.a[j] > 0.0 { (stats.b[j] / stats.a[j]) as f32 } else { 0.0 })
            .collect(),
        Axis::Scalar => {
            let a: f64 = stats.a.iter().sum();
            let b: f64 = stats.b.iter().sum();
            vec![if a > 0.0 { (b / a) as f32 } else { 0.0 }]
        }
        Axis::Group(g) => {
            let g = g.max(1) as usize;
            (0..d_out.div_ceil(g))
                .map(|grp| {
                    let j0 = grp * g;
                    let j1 = (j0 + g).min(d_out);
                    let a: f64 = stats.a[j0..j1].iter().sum();
                    let b: f64 = stats.b[j0..j1].iter().sum();
                    if a > 0.0 {
                        (b / a) as f32
                    } else {
                        0.0
                    }
                })
                .collect()
        }
        Axis::Col => unreachable!(),
    }
}

/// Col closed form: solve `(G + ridge·diag(G)) v = c`.
pub fn closed_form_col(stats: &ColStats, ridge: f32) -> Vec<f32> {
    let d_in = stats.c.len();
    let mut g = stats.g.clone();
    // Relative ridge keeps conditioning scale-free.
    let mean_diag =
        (0..d_in).map(|i| g.at(i, i) as f64).sum::<f64>() / d_in as f64;
    let eps = (ridge as f64 * mean_diag).max(1e-12) as f32;
    for i in 0..d_in {
        *g.at_mut(i, i) += eps;
    }
    let c32: Vec<f32> = stats.c.iter().map(|&x| x as f32).collect();
    cholesky_solve(&g, &c32).unwrap_or_else(|| vec![0.0; d_in])
}

// ---------------------------------------------------------------------------
// AdamW (paper-faithful training path, Alg. 4)
// ---------------------------------------------------------------------------

/// Minimal AdamW optimizer over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl AdamW {
    pub fn new(n: usize, lr: f32) -> AdamW {
        AdamW { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
    }
}

/// Train row-family scales with AdamW on the quadratic objective.
pub fn adamw_rowfam(stats: &RowStats, axis: Axis, init: Vec<f32>, cfg: &CalibConfig) -> Vec<f32> {
    let mut v = init;
    let mut opt = AdamW::new(v.len(), cfg.lr);
    let mut grads = vec![0f32; v.len()];
    let d_out = stats.a.len();
    for _ in 0..cfg.epochs * cfg.steps_per_epoch {
        grads.iter_mut().for_each(|g| *g = 0.0);
        for j in 0..d_out {
            let idx = match axis {
                Axis::Row => j,
                Axis::Scalar => 0,
                Axis::Group(g) => j / g.max(1) as usize,
                Axis::Col => unreachable!(),
            };
            let vj = v[idx] as f64;
            grads[idx] += (2.0 * (vj * stats.a[j] - stats.b[j]) / stats.n_elems) as f32;
        }
        opt.step(&mut v, &grads);
    }
    v
}

/// Train col scales with AdamW: grad = 2(Gv − c)/N.
pub fn adamw_col(stats: &ColStats, init: Vec<f32>, cfg: &CalibConfig) -> Vec<f32> {
    let mut v = init;
    let d_in = v.len();
    let mut opt = AdamW::new(d_in, cfg.lr);
    let mut grads = vec![0f32; d_in];
    for _ in 0..cfg.epochs * cfg.steps_per_epoch {
        for i in 0..d_in {
            let gi = stats.g.row(i);
            let mut gv = 0f64;
            for (k, &g) in gi.iter().enumerate() {
                gv += g as f64 * v[k] as f64;
            }
            grads[i] = (2.0 * (gv - stats.c[i]) / stats.n_elems) as f32;
        }
        opt.step(&mut v, &grads);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Build a synthetic module whose delta truly is `v* ⊙ B` for a known
    /// ground-truth v*, plus noise. Calibration must recover v*.
    struct Fixture {
        x: Tensor2,
        r: Tensor2,
        mask: PackedMask,
        truth_row: Vec<f32>,
    }

    fn fixture(n: usize, d_out: usize, d_in: usize, noise: f32, seed: u64) -> Fixture {
        let mut rng = Rng::new(seed);
        let mut x = Tensor2::zeros(n, d_in);
        rng.fill_normal(&mut x.data, 1.0);
        // Random sign pattern and positive ground-truth row scales.
        let signs: Vec<f32> =
            (0..d_out * d_in).map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 }).collect();
        let mask = PackedMask::pack(&signs, d_out, d_in);
        let truth_row: Vec<f32> = (0..d_out).map(|_| rng.uniform_in(0.02, 0.3)).collect();
        // R = X · (v ⊙ B)ᵀ + noise
        let mut delta = vec![0f32; d_out * d_in];
        for j in 0..d_out {
            for i in 0..d_in {
                delta[j * d_in + i] = truth_row[j] * signs[j * d_in + i];
            }
        }
        let dt = Tensor2::from_vec(d_out, d_in, delta);
        let mut r = x.matmul_bt(&dt);
        for v in &mut r.data {
            *v += rng.normal_f32(0.0, noise);
        }
        Fixture { x, r, mask, truth_row }
    }

    #[test]
    fn closed_form_row_recovers_truth() {
        let f = fixture(256, 12, 24, 0.01, 1);
        let stats = row_stats(&f.x, &f.r, &f.mask);
        let v = closed_form_rowfam(&stats, Axis::Row);
        for (got, want) in v.iter().zip(&f.truth_row) {
            assert!((got - want).abs() < 0.01, "{got} vs {want}");
        }
    }

    #[test]
    fn closed_form_is_global_minimum() {
        let f = fixture(128, 8, 16, 0.05, 2);
        let stats = row_stats(&f.x, &f.r, &f.mask);
        let v_star = closed_form_rowfam(&stats, Axis::Row);
        let best = mse_rowfam(&stats, Axis::Row, &v_star);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let perturbed: Vec<f32> =
                v_star.iter().map(|&v| v + rng.normal_f32(0.0, 0.05)).collect();
            assert!(mse_rowfam(&stats, Axis::Row, &perturbed) >= best - 1e-9);
        }
    }

    #[test]
    fn col_mode_recovers_col_structured_delta() {
        let mut rng = Rng::new(4);
        let (n, d_out, d_in) = (256, 16, 12);
        let mut x = Tensor2::zeros(n, d_in);
        rng.fill_normal(&mut x.data, 1.0);
        let signs: Vec<f32> =
            (0..d_out * d_in).map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 }).collect();
        let mask = PackedMask::pack(&signs, d_out, d_in);
        let truth_col: Vec<f32> = (0..d_in).map(|_| rng.uniform_in(0.02, 0.3)).collect();
        let mut delta = vec![0f32; d_out * d_in];
        for j in 0..d_out {
            for i in 0..d_in {
                delta[j * d_in + i] = truth_col[i] * signs[j * d_in + i];
            }
        }
        let dt = Tensor2::from_vec(d_out, d_in, delta);
        let r = x.matmul_bt(&dt);
        let stats = col_stats(&x, &r, &mask);
        let v = closed_form_col(&stats, 1e-6);
        for (got, want) in v.iter().zip(&truth_col) {
            assert!((got - want).abs() < 0.01, "{got} vs {want}");
        }
        // And the col MSE at the solution is near zero.
        assert!(mse_col(&stats, &v) < 1e-6);
    }

    #[test]
    fn adamw_approaches_closed_form() {
        let f = fixture(128, 10, 20, 0.02, 5);
        let stats = row_stats(&f.x, &f.r, &f.mask);
        let exact = closed_form_rowfam(&stats, Axis::Row);
        let init = vec![0.1f32; 10];
        // Generous budget so the optimizer converges in the test.
        let cfg = CalibConfig { lr: 5e-3, epochs: 200, steps_per_epoch: 10, ..Default::default() };
        let trained = adamw_rowfam(&stats, Axis::Row, init, &cfg);
        let m_exact = mse_rowfam(&stats, Axis::Row, &exact);
        let m_train = mse_rowfam(&stats, Axis::Row, &trained);
        assert!(m_train <= m_exact * 1.5 + 1e-8, "train {m_train} vs exact {m_exact}");
    }

    #[test]
    fn scalar_fit_is_worse_than_row_on_anisotropic_delta() {
        // The paper's core claim: per-axis beats scalar when ΔW scales vary
        // across rows.
        let f = fixture(256, 16, 24, 0.01, 6);
        let stats = row_stats(&f.x, &f.r, &f.mask);
        let row = closed_form_rowfam(&stats, Axis::Row);
        let scalar = closed_form_rowfam(&stats, Axis::Scalar);
        let m_row = mse_rowfam(&stats, Axis::Row, &row);
        let m_scalar = mse_rowfam(&stats, Axis::Scalar, &scalar);
        assert!(
            m_row < m_scalar * 0.8,
            "row {m_row} should clearly beat scalar {m_scalar} on anisotropic delta"
        );
    }

    #[test]
    fn group_interpolates_between_row_and_scalar() {
        let f = fixture(256, 16, 24, 0.01, 7);
        let stats = row_stats(&f.x, &f.r, &f.mask);
        let m_row = mse_rowfam(&stats, Axis::Row, &closed_form_rowfam(&stats, Axis::Row));
        let m_g4 =
            mse_rowfam(&stats, Axis::Group(4), &closed_form_rowfam(&stats, Axis::Group(4)));
        let m_scalar =
            mse_rowfam(&stats, Axis::Scalar, &closed_form_rowfam(&stats, Axis::Scalar));
        assert!(m_row <= m_g4 + 1e-9);
        assert!(m_g4 <= m_scalar + 1e-9);
    }

    #[test]
    fn init_scales_mean_abs() {
        let delta = vec![1.0f32, -3.0, 2.0, -2.0]; // 2x2
        assert_eq!(init_scales(&delta, 2, 2, Axis::Row), vec![2.0, 2.0]);
        assert_eq!(init_scales(&delta, 2, 2, Axis::Col), vec![1.5, 2.5]);
        assert_eq!(init_scales(&delta, 2, 2, Axis::Scalar), vec![2.0]);
        assert_eq!(init_scales(&delta, 2, 2, Axis::Group(2)), vec![2.0]);
    }
}
