//! Delta-structure statistics: anisotropy measurements backing the paper's
//! §4 limitation discussion ("gains rely on the anisotropy of the
//! task-induced deltas across rows/columns") and our ablation A1.

/// Per-module delta statistics.
#[derive(Clone, Debug)]
pub struct DeltaStats {
    pub d_out: usize,
    pub d_in: usize,
    /// Frobenius norm of ΔW.
    pub delta_norm: f64,
    /// ‖ΔW‖ / ‖W_b‖ — how far the fine-tune moved.
    pub relative_norm: f64,
    /// Coefficient of variation of per-row mean |ΔW| (row anisotropy).
    pub row_cv: f64,
    /// Coefficient of variation of per-column mean |ΔW| (col anisotropy).
    pub col_cv: f64,
}

pub fn delta_stats(w_base: &[f32], w_ft: &[f32], d_out: usize, d_in: usize) -> DeltaStats {
    assert_eq!(w_base.len(), d_out * d_in);
    assert_eq!(w_ft.len(), d_out * d_in);
    let mut row_mean = vec![0f64; d_out];
    let mut col_mean = vec![0f64; d_in];
    let mut dsq = 0f64;
    let mut bsq = 0f64;
    for j in 0..d_out {
        for i in 0..d_in {
            let idx = j * d_in + i;
            let d = (w_ft[idx] - w_base[idx]) as f64;
            let ad = d.abs();
            row_mean[j] += ad;
            col_mean[i] += ad;
            dsq += d * d;
            bsq += (w_base[idx] as f64) * (w_base[idx] as f64);
        }
    }
    for r in &mut row_mean {
        *r /= d_in as f64;
    }
    for c in &mut col_mean {
        *c /= d_out as f64;
    }
    DeltaStats {
        d_out,
        d_in,
        delta_norm: dsq.sqrt(),
        relative_norm: if bsq > 0.0 { (dsq / bsq).sqrt() } else { 0.0 },
        row_cv: cv(&row_mean),
        col_cv: cv(&col_mean),
    }
}

/// Coefficient of variation (std / mean).
fn cv(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if mean <= 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn isotropic_delta_has_low_cv() {
        let mut rng = Rng::new(1);
        let (d_out, d_in) = (32, 48);
        let base = vec![0f32; d_out * d_in];
        let ft: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let s = delta_stats(&base, &ft, d_out, d_in);
        assert!(s.row_cv < 0.3, "row_cv={}", s.row_cv);
        assert!(s.col_cv < 0.3, "col_cv={}", s.col_cv);
    }

    #[test]
    fn row_scaled_delta_has_high_row_cv() {
        let mut rng = Rng::new(2);
        let (d_out, d_in) = (32, 48);
        let base = vec![0f32; d_out * d_in];
        let mut ft = vec![0f32; d_out * d_in];
        for j in 0..d_out {
            let scale = (rng.normal_f32(0.0, 1.5)).exp();
            for i in 0..d_in {
                ft[j * d_in + i] = 0.05 * scale * rng.normal_f32(0.0, 1.0);
            }
        }
        let s = delta_stats(&base, &ft, d_out, d_in);
        assert!(s.row_cv > 0.8, "row_cv={}", s.row_cv);
        assert!(s.row_cv > s.col_cv * 2.0, "row {} col {}", s.row_cv, s.col_cv);
    }

    #[test]
    fn relative_norm_zero_for_identical() {
        let base = vec![1f32; 16];
        let s = delta_stats(&base, &base, 4, 4);
        assert_eq!(s.delta_norm, 0.0);
        assert_eq!(s.relative_norm, 0.0);
    }
}
