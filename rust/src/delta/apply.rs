//! Delta application — the serving hot path.
//!
//! Materializes `Ŵ = W_b + v ⊙ B` for one module or a whole model. This is
//! the Rust-native counterpart of the L1 Pallas `delta_apply` kernel (the
//! runtime path exists for validation and the fused on-the-fly mode; hot
//! swaps in the coordinator's *dense* exec mode use this native path —
//! fused mode never materializes and executes the packed delta through
//! [`crate::exec::FusedDeltaLinear`] instead).
//!
//! Performance notes (see EXPERIMENTS.md §Perf):
//! * word-at-a-time bit expansion, branchless sign via IEEE bit tricks
//!   (`±1.0` differ only in the sign bit);
//! * one pass: read base, add signed scale, write out — the same traffic as
//!   a memcpy plus one add, so the roofline is memory bandwidth;
//! * row-parallel across threads for large modules.

use super::types::{Axis, DeltaModule};
use crate::model::FlatParams;
use crate::util::par;

/// `out[j,i] = base[j,i] + scale(j,i) * sign(j,i)` for one module, plus the
/// low-rank residual `B·A` for modules under that codec.
pub fn apply_module_into(base: &[f32], out: &mut [f32], m: &DeltaModule) {
    let (d_out, d_in) = (m.d_out(), m.d_in());
    assert_eq!(base.len(), d_out * d_in);
    assert_eq!(out.len(), d_out * d_in);
    match m.axis {
        Axis::Col => {
            let scales = &m.scales;
            par::parallel_rows_mut(out, d_out, d_in, 16, |row0, chunk| {
                for (r, orow) in chunk.chunks_mut(d_in).enumerate() {
                    let j = row0 + r;
                    apply_row_col(&base[j * d_in..(j + 1) * d_in], orow, m.mask.row_words(j), scales);
                }
            });
        }
        _ => {
            // Row / Scalar / Group: constant scale within each row.
            par::parallel_rows_mut(out, d_out, d_in, 16, |row0, chunk| {
                for (r, orow) in chunk.chunks_mut(d_in).enumerate() {
                    let j = row0 + r;
                    let v = row_scale(m, j);
                    apply_row_const(&base[j * d_in..(j + 1) * d_in], orow, m.mask.row_words(j), v);
                }
            });
        }
    }
    add_lowrank_dense(out, m, 1.0);
}

/// In-place variant: `w += v ⊙ B` (pass `negate=true` to subtract, i.e.
/// revert a previously applied delta during an in-place variant swap).
pub fn apply_module_inplace(w: &mut [f32], m: &DeltaModule, negate: bool) {
    let (d_out, d_in) = (m.d_out(), m.d_in());
    assert_eq!(w.len(), d_out * d_in);
    let sgn = if negate { -1.0f32 } else { 1.0 };
    match m.axis {
        Axis::Col => {
            // Negation is a sign flip on every entry, and the sign already
            // comes from the mask bit — so revert just XORs every mask word
            // with all-ones instead of cloning the whole scales vector.
            let flip: u32 = if negate { u32::MAX } else { 0 };
            par::parallel_rows_mut(w, d_out, d_in, 16, |row0, chunk| {
                for (r, wrow) in chunk.chunks_mut(d_in).enumerate() {
                    let j = row0 + r;
                    add_row_col(wrow, m.mask.row_words(j), &m.scales, flip);
                }
            });
        }
        _ => {
            par::parallel_rows_mut(w, d_out, d_in, 16, |row0, chunk| {
                for (r, wrow) in chunk.chunks_mut(d_in).enumerate() {
                    let j = row0 + r;
                    let v = row_scale(m, j) * sgn;
                    add_row_const(wrow, m.mask.row_words(j), v);
                }
            });
        }
    }
    add_lowrank_dense(w, m, sgn);
}

/// Accumulate `sgn · (B·A)` — the low-rank residual of `m`, if any — onto a
/// dense `[d_out, d_in]` buffer. Row-parallel like the bitplane passes; the
/// rank-k outer products stream `A` row-by-row so the product matrix never
/// materializes separately.
fn add_lowrank_dense(w: &mut [f32], m: &DeltaModule, sgn: f32) {
    let Some(lr) = m.lowrank() else { return };
    let (d_in, rank) = (m.d_in(), lr.rank);
    par::parallel_rows_mut(w, m.d_out(), d_in, 16, |row0, chunk| {
        for (r, wrow) in chunk.chunks_mut(d_in).enumerate() {
            let j = row0 + r;
            for (k, &bk) in lr.b[j * rank..(j + 1) * rank].iter().enumerate() {
                let s = sgn * bk;
                for (wi, &ai) in wrow.iter_mut().zip(&lr.a[k * d_in..(k + 1) * d_in]) {
                    *wi += s * ai;
                }
            }
        }
    });
}

#[inline]
fn row_scale(m: &DeltaModule, j: usize) -> f32 {
    match m.axis {
        Axis::Row => m.scales[j],
        Axis::Scalar => m.scales[0],
        Axis::Group(g) => m.scales[j / g.max(1) as usize],
        Axis::Col => unreachable!(),
    }
}

/// Branchless signed scale from a mask bit: bit=1 -> +v, bit=0 -> -v.
/// `±v` differ only in the IEEE sign bit.
#[inline(always)]
fn signed(v: f32, bit: u32) -> f32 {
    f32::from_bits(v.to_bits() ^ ((bit ^ 1) << 31))
}

// Perf note (EXPERIMENTS.md §Perf): the original single loop used a
// variable bound `min(32, remaining)` per word, which blocked LLVM's
// vectorizer (~9 GB/s vs 25 GB/s memcpy). Splitting full 32-bit words
// (constant-bound inner loop over fixed-size array chunks) from the single
// tail word lets the sign-injection vectorize.

#[inline]
fn apply_row_const(base: &[f32], out: &mut [f32], words: &[u32], v: f32) {
    let d_in = base.len();
    let full = d_in / 32;
    let vb = v.to_bits();
    // Full words: constant 32-wide inner loop over array chunks.
    for wi in 0..full {
        let w = words[wi];
        let b32: &[f32; 32] = base[wi * 32..wi * 32 + 32].try_into().unwrap();
        let o32: &mut [f32; 32] = (&mut out[wi * 32..wi * 32 + 32]).try_into().unwrap();
        for b in 0..32 {
            o32[b] = b32[b] + f32::from_bits(vb ^ ((((w >> b) & 1) ^ 1) << 31));
        }
    }
    // Tail word.
    for b in 0..d_in - full * 32 {
        let i = full * 32 + b;
        out[i] = base[i] + signed(v, (words[full] >> b) & 1);
    }
}

#[inline]
fn apply_row_col(base: &[f32], out: &mut [f32], words: &[u32], scales: &[f32]) {
    let d_in = base.len();
    let full = d_in / 32;
    for wi in 0..full {
        let w = words[wi];
        let b32: &[f32; 32] = base[wi * 32..wi * 32 + 32].try_into().unwrap();
        let s32: &[f32; 32] = scales[wi * 32..wi * 32 + 32].try_into().unwrap();
        let o32: &mut [f32; 32] = (&mut out[wi * 32..wi * 32 + 32]).try_into().unwrap();
        for b in 0..32 {
            o32[b] = b32[b] + f32::from_bits(s32[b].to_bits() ^ ((((w >> b) & 1) ^ 1) << 31));
        }
    }
    for b in 0..d_in - full * 32 {
        let i = full * 32 + b;
        out[i] = base[i] + signed(scales[i], (words[full] >> b) & 1);
    }
}

#[inline]
fn add_row_const(wrow: &mut [f32], words: &[u32], v: f32) {
    let d_in = wrow.len();
    let full = d_in / 32;
    let vb = v.to_bits();
    for wi in 0..full {
        let w = words[wi];
        let o32: &mut [f32; 32] = (&mut wrow[wi * 32..wi * 32 + 32]).try_into().unwrap();
        for b in 0..32 {
            o32[b] += f32::from_bits(vb ^ ((((w >> b) & 1) ^ 1) << 31));
        }
    }
    for b in 0..d_in - full * 32 {
        let i = full * 32 + b;
        wrow[i] += signed(v, (words[full] >> b) & 1);
    }
}

/// `flip == u32::MAX` inverts every mask bit, turning the add into the
/// exact bitwise negation (used by the in-place revert path).
#[inline]
fn add_row_col(wrow: &mut [f32], words: &[u32], scales: &[f32], flip: u32) {
    let d_in = wrow.len();
    let full = d_in / 32;
    for wi in 0..full {
        let w = words[wi] ^ flip;
        let s32: &[f32; 32] = scales[wi * 32..wi * 32 + 32].try_into().unwrap();
        let o32: &mut [f32; 32] = (&mut wrow[wi * 32..wi * 32 + 32]).try_into().unwrap();
        for b in 0..32 {
            o32[b] += f32::from_bits(s32[b].to_bits() ^ ((((w >> b) & 1) ^ 1) << 31));
        }
    }
    let rem = d_in - full * 32;
    if rem > 0 {
        let tail = words[full] ^ flip;
        for b in 0..rem {
            let i = full * 32 + b;
            wrow[i] += signed(scales[i], (tail >> b) & 1);
        }
    }
}

/// Apply a list of module deltas onto base params *in place* (the hot-swap
/// loader path: one apply per module, paper §1 "single operation per
/// module"). Generic over the module holder so both plain slices and the
/// `Arc<DeltaModule>` slices a [`DeltaModel`](super::DeltaModel) carries
/// apply without cloning.
pub fn apply_deltas_inplace<M: std::borrow::Borrow<DeltaModule>>(
    params: &mut FlatParams,
    modules: &[M],
) {
    for m in modules {
        let m = m.borrow();
        let (rows, cols) = m.id.kind.shape(params.cfg());
        assert_eq!((rows, cols), (m.d_out(), m.d_in()), "delta/module shape mismatch for {}", m.id);
        apply_module_inplace(params.module_mut(m.id), m, false);
    }
}

/// Revert previously applied deltas (in-place variant swap without
/// re-reading the base checkpoint).
pub fn revert_deltas_inplace<M: std::borrow::Borrow<DeltaModule>>(
    params: &mut FlatParams,
    modules: &[M],
) {
    for m in modules {
        let m = m.borrow();
        apply_module_inplace(params.module_mut(m.id), m, true);
    }
}

/// Materialize a fine-tuned variant: clone base then apply (the cache-fill
/// path; the clone is the unavoidable cost of keeping the base pristine).
pub fn materialize<M: std::borrow::Borrow<DeltaModule>>(
    base: &FlatParams,
    modules: &[M],
) -> FlatParams {
    let mut out = base.clone();
    apply_deltas_inplace(&mut out, modules);
    out
}

/// Reference (scalar, unoptimized) apply used by tests to validate the
/// optimized path.
pub fn apply_module_reference(base: &[f32], m: &DeltaModule) -> Vec<f32> {
    let (d_out, d_in) = (m.d_out(), m.d_in());
    let mut out = vec![0f32; d_out * d_in];
    for j in 0..d_out {
        for i in 0..d_in {
            out[j * d_in + i] = base[j * d_in + i] + m.scale_at(j, i) * m.mask.sign(j, i);
        }
    }
    if let Some(lr) = m.lowrank() {
        // Same accumulation order as `add_lowrank_dense` (one += per rank
        // component) so optimized-vs-reference stays bitwise.
        for j in 0..d_out {
            for k in 0..lr.rank {
                let s = lr.b[j * lr.rank + k];
                for i in 0..d_in {
                    out[j * d_in + i] += s * lr.a[k * d_in + i];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::pack::PackedMask;
    use crate::delta::types::{Codec, LowRank};
    use crate::model::{ModuleId, ProjKind};
    use crate::util::rng::Rng;

    fn mk_module(d_out: usize, d_in: usize, axis: Axis, seed: u64) -> (Vec<f32>, DeltaModule) {
        let mut r = Rng::new(seed);
        let base: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let delta: Vec<f32> = (0..d_out * d_in).map(|_| r.normal_f32(0.0, 0.1)).collect();
        let mask = PackedMask::pack(&delta, d_out, d_in);
        let n = axis.n_scales(d_out, d_in);
        let scales: Vec<f32> = (0..n).map(|_| r.uniform_in(0.01, 0.2)).collect();
        (
            base,
            DeltaModule {
                id: ModuleId { layer: 0, kind: ProjKind::Q },
                mask,
                axis,
                scales,
                codec: Codec::PerAxis,
            },
        )
    }

    #[test]
    fn optimized_matches_reference_all_axes() {
        for (k, axis) in
            [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(3)].into_iter().enumerate()
        {
            for &(d_out, d_in) in &[(1, 1), (5, 33), (8, 32), (17, 100)] {
                let (base, m) = mk_module(d_out, d_in, axis, k as u64 * 10 + d_in as u64);
                let want = apply_module_reference(&base, &m);
                let mut got = vec![0f32; base.len()];
                apply_module_into(&base, &mut got, &m);
                assert_eq!(got, want, "axis {axis:?} shape {d_out}x{d_in}");
            }
        }
    }

    fn mk_lowrank(d_out: usize, d_in: usize, rank: usize, seed: u64) -> (Vec<f32>, DeltaModule) {
        let (base, mut m) = mk_module(d_out, d_in, Axis::Row, seed);
        let mut r = Rng::new(seed ^ 0x10);
        let a: Vec<f32> = (0..rank * d_in).map(|_| r.normal_f32(0.0, 0.05)).collect();
        let b: Vec<f32> = (0..d_out * rank).map(|_| r.normal_f32(0.0, 0.05)).collect();
        m.codec = Codec::LowRank(LowRank { rank, a, b });
        (base, m)
    }

    #[test]
    fn lowrank_optimized_matches_reference_bitwise() {
        for &(d_out, d_in, rank) in &[(1, 1, 1), (5, 33, 2), (8, 32, 3), (17, 100, 4)] {
            let (base, m) = mk_lowrank(d_out, d_in, rank, 41 + d_in as u64);
            let want = apply_module_reference(&base, &m);
            let mut got = vec![0f32; base.len()];
            apply_module_into(&base, &mut got, &m);
            assert_eq!(got, want, "lowrank rank {rank} shape {d_out}x{d_in}");
        }
    }

    #[test]
    fn lowrank_inplace_apply_then_revert_is_identity() {
        let (base, m) = mk_lowrank(13, 47, 3, 7);
        let mut w = base.clone();
        apply_module_inplace(&mut w, &m, false);
        assert_ne!(w, base);
        apply_module_inplace(&mut w, &m, true);
        for (a, b) in w.iter().zip(&base) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn inplace_apply_then_revert_is_identity() {
        for axis in [Axis::Row, Axis::Col, Axis::Scalar, Axis::Group(4)] {
            let (base, m) = mk_module(13, 47, axis, 99);
            let mut w = base.clone();
            apply_module_inplace(&mut w, &m, false);
            assert_ne!(w, base);
            apply_module_inplace(&mut w, &m, true);
            for (a, b) in w.iter().zip(&base) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn signed_bit_trick() {
        assert_eq!(signed(2.5, 1), 2.5);
        assert_eq!(signed(2.5, 0), -2.5);
        assert_eq!(signed(-2.5, 1), -2.5); // sign of v composes with the bit
        assert_eq!(signed(0.0, 0), -0.0);
    }

    #[test]
    fn materialize_respects_base() {
        use crate::model::config::ModelConfig;
        let cfg = ModelConfig::preset("tiny").unwrap();
        let base = FlatParams::init(&cfg, 5);
        let ids = base.layout.patchable_modules();
        let mut modules = Vec::new();
        for (i, &id) in ids.iter().take(3).enumerate() {
            let (rows, cols) = id.kind.shape(&cfg);
            let mut r = Rng::new(i as u64);
            let delta: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
            modules.push(DeltaModule {
                id,
                mask: PackedMask::pack(&delta, rows, cols),
                axis: Axis::Row,
                scales: vec![0.05; rows],
                codec: Codec::PerAxis,
            });
        }
        let v = materialize(&base, &modules);
        // Touched modules differ, untouched identical.
        for (i, &id) in ids.iter().enumerate() {
            if i < 3 {
                assert_ne!(base.module(id), v.module(id));
            } else {
                assert_eq!(base.module(id), v.module(id));
            }
        }
        // Revert returns to base.
        let mut v2 = v.clone();
        revert_deltas_inplace(&mut v2, &modules);
        for (a, b) in v2.data.iter().zip(&base.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
