//! # pawd — Per-Axis Weight Deltas for Frequent Model Updates
//!
//! Production-style reproduction of *"Per-Axis Weight Deltas for Frequent
//! Model Updates"* (NeurIPS 2025 CCFM workshop): a 1-bit delta compression
//! scheme for fine-tuned checkpoints (`Ŵ = v ⊙ sign(W_f − W_b) + W_b` with
//! learned per-row/column FP16 scales) integrated into a multi-variant
//! serving coordinator.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — serving coordinator (router, dynamic batcher,
//!   variant cache, hot-swap loader) plus the full delta compression
//!   library and all substrates (tensor math, transformer, synthetic data,
//!   eval harness).
//!
//! Within L3, the [`exec`] layer abstracts how projections execute: every
//! forward pass routes through a [`exec::LinearOp`], either
//! [`exec::DenseLinear`] (materialized weights) or [`exec::FusedDeltaLinear`]
//! (base + packed 1-bit delta, executed in place via word-at-a-time signed
//! accumulation — dense `Ŵ` is never reconstructed). The variant cache holds
//! one shared base plus per-variant *packed* artifacts, so its byte budget
//! is charged in packed bytes and hot-swapping a variant is a pointer flip.
//! The [`net`] plane exposes the coordinator over dependency-free HTTP/1.1
//! — data/admin JSON routes plus a long-poll replication transport — so
//! followers on other hosts can track a leader's publishes.
//! * **L2 (python/compile)** — JAX transformer fwd / fused-AdamW train step
//!   / logit-matching grad, AOT-lowered to HLO text once at build time.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the packed-sign
//!   delta apply and the fused delta-GEMM, lowered into the same HLO.
//!
//! Python never runs at serving time: `rust/src/runtime` loads the HLO
//! artifacts through the PJRT CPU client (`xla` crate) and executes them
//! from the Rust hot path.

pub mod audit;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod delta;
pub mod eval;
pub mod exec;
pub mod model;
pub mod net;
pub mod pipeline;
pub mod runtime;
pub mod tensor;
pub mod util;
