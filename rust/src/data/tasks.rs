//! The five zero-shot multiple-choice task families (synthetic analogs of
//! the paper's ARC-Challenge, ARC-Easy, HellaSwag, PIQA, Winogrande).
//!
//! Scoring follows lm-eval-harness: each choice is appended to the prompt
//! and ranked by completion log-likelihood (see `eval::harness`). Families:
//!
//! * `AttrChain`   (ARC-C analog)    — 4-way, two-hop relational question.
//! * `AttrEasy`    (ARC-E analog)    — 4-way, single attribute lookup.
//! * `Continuation`(HellaSwag analog)— 4-way, pick the world-consistent
//!                                     story continuation.
//! * `Physical`    (PIQA analog)     — 2-way, procedural "how do you X".
//! * `Pronoun`     (Winogrande analog)— 2-way, referent resolution by a
//!                                     templated convention.

use super::world::{Fact, World, CRAFTS, PRODUCTS};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskFamily {
    AttrChain,
    AttrEasy,
    Continuation,
    Physical,
    Pronoun,
}

impl TaskFamily {
    pub const ALL: [TaskFamily; 5] = [
        TaskFamily::AttrChain,
        TaskFamily::AttrEasy,
        TaskFamily::Continuation,
        TaskFamily::Physical,
        TaskFamily::Pronoun,
    ];

    /// Paper-table column name.
    pub fn paper_name(&self) -> &'static str {
        match self {
            TaskFamily::AttrChain => "ARC-C*",
            TaskFamily::AttrEasy => "ARC-E*",
            TaskFamily::Continuation => "HellaSwag*",
            TaskFamily::Physical => "PIQA*",
            TaskFamily::Pronoun => "Winogrande*",
        }
    }

    pub fn n_choices(&self) -> usize {
        match self {
            TaskFamily::Physical | TaskFamily::Pronoun => 2,
            _ => 4,
        }
    }
}

/// One multiple-choice item. The full scored text for choice `i` is
/// `format!("{}{}", prompt, choices[i])`.
#[derive(Clone, Debug)]
pub struct McItem {
    pub family: TaskFamily,
    pub prompt: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

/// Procedural rule base for the Physical family: (goal, correct, distractor).
pub const PHYSICAL_RULES: [(&str, &str, &str); 12] = [
    ("open the jar", "twist the lid", "shake the jar"),
    ("light the lamp", "strike a match", "pour out the oil"),
    ("cross the river", "row the boat", "drop the oars"),
    ("warm the bread", "heat the oven", "open the window"),
    ("sharpen the knife", "use the whetstone", "dip it in water"),
    ("dry the cloth", "hang it in the sun", "fold it in a box"),
    ("quiet the drum", "rest the sticks", "hit it harder"),
    ("fill the jug", "pour from the well", "tip it over"),
    ("mend the net", "knot the torn cord", "cut more holes"),
    ("cool the tea", "let it stand", "add more fire"),
    ("raise the kite", "run against the wind", "wet the string"),
    ("seal the letter", "press the wax", "tear the page"),
];

/// Adjective conventions for the Pronoun family: these adjectives describe
/// the *giver* (first entity)...
pub const GIVER_ADJS: [&str; 3] = ["kind", "generous", "gentle"];
/// ...and these the *receiver* (second entity).
pub const RECEIVER_ADJS: [&str; 3] = ["glad", "lucky", "grateful"];

/// Generate evaluation items for a family (held-out facts / instances only).
pub fn eval_items(world: &World, family: TaskFamily, n: usize, seed: u64) -> Vec<McItem> {
    let mut rng = Rng::new(seed ^ family_salt(family));
    let mut out = Vec::with_capacity(n);
    let mut guard = 0usize;
    while out.len() < n && guard < n * 200 {
        guard += 1;
        if let Some(item) = gen_item(world, family, &mut rng, false) {
            out.push(item);
        }
    }
    out
}

/// Generate training Q/A strings for the instruct fine-tuning mixture (the
/// train split of each family, rendered as prompt+answer text).
pub fn train_texts(world: &World, family: TaskFamily, n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed ^ family_salt(family) ^ 0x7121);
    let mut out = Vec::with_capacity(n);
    let mut guard = 0usize;
    while out.len() < n && guard < n * 200 {
        guard += 1;
        if let Some(item) = gen_item(world, family, &mut rng, true) {
            out.push(format!("{}{}", item.prompt, item.choices[item.correct]));
        }
    }
    out
}

fn family_salt(f: TaskFamily) -> u64 {
    match f {
        TaskFamily::AttrChain => 0xA11C,
        TaskFamily::AttrEasy => 0xA11E,
        TaskFamily::Continuation => 0xC047,
        TaskFamily::Physical => 0xF151,
        TaskFamily::Pronoun => 0x9409,
    }
}

/// Instance-level train/eval split shared by all families.
fn is_train_instance(key: u64) -> bool {
    let h = key.wrapping_mul(0xD6E8FEB86659FD93);
    (h >> 33) % 10 < 7
}

fn gen_item(world: &World, family: TaskFamily, rng: &mut Rng, train: bool) -> Option<McItem> {
    match family {
        TaskFamily::AttrEasy => {
            let e = rng.below(world.n());
            let fact = match rng.below(4) {
                0 => Fact::Color(e),
                1 => Fact::Place(e),
                2 => Fact::Craft(e),
                _ => Fact::Owns(e),
            };
            if world.is_train_fact(fact) != train {
                return None;
            }
            let (q, a) = world.render_qa(fact);
            let mut choices = world.distractors(fact, 3, rng);
            let correct = rng.below(4);
            choices.insert(correct, a);
            Some(McItem { family, prompt: format!("{q} A: "), choices, correct })
        }
        TaskFamily::AttrChain => {
            // Two-hop: attribute of the entity that e likes.
            let e = rng.below(world.n());
            let friend = world.likes[e];
            let fact = match rng.below(3) {
                0 => Fact::Color(friend),
                1 => Fact::Place(friend),
                _ => Fact::Craft(friend),
            };
            // The item is train iff BOTH hops are in the train split.
            let hop_train = world.is_train_fact(Fact::Likes(e)) && world.is_train_fact(fact);
            if hop_train != train {
                return None;
            }
            let (attr_word, answer) = match fact {
                Fact::Color(f) => ("color", super::world::COLORS[world.color[f]].to_string()),
                Fact::Place(f) => ("home", super::world::PLACES[world.place[f]].to_string()),
                Fact::Craft(f) => ("craft", CRAFTS[world.craft[f]].to_string()),
                _ => unreachable!(),
            };
            let q = format!(
                "Q: {} likes someone. what is the {} of that friend?",
                world.entities[e], attr_word
            );
            let mut choices = world.distractors(fact, 3, rng);
            let correct = rng.below(4);
            choices.insert(correct, answer);
            Some(McItem { family, prompt: format!("{q} A: "), choices, correct })
        }
        TaskFamily::Continuation => {
            let e = rng.below(world.n());
            let craft = world.craft[e];
            if is_train_instance(e as u64 ^ 0xC0) != train {
                return None;
            }
            let name = &world.entities[e];
            let prompt = format!(
                "{} is a {}. {} started the day of work. then ",
                name, CRAFTS[craft], name
            );
            let correct_text = format!("{} made {}.", name, PRODUCTS[craft]);
            let mut choices = Vec::with_capacity(4);
            let mut used = vec![craft];
            while choices.len() < 3 {
                let c = rng.below(CRAFTS.len());
                if !used.contains(&c) {
                    used.push(c);
                    choices.push(format!("{} made {}.", name, PRODUCTS[c]));
                }
            }
            let correct = rng.below(4);
            choices.insert(correct, correct_text);
            Some(McItem { family, prompt, choices, correct })
        }
        TaskFamily::Physical => {
            let ri = rng.below(PHYSICAL_RULES.len());
            if is_train_instance(ri as u64 ^ 0xF1) != train {
                return None;
            }
            let (goal, good, bad) = PHYSICAL_RULES[ri];
            let prompt = format!("Q: to {goal}, what do you do? A: ");
            let correct = rng.below(2);
            let choices = if correct == 0 {
                vec![good.to_string(), bad.to_string()]
            } else {
                vec![bad.to_string(), good.to_string()]
            };
            Some(McItem { family, prompt, choices, correct })
        }
        TaskFamily::Pronoun => {
            let a = rng.below(world.n());
            let mut b = rng.below(world.n());
            while b == a {
                b = rng.below(world.n());
            }
            let giver_case = rng.chance(0.5);
            let adj = if giver_case {
                *rng.choice(&GIVER_ADJS)
            } else {
                *rng.choice(&RECEIVER_ADJS)
            };
            // Split on the (pair, adjective) instance.
            let key = (a as u64) << 24 | (b as u64) << 8 | adj.len() as u64;
            if is_train_instance(key) != train {
                return None;
            }
            let item_word = super::world::ITEMS[world.item[a]];
            let prompt = format!(
                "{} gave {} the {} because the {} one is ",
                world.entities[a], world.entities[b], item_word, adj
            );
            let correct_name =
                if giver_case { world.entities[a].clone() } else { world.entities[b].clone() };
            let other_name =
                if giver_case { world.entities[b].clone() } else { world.entities[a].clone() };
            let correct = rng.below(2);
            let choices = if correct == 0 {
                vec![format!("{correct_name}."), format!("{other_name}.")]
            } else {
                vec![format!("{other_name}."), format!("{correct_name}.")]
            };
            Some(McItem { family, prompt, choices, correct })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::generate(42, 40)
    }

    #[test]
    fn all_families_generate_requested_count() {
        let w = world();
        for fam in TaskFamily::ALL {
            let items = eval_items(&w, fam, 50, 1);
            assert_eq!(items.len(), 50, "{fam:?}");
            for it in &items {
                assert_eq!(it.choices.len(), fam.n_choices());
                assert!(it.correct < it.choices.len());
                assert!(!it.prompt.is_empty());
            }
        }
    }

    #[test]
    fn items_are_deterministic_per_seed() {
        let w = world();
        let a = eval_items(&w, TaskFamily::AttrEasy, 10, 7);
        let b = eval_items(&w, TaskFamily::AttrEasy, 10, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn correct_choice_position_is_uniformish() {
        let w = world();
        let items = eval_items(&w, TaskFamily::AttrEasy, 200, 3);
        let mut counts = [0usize; 4];
        for it in &items {
            counts[it.correct] += 1;
        }
        for &c in &counts {
            assert!(c > 20, "position bias: {counts:?}");
        }
    }

    #[test]
    fn train_and_eval_instances_disjoint_for_physical() {
        let w = world();
        let train: std::collections::HashSet<String> =
            train_texts(&w, TaskFamily::Physical, 50, 1).into_iter().collect();
        let eval = eval_items(&w, TaskFamily::Physical, 30, 2);
        for it in &eval {
            let full = format!("{}{}", it.prompt, it.choices[it.correct]);
            assert!(!train.contains(&full), "eval item leaked into train: {full}");
        }
    }

    #[test]
    fn train_texts_end_with_correct_answer() {
        let w = world();
        for fam in TaskFamily::ALL {
            for t in train_texts(&w, fam, 10, 5) {
                assert!(t.len() > 10);
            }
        }
    }

    #[test]
    fn choices_are_distinct() {
        let w = world();
        for fam in TaskFamily::ALL {
            for it in eval_items(&w, fam, 40, 9) {
                let mut c = it.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), it.choices.len(), "dup choices in {:?}", it);
            }
        }
    }
}
