//! Corpus generation: base (pre-training) documents, the instruct
//! fine-tuning mixture, and calibration samples (the stand-in for the
//! paper's 50 + 150 C4 examples).

use super::tasks::{train_texts, TaskFamily};
use super::world::World;
use crate::util::rng::Rng;

/// Filler sentence templates to give the base corpus generic "web text"
/// structure beyond raw facts (keeps the LM from degenerating into a pure
/// fact lookup table).
const FILLERS: [&str; 8] = [
    "the mill by the river turns all day.",
    "rain fell on the old stone road.",
    "a cart rolled past the market square.",
    "the bell rang twice at dusk.",
    "ships came in with the morning tide.",
    "the lamplighter walked the long lane.",
    "snow settled on the quiet field.",
    "the well in the yard ran clear.",
];

/// Base (pre-training) corpus: declarative facts + filler, a few sentences
/// per document.
pub fn base_corpus(world: &World, n_docs: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed ^ 0xBA5E);
    let facts = world.all_facts();
    let mut docs = Vec::with_capacity(n_docs);
    for _ in 0..n_docs {
        let n_sent = rng.range(3, 6);
        let mut doc = String::new();
        for s in 0..n_sent {
            if s > 0 {
                doc.push(' ');
            }
            if rng.chance(0.75) {
                let f = *rng.choice(&facts);
                doc.push_str(&world.render_declarative(f));
            } else {
                doc.push_str(FILLERS[rng.below(FILLERS.len())]);
            }
        }
        docs.push(doc);
    }
    docs
}

/// Instruct fine-tuning mixture: Q/A texts over the train split of every
/// task family, plus a sprinkle of declarative facts to avoid format
/// overfitting.
pub fn instruct_corpus(world: &World, n_docs: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed ^ 0x1257);
    let per_family = n_docs / (TaskFamily::ALL.len() + 1);
    let mut docs = Vec::with_capacity(n_docs);
    for fam in TaskFamily::ALL {
        docs.extend(train_texts(world, fam, per_family, seed));
    }
    let facts = world.all_facts();
    while docs.len() < n_docs {
        let f = *rng.choice(&facts);
        if world.is_train_fact(f) {
            let (q, a) = world.render_qa(f);
            docs.push(format!("{q} A: {a}"));
        }
    }
    rng.shuffle(&mut docs);
    docs
}

/// Calibration samples (the C4 stand-in): documents drawn from the *base*
/// distribution, disjoint seed from training. The paper uses 50 samples for
/// the per-layer caches and 150 for the end-to-end objective.
pub fn calibration_samples(world: &World, n: usize, seed: u64) -> Vec<String> {
    base_corpus(world, n, seed ^ 0xCA11B)
}

/// Byte-level tokenization (vocab = 256): the corpus is ASCII by
/// construction so bytes == chars.
pub fn encode(text: &str) -> Vec<u8> {
    text.as_bytes().to_vec()
}

pub fn decode(tokens: &[u8]) -> String {
    String::from_utf8_lossy(tokens).into_owned()
}

/// Pack documents into fixed-length training windows: documents are joined
/// with `\n` and split into consecutive `seq_len + 1`-byte windows (inputs +
/// next-token targets), shuffled deterministically.
pub fn pack_windows(docs: &[String], seq_len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut stream = Vec::new();
    for d in docs {
        stream.extend_from_slice(d.as_bytes());
        stream.push(b'\n');
    }
    let w = seq_len + 1;
    let mut windows: Vec<Vec<u8>> =
        stream.chunks_exact(w).map(|c| c.to_vec()).collect();
    Rng::new(seed ^ 0x57D0).shuffle(&mut windows);
    windows
}

/// Round-robin batches of `batch` windows (drops the ragged tail).
pub fn batches(windows: &[Vec<u8>], batch: usize) -> Vec<Vec<Vec<u8>>> {
    windows.chunks_exact(batch).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::generate(11, 30)
    }

    #[test]
    fn base_corpus_is_ascii_and_deterministic() {
        let w = world();
        let a = base_corpus(&w, 50, 1);
        let b = base_corpus(&w, 50, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        for d in &a {
            assert!(d.is_ascii());
            assert!(d.len() > 20);
        }
    }

    #[test]
    fn instruct_corpus_contains_qa_format() {
        let w = world();
        let docs = instruct_corpus(&w, 120, 2);
        assert_eq!(docs.len(), 120);
        let qa = docs.iter().filter(|d| d.starts_with("Q:") || d.contains("A: ")).count();
        assert!(qa > 60, "expected mostly Q/A docs, got {qa}/120");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = "Q: where does bela live? A: rome";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn windows_have_exact_length() {
        let w = world();
        let docs = base_corpus(&w, 30, 3);
        let wins = pack_windows(&docs, 64, 4);
        assert!(!wins.is_empty());
        for win in &wins {
            assert_eq!(win.len(), 65);
        }
    }

    #[test]
    fn batches_are_full() {
        let w = world();
        let docs = base_corpus(&w, 40, 5);
        let wins = pack_windows(&docs, 32, 6);
        let bs = batches(&wins, 4);
        for b in &bs {
            assert_eq!(b.len(), 4);
        }
        assert!(bs.len() * 4 <= wins.len());
    }

    #[test]
    fn calibration_disjoint_from_training_seeded_corpus() {
        let w = world();
        let train = base_corpus(&w, 30, 7);
        let calib = calibration_samples(&w, 30, 7);
        // Same world so the same facts appear, but document composition
        // should differ (different stream).
        assert_ne!(train, calib);
    }
}
